"""Data substrate: synthetic sharded pipelines (no external datasets)."""
from .synthetic import SyntheticConfig, SyntheticTokens, make_batch_specs

__all__ = ["SyntheticConfig", "SyntheticTokens", "make_batch_specs"]
