"""Numpy-based pytree checkpointing (orbax is not available offline).

Layout: <dir>/step_<N>/arrays.npz + manifest.json (treedef + shapes +
dtypes).  Arrays are fetched to host (fully addressable on this
single-process runtime; a multi-host deployment would write per-shard
files keyed by process index — the manifest format already carries the
shard map for that extension).
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # numpy can't serialize bf16
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save_pytree(directory: str, step: int, tree: Any, *,
                extra: Optional[Dict] = None) -> str:
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    arrays = _flatten_with_paths(tree)
    np.savez(os.path.join(d, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                 for k, v in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return d


def restore_pytree(directory: str, step: int, like: Any) -> Any:
    """Restore into the structure (and shardings) of `like`."""
    d = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    flat = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = data[key]
        if hasattr(leaf, "sharding"):
            leaves.append(jax.device_put(arr.astype(leaf.dtype), leaf.sharding))
        else:
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat[1], leaves)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", f))]
    return max(steps) if steps else None
