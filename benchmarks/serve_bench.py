"""Serving benchmark — tok/s and latency under a synthetic arrival
process (the ML-serving face of the paper's offload thesis).

Three variants serve the SAME synthetic request trace (fixed prompts,
Poisson arrival offsets) on a tiny dense config:

host_stepped  static batching, legacy decode loop: one host dispatch
              per generated token — the "CPU orchestrates every op"
              anti-pattern the ST design eliminates.
resident      static batching, decode as ONE device-resident
              ``lax.while_loop`` dispatch per batch.
continuous    continuous batching: requests admitted into freed cache
              slots between dispatches, prefill of incoming requests
              composed with in-flight decode in ONE dispatch
              (:func:`repro.launch.serve.serve_continuous`).

Reports per-variant tok/s (all emitted tokens / serve wall-clock),
median wall ms over repeats, dispatch counts, and p50/p99 per-request
latency.  Emits ``BENCH_serve.json`` (via ``benchmarks/run.py serve``)
with a ``_meta`` workload stamp; ``--check-against BENCH_serve.json``
gates CI:

* unconditional same-run invariant: **continuous batching must beat the
  host-stepped loop on tok/s** (measured back-to-back in one process,
  so machine speed cancels out), and the resident variants must use
  strictly fewer host dispatches;
* stored-file median comparison (speed-factor-normalized like the Faces
  gate) only when ``_meta`` matches.

Env knobs: SERVE_SLOTS, SERVE_PROMPT, SERVE_MAXNEW, SERVE_REQUESTS,
SERVE_CHUNK, SERVE_RATE, SERVE_REPEATS.
"""

from __future__ import annotations

import dataclasses
import json
import os
import sys
import time
from typing import Dict, List

import numpy as np

CHECK_TOLERANCE = 1.20


def _cfg_env(name, default, cast=int):
    return cast(os.environ.get(name, default))


def _workload():
    # decode-heavy on purpose: the dispatch-amortization win lives in
    # the decode loop, while every admission round re-runs a full-batch
    # prefill — short prompts + long generations keep the contrast at
    # the serving regime the paper's offload argument targets
    return {
        "slots": _cfg_env("SERVE_SLOTS", 4),
        "prompt_len": _cfg_env("SERVE_PROMPT", 8),
        "max_new": _cfg_env("SERVE_MAXNEW", 32),
        "n_requests": _cfg_env("SERVE_REQUESTS", 12),
        "chunk": _cfg_env("SERVE_CHUNK", 8),
        "rate": _cfg_env("SERVE_RATE", 50.0, float),
        "repeats": _cfg_env("SERVE_REPEATS", 3),
    }


def _tiny_cfg():
    # dense (non-MoE) on purpose: expert capacity couples batch rows,
    # which would break the continuous == serial token equality
    from repro.configs.base import get_config
    return dataclasses.replace(get_config("qwen1.5-0.5b").smoke(),
                               name="serve-bench-tiny")


def _lockstep(cfg, mesh, eng, params, prompts, arrivals, w, *,
              device_resident):
    """Static-batching baseline: wait until ``slots`` requests have
    arrived, serve the full batch in lockstep, repeat.  Per-request
    latency is batch completion minus arrival — the tail-latency
    lockstep the tentpole's continuous batching replaces."""
    import jax.numpy as jnp
    from repro.launch.serve import PAD_TOKEN, serve

    n, slots = w["n_requests"], w["slots"]
    lat, tokens, disp = [], 0, 0
    t0 = time.time()
    for lo in range(0, n, slots):
        rids = list(range(lo, min(lo + slots, n)))
        # open-loop arrivals: the batch cannot start before its last
        # member arrives (same trace the continuous variant serves)
        wait = arrivals[rids[-1]] - (time.time() - t0)
        if wait > 0:
            time.sleep(wait)
        rows = {k: np.asarray(v)[rids] for k, v in prompts.items()}
        if len(rids) < slots:   # ragged tail batch: pad with repeats
            pad = [rids[-1]] * (slots - len(rids))
            rows = {k: np.concatenate([v, np.asarray(prompts[k])[pad]])
                    for k, v in rows.items()}
        batch_in = {k: jnp.asarray(v) for k, v in rows.items()}
        gen, st = serve(cfg, mesh, batch=slots, prompt_len=w["prompt_len"],
                        gen_len=w["max_new"], params=params,
                        batch_in=batch_in, engine=eng,
                        device_resident=device_resident)
        t_done = time.time() - t0
        tokens += int((gen[:len(rids)] != PAD_TOKEN).sum())
        disp += st["dispatches"]
        lat += [t_done - arrivals[r] for r in rids]
    total_s = time.time() - t0
    return {"total_s": total_s, "total_tokens": tokens,
            "tok_per_s": tokens / max(total_s, 1e-9),
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "dispatches": disp}


def run_all() -> List[Dict]:
    import jax
    from repro.launch.serve import ServeEngine, serve_continuous, \
        synthetic_batch, poisson_arrivals
    from repro.parallel import make_mesh

    w = _workload()
    cfg = _tiny_cfg()
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = ServeEngine(cfg, mesh, slots=w["slots"],
                      prompt_len=w["prompt_len"], max_new=w["max_new"],
                      chunk=w["chunk"], eos_id=-1)
    # lockstep variants decode the whole budget in one chunk
    eng_full = ServeEngine(cfg, mesh, slots=w["slots"],
                           prompt_len=w["prompt_len"], max_new=w["max_new"],
                           chunk=w["max_new"] - 1, eos_id=-1)
    with mesh:
        params, _ = eng.model.init(jax.random.PRNGKey(0))
        params = jax.device_put(params, eng.pre.in_shardings[0])
    rng = np.random.RandomState(0)
    prompts = synthetic_batch(cfg, rng, w["n_requests"], w["prompt_len"])
    arrivals = poisson_arrivals(w["n_requests"], w["rate"],
                                np.random.RandomState(1))

    def run_continuous():
        res, st = serve_continuous(
            cfg, mesh, slots=w["slots"], prompt_len=w["prompt_len"],
            max_new=w["max_new"], n_requests=w["n_requests"],
            chunk=w["chunk"], arrival_rate=w["rate"], seed=0,
            params=params, prompts=prompts, engine=eng)
        assert all(len(r.tokens) == w["max_new"] for r in res)
        return st

    variants = {
        "host_stepped": lambda: _lockstep(cfg, mesh, eng_full, params,
                                          prompts, arrivals, w,
                                          device_resident=False),
        "resident": lambda: _lockstep(cfg, mesh, eng_full, params,
                                      prompts, arrivals, w,
                                      device_resident=True),
        "continuous": run_continuous,
    }

    print(f"\n== serve bench == workload {w}")
    results = []
    for name, fn in variants.items():
        fn()                              # warm-up: compile outside timing
        runs = [fn() for _ in range(w["repeats"])]
        med = sorted(runs, key=lambda r: r["total_s"])[len(runs) // 2]
        row = {
            "bench": "serve", "variant": name,
            "us_per_call": med["total_s"] * 1e6 / med["total_tokens"],
            "median_ms": med["total_s"] * 1e3,
            "tok_per_s": round(med["tok_per_s"], 2),
            "dispatches": med["dispatches"],
            "p50_ms": round(med["p50_ms"], 2),
            "p99_ms": round(med["p99_ms"], 2),
            "derived": (f"tok_per_s={med['tok_per_s']:.1f};"
                        f"dispatches={med['dispatches']};"
                        f"p50_ms={med['p50_ms']:.1f};"
                        f"p99_ms={med['p99_ms']:.1f}"),
        }
        results.append(row)
        print(f"  {name:13s} {med['tok_per_s']:8.1f} tok/s  "
              f"{med['total_s']*1e3:8.1f} ms  "
              f"dispatches={med['dispatches']:3d}  "
              f"p50={med['p50_ms']:7.1f}ms p99={med['p99_ms']:7.1f}ms")

    by = {r["variant"]: r for r in results}
    speedup = by["continuous"]["tok_per_s"] / by["host_stepped"]["tok_per_s"]
    print(f"  continuous vs host_stepped: x{speedup:.2f} tok/s "
          f"({by['host_stepped']['dispatches']} -> "
          f"{by['continuous']['dispatches']} dispatches)")
    return results


def collect(results: List[Dict]) -> Dict:
    """BENCH_serve.json payload from run_all() rows."""
    out = {
        f"{r['bench']}/{r['variant']}": {
            "median_ms": round(r["median_ms"], 4),
            "tok_per_s": r["tok_per_s"],
            "dispatches": r["dispatches"],
            "p50_ms": r["p50_ms"],
            "p99_ms": r["p99_ms"],
        }
        for r in results if r["bench"] == "serve"
    }
    if out:
        w = _workload()
        out["_meta"] = {k: w[k] for k in
                        ("slots", "prompt_len", "max_new", "n_requests",
                         "chunk", "rate", "repeats")}
    return out


def check_against(fresh: Dict, path: str) -> int:
    """Serve perf gate (cf. the Faces gate in benchmarks/run.py).

    Same-run invariants are unconditional — the variants are measured
    back-to-back in one process, so machine speed cancels out:

    * continuous batching beats the host-stepped loop on tok/s (the
      acceptance criterion of the device-resident serving PR);
    * the device-resident variants use strictly fewer host dispatches
      than one-dispatch-per-token.

    Stored medians are only compared when the ``_meta`` workload stamp
    matches, normalized by the run-wide speed factor.
    """
    with open(path) as f:
        stored = json.load(f)

    failures = []
    cont = fresh.get("serve/continuous")
    host = fresh.get("serve/host_stepped")
    resi = fresh.get("serve/resident")
    if cont and host and cont["tok_per_s"] <= host["tok_per_s"]:
        failures.append(
            f"serve/continuous ({cont['tok_per_s']:.1f} tok/s) does not "
            f"beat serve/host_stepped ({host['tok_per_s']:.1f} tok/s): "
            f"device-resident continuous batching must win")
    for key, row in (("serve/continuous", cont), ("serve/resident", resi)):
        if row and host and row["dispatches"] >= host["dispatches"]:
            failures.append(
                f"{key} uses {row['dispatches']} dispatches vs "
                f"host_stepped's {host['dispatches']}: the resident path "
                f"must collapse the dispatch count")

    stored_meta = stored.get("_meta", {})
    if not stored_meta:
        print("note: recorded file has no _meta stamp — median checks "
              "skipped (invariants only)")
        compare = False
    elif stored_meta != fresh.get("_meta", {}):
        print(f"note: workload differs from recorded ({fresh.get('_meta')} "
              f"vs {stored_meta}) — median checks skipped, invariants "
              f"enforced")
        compare = False
    else:
        compare = True

    if compare:
        # --noise-factor / BENCH_NOISE_FACTOR widens the median bound
        # for noisy 1-core runners (never below the recorded pin)
        tol = CHECK_TOLERANCE * max(
            1.0, float(os.environ.get("BENCH_NOISE_FACTOR", "1")))
        keys = [k for k in fresh if not k.startswith("_")
                and isinstance(stored.get(k), dict)
                and stored[k].get("median_ms")]
        ratios = sorted(fresh[k]["median_ms"] / stored[k]["median_ms"]
                        for k in keys)
        speed = ratios[len(ratios) // 2] if ratios else 1.0
        for k in keys:
            bound = stored[k]["median_ms"] * speed * tol
            if fresh[k]["median_ms"] > bound:
                failures.append(
                    f"{k}: median {fresh[k]['median_ms']:.1f}ms > bound "
                    f"{bound:.1f}ms (recorded "
                    f"{stored[k]['median_ms']:.1f}ms x speed {speed:.2f} "
                    f"x tolerance {tol:.2f}: "
                    f">{(tol-1)*100:.0f}% regression)")

    if failures:
        # stderr + flush, mirroring the Faces gate: the non-zero exit
        # must name every failing row in the CI log
        print(f"\nSERVE PERF GATE FAILED ({len(failures)} failing row(s)):",
              file=sys.stderr, flush=True)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr, flush=True)
        names = ", ".join(msg.split(":", 1)[0] for msg in failures)
        print(f"SERVE PERF GATE FAILED rows: {names}", file=sys.stderr,
              flush=True)
        return 1
    print("\nserve perf gate OK: continuous beats host-stepped tok/s; "
          "resident dispatch counts collapsed"
          + ("; medians within tolerance" if compare else ""))
    return 0
