"""STProve (repro.core.effects) — effect traces, certificates, tuning.

Covers the effect substrate end to end: declared effect sets recorded on
every built batch, unique staging stamps, the per-buffer effect trace
and its digest (invariant under every numerics-preserving knob, sensitive
to structural change), transform-equivalence certificates, their
consumption by the auto-tuner (certified candidates skip the numeric
check; uncertified ones are disqualified), and the property the whole
pyramid rests on: a certified race-free composed schedule is
**bit-identical** on the persistent engine under any legal segment
interleaving (random orders × granularities; hypothesis-driven when the
library is available, a seeded deterministic sweep otherwise).
"""

import dataclasses
import random

import numpy as np
import pytest

from repro.core import (
    FacesConfig,
    OffsetPeer,
    PersistentEngine,
    STQueue,
    build_faces_program,
    compose,
    half_config,
    split_halves,
)
from repro.core.effects import (
    EquivalenceCertificate,
    certify_equivalence,
    effect_trace,
    program_certificate,
    program_digest,
)
from repro.core.schedule import InterleavePolicy

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container ships without hypothesis; gated below
    HAVE_HYPOTHESIS = False


GRID = (1, 1, 1)
POINTS = (6, 6, 6)
INNER = 2


def _mesh():
    from repro.parallel import make_mesh
    return make_mesh(GRID, ("gx", "gy", "gz"))


def _half_cfg(**kw):
    return half_config(FacesConfig(grid=GRID, points=POINTS, **kw))


def _halves_sched(interleave=None, coalesce=True):
    mesh = _mesh()
    cfg = _half_cfg()
    pA = build_faces_program(cfg, mesh, name="facesA",
                             coalesce=coalesce).persistent(INNER)
    pB = build_faces_program(cfg, mesh, name="facesB",
                             coalesce=coalesce).persistent(INNER)
    kw = {} if interleave is None else {"interleave": interleave}
    return compose(pA, pB, verify="off", **kw)


def _halves_inputs():
    rng = np.random.RandomState(0)
    ua, ub = split_halves(rng.randn(*GRID, *POINTS).astype(np.float32))
    return ua, ub


# -- effect recording ---------------------------------------------------------


class TestEffectRecording:
    def _exchange(self, n_batches=2):
        q = STQueue(_mesh(), name="p")
        q.buffer("u", (4,), np.float32, pspec=("gx",))
        for b in range(n_batches):
            q.buffer(f"halo{b}", (4,), np.float32, pspec=("gx",))
        for b in range(n_batches):
            q.enqueue_send("u", OffsetPeer("gx", 0, periodic=True), tag=b)
            q.enqueue_recv(f"halo{b}", OffsetPeer("gx", 0, periodic=True),
                           tag=b)
            q.enqueue_start()
        q.enqueue_wait()
        return q.build(verify="off")

    def test_batches_carry_effects(self):
        prog = self._exchange()
        for b in prog.batches:
            assert b.effects, b
            kinds = {(e.source, e.kind) for e in b.effects}
            # pack read of the send source + deposit write into the slot
            assert ("pack", "read") in kinds
            assert ("deposit", "write") in kinds

    def test_staging_effects_and_unique_stamps(self):
        prog = self._exchange()
        stamps = [t.staging for b in prog.batches if b.plan
                  for t in b.plan.transfers]
        assert stamps and all(s for s in stamps)
        assert len(stamps) == len(set(stamps))  # unique per batch/transfer
        for b in prog.batches:
            stage = [e for e in b.effects if e.source == "stage"]
            # each transfer stages: one write (pack-in) + one read (deposit)
            assert {e.kind for e in stage} == {"read", "write"}

    def test_composed_batches_rerecord_effects(self):
        sched = _halves_sched()
        comm = [b for b in sched.batches if b.channels or b.colls]
        assert comm
        for b in comm:
            assert b.effects
            # namespaced buffer names survive into the effect records
            assert all("/" in e.buf or e.buf.startswith("~")
                       for e in b.effects), b.effects


# -- traces, digests, certificates --------------------------------------------


class TestCertificates:
    def test_digest_invariant_under_schedule_knobs(self):
        base = program_digest(_halves_sched())
        assert base == program_digest(_halves_sched())  # deterministic
        assert base == program_digest(_halves_sched(interleave="sequential"))
        assert base == program_digest(
            _halves_sched(interleave=InterleavePolicy(order=(1, 0),
                                                      granularity=3)))
        assert base == program_digest(_halves_sched(coalesce=False))

    def test_certify_equivalence_across_interleaves(self):
        cert = certify_equivalence(_halves_sched(),
                                   _halves_sched(interleave="sequential"))
        assert isinstance(cert, EquivalenceCertificate)
        assert cert.equivalent and cert.race_free
        assert cert.baseline_digest == cert.candidate_digest
        assert cert.n_buffers > 0

    def test_structural_change_breaks_certificate(self):
        base = _halves_sched()
        mutated = _halves_sched()
        descs = list(mutated.descriptors)
        from repro.core.descriptors import KernelDesc
        ki = next(i for i, d in enumerate(descs)
                  if isinstance(d, KernelDesc))
        descs[ki] = dataclasses.replace(descs[ki], name="tampered")
        mutated = dataclasses.replace(mutated, descriptors=tuple(descs))
        cert = certify_equivalence(base, mutated)
        assert not cert.equivalent
        assert cert.reason  # names the first diverging buffer

    def test_different_buffer_sets_not_equivalent(self):
        mesh = _mesh()
        solo = build_faces_program(_half_cfg(), mesh,
                                   name="facesA").persistent(INNER)
        cert = certify_equivalence(_halves_sched(), solo)
        assert not cert.equivalent
        assert "buffer" in cert.reason

    def test_program_certificate(self):
        prog = _halves_sched()
        cert = program_certificate(prog)
        assert cert.race_free and cert.n_races == 0
        assert cert.digest == program_digest(prog)
        assert cert.n_effects == sum(
            len(t) for t in effect_trace(prog).values())

    def test_registry_certificates_all_race_free(self):
        from repro.analysis import certificates
        certs = certificates(device_count=1)
        assert len(certs) >= 10
        racy = [n for n, c in certs if not c.race_free]
        assert not racy, racy


# -- tuner consumption --------------------------------------------------------


class TestTuneCertification:
    def _build(self, knobs):
        ua, ub = _halves_inputs()
        sched = _halves_sched(interleave=knobs.interleave_policy())
        eng = PersistentEngine(sched, mode=knobs.mode, donate=True)
        fresh = lambda: eng.init_buffers({"facesA/u": ua, "facesB/u": ub})
        return eng, fresh

    SPACE = {"interleave": ["round_robin", "sequential"]}

    def test_certified_candidates_skip_check(self):
        from repro.launch.tune import tune
        calls = []
        res = tune(self._build, self.SPACE, inner=1, repeats=1,
                   measure_top=1, certify=True, check=calls.append)
        assert calls == []  # the proof replaced the allclose
        for c in res.candidates:
            assert c.certificate is not None and c.certificate.equivalent
            assert c.error is None

    def test_certification_does_not_change_measured_pool(self):
        from repro.launch.tune import tune
        r1 = tune(self._build, self.SPACE, inner=1, repeats=1,
                  measure_top=2, certify=True)
        r0 = tune(self._build, self.SPACE, inner=1, repeats=1,
                  measure_top=2)
        assert (sorted(c.knobs.label() for c in r1.measured)
                == sorted(c.knobs.label() for c in r0.measured))

    def test_uncertified_candidates_fall_back_to_check(self):
        from repro.launch.tune import tune

        def reject(cand):
            raise AssertionError("numerics rejected")

        # without certificates the failing check disqualifies everything
        with pytest.raises(ValueError, match="no measured candidate"):
            tune(self._build, self.SPACE, inner=1, repeats=1,
                 measure_top=1, check=reject)
        # with certificates the same failing check never runs
        res = tune(self._build, self.SPACE, inner=1, repeats=1,
                   measure_top=1, certify=True, check=reject)
        assert res.best.certificate.equivalent


# -- the property: certified race-free => interleave-invariant execution ------


def _run_interleaving(order, granularity, ua, ub):
    sched = _halves_sched(
        interleave=InterleavePolicy(order=order, granularity=granularity))
    assert program_certificate(sched).race_free
    eng = PersistentEngine(sched, mode="dataflow", donate=True)
    out = eng(eng.init_buffers({"facesA/u": ua, "facesB/u": ub}))
    return {k: np.asarray(v) for k, v in out.items()}


class TestInterleaveInvariance:
    def test_random_legal_interleavings_bit_identical(self):
        ua, ub = _halves_inputs()
        ref = _run_interleaving(None, 1, ua, ub)
        r = random.Random(1234)
        for _ in range(3):
            g = r.choice([1, 2, 3, 5, 50])
            order = tuple(r.sample(range(2), 2))
            got = _run_interleaving(order, g, ua, ub)
            assert set(got) == set(ref)
            for k in ref:
                np.testing.assert_array_equal(
                    ref[k], got[k],
                    err_msg=f"{k} diverged under order={order} g={g}")

    if HAVE_HYPOTHESIS:
        @settings(max_examples=8, deadline=None)
        @given(order=st.permutations(range(2)),
               granularity=st.integers(min_value=1, max_value=64))
        def test_hypothesis_interleavings_bit_identical(self, order,
                                                        granularity):
            ua, ub = _halves_inputs()
            ref = _run_interleaving(None, 1, ua, ub)
            got = _run_interleaving(tuple(order), granularity, ua, ub)
            for k in ref:
                np.testing.assert_array_equal(
                    ref[k], got[k],
                    err_msg=f"{k} diverged under order={order} "
                            f"g={granularity}")
    else:
        def test_hypothesis_interleavings_bit_identical(self):
            pytest.importorskip("hypothesis")
