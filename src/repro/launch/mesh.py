"""Production mesh construction.

A function (NOT a module-level constant) so importing this module never
touches jax device state.  Target: TPU v5e, 256 chips/pod (16×16 2-D
torus), optional 2-pod deployment (512 chips).

Axes:
* ``data``  — batch / FSDP sharding (16-way per pod);
* ``model`` — tensor/expert parallel (16-way, matches the torus row);
* ``pod``   — (multi-pod) data-parallel replication across pods; the
  gradient all-reduce over this axis crosses the inter-pod DCI and is
  what the multi-pod dry-run proves out.
"""

from __future__ import annotations

import jax

from repro.compat import auto_axis_types


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **auto_axis_types(len(axes)))


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over host (CPU) devices for tests/examples."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **auto_axis_types(len(axes)))


# Hardware constants (TPU v5e) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (~4 usable links/chip)
ICI_LINKS = 4
DCI_BW = 25e9                  # inter-pod (conservative)
