"""Parse compiled HLO text for roofline inputs.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but NOT
collective traffic; this module extracts it from the post-SPMD optimized
HLO (``compiled.as_text()``): every ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` op's tensor
bytes, bucketed by op kind.

Byte conventions (per-device, estimates for the roofline term):
* all-gather          — result bytes × (n−1)/n   (data received)
* all-reduce          — 2 × operand bytes × (n−1)/n (ring RS+AG)
* reduce-scatter      — operand bytes × (n−1)/n
* all-to-all          — operand bytes × (n−1)/n
* collective-permute  — operand bytes (one hop)

`n` is the replica-group size parsed per op.  These are the standard
ring-algorithm wire-byte counts; the ICI term divides by per-chip link
bandwidth × usable links.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:%|ROOT\s+%?)?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{([^}]*)\}")


def _tensor_bytes(type_str: str) -> int:
    """Total bytes of a '(bf16[2,3], f32[4])' or 'bf16[2,3]' type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    def as_dict(self) -> Dict:
        return {
            "bytes_by_kind": dict(self.bytes_by_kind),
            "count_by_kind": dict(self.count_by_kind),
            "total_bytes": self.total_bytes,
        }


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].split("{")[-1]
        ids = [x for x in first.replace("{", "").split(",") if x.strip() != ""]
        return max(len(ids), 1)
    return default


def analyze_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    bytes_by = defaultdict(float)
    count_by = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        nbytes = _tensor_bytes(type_str)
        n = _group_size(line, n_devices)
        frac = (n - 1) / max(n, 1)
        if kind == "all-gather":
            wire = nbytes * frac  # result bytes are the gathered size
        elif kind == "all-reduce":
            wire = 2.0 * nbytes * frac
        elif kind == "reduce-scatter":
            wire = nbytes * frac
        elif kind == "all-to-all":
            wire = nbytes * frac
        else:  # collective-permute
            wire = float(nbytes)
        bytes_by[kind] += wire
        count_by[kind] += 1
    return CollectiveStats(dict(bytes_by), dict(count_by))


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\b", hlo_text))


# --------------------------------------------------------------------------
# dot-op FLOP accounting (exact MXU work, per device)
# --------------------------------------------------------------------------

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
_DOT_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\][^=]*"
    r"\bdot\(\s*(%[\w.\-]+)\s*,")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?[\w.\-]+\s*\(.*\)\s*->.*\{")


@dataclasses.dataclass
class DotStats:
    total_flops: float
    n_dots: int
    largest: List[Tuple[float, str]]  # (flops, descriptor) top entries


def analyze_dots(hlo_text: str, top_k: int = 12) -> DotStats:
    """Sum 2·(result elements)·(contraction size) over every dot op.

    Shapes in post-SPMD HLO are per-device shards, so the sum is the
    per-device MXU FLOPs — the roofline compute-term numerator.  Operand
    shapes are resolved from instruction definitions, scoped per
    computation (names repeat across computations).
    """
    total = 0.0
    entries: List[Tuple[float, str]] = []
    scope: Dict[str, List[int]] = {}
    for line in hlo_text.splitlines():
        if _COMP_START_RE.match(line):
            scope = {}
        dm = _DEF_RE.match(line)
        if dm:
            dims = [int(x) for x in dm.group(3).split(",") if x]
            scope[dm.group(1)] = dims
        dot = _DOT_LINE_RE.match(line)
        if not dot or " dot(" not in line:
            continue
        cm = _LHS_CONTRACT_RE.search(line)
        if not cm:
            continue
        res_dims = [int(x) for x in dot.group(3).split(",") if x]
        lhs_dims = scope.get(dot.group(4), [])
        contract = [int(x) for x in cm.group(1).split(",") if x]
        k = 1
        for c in contract:
            if c < len(lhs_dims):
                k *= lhs_dims[c]
        res = 1
        for d in res_dims:
            res *= d
        flops = 2.0 * res * k
        total += flops
        entries.append((flops, f"{dot.group(2)}[{dot.group(3)}] k={k}"))
    entries.sort(key=lambda e: e[0], reverse=True)
    return DotStats(total, len(entries), entries[:top_k])
