"""Pallas TPU kernels for Faces boundary packing (paper §V-A steps 2/6).

The paper's Faces benchmark launches GPU kernels that "copy into
contiguous MPI buffers from faces, edges, and corners of spectral
elements" before sending, and kernels that add received messages back
after the wait.  These are the compute hot-spots of the communication
loop, so they get Pallas kernels:

* ``halo_pack_kernel``          — extract one static boundary slab;
* ``halo_unpack_add_kernel``    — add one received slab into the block;
* ``pack_boundary_kernel``      — all 26 regions into ONE contiguous 1-D
                                  buffer (the paper's "contiguous MPI
                                  buffer"), static region offsets;
* ``unpack_boundary_add_kernel``— scatter-add the contiguous buffer back;
* ``pack_segments_kernel``      — N *separate* source slabs into ONE
                                  contiguous staging buffer at static
                                  offsets: the same layout a
                                  :class:`~repro.core.matching.CoalescedChannel`
                                  fused transfer stages (members send
                                  from distinct buffers).  The engines
                                  currently lower that pack with
                                  ``jnp.concatenate`` (which XLA:CPU
                                  fuses best); this Pallas kernel is
                                  the parity-tested TPU drop-in
                                  (ROADMAP follow-on), not yet wired
                                  into ``_run_coalesced_batch``;
* ``unpack_segments_kernel``    — split the received staging buffer back
                                  into the per-member slabs (inverse;
                                  same status).

TPU adaptation: a face slab of a local (px,py,pz) block is at most
px·py ≲ 10⁴ elements — far below VMEM, so each kernel runs as a single
grid cell with whole-block BlockSpecs in VMEM, and the packing loop is
fully unrolled over static regions (the MXU is not involved; this is a
VPU copy/accumulate kernel).  For blocks too large for VMEM the wrapper
falls back to tiling along the leading axis.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _region_shape(region: Tuple[slice, ...]) -> Tuple[int, ...]:
    return tuple(s.stop - s.start for s in region)


def _region_size(region: Tuple[slice, ...]) -> int:
    return int(np.prod(_region_shape(region)))


# --------------------------------------------------------------------------
# single-slab pack / unpack
# --------------------------------------------------------------------------


def _pack_body(u_ref, out_ref, *, region):
    out_ref[...] = u_ref[region]


def halo_pack_call(u: jax.Array, region: Tuple[slice, ...], *,
                   interpret: bool = False) -> jax.Array:
    shape = _region_shape(region)
    return pl.pallas_call(
        functools.partial(_pack_body, region=region),
        out_shape=jax.ShapeDtypeStruct(shape, u.dtype),
        in_specs=[pl.BlockSpec(u.shape, lambda: (0,) * u.ndim)],
        out_specs=pl.BlockSpec(shape, lambda: (0,) * len(shape)),
        interpret=interpret,
    )(u)


def _unpack_add_body(u_ref, msg_ref, out_ref, *, region):
    out_ref[...] = u_ref[...]
    out_ref[region] = u_ref[region] + msg_ref[...].astype(u_ref.dtype)


def halo_unpack_add_call(u: jax.Array, msg: jax.Array,
                         region: Tuple[slice, ...], *,
                         interpret: bool = False) -> jax.Array:
    return pl.pallas_call(
        functools.partial(_unpack_add_body, region=region),
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        in_specs=[
            pl.BlockSpec(u.shape, lambda: (0,) * u.ndim),
            pl.BlockSpec(msg.shape, lambda: (0,) * msg.ndim),
        ],
        out_specs=pl.BlockSpec(u.shape, lambda: (0,) * u.ndim),
        interpret=interpret,
    )(u, msg)


# --------------------------------------------------------------------------
# contiguous 26-region pack / unpack (paper-faithful "one MPI buffer")
# --------------------------------------------------------------------------


def _pack_boundary_body(u_ref, out_ref, *, regions):
    off = 0
    for r in regions:  # static unroll
        size = _region_size(r)
        out_ref[pl.ds(off, size)] = u_ref[r].reshape(-1)
        off += size


def pack_boundary_call(u: jax.Array, regions: Sequence[Tuple[slice, ...]], *,
                       interpret: bool = False) -> jax.Array:
    total = sum(_region_size(r) for r in regions)
    return pl.pallas_call(
        functools.partial(_pack_boundary_body, regions=tuple(regions)),
        out_shape=jax.ShapeDtypeStruct((total,), u.dtype),
        in_specs=[pl.BlockSpec(u.shape, lambda: (0,) * u.ndim)],
        out_specs=pl.BlockSpec((total,), lambda: (0,)),
        interpret=interpret,
    )(u)


def _unpack_boundary_body(u_ref, buf_ref, out_ref, *, regions):
    out_ref[...] = u_ref[...]
    off = 0
    for r in regions:  # static unroll; overlapping regions accumulate
        size = _region_size(r)
        seg = buf_ref[pl.ds(off, size)].reshape(_region_shape(r))
        out_ref[r] = out_ref[r] + seg.astype(out_ref.dtype)
        off += size


def unpack_boundary_add_call(u: jax.Array, buf: jax.Array,
                             regions: Sequence[Tuple[slice, ...]], *,
                             interpret: bool = False) -> jax.Array:
    return pl.pallas_call(
        functools.partial(_unpack_boundary_body, regions=tuple(regions)),
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        in_specs=[
            pl.BlockSpec(u.shape, lambda: (0,) * u.ndim),
            pl.BlockSpec(buf.shape, lambda: (0,)),
        ],
        out_specs=pl.BlockSpec(u.shape, lambda: (0,) * u.ndim),
        interpret=interpret,
    )(u, buf)


# --------------------------------------------------------------------------
# multi-source segment pack / unpack (channel-coalescing staging buffers)
# --------------------------------------------------------------------------


def _pack_segments_body(*refs):
    *in_refs, out_ref = refs
    off = 0
    for r in in_refs:  # static unroll over the group's members
        size = int(np.prod(r.shape))
        out_ref[pl.ds(off, size)] = r[...].reshape(-1)
        off += size


def pack_segments_call(arrays: Sequence[jax.Array], *,
                       interpret: bool = False) -> jax.Array:
    """Pack N source slabs into ONE contiguous 1-D staging buffer.

    The coalescing analogue of :func:`pack_boundary_call`: member slabs
    live in *separate* buffers (one per matched channel), and each lands
    at a static offset — the layout recorded in the batch's
    :class:`~repro.core.matching.CoalescePlan`.  All slabs must share a
    dtype (the plan groups by dtype).

    Status: the engines stage this layout with ``jnp.concatenate``
    (see ``engine_fused._run_coalesced_batch``); this kernel is the
    TPU drop-in for that pack, parity-tested but not yet wired in.
    """
    arrays = list(arrays)
    if not arrays:
        raise ValueError("pack_segments_call needs at least one slab")
    dtype = arrays[0].dtype
    if any(a.dtype != dtype for a in arrays):
        raise ValueError("coalesced segments must share a dtype")
    total = sum(int(np.prod(a.shape)) for a in arrays)
    return pl.pallas_call(
        _pack_segments_body,
        out_shape=jax.ShapeDtypeStruct((total,), dtype),
        in_specs=[pl.BlockSpec(a.shape, lambda _n=a.ndim: (0,) * _n)
                  for a in arrays],
        out_specs=pl.BlockSpec((total,), lambda: (0,)),
        interpret=interpret,
    )(*arrays)


def _unpack_segments_body(buf_ref, *out_refs):
    off = 0
    for r in out_refs:  # static unroll
        size = int(np.prod(r.shape))
        r[...] = buf_ref[pl.ds(off, size)].reshape(r.shape)
        off += size


def unpack_segments_call(buf: jax.Array, shapes: Sequence[Tuple[int, ...]], *,
                         interpret: bool = False) -> Tuple[jax.Array, ...]:
    """Split a received staging buffer back into per-member slabs
    (inverse of :func:`pack_segments_call`, static offsets)."""
    shapes = [tuple(s) for s in shapes]
    total = sum(int(np.prod(s)) for s in shapes)
    if total != int(np.prod(buf.shape)):
        raise ValueError(
            f"segment shapes cover {total} elements, buffer has "
            f"{int(np.prod(buf.shape))}")
    outs = pl.pallas_call(
        _unpack_segments_body,
        out_shape=tuple(jax.ShapeDtypeStruct(s, buf.dtype) for s in shapes),
        in_specs=[pl.BlockSpec(buf.shape, lambda: (0,))],
        out_specs=tuple(pl.BlockSpec(s, lambda _n=len(s): (0,) * _n)
                        for s in shapes),
        interpret=interpret,
    )(buf)
    return outs if isinstance(outs, tuple) else (outs,)
