"""Architecture configs (one module per assigned arch) + input shapes."""
from .base import (
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    all_configs,
    get_config,
)

__all__ = ["ARCH_IDS", "SHAPES", "ModelConfig", "ShapeConfig",
           "all_configs", "get_config"]
