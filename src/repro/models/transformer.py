"""Transformer blocks + scanned stacks for every assigned arch family.

A model trunk is a list of **segments** — runs of structurally identical
layers — each executed with ``jax.lax.scan`` over stacked parameters
(keeps HLO size O(1) in depth; essential for the 80-layer archs).
Layer-dependent attention settings (gemma3's 5:1 local:global pattern,
per-layer rope theta) ride through the scan as traced per-layer arrays.

Block kinds:
``attn_mlp``  — attention + MLP            (dense, vlm, whisper encoder)
``attn_moe``  — attention + MoE            (deepseek-v3, grok-1)
``ssm``       — mamba2 SSD block           (attention-free)
``hybrid``    — parallel attn ‖ SSD + MLP  (hymba)
``dec_cross`` — self-attn + cross-attn + MLP (whisper decoder)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from . import moe as moe_lib
from . import ssm as ssm_lib
from .nn import (
    apply_attention,
    apply_mlp,
    apply_rmsnorm,
    init_attention,
    init_mlp,
    init_rmsnorm,
    param,
    stack_boxed,
)


# --------------------------------------------------------------------------
# segments
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str
    n_layers: int
    first_layer: int  # absolute index of the segment's first layer


def plan_segments(cfg: ModelConfig, *, decoder: bool = True) -> List[Segment]:
    if cfg.enc_dec and not decoder:
        return [Segment("attn_mlp", cfg.n_enc_layers, 0)]
    if cfg.enc_dec:
        return [Segment("dec_cross", cfg.n_layers, 0)]
    if cfg.arch_type == "ssm":
        return [Segment("ssm", cfg.n_layers, 0)]
    if cfg.hybrid:
        return [Segment("hybrid", cfg.n_layers, 0)]
    if cfg.n_experts > 0:
        segs = []
        if cfg.first_k_dense:
            segs.append(Segment("attn_mlp", cfg.first_k_dense, 0))
        segs.append(Segment("attn_moe", cfg.n_layers - cfg.first_k_dense,
                            cfg.first_k_dense))
        return segs
    return [Segment("attn_mlp", cfg.n_layers, 0)]


def layer_window_theta(cfg: ModelConfig, layer_idx: int,
                       serve_window: int = 0) -> Tuple[int, float]:
    """Static per-layer (window, rope_theta).  window 0 → full attention."""
    is_global = bool(cfg.global_every) and ((layer_idx + 1) % cfg.global_every == 0)
    if cfg.global_every and not is_global:
        window = cfg.sliding_window
        theta = cfg.rope_theta
    elif cfg.sliding_window and not cfg.global_every:
        window, theta = cfg.sliding_window, cfg.rope_theta
    else:
        window = 0
        theta = cfg.rope_theta_global or cfg.rope_theta
    if serve_window:
        window = serve_window if window == 0 else min(window, serve_window)
    return window, theta


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    if kind in ("attn_mlp", "attn_moe", "hybrid", "dec_cross"):
        p["ln_attn"] = init_rmsnorm(ks[0], cfg.d_model, jnp.dtype(cfg.param_dtype))
        p["attn"] = init_attention(ks[1], cfg)
    if kind == "dec_cross":
        p["ln_cross"] = init_rmsnorm(ks[2], cfg.d_model, jnp.dtype(cfg.param_dtype))
        p["cross"] = init_attention(ks[3], cfg, cross=True)
    if kind in ("attn_mlp", "dec_cross"):
        p["ln_mlp"] = init_rmsnorm(ks[4], cfg.d_model, jnp.dtype(cfg.param_dtype))
        p["mlp"] = init_mlp(ks[5], cfg)
    if kind == "attn_moe":
        p["ln_mlp"] = init_rmsnorm(ks[4], cfg.d_model, jnp.dtype(cfg.param_dtype))
        p["moe"] = moe_lib.init_moe(ks[5], cfg)
    if kind in ("ssm", "hybrid"):
        p["ln_ssm"] = init_rmsnorm(ks[6], cfg.d_model, jnp.dtype(cfg.param_dtype))
        p["ssm"] = ssm_lib.init_ssm(ks[7], cfg)
        if kind == "hybrid":
            # learned output mixing of the two parallel heads
            p["mix"] = param(ks[6], (2,), (None,), jnp.dtype("float32"), init="ones")
            p["ln_mlp"] = init_rmsnorm(ks[4], cfg.d_model, jnp.dtype(cfg.param_dtype))
            p["mlp"] = init_mlp(ks[5], cfg)
    return p


def _attn_cache(cache, cache_pos):
    if cache is None or "attn" not in cache:
        return None
    return {**cache["attn"], "pos": cache_pos}


def apply_block(p, x, cfg: ModelConfig, kind: str, *,
                causal: bool = True,
                window=0, rope_theta=None, positions=None,
                cache: Optional[Dict] = None,
                cache_pos=None,
                enc_out: Optional[jax.Array] = None):
    """Returns (y, new_cache, aux_losses)."""
    new_cache: Dict[str, Any] = {}
    aux = {}

    if kind in ("attn_mlp", "attn_moe", "dec_cross"):
        h = apply_rmsnorm(p["ln_attn"], x, cfg)
        a, kv = apply_attention(p["attn"], h, cfg, causal=causal, window=window,
                                rope_theta=rope_theta, positions=positions,
                                cache=_attn_cache(cache, cache_pos))
        if kv is not None:
            new_cache["attn"] = kv
        x = x + a
        if kind == "dec_cross":
            h = apply_rmsnorm(p["ln_cross"], x, cfg)
            c, _ = apply_attention(p["cross"], h, cfg, causal=False,
                                   positions=positions, kv_x=enc_out)
            x = x + c
        h = apply_rmsnorm(p["ln_mlp"], x, cfg)
        if kind == "attn_moe":
            m, moe_aux = moe_lib.apply_moe(p["moe"], h, cfg)
            aux.update(moe_aux)
        else:
            m = apply_mlp(p["mlp"], h, cfg)
        x = x + m

    elif kind == "ssm":
        h = apply_rmsnorm(p["ln_ssm"], x, cfg)
        s, sc = ssm_lib.apply_ssm(p["ssm"], h, cfg,
                                  cache=cache.get("ssm") if cache else None)
        if sc is not None:
            new_cache["ssm"] = sc
        x = x + s

    elif kind == "hybrid":
        # parallel attention + SSD heads on the same normed input
        h_attn = apply_rmsnorm(p["ln_attn"], x, cfg)
        a, kv = apply_attention(p["attn"], h_attn, cfg, causal=causal,
                                window=window, rope_theta=rope_theta,
                                positions=positions,
                                cache=_attn_cache(cache, cache_pos))
        if kv is not None:
            new_cache["attn"] = kv
        h_ssm = apply_rmsnorm(p["ln_ssm"], x, cfg)
        s, sc = ssm_lib.apply_ssm(p["ssm"], h_ssm, cfg,
                                  cache=cache.get("ssm") if cache else None)
        if sc is not None:
            new_cache["ssm"] = sc
        mix = jax.nn.softmax(p["mix"].astype(jnp.float32))
        x = x + (mix[0] * a.astype(jnp.float32)
                 + mix[1] * s.astype(jnp.float32)).astype(x.dtype)
        h = apply_rmsnorm(p["ln_mlp"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)

    else:
        raise ValueError(kind)
    return x, new_cache, aux


# --------------------------------------------------------------------------
# stacks (scan over layers per segment)
# --------------------------------------------------------------------------


def init_stack(key, cfg: ModelConfig, *, decoder: bool = True):
    segments = plan_segments(cfg, decoder=decoder)
    params = []
    for si, seg in enumerate(segments):
        kseg = jax.random.fold_in(key, si)
        layer_ps = [init_block(jax.random.fold_in(kseg, i), cfg, seg.kind)
                    for i in range(seg.n_layers)]
        if cfg.scan_layers:
            params.append(stack_boxed(layer_ps))
        else:
            params.append(layer_ps)
    return {"segments": params}


def _seg_layer_meta(cfg: ModelConfig, seg: Segment, serve_window: int):
    """Per-layer (window, theta) as python lists (static); the scan path
    converts them to traced arrays, the unrolled path keeps them static."""
    wins, thetas = [], []
    for i in range(seg.n_layers):
        w, t = layer_window_theta(cfg, seg.first_layer + i, serve_window)
        wins.append(w)
        thetas.append(t)
    return wins, thetas


def apply_stack(params, x, cfg: ModelConfig, *,
                decoder: bool = True,
                causal: bool = True,
                positions=None,
                caches: Optional[List] = None,   # per-segment stacked caches
                cache_pos=None,
                enc_out: Optional[jax.Array] = None,
                serve_window: int = 0):
    """Run all segments.  Returns (y, new_caches, aux)."""
    segments = plan_segments(cfg, decoder=decoder)
    new_caches = []
    aux_total: Dict[str, Any] = {}

    for si, seg in enumerate(segments):
        seg_params = params["segments"][si]
        wins, thetas = _seg_layer_meta(cfg, seg, serve_window)
        seg_cache = caches[si] if caches is not None else None

        if cfg.scan_layers:
            def body(carry, xs, _kind=seg.kind):
                h = carry
                layer_p, w, th, layer_cache = xs
                h, nc, aux = apply_block(
                    layer_p, h, cfg, _kind, causal=causal, window=w,
                    rope_theta=th, positions=positions, cache=layer_cache,
                    cache_pos=cache_pos, enc_out=enc_out)
                lb = aux.get("lb_loss", jnp.zeros((), jnp.float32))
                return h, (nc, lb)

            if cfg.remat == "block":
                body = jax.checkpoint(body)
            xs = (seg_params, jnp.asarray(wins, jnp.int32),
                  jnp.asarray(thetas, jnp.float32), seg_cache)
            x, (seg_new_cache, lbs) = jax.lax.scan(body, x, xs)
            new_caches.append(seg_new_cache)
            if seg.kind == "attn_moe":
                aux_total["lb_loss"] = aux_total.get("lb_loss", 0.0) + jnp.sum(lbs)
        else:
            seg_new = []
            for i in range(seg.n_layers):
                layer_cache = (jax.tree.map(lambda c, _i=i: c[_i], seg_cache)
                               if seg_cache is not None else None)
                x, nc, aux = apply_block(
                    seg_params[i], x, cfg, seg.kind, causal=causal,
                    window=wins[i], rope_theta=thetas[i],
                    positions=positions, cache=layer_cache,
                    cache_pos=cache_pos, enc_out=enc_out)
                seg_new.append(nc)
                if "lb_loss" in aux:
                    aux_total["lb_loss"] = aux_total.get("lb_loss", 0.0) + aux["lb_loss"]
            if seg_new and seg_new[0]:
                new_caches.append(jax.tree.map(lambda *cs: jnp.stack(cs), *seg_new))
            else:
                new_caches.append(None)
    return x, new_caches, aux_total


# --------------------------------------------------------------------------
# cache init
# --------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=None) -> Tuple[List, Any]:
    """Per-segment stacked decode caches (zeros) + the pos scalar.

    Layout: attn k/v [L, B, S, Hkv, hd]; MLA c_kv [L, B, S, kv_lora],
    k_rope [L, B, S, rope_dim]; ssm conv [L, B, K-1, conv_dim],
    state [L, B, H, P, N].  Logical axes for sharding are provided by
    :func:`cache_logical_axes`.
    """
    dtype = jnp.dtype(dtype or cfg.dtype)
    segments = plan_segments(cfg, decoder=True)
    caches = []
    for seg in segments:
        L = seg.n_layers
        entry: Dict[str, Any] = {}
        if seg.kind in ("attn_mlp", "attn_moe", "hybrid", "dec_cross"):
            if cfg.use_mla:
                entry["attn"] = {
                    "c_kv": jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dtype),
                    "k_rope": jnp.zeros((L, batch, max_len, cfg.qk_rope_head_dim), dtype),
                }
            else:
                hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim()
                entry["attn"] = {
                    "k": jnp.zeros((L, batch, max_len, hkv, hd), dtype),
                    "v": jnp.zeros((L, batch, max_len, hkv, hd), dtype),
                }
        if seg.kind in ("ssm", "hybrid"):
            d_inner, H, conv_dim = ssm_lib.ssm_dims(cfg)
            entry["ssm"] = {
                "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_dim), dtype),
                "state": jnp.zeros((L, batch, H, cfg.ssm_head_dim, cfg.ssm_state),
                                   jnp.float32),
            }
        caches.append(entry)
    return caches, jnp.zeros((), jnp.int32)


def cache_logical_axes(cfg: ModelConfig) -> List:
    segments = plan_segments(cfg, decoder=True)
    out = []
    for seg in segments:
        entry: Dict[str, Any] = {}
        if seg.kind in ("attn_mlp", "attn_moe", "hybrid", "dec_cross"):
            if cfg.use_mla:
                entry["attn"] = {
                    "c_kv": ("layers", "batch", "cache_seq", "kv_lora"),
                    "k_rope": ("layers", "batch", "cache_seq", "head_dim"),
                }
            else:
                entry["attn"] = {
                    "k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                    "v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                }
        if seg.kind in ("ssm", "hybrid"):
            entry["ssm"] = {
                "conv": ("layers", "batch", None, "act_mlp"),
                "state": ("layers", "batch", "act_heads", None, "state"),
            }
        out.append(entry)
    return out
