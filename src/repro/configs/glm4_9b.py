"""glm4-9b [dense] — GQA kv=2, partial rotary (rotary_frac=0.5), QKV bias.
[hf:THUDM/glm-4-9b]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    arch_type="dense",
    source="hf:THUDM/glm-4-9b",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=151552,
    act="silu",
    qkv_bias=True,
    rope_theta=10_000.0,
    rotary_frac=0.5,
    norm_eps=1.5625e-07,
    serve_window=8192,      # beyond-paper windowed-serving variant
    long_context_ok=True,   # long_500k via the sliding-window serve path
)
