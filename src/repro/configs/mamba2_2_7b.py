"""mamba2-2.7b [ssm] — attention-free SSD (state-space duality).
[arXiv:2405.21060]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    source="arXiv:2405.21060",
    n_layers=64,
    d_model=2560,
    n_heads=0,              # attention-free
    n_kv_heads=0,
    d_ff=0,                 # no separate MLP block (mamba block only)
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,        # 2*2560/64 = 80 SSD heads
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=128,
    norm_eps=1e-5,
    tie_embeddings=True,
    use_ssd_kernel=True,
    long_context_ok=True,   # O(1) state → long_500k runs
)
