"""Step builders: train / prefill / serve with resolved shardings.

Bridges the model zoo and the launcher: for a (ModelConfig, ShapeConfig,
Mesh) triple this module resolves every pytree (params, optimizer state,
batch, caches) to ``NamedSharding`` via the logical rules, and returns
jit-ready step callables plus ShapeDtypeStruct input stand-ins for the
dry-run (``.lower(...).compile()`` with zero allocation).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.synthetic import make_batch_specs
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update, linear_warmup_cosine
from repro.parallel import (
    RULES_DECODE,
    RULES_LONG_DECODE,
    RULES_TRAIN,
    LogicalRules,
    logical_spec,
    logical_spec_sized,
    sharding_ctx,
)


def rules_for(shape: ShapeConfig) -> LogicalRules:
    if shape.kind == "train" or shape.kind == "prefill":
        return RULES_TRAIN if shape.kind == "train" else RULES_DECODE
    return RULES_LONG_DECODE if shape.global_batch == 1 else RULES_DECODE


def _tree_shardings(sds_tree, axes_tree, rules: LogicalRules, mesh: Mesh):
    """Shape-aware sharding resolution (indivisible dims fall back)."""
    return jax.tree.map(
        lambda sd, axes: NamedSharding(
            mesh, logical_spec_sized(sd.shape, axes, rules, mesh)),
        sds_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and not any(
            hasattr(e, "shape") for e in x),
    )


def _sds_like(shape_dtype_tree, shardings_tree):
    return jax.tree.map(
        lambda sd, sh: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=sh),
        shape_dtype_tree, shardings_tree)


@dataclasses.dataclass
class StepBundle:
    """Everything the launcher / dry-run needs for one (arch × shape)."""

    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    rules: LogicalRules
    model: Model
    step_fn: Callable          # jit-able python callable
    in_shardings: Any
    out_shardings: Any
    input_sds: Tuple           # ShapeDtypeStructs for .lower(*input_sds)
    # Train-only split of step_fn into its two ST-queue phases (set by
    # build_train_step; None for prefill/serve bundles):
    #   grad_fn(params, batch)            -> (grads, metrics)
    #   apply_fn(params, opt_state, grads) -> (params, opt_state, metrics)
    # ``step_fn == apply ∘ grad``; :func:`pipelined_steps` interleaves
    # them across consecutive steps (software pipelining).
    grad_fn: Optional[Callable] = None
    apply_fn: Optional[Callable] = None

    def lower(self):
        jitted = jax.jit(self.step_fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings)
        with self.mesh:
            return jitted.lower(*self.input_sds)


# --------------------------------------------------------------------------
# train
# --------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     opt: Optional[AdamWConfig] = None,
                     total_steps: int = 10_000) -> StepBundle:
    assert shape.kind == "train"
    rules = RULES_TRAIN
    model = Model(cfg)
    opt = opt or AdamWConfig()

    params_sd, axes = model.abstract_init()
    param_shardings = _tree_shardings(params_sd, axes, rules, mesh)
    opt_sd = jax.eval_shape(lambda p: adamw_init(p, opt), params_sd)
    opt_shardings = {
        "m": param_shardings, "v": param_shardings,
        "step": NamedSharding(mesh, P()),
    }
    batch_axes = make_batch_specs(cfg, shape)
    raw_sds = model.input_specs(shape)
    batch_shardings = {
        k: NamedSharding(mesh, logical_spec_sized(
            raw_sds[k].shape, batch_axes[k], rules, mesh))
        for k in raw_sds
    }
    batch_sds = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=batch_shardings[k])
        for k, v in raw_sds.items()
    }

    # The step in its two ST phases: the forward/backward "compute
    # queue" and the gradient-collective + optimizer "apply queue".
    # train_step chains them; pipelined_steps overlaps apply(i) with
    # grad(i+1) instead.
    def grad_step(params, batch):
        def loss_fn(p):
            with sharding_ctx(rules, mesh):
                return model.loss(p, batch)
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return grads, dict(metrics)

    def apply_step(params, opt_state, grads):
        lr = linear_warmup_cosine(opt_state["step"], base_lr=opt.lr,
                                  warmup_steps=max(total_steps // 50, 10),
                                  total_steps=total_steps)
        return adamw_update(params, grads, opt_state, opt, lr=lr)

    def train_step(params, opt_state, batch):
        grads, metrics = grad_step(params, batch)
        new_params, new_opt, opt_metrics = apply_step(params, opt_state, grads)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    metrics_sh = None  # let jit infer (scalars)
    in_sh = (param_shardings, opt_shardings, batch_shardings)
    out_sh = (param_shardings, opt_shardings, metrics_sh)

    input_sds = (
        _sds_like(params_sd, param_shardings),
        _sds_like(opt_sd, opt_shardings),
        batch_sds,
    )
    return StepBundle(cfg, shape, mesh, rules, model, train_step,
                      in_sh, out_sh, input_sds,
                      grad_fn=grad_step, apply_fn=apply_step)


# --------------------------------------------------------------------------
# prefill / decode
# --------------------------------------------------------------------------


def _cache_shardings(caches_sd, model: Model, rules: LogicalRules, mesh: Mesh,
                     per_seq_pos: bool = False):
    axes = model.cache_axes(per_sequence=per_seq_pos)
    return jax.tree.map(
        lambda sd, a: NamedSharding(
            mesh, logical_spec_sized(sd.shape, a, rules, mesh)),
        caches_sd, axes,
        is_leaf=lambda x: isinstance(x, tuple) and not any(
            hasattr(e, "shape") for e in x))


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       serve_window: int = 0) -> StepBundle:
    assert shape.kind == "prefill"
    rules = RULES_DECODE
    model = Model(cfg)

    params_sd, axes = model.abstract_init()
    param_shardings = _tree_shardings(params_sd, axes, rules, mesh)

    B, S = shape.global_batch, shape.seq_len
    max_len = S + model._prefix_len()
    caches_sd = jax.eval_shape(lambda: model.init_caches(B, max_len))
    cache_shardings = _cache_shardings(caches_sd, model, rules, mesh)

    batch_axes = make_batch_specs(cfg, shape)
    raw_sds = model.input_specs(shape)
    batch_shardings = {
        k: NamedSharding(mesh, logical_spec_sized(
            raw_sds[k].shape, batch_axes[k], rules, mesh))
        for k in raw_sds
    }
    batch_sds = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=batch_shardings[k])
        for k, v in raw_sds.items()
    }

    def prefill_step(params, batch, caches):
        with sharding_ctx(rules, mesh):
            return model.prefill(params, batch, caches,
                                 serve_window=serve_window)

    in_sh = (param_shardings, batch_shardings, cache_shardings)
    out_sh = (NamedSharding(mesh, logical_spec_sized(
                  (B, cfg.vocab), ("batch", "act_vocab"), rules, mesh)),
              _prefill_out_cache_shardings(cache_shardings))
    input_sds = (
        _sds_like(params_sd, param_shardings),
        batch_sds,
        _sds_like(caches_sd, cache_shardings),
    )
    return StepBundle(cfg, shape, mesh, rules, model, prefill_step,
                      in_sh, out_sh, input_sds)


def _prefill_out_cache_shardings(cache_shardings):
    return cache_shardings


def build_serve_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     serve_window: int = 0,
                     per_seq_pos: bool = False) -> StepBundle:
    """Decode-step bundle.  ``per_seq_pos=True`` sizes the caches with a
    [batch] position vector (each slot at its own depth) — required by
    the continuous-batching serve path (:mod:`repro.launch.serve`)."""
    assert shape.kind == "decode"
    rules = rules_for(shape)
    model = Model(cfg)

    params_sd, axes = model.abstract_init()
    param_shardings = _tree_shardings(params_sd, axes, rules, mesh)

    B, S = shape.global_batch, shape.seq_len
    caches_sd = jax.eval_shape(
        lambda: model.init_caches(B, S, per_sequence=per_seq_pos))
    cache_shardings = _cache_shardings(caches_sd, model, rules, mesh,
                                       per_seq_pos=per_seq_pos)

    token_sh = NamedSharding(mesh, logical_spec_sized((B,), ("batch",),
                                                       rules, mesh))
    token_sds = jax.ShapeDtypeStruct((B,), jnp.int32, sharding=token_sh)

    def serve_step(params, caches, token):
        with sharding_ctx(rules, mesh):
            return model.decode_step(params, caches, token,
                                     serve_window=serve_window)

    logits_sh = NamedSharding(mesh, logical_spec_sized(
        (B, cfg.vocab), ("batch", "act_vocab"), rules, mesh))
    in_sh = (param_shardings, cache_shardings, token_sh)
    out_sh = (logits_sh, cache_shardings)
    input_sds = (
        _sds_like(params_sd, param_shardings),
        _sds_like(caches_sd, cache_shardings),
        token_sds,
    )
    return StepBundle(cfg, shape, mesh, rules, model, serve_step,
                      in_sh, out_sh, input_sds)


def loss_plateau(eps: float = 1e-4, key: str = "loss"):
    """Build an ``until(metrics, i) -> bool`` continue-predicate for
    :func:`persistent_steps`: keep stepping while the last two realized
    values of ``metrics[key]`` differ by more than ``eps`` (the first
    two steps always run — there is nothing to compare before them)."""

    def cond(metrics, i):
        trace = metrics[key]
        still_moving = jnp.abs(trace[i - 1] - trace[i - 2]) > eps
        return jnp.logical_or(i < 2, still_moving)

    return cond


def _batch_indexer(bundle: StepBundle, n_iters: int,
                   stacked: Optional[bool], batch) -> Callable:
    """Resolve the stacked-vs-broadcast batch regime and return
    ``batch_at(i)`` (see :func:`persistent_steps` for the inference
    rules; shared with :func:`pipelined_steps`)."""
    if stacked is not None:
        is_stacked = bool(stacked)
    else:
        leaves = jax.tree.leaves(batch)
        ref = bundle.input_sds[2] if len(bundle.input_sds) > 2 else None
        ref_leaves = jax.tree.leaves(ref) if ref is not None else None
        if ref_leaves and len(ref_leaves) == len(leaves):
            if all(tuple(l.shape) == tuple(r.shape)
                   for l, r in zip(leaves, ref_leaves)):
                is_stacked = False
            elif all(tuple(l.shape) == (n_iters, *r.shape)
                     for l, r in zip(leaves, ref_leaves)):
                is_stacked = True
            else:
                raise ValueError(
                    "batch shapes match neither the per-step spec nor the "
                    f"stacked (n_iters={n_iters}, ...) spec")
        else:
            is_stacked = bool(leaves) and all(
                getattr(l, "ndim", 0) >= 1 and l.shape[0] == n_iters
                for l in leaves)

    def batch_at(i):
        if not is_stacked:
            return batch  # broadcast: every inner step sees the same data
        return jax.tree.map(
            lambda x: jax.lax.dynamic_index_in_dim(
                x, i, axis=0, keepdims=False), batch)

    return batch_at


def persistent_steps(bundle: StepBundle, n_iters: int, *,
                     until=None, stacked: Optional[bool] = None) -> StepBundle:
    """Device-resident multi-step bundle: ONE host dispatch for up to
    ``n_iters`` train steps.

    The training-loop analogue of
    :mod:`repro.core.engine_persistent`: the returned bundle's
    ``step_fn`` wraps the original step in an on-device loop, so
    params/optimizer state round-trip through device memory — never the
    host — between inner steps.

    Data: the batch may carry a leading ``n_iters`` axis (one slice per
    inner step, indexed on-device), or keep the per-step shape, in which
    case the same batch feeds every inner step (the synthetic regime the
    dry-run/benchmarks use).  ``stacked`` forces the interpretation;
    by default it is inferred from the leaf shapes (against
    ``bundle.input_sds`` when available).  Without ``input_sds`` the
    inference is a heuristic — a per-step batch whose own leading dim
    happens to equal ``n_iters`` is indistinguishable from a stacked
    one, so such callers should pass ``stacked`` explicitly.

    Metrics: a **stacked carry** — every entry gains a leading
    ``n_iters`` axis holding the per-step trace (zero-padded past the
    realized count), plus a scalar ``steps_done``.  Not last-step-only:
    a multi-step dispatch loses no observability.

    Termination: with ``until(metrics, i) -> bool`` set (see
    :func:`loss_plateau`), the ``fori_loop`` becomes a
    ``lax.while_loop`` that keeps stepping while the predicate holds —
    ``metrics`` is the stacked carry, ``i`` the number of completed
    steps — bounded by ``n_iters``.  Loss-plateau termination without a
    host round-trip per step.

    Shardings and input stand-ins are unchanged — stacked-batch callers
    place their own leading-axis arrays (see
    :func:`repro.launch.train.train`).
    """
    if n_iters < 1:
        raise ValueError(f"n_iters must be >= 1, got {n_iters}")
    inner = bundle.step_fn

    def persistent_step(params, opt_state, batch):
        batch_at = _batch_indexer(bundle, n_iters, stacked, batch)

        # seed the metrics carry abstractly so the step traces ONCE (in
        # the loop body), not twice in the compiled program
        met_sd = jax.eval_shape(inner, params, opt_state, batch_at(0))[2]
        met0 = jax.tree.map(
            lambda sd: jnp.zeros((n_iters, *sd.shape), sd.dtype), met_sd)

        def record(mets, m, i):
            return jax.tree.map(
                lambda acc, v: jax.lax.dynamic_update_index_in_dim(
                    acc, jnp.asarray(v, acc.dtype), i, axis=0), mets, m)

        if until is None:
            def body(i, c):
                p, o, mets = c
                p, o, m = inner(p, o, batch_at(i))
                return p, o, record(mets, m, i)

            params, opt_state, mets = jax.lax.fori_loop(
                0, n_iters, body, (params, opt_state, met0))
            steps_done = jnp.asarray(n_iters, jnp.int32)
        else:
            def wcond(carry):
                i, keep_going, *_ = carry
                return jnp.logical_and(keep_going, i < n_iters)

            def wbody(carry):
                i, _, p, o, mets = carry
                p, o, m = inner(p, o, batch_at(i))
                mets = record(mets, m, i)
                i = i + 1
                keep_going = jnp.asarray(until(mets, i), jnp.bool_).reshape(())
                return i, keep_going, p, o, mets

            carry0 = (jnp.zeros((), jnp.int32), jnp.asarray(True),
                      params, opt_state, met0)
            steps_done, _, params, opt_state, mets = jax.lax.while_loop(
                wcond, wbody, carry0)

        mets = dict(mets)
        mets["steps_done"] = steps_done
        return params, opt_state, mets

    return dataclasses.replace(bundle, step_fn=persistent_step)


def build_persistent_train_step(cfg: ModelConfig, shape: ShapeConfig,
                                mesh: Mesh, n_iters: int,
                                until=None, stacked: Optional[bool] = None,
                                **kwargs) -> StepBundle:
    """:func:`build_train_step`, then fold up to ``n_iters`` steps into
    one dispatch via :func:`persistent_steps`."""
    return persistent_steps(build_train_step(cfg, shape, mesh, **kwargs),
                            n_iters, until=until, stacked=stacked)


def pipelined_steps(bundle: StepBundle, n_iters: int, *,
                    stacked: Optional[bool] = None) -> StepBundle:
    """Software-pipelined multi-step bundle: the gradient-collective +
    optimizer *apply* of step i overlaps the forward/backward *compute*
    of step i+1, inside ONE device-resident dispatch.

    The launch-layer analogue of :func:`repro.core.schedule.compose`:
    the train step is split into its two ST queues
    (``bundle.grad_fn`` — the compute queue; ``bundle.apply_fn`` — the
    gradient-collective queue, see :func:`build_train_step`), and the
    loop body round-robins them one step out of phase::

        g_0 = grad(p_0, batch_0)                       # prologue
        for i in 1..n-1:   # both read the SAME params -> may overlap
            g_i = grad(p_{i-1}, batch_i)               # compute, step i
            p_i = apply(p_{i-1}, g_{i-1})              # collective+opt, step i-1
        p_n = apply(p_{n-1}, g_{n-1})                  # epilogue

    Because ``grad`` of step i and ``apply`` of step i-1 have no data
    dependency on each other, XLA is free to run step i's backward while
    step i-1's gradient all-reduce and optimizer update are in flight —
    the communication/compute overlap a sequential ``step_fn`` chain
    forbids.  The price is the classic *staleness-1* pipelined-SGD
    semantics: step i's gradients are evaluated on parameters that do
    not yet include step i-1's update.  ``n_iters=1`` degenerates to
    the exact sequential step.

    Metrics: stacked like :func:`persistent_steps` — slot i holds step
    i's grad-phase metrics (loss, ...) AND the apply-phase metrics of
    step i's own gradient application (grad_norm, lr, ...), plus
    ``steps_done``.

    Requires a bundle with the grad/apply split (train bundles have it);
    batches follow the same stacked/broadcast regime as
    :func:`persistent_steps`.
    """
    if n_iters < 1:
        raise ValueError(f"n_iters must be >= 1, got {n_iters}")
    if bundle.grad_fn is None or bundle.apply_fn is None:
        raise ValueError(
            "pipelined_steps needs the grad/apply phase split "
            "(bundle.grad_fn/apply_fn) — build the bundle with "
            "build_train_step")
    grad_fn, apply_fn = bundle.grad_fn, bundle.apply_fn

    def pipelined_step(params, opt_state, batch):
        batch_at = _batch_indexer(bundle, n_iters, stacked, batch)

        # seed the stacked metrics carry abstractly (trace once)
        grads_sd, gmet_sd = jax.eval_shape(grad_fn, params, batch_at(0))
        _, _, omet_sd = jax.eval_shape(apply_fn, params, opt_state, grads_sd)
        overlap = set(gmet_sd) & set(omet_sd)
        if overlap:
            raise ValueError(
                f"grad/apply metrics keys collide: {sorted(overlap)}")
        met0 = {
            k: jnp.zeros((n_iters, *sd.shape), sd.dtype)
            for k, sd in {**gmet_sd, **omet_sd}.items()
        }

        def record(mets, m, i):
            out = dict(mets)
            for k, v in m.items():
                out[k] = jax.lax.dynamic_update_index_in_dim(
                    mets[k], jnp.asarray(v, mets[k].dtype), i, axis=0)
            return out

        # prologue: compute step 0's gradients (nothing to apply yet)
        g_prev, gmet = grad_fn(params, batch_at(0))
        mets = record(met0, gmet, 0)

        def body(i, carry):
            p, o, g_prev, mets = carry
            # compute queue, step i — reads the PRE-apply params, so it
            # carries no dependency on the apply below (overlap window)
            g_i, gmet = grad_fn(p, batch_at(i))
            # gradient-collective queue, step i-1
            p, o, omet = apply_fn(p, o, g_prev)
            mets = record(mets, gmet, i)
            mets = record(mets, omet, i - 1)
            return p, o, g_i, mets

        params, opt_state, g_prev, mets = jax.lax.fori_loop(
            1, n_iters, body, (params, opt_state, g_prev, mets))

        # epilogue: drain the pipeline (apply the last step's gradients)
        params, opt_state, omet = apply_fn(params, opt_state, g_prev)
        mets = record(mets, omet, n_iters - 1)
        mets["steps_done"] = jnp.asarray(n_iters, jnp.int32)
        return params, opt_state, mets

    return dataclasses.replace(bundle, step_fn=pipelined_step)


def build_pipelined_train_step(cfg: ModelConfig, shape: ShapeConfig,
                               mesh: Mesh, n_iters: int,
                               stacked: Optional[bool] = None,
                               **kwargs) -> StepBundle:
    """:func:`build_train_step`, then software-pipeline ``n_iters``
    steps (apply of step i overlapping compute of step i+1) into one
    dispatch via :func:`pipelined_steps`."""
    return pipelined_steps(build_train_step(cfg, shape, mesh, **kwargs),
                           n_iters, stacked=stacked)


def build_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                 **kwargs) -> StepBundle:
    serve_window = cfg.serve_window if (shape.name == "long_500k") else 0
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, **kwargs)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, serve_window=serve_window,
                                  **kwargs)
    return build_serve_step(cfg, shape, mesh, serve_window=serve_window,
                            **kwargs)


# -- collective-matmul wiring (ROADMAP: collective-matmul unification) ------


def tp_block_schedule(mesh: Mesh, axis: str, m: int, k: int, f: int, *,
                      companions: Sequence[Any] = (),
                      dtype=jnp.float32, bidirectional: bool = False,
                      interleave: Any = "round_robin",
                      verify: str = "error",
                      name: Optional[str] = None):
    """A tensor-parallel block's grad/activation collectives composed
    INTO the same schedule as other queues (halo exchange, pipeline
    stages): the "transformer block as ST schedule".

    Builds the Megatron-MLP ST program
    (:func:`repro.core.collectives.build_tp_block` — all-gather-matmul
    → relu → matmul-reduce-scatter, every ring step a trigger→wait
    channel) and fuses it with ``companions`` (any built STPrograms,
    e.g. :func:`repro.core.halo.build_faces_program`) via
    :func:`repro.core.schedule.compose` — so the TP matmul chunks are
    scheduled into the companions' trigger→wait windows and the whole
    step runs as ONE dispatch (the SUMMA-pipelined pattern: compute on
    chunk s overlaps the transfer of chunk s+1 *and* the companions'
    halo traffic).

    Returns ``(schedule_or_program, tp)`` where ``tp`` is the
    :class:`~repro.core.collectives.CollectiveMatmul` carrying the
    TP program's buffer names and bit-identity references.  With no
    companions the bare TP program is returned (engine-ready either
    way).  Under composition the TP buffers are namespaced
    ``"{tp.program.name}/{buffer}"``.
    """
    from repro.core.collectives import build_tp_block
    from repro.core.schedule import compose

    tp = build_tp_block(mesh, axis, m, k, f, dtype,
                        bidirectional=bidirectional, verify="warn")
    if not companions:
        return tp.program, tp
    sched = compose(tp.program, *companions, interleave=interleave,
                    verify=verify, name=name or "tp_block_sched")
    return sched, tp
