"""Faces microbenchmark — the paper's §V experiments (Figs. 8–12).

Reproduces each figure's *experimental contrast* on the CPU-device grid
(absolute Slingshot timings need the NIC; the control-path contrasts do
not — see DESIGN.md §9):

fig8   64×1×1-style 1-D multi-rank: baseline (host-orchestrated, batch
       sync) vs ST-emulated (host engine, per-op sync — the progress-
       thread tax) vs ST-offloaded (fused).  Paper: ST 10% *slower*
       when the progress thread dominates.
fig9   single-node intra: baseline vs progress-thread emulation.
       Paper: ST 4% slower.
fig10  1 rank/node 1-D: baseline vs fully-offloaded ST.  Paper: parity.
fig11  2×2×2 3-D (26 neighbors): same A/B.  Paper: ST +4% — the win
       grows with message count because each message costs the host a
       dispatch but costs the fused program nothing.
fig12  trigger tuning: stock stream-memory ops (ST `stream` mode,
       strict FIFO barriers) vs hand-tuned shaders (ST `dataflow` mode,
       minimal ordering).  Paper: +8% over baseline.
figP   persistent iteration loop (beyond-paper; the "fully offloaded"
       follow-up): host per-op vs fused per-iteration vs persistent
       (device-resident fori_loop) — the host-dispatch count for the
       whole N-iteration timed loop collapses from N×per-op and N×1
       down to exactly 1, measured via HostStats counters.
fig_pipeline  multi-queue composition (beyond-paper; the multi-DWQ
       schedule): two half-grid Faces queues run sequentially (two
       persistent dispatches, no cross-queue overlap) vs composed via
       ``repro.core.schedule.compose`` (ONE dispatch, round-robin
       interleaved) — reports the overlap speedup and dispatch counts.

Loop configuration mirrors the paper (§V-B): outer × middle × inner
with buffer alloc in the outer loop; defaults are scaled down for CPU
(env FACES_INNER etc. override).
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

RESULTS: List[Dict] = []

# tuner-chosen knobs per published row ("bench/variant" -> knob dict);
# benchmarks/run.py stamps this into BENCH_faces.json's _meta so the
# perf gate pins the choices and flags drift on re-tune
TUNED_KNOBS: Dict[str, Dict] = {}


def _cfg_env(name, default):
    return int(os.environ.get(name, default))


def _time_engine(engine, mem, inner: int, repeats: int = 5, fresh=None):
    """Time ``inner`` chained engine calls, ``repeats`` times.

    ``fresh`` (a zero-arg factory) re-materializes the input buffers
    before each repeat *outside* the timed section — required for
    donating engines, whose calls consume their inputs (the ``m =
    engine(m)`` chain donates every intermediate, which is the point).

    The loop itself is the tuner's (:func:`repro.launch.tune.measure`)
    — one timing implementation for benches and auto-tuning; callers
    here warm their engines explicitly, so warmup is skipped.
    """
    from repro.launch.tune import measure
    return measure(engine,
                   fresh if fresh is not None else (lambda: dict(mem)),
                   inner, repeats, warm=False)


def _setup(grid, points, **cfg_kw):
    import jax
    from repro.core import FacesConfig, FusedEngine, HostEngine, build_faces_program
    from repro.parallel import make_mesh

    mesh = make_mesh(grid, ("gx", "gy", "gz"))
    cfg = FacesConfig(grid=grid, points=points, **cfg_kw)
    prog = build_faces_program(cfg, mesh)
    rng = np.random.RandomState(0)
    u0 = rng.randn(*grid, *points).astype(np.float32)
    return cfg, prog, u0


def _variants(prog, u0, inner, which=("baseline", "st_emulated", "st_offload")):
    from repro.core import FusedEngine, HostEngine

    out = {}
    # ST engines donate their inputs: the m = engine(m) timed chain then
    # rotates buffers zero-copy across dispatches (host baselines keep
    # the conventional copy-per-dispatch behaviour they model)
    specs = {
        "baseline": (HostEngine, {"sync": "batch"}, prog.dispatch_count_host()),
        "st_emulated": (HostEngine, {"sync": "every_op"},
                        prog.dispatch_count_host()),
        "st_offload": (FusedEngine, {"mode": "stream", "donate": True}, 1),
        "st_tuned": (FusedEngine, {"mode": "dataflow", "donate": True}, 1),
    }
    for name in which:
        cls, kw, n_disp = specs[name]
        eng = cls(prog, **kw)
        fresh = (lambda e=eng: e.init_buffers({"u": u0}))
        eng(fresh())  # warm every per-descriptor/fused compile
        donating = kw.get("donate", False)
        r = _time_engine(eng, None if donating else fresh(), inner,
                         fresh=fresh if donating else None)
        r["dispatches_per_iter"] = n_disp
        out[name] = r
    return out


def _report(fig: str, variants: Dict, paper_claim: str):
    base = variants.get("baseline", {}).get("avg_s")
    for name, r in variants.items():
        rel = (r["avg_s"] / base) if base else float("nan")
        derived = (f"rel_to_baseline={rel:.3f};"
                   f"dispatches={r['dispatches_per_iter']}")
        if r.get("note"):
            derived += f";{r['note']}"
        RESULTS.append({
            "bench": f"faces_{fig}", "variant": name,
            "us_per_call": r["avg_s"] * 1e6,
            "median_ms": r["med_s"] * 1e3,
            "dispatches": r["dispatches_per_iter"],
            "derived": derived,
        })
        print(f"  {fig:6s} {name:12s} avg={r['avg_s']*1e3:9.2f}ms "
              f"min={r['min_s']*1e3:9.2f}ms rel={rel:6.3f} "
              f"dispatch/iter={r['dispatches_per_iter']}")
    print(f"  paper: {paper_claim}")


def fig8(inner=None):
    """8 ranks 1-D, many messages per rank, progress-thread emulation."""
    inner = inner or _cfg_env("FACES_INNER", 10)
    _, prog, u0 = _setup((8, 1, 1), (12, 12, 12))
    v = _variants(prog, u0, inner)
    _report("fig8", v, "ST 10% slower than baseline (progress-thread tax)")
    return v


def fig9(inner=None):
    """Intra-node: baseline vs per-op progress thread."""
    inner = inner or _cfg_env("FACES_INNER", 10)
    _, prog, u0 = _setup((8, 1, 1), (12, 12, 12))
    v = _variants(prog, u0, inner, which=("baseline", "st_emulated"))
    _report("fig9", v, "ST 4% slower (progress thread per MPI process)")
    return v


def fig10(inner=None):
    """1-D, full NIC offload: parity or better."""
    inner = inner or _cfg_env("FACES_INNER", 10)
    _, prog, u0 = _setup((8, 1, 1), (12, 12, 12))
    v = _variants(prog, u0, inner, which=("baseline", "st_offload"))
    _report("fig10", v, "ST ≈ parity with baseline (HW offload)")
    return v


def fig11(inner=None):
    """2×2×2 3-D (26 neighbors): offload advantage grows."""
    inner = inner or _cfg_env("FACES_INNER", 10)
    _, prog, u0 = _setup((2, 2, 2), (12, 12, 12))
    v = _variants(prog, u0, inner, which=("baseline", "st_offload"))
    _report("fig11", v, "ST 4% faster (NIC offload, more messages)")
    return v


def fig12(inner=None):
    """Trigger tuning: strict stream-memory ops vs relaxed triggers."""
    from repro.core import FusedEngine
    from repro.launch.tune import tune

    inner = inner or _cfg_env("FACES_INNER", 10)
    _, prog, u0 = _setup((2, 2, 2), (12, 12, 12))
    v = _variants(prog, u0, inner, which=("baseline",))
    # st_tuned is an *auto-tuner*: the generic searcher
    # (repro.launch.tune) measures the trigger-ordering knob space and
    # publishes the best knob for this platform rather than pinning
    # `dataflow` — if strict stream ordering measured faster here, that
    # IS the tuned setting (the paper's hand-tuned shaders played the
    # same game on the NIC side).  Both candidates' measurements become
    # the rows directly: st_offload is the stream candidate, and the
    # raw dataflow measurement stays tracked as its own variant so a
    # dataflow-mode regression remains visible in the trajectory even
    # when the stream fallback hides it from the published st_tuned
    # number.

    def build(knobs):
        eng = FusedEngine(prog, mode=knobs.mode, donate=True,
                          coalesce=knobs.coalesce)
        return eng, (lambda e=eng: e.init_buffers({"u": u0}))

    res = tune(build, {"mode": ["stream", "dataflow"]}, inner=inner,
               repeats=5, measure_top=2, engine_kind="fused")
    by_mode = {c.knobs.mode: c for c in res.measured}
    v["st_offload"] = dict(by_mode["stream"].stats, dispatches_per_iter=1)
    v["st_tuned_raw"] = dict(by_mode["dataflow"].stats,
                             dispatches_per_iter=1, note="knob=dataflow_raw")
    best_mode = res.best.knobs.mode
    v["st_tuned"] = dict(
        res.best.stats, dispatches_per_iter=1,
        note=f"knob={'dataflow' if best_mode == 'dataflow' else 'stream_fallback'}")
    TUNED_KNOBS["faces_fig12/st_tuned"] = res.knobs_dict()
    _report("fig12", v, "ST-shader 8% faster than baseline (tuned triggers)")
    return v


def fig_persistent(inner=None):
    """Persistent loop: N iterations as ONE dispatch (vs N, vs N×per-op)."""
    inner = inner or _cfg_env("FACES_INNER", 10)
    from repro.core import FusedEngine, HostEngine, PersistentEngine

    _, prog, u0 = _setup((2, 2, 2), (12, 12, 12))
    pprog = prog.persistent(inner)
    repeats = 5
    rows = {}

    # host per-op: every descriptor its own dispatch, each iteration
    host = HostEngine(prog, sync="every_op")
    mem = host.init_buffers({"u": u0})
    host(dict(mem))  # warm per-descriptor compiles
    host.stats.reset()
    rows["host_per_op"] = _time_engine(host, mem, inner, repeats)
    rows["host_per_op"]["dispatches_per_loop"] = host.stats.dispatches // repeats

    # fused: one dispatch per iteration (donated: buffers rotate
    # zero-copy across the chained dispatches)
    fused = FusedEngine(prog, mode="dataflow", donate=True)
    fresh_f = lambda: fused.init_buffers({"u": u0})
    fused(fresh_f())  # warm
    fused.stats.reset()
    rows["fused_per_iter"] = _time_engine(fused, None, inner, repeats,
                                          fresh=fresh_f)
    rows["fused_per_iter"]["dispatches_per_loop"] = fused.stats.dispatches // repeats

    # persistent: ONE dispatch for the whole inner loop
    pers = PersistentEngine(pprog, mode="dataflow", donate=True)
    fresh_p = lambda: pers.init_buffers({"u": u0})
    pers(fresh_p())  # warm
    pers.stats.reset()
    rows["persistent"] = _time_engine(pers, None, 1, repeats,  # 1 call = inner iters
                                      fresh=fresh_p)
    rows["persistent"]["dispatches_per_loop"] = pers.stats.dispatches // repeats

    base = rows["host_per_op"]["avg_s"]
    for name, r in rows.items():
        rel = r["avg_s"] / base if base else float("nan")
        RESULTS.append({
            "bench": "faces_figP", "variant": name,
            "us_per_call": r["avg_s"] * 1e6,
            "median_ms": r["med_s"] * 1e3,
            "dispatches": r["dispatches_per_loop"],
            "derived": f"rel_to_host={rel:.3f};"
                       f"dispatches_per_loop={r['dispatches_per_loop']}",
        })
        print(f"  figP   {name:14s} avg={r['avg_s']*1e3:9.2f}ms "
              f"rel={rel:6.3f} dispatch/loop={r['dispatches_per_loop']}")
    assert rows["persistent"]["dispatches_per_loop"] == 1
    print(f"  contrast: {inner} iterations cost the host "
          f"{rows['host_per_op']['dispatches_per_loop']} dispatches, the fused "
          f"engine {rows['fused_per_iter']['dispatches_per_loop']}, the "
          f"persistent engine 1 (device-resident loop)")
    return rows


def fig_convergence(tols=(1e-1, 1e-2, 1e-3), max_iters=None):
    """Convergence loop: host-polled stopping vs device-resident while_loop."""
    import jax
    import jax.numpy as jnp
    from repro.core import (
        FacesConfig, FusedEngine, PersistentEngine, build_faces_program,
        global_residual_fn,
    )
    from repro.parallel import make_mesh

    max_iters = max_iters or _cfg_env("FACES_MAX_ITERS", 64)
    grid, points = (2, 2, 2), (12, 12, 12)
    mesh = make_mesh(grid, ("gx", "gy", "gz"))
    # damping=0.12 makes the damped Faces update a contraction on this
    # grid: tols (1e-1, 1e-2, 1e-3) realize ~1 / 3 / 11 iterations
    cfg = FacesConfig(grid=grid, points=points, damping=0.12)
    rng = np.random.RandomState(0)
    u0 = rng.randn(*grid, *points).astype(np.float32)
    residual = global_residual_fn(cfg)

    # host-polled baseline: one dispatch per iteration, and the host
    # fetches the residual after EACH iteration to decide whether to
    # stop — the control-path round-trip the ST model removes.
    prog = build_faces_program(cfg, mesh)
    fused = FusedEngine(prog, mode="dataflow")
    poll = jax.jit(
        lambda u: jnp.sqrt(jnp.sum(jnp.square(u.astype(jnp.float32)))
                           / cfg.n_points))

    for tol in tols:
        # device-resident: the while_loop owns termination (ONE dispatch)
        pprog = build_faces_program(cfg, mesh).persistent(
            max_iters, until=lambda r, tol=tol: r >= tol)
        pers = PersistentEngine(pprog, mode="dataflow", reduce_fn=residual,
                                donate=True)

        # warm every compile outside the timed sections
        mem = fused.init_buffers({"u": u0})
        fused(dict(mem))
        float(poll(mem["u"]))
        pers(pers.init_buffers({"u": u0}))

        fused.stats.reset()
        t0 = time.perf_counter()
        mem = fused.init_buffers({"u": u0})
        host_iters = 0
        while host_iters < max_iters:
            mem = fused(mem)
            host_iters += 1
            if float(poll(mem["u"])) < tol:  # host sync, every iteration
                break
        host_s = time.perf_counter() - t0
        host_dispatches = fused.stats.dispatches

        pers.stats.reset()
        mem0 = pers.init_buffers({"u": u0})
        t0 = time.perf_counter()
        _, res, n_done = pers(mem0)
        n_done = int(n_done)  # the single host read, after convergence
        dev_s = time.perf_counter() - t0

        # the two residuals are differently-ordered float reductions
        # (sharded psum vs one host-side sum): allow a one-iteration
        # disagreement at a tolerance boundary
        assert pers.stats.dispatches == 1 and abs(n_done - host_iters) <= 1, (
            pers.stats.dispatches, n_done, host_iters)
        for name, secs, iters, disp, syncs in (
                ("host_polled", host_s, host_iters, host_dispatches,
                 host_iters),
                ("device_resident", dev_s, n_done, 1, 0)):
            RESULTS.append({
                "bench": "faces_convergence", "variant": f"{name}_tol{tol:g}",
                "us_per_call": secs * 1e6,
                "median_ms": secs * 1e3,
                "dispatches": disp,
                "derived": f"tol={tol:g};iters={iters};dispatches={disp};"
                           f"host_syncs={syncs}",
            })
            print(f"  conv   tol={tol:<7g} {name:16s} iters={iters:3d} "
                  f"dispatches={disp:3d} host_syncs={syncs:3d} "
                  f"wall={secs*1e3:8.2f}ms")
    return RESULTS


def fig_pipeline(inner=None, repeats=5):
    """Pipelined multi-queue: 2 composed half-grid queues, 1 dispatch,
    vs the same two persistent programs dispatched sequentially (2) —
    plus the LINKED N-way rows: cross-program channels make the
    composed parts exchange their shared faces, so the composed run is
    the TRUE full-domain solve (verified against the single-queue
    full-domain run) while still costing one dispatch."""
    import jax
    from repro.core import (
        FacesConfig, PersistentEngine, build_faces_program,
        build_faces_part_program, compose, half_config, merge_parts,
        part_names, split_halves, split_parts,
    )
    from repro.parallel import make_mesh

    inner = inner or _cfg_env("FACES_INNER", 10)
    grid, points = (2, 2, 2), (12, 12, 12)
    mesh = make_mesh(grid, ("gx", "gy", "gz"))
    cfg = FacesConfig(grid=grid, points=points)
    cfgh = half_config(cfg)
    rng = np.random.RandomState(0)
    u0 = rng.randn(*grid, *points).astype(np.float32)
    ua, ub = split_halves(u0)

    progA = build_faces_program(cfgh, mesh, name="facesA").persistent(inner)
    progB = build_faces_program(cfgh, mesh, name="facesB").persistent(inner)
    engA = PersistentEngine(progA, mode="dataflow", donate=True)
    engB = PersistentEngine(progB, mode="dataflow", donate=True)
    freshA = lambda: engA.init_buffers({"u": ua})
    freshB = lambda: engB.init_buffers({"u": ub})
    outA, outB = engA(freshA()), engB(freshB())  # warm compiles

    # sequential: two host dispatches per loop, no cross-queue overlap
    engA.stats.reset(), engB.stats.reset()
    times = []
    for _ in range(repeats):
        memA, memB = freshA(), freshB()
        t0 = time.perf_counter()
        outA, outB = engA(memA), engB(memB)
        jax.block_until_ready([list(outA.values()), list(outB.values())])
        times.append(time.perf_counter() - t0)
    seq = {"avg_s": float(np.mean(times)), "med_s": float(np.median(times)),
           "min_s": float(np.min(times))}
    seq_disp = (engA.stats.dispatches + engB.stats.dispatches) // repeats

    # composed (unlinked): ONE dispatch, B's compute interleaves A's
    # comm windows, each half still an independent solve
    sched = compose(progA, progB)
    engC = PersistentEngine(sched, mode="dataflow", donate=True)
    freshC = lambda: engC.init_buffers({"facesA/u": ua, "facesB/u": ub})
    warm = engC(freshC())
    # the composition must not perturb either queue's numerics
    np.testing.assert_allclose(np.asarray(warm["facesA/u"]),
                               np.asarray(outA["u"]), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(warm["facesB/u"]),
                               np.asarray(outB["u"]), rtol=1e-5, atol=1e-6)
    engC.stats.reset()
    comp = _time_engine(engC, None, 1, repeats, fresh=freshC)
    comp_disp = engC.stats.dispatches // repeats
    assert (seq_disp, comp_disp) == (2, 1), (seq_disp, comp_disp)

    # full-domain reference: ONE queue over the unsplit block (what the
    # linked rows must reproduce bit-for-bit modulo the documented
    # coalesced-dataflow FMA ULPs)
    fprog = build_faces_program(cfg, mesh).persistent(inner)
    engF = PersistentEngine(fprog, mode="dataflow", donate=True)
    freshF = lambda: engF.init_buffers({"u": u0})
    full_out = engF(freshF())
    full_u = np.asarray(full_out["u"])
    engF.stats.reset()
    full = _time_engine(engF, None, 1, repeats, fresh=freshF)
    full_disp = engF.stats.dispatches // repeats

    # linked N-way: cross-program channels carry the shared faces (and
    # the stencil's ghost planes), one dispatch for the REAL solve.
    # Each part count gets TWO rows: `_untuned` pins the default knobs
    # (round-robin interleave, dataflow) as the regression reference,
    # and the published linked row is what the generic auto-tuner
    # (repro.launch.tune) picks over interleave policy × trigger mode.
    from repro.launch.tune import Knobs, tune as tune_search

    rows = [("sequential_2q", seq, seq_disp),
            ("composed_1q", comp, comp_disp),
            ("full_domain_1q", full, full_disp)]
    for n_parts in (2, 4):
        names = part_names(n_parts)
        progs = [build_faces_part_program(cfg, mesh, k, n_parts,
                                          names=names).persistent(inner)
                 for k in range(n_parts)]
        parts = split_parts(u0, n_parts)

        def mk_fresh(eng, nm=names, p=parts):
            return lambda: eng.init_buffers(
                {f"{n}/u": x for n, x in zip(nm, p)})

        engL = PersistentEngine(compose(*progs), mode="dataflow",
                                donate=True)
        freshL = mk_fresh(engL)
        warmL = engL(freshL())
        got = np.asarray(merge_parts([warmL[f"{n}/u"] for n in names]))
        np.testing.assert_allclose(got, full_u, rtol=1e-5, atol=1e-6)
        engL.stats.reset()
        linked = _time_engine(engL, None, 1, repeats, fresh=freshL)
        linked_disp = engL.stats.dispatches // repeats
        assert linked_disp == 1, linked_disp
        rows.append((f"linked_1q_n{n_parts}_untuned", linked, linked_disp))

        def build(knobs, progs=progs, mk=mk_fresh):
            eng = PersistentEngine(
                compose(*progs, interleave=knobs.interleave_policy()),
                mode=knobs.mode, donate=True)
            return eng, mk(eng)

        def check_solve(cand, nm=names):
            w = cand.engine(cand.fresh())
            got = np.asarray(merge_parts([w[f"{n}/u"] for n in nm]))
            np.testing.assert_allclose(got, full_u, rtol=1e-5, atol=1e-6)

        res = tune_search(build,
                          {"interleave": ["round_robin", "sequential", 2],
                           "mode": ["dataflow", "stream"]},
                          inner=1, repeats=repeats, measure_top=2,
                          certify=True, check=check_solve)
        engT, freshT = res.best.engine, res.best.fresh
        cert = res.best.certificate
        if cert is None or not cert.equivalent:
            # no effect-trace proof: fall back to the numeric check
            # (the tuner already warmed the winner, so this is the
            # only extra solve we pay)
            warmT = engT(freshT())
            gotT = np.asarray(merge_parts([warmT[f"{n}/u"] for n in names]))
            np.testing.assert_allclose(gotT, full_u, rtol=1e-5, atol=1e-6)
        # publish an apples-to-apples number: re-measure the winner
        # back-to-back with the untuned reference above (the tuner's own
        # medians come from a different cache/compile context), and if
        # the head-to-head says the default wins, the tuned choice IS
        # the default — the published row must never be the slower one.
        engT.stats.reset()
        tuned_meas = _time_engine(engT, None, 1, repeats, fresh=freshT)
        assert engT.stats.dispatches // repeats == 1, engT.stats.dispatches
        if tuned_meas["med_s"] <= linked["med_s"]:
            knobs = res.knobs_dict()
            tuned = dict(tuned_meas, note="knobs=" + res.best.knobs.label())
        else:
            knobs = Knobs().asdict()
            tuned = dict(linked, note="knobs=default_fallback")
        TUNED_KNOBS[f"faces_pipeline/linked_1q_n{n_parts}"] = knobs
        rows.append((f"linked_1q_n{n_parts}", tuned, 1))

    speedup = seq["avg_s"] / comp["avg_s"] if comp["avg_s"] else float("nan")
    linked2 = next(r for n, r, _ in rows if n == "linked_1q_n2")
    linked_speedup = (full["avg_s"] / linked2["avg_s"]
                      if linked2["avg_s"] else float("nan"))
    for name, r, disp in rows:
        derived = (f"dispatches_per_loop={disp};"
                   f"overlap_speedup={speedup:.3f};"
                   f"linked_vs_full={linked_speedup:.3f}")
        if r.get("note"):
            derived += f";{r['note']}"
        RESULTS.append({
            "bench": "faces_pipeline", "variant": name,
            "us_per_call": r["avg_s"] * 1e6,
            "median_ms": r["med_s"] * 1e3,
            "dispatches": disp,
            "derived": derived,
        })
        print(f"  pipe   {name:15s} avg={r['avg_s']*1e3:9.2f}ms "
              f"med={r['med_s']*1e3:9.2f}ms dispatch/loop={disp}")
    print(f"  overlap speedup (sequential/composed): {speedup:.3f}x "
          f"({inner} iterations, 2 half-grid queues); linked full-domain "
          f"solve vs single queue: {linked_speedup:.3f}x")
    return {"sequential_2q": seq, "composed_1q": comp, "full_domain_1q": full,
            "speedup": speedup, "linked_vs_full": linked_speedup}


def run_all():
    print("Faces microbenchmark (paper §V; 8 host devices)")
    for fn in (fig8, fig9, fig10, fig11, fig12, fig_persistent,
               fig_convergence, fig_pipeline):
        print(f"-- {fn.__name__}: {fn.__doc__.splitlines()[0]}")
        fn()
    return RESULTS
