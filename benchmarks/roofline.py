"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh).

Reads the dry-run artifacts (``artifacts/dryrun/*.json``) and derives:

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_wire_bytes / (ICI links × link bw)

``cost_analysis()`` on the partitioned module reports per-device FLOPs /
bytes.  Collective bytes are parsed from the optimized HLO
(hlo_analysis.py) — with one correction applied here: collectives inside
``while``-loop bodies (the scan over layers) appear ONCE in the text but
execute once per layer, so ops inside loop-body computations are scaled
by the layer trip count.  This is an estimate, cross-checked against the
analytic per-layer expectation in EXPERIMENTS.md §Roofline.

Also reports MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D
(inference) + the attention S² term, and the usefulness ratio
MODEL_FLOPS / (HLO_FLOPs × chips).

A second section (:func:`st_table`) covers the ST side: the analytic
schedule cost model (``repro.launch.costing.schedule_cost``) prices
every program in the benchmark registry and the rows pair each
prediction with the recorded ``BENCH_faces.json`` median it mirrors —
the printed rank agreement is the model's ongoing spot check.
"""

from __future__ import annotations

import glob
import json
import os
import re
import sys
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import SHAPES, get_config  # noqa: E402
from repro.launch.mesh import HBM_BW, ICI_BW, ICI_LINKS, PEAK_FLOPS_BF16  # noqa: E402
from repro.models.counting import model_flops, model_memory_bytes  # noqa: E402

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
COSTING = os.path.join(os.path.dirname(__file__), "..", "artifacts", "costing")
OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts", "roofline")


def _loop_scale(cfg, shape_kind: str) -> float:
    """Scan-over-layers trip count (collectives in the loop body execute
    this many times but appear once in the HLO text)."""
    n = cfg.n_layers + (cfg.n_enc_layers if cfg.enc_dec else 0)
    return float(max(n, 1))


def load_record(arch: str, shape: str, mesh: str) -> Optional[Dict]:
    path = os.path.join(ARTIFACTS, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(path):
        return None
    return json.load(open(path))


def roofline_row(rec: Dict, *, loop_scale_colls: bool = True) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    arch, shape_name = rec["arch"], rec["shape"]
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = rec["n_devices"]

    # prefer the calibrated (unrolled) costing artifact when available —
    # it has exact static costs (no scan-body undercount)
    cost_path = os.path.join(
        COSTING, f"{arch}__{shape_name}__{rec['mesh']}.json")
    source = "dryrun+loopscale"
    dot_flops = None
    if os.path.exists(cost_path):
        crec = json.load(open(cost_path))
        if crec.get("status") == "ok":
            flops_dev = crec["flops"]
            bytes_dev = crec["bytes"]
            coll_bytes = crec["coll_bytes"]
            coll_mix = crec.get("coll_by_kind", {})
            dot_flops = crec.get("dot_flops")
            source = crec.get("mode", "costing")
            return _row(rec, cfg, shape, chips, flops_dev, bytes_dev,
                        coll_bytes, coll_mix, dot_flops, source)

    flops_dev = rec["flops"]                      # per device
    bytes_dev = rec["bytes_accessed"]             # per device
    coll_bytes = rec["collectives"]["total_bytes"]
    if loop_scale_colls:
        coll_bytes = coll_bytes * _loop_scale(cfg, shape.kind)
        flops_dev = flops_dev * _loop_scale(cfg, shape.kind)
        bytes_dev = bytes_dev * _loop_scale(cfg, shape.kind)
    return _row(rec, cfg, shape, chips, flops_dev, bytes_dev, coll_bytes,
                rec["collectives"]["bytes_by_kind"], None, source)


def _row(rec, cfg, shape, chips, flops_dev, bytes_dev, coll_bytes,
         coll_mix, dot_flops, source):

    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory_ub = bytes_dev / HBM_BW          # HLO bytes: unfused UPPER bound
    mem_lb = model_memory_bytes(cfg, shape, chips=chips)
    t_memory = mem_lb / HBM_BW                # analytic fused LOWER bound
    t_coll = coll_bytes / (ICI_BW * ICI_LINKS)

    # dominance uses the fused (lower-bound) memory term: TPU fusion is
    # good, and the unfused bound would mark every row memory-bound.
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    useful_ratio = mf["model_flops"] / max(flops_dev * chips, 1.0)

    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips, "source": source,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_memory_ub_s": t_memory_ub,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf["model_flops"],
        "hlo_flops_total": flops_dev * chips,
        "dot_flops_dev": dot_flops,
        "useful_ratio": useful_ratio,
        "n_params": mf["n_params"], "n_active": mf["n_active"],
        "coll_bytes_dev": coll_bytes,
        "collective_mix": coll_mix,
        "temp_bytes_dev": rec.get("memory", {}).get("temp_size_in_bytes"),
        "arg_bytes_dev": rec.get("memory", {}).get("argument_size_in_bytes"),
    }


def build_table(mesh: str = "pod16x16") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, f"*__{mesh}.json"))):
        rec = json.load(open(path))
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": mesh, "skipped": rec["reason"]})
            continue
        row = roofline_row(rec)
        if row:
            rows.append(row)
    return rows


def st_table() -> List[Dict]:
    """Predicted-vs-measured rows for the ST program registry.

    Predictions come from the analytic schedule cost model
    (:func:`repro.launch.costing.schedule_cost`) walking each program
    in ``repro.analysis.programs``; measurements are the recorded
    medians in ``BENCH_faces.json`` for the benchmark row each registry
    entry mirrors (same engine/mode knobs, same iteration depth: every
    mapped median covers ``INNER`` solver iterations).  Rank agreement
    between the two orderings is the cost model's spot check — printed,
    never asserted (the model prices control structure, not this
    machine's cache behaviour).  Medians are only attached when the
    registry built the true benchmark grids (8 devices) at the recorded
    iteration depth; otherwise the rows carry predictions alone.
    """
    import jax

    from repro.analysis.programs import INNER, iter_programs
    from repro.launch.costing import schedule_cost

    # registry program -> (BENCH row, dispatch model, trigger mode, iters)
    bench_map = {
        "faces_fig8_1d": ("faces_fig8/st_offload", "fused", "stream", INNER),
        "faces_fig11_3d": ("faces_fig11/st_offload", "fused", "stream",
                           INNER),
        "faces_fig_persistent": ("faces_figP/persistent", "persistent",
                                 "dataflow", None),
        "faces_pipeline_halves": ("faces_pipeline/composed_1q", "persistent",
                                  "dataflow", None),
        "faces_pipeline_linked_n2": ("faces_pipeline/linked_1q_n2_untuned",
                                     "persistent", "dataflow", None),
        "faces_pipeline_linked_n4": ("faces_pipeline/linked_1q_n4_untuned",
                                     "persistent", "dataflow", None),
    }

    bench_path = os.path.join(os.path.dirname(__file__), "..",
                              "BENCH_faces.json")
    stored = json.load(open(bench_path)) if os.path.exists(bench_path) else {}
    meta = stored.get("_meta", {})
    comparable = (jax.device_count() >= 8
                  and meta.get("faces_inner") == INNER)

    progs = dict(iter_programs())
    rows = []
    for name, (key, engine, mode, iters) in bench_map.items():
        prog = progs.get(name)
        if prog is None:
            continue
        cost = schedule_cost(prog, engine=engine, mode=mode, n_iters=iters)
        measured = None
        if comparable and isinstance(stored.get(key), dict):
            measured = stored[key].get("median_ms")
        rows.append({
            "st_program": name, "bench_row": key, "engine": engine,
            "mode": mode, "predicted_us": cost.total_us,
            "measured_ms": measured,
            "n_collectives": cost.n_collectives,
            "n_elided": cost.n_elided,
        })
    return rows


def _rank_agreement(rows: List[Dict]):
    """Concordant predicted/measured orderings among comparable pairs."""
    both = [r for r in rows if r.get("measured_ms") is not None]
    pairs = concordant = 0
    for i in range(len(both)):
        for j in range(i + 1, len(both)):
            a, b = both[i], both[j]
            pairs += 1
            if ((a["predicted_us"] - b["predicted_us"])
                    * (a["measured_ms"] - b["measured_ms"])) > 0:
                concordant += 1
    return concordant, pairs


def print_st_table(rows: List[Dict], file=sys.stdout):
    hdr = (f"{'st program':28s} {'engine':>10s} {'predicted':>12s} "
           f"{'measured':>10s} {'colls':>6s} {'elided':>7s}")
    print(hdr, file=file)
    print("-" * len(hdr), file=file)
    for r in rows:
        meas = (f"{r['measured_ms']:.2f}ms" if r.get("measured_ms") is not None
                else "-")
        print(f"{r['st_program']:28s} {r['engine']:>10s} "
              f"{r['predicted_us']:>10.0f}us {meas:>10s} "
              f"{r['n_collectives']:>6d} {r['n_elided']:>7d}", file=file)
    concordant, pairs = _rank_agreement(rows)
    if pairs:
        print(f"rank agreement (predicted vs measured): "
              f"{concordant}/{pairs} concordant pairs", file=file)
        if concordant * 2 < pairs:
            # below coin-flip: the calibrated constants no longer rank
            # this machine's programs — a warning, never a failure (the
            # model prices control structure, not cache behaviour)
            print("WARNING cost-model drift: predicted ordering agrees "
                  "on fewer than half the measured pairs — re-fit the "
                  "CostParams constants with scripts/calibrate_cost.py "
                  "and update repro/launch/costing.py", file=file)
    else:
        print("rank agreement: no measured medians to compare "
              "(need 8 devices + a recorded BENCH_faces.json at "
              "matching settings)", file=file)


def _fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def print_table(rows: List[Dict], file=sys.stdout):
    hdr = (f"{'arch':20s} {'shape':12s} {'compute':>10s} {'mem_lb':>10s} "
           f"{'mem_ub':>10s} {'collect':>10s} {'dominant':>10s} {'useful':>7s}")
    print(hdr, file=file)
    print("-" * len(hdr), file=file)
    for r in rows:
        if "skipped" in r:
            print(f"{r['arch']:20s} {r['shape']:12s} {'SKIPPED: ' + r['skipped'][:60]}",
                  file=file)
            continue
        print(f"{r['arch']:20s} {r['shape']:12s} "
              f"{_fmt_s(r['t_compute_s']):>10s} {_fmt_s(r['t_memory_s']):>10s} "
              f"{_fmt_s(r.get('t_memory_ub_s')):>10s} "
              f"{_fmt_s(r['t_collective_s']):>10s} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.3f}", file=file)


def save(rows: List[Dict], mesh: str):
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, f"roofline_{mesh}.json"), "w") as f:
        json.dump(rows, f, indent=1)


def main(argv=None):
    mesh = "pod16x16"
    if argv and len(argv) > 1:
        mesh = argv[1]
    rows = build_table(mesh)
    print(f"\n=== Roofline table ({mesh}) — terms in seconds/step ===\n")
    print_table(rows)
    st = st_table()
    if st:
        print("\n=== ST schedule cost model — predicted vs measured ===\n")
        print_st_table(st)
        rows = rows + st  # ride along in the saved artifact + CSV
    save(rows, mesh)
    n_dom = {}
    for r in rows:
        if "dominant" in r:
            n_dom[r["dominant"]] = n_dom.get(r["dominant"], 0) + 1
    print(f"\nDominant-term counts: {n_dom}")
    return rows


if __name__ == "__main__":
    main(sys.argv)
