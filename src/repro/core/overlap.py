"""Decomposed, overlap-friendly collectives (beyond-paper §Perf lever).

The paper's §V-F result (hand-tuned shader triggers beat the stock
stream-memory ops by 8%) says: once control is on the device, *how* the
trigger/communication schedule is expressed decides the win.  The TPU
analogue: how a collective is *lowered* decides whether XLA can overlap
it with compute.  This module provides ppermute-decomposed collectives
whose per-step structure interleaves with per-chunk compute — the
"collective matmul" family (Wang et al.; used by MaxText et al.) —
expressed with the same trigger/tie primitives as the ST engines.

All functions are written for use **inside shard_map** over the given
axis name.

Provided:
* ``all_gather_ring``        — N-1 ppermute steps, uni/bidirectional;
* ``reduce_scatter_ring``    — ring reduce-scatter;
* ``all_gather_matmul``      — A[local] @ W, A gathered along the ring,
                               matmul chunks overlap the permutes;
* ``matmul_reduce_scatter``  — Y = X @ W with Y reduce-scattered,
                               chunk matmuls overlap the ring;
* ``all_to_all_ppermute``    — a2a as explicit ppermute rounds (MoE
                               dispatch building block).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size as _compat_axis_size

from . import counters


def _axis_size(axis) -> int:
    return _compat_axis_size(axis)


def _shift_perm(n: int, delta: int):
    return [(i, (i + delta) % n) for i in range(n)]


# --------------------------------------------------------------------------
# ring collectives
# --------------------------------------------------------------------------


def all_gather_ring(x: jax.Array, axis: str, *, bidirectional: bool = True,
                    tile_axis: int = 0) -> jax.Array:
    """All-gather `x` along `axis` via ring ppermutes (tiled layout).

    Bidirectional halves the number of serial steps (ceil((n-1)/2)) by
    sending both ways — the ICI-friendly schedule on a torus.
    """
    n = _axis_size(axis)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis)
    chunks = [None] * n
    chunks[0] = x

    if not bidirectional:
        cur = x
        for step in range(1, n):
            cur = jax.lax.ppermute(cur, axis, _shift_perm(n, 1))
            chunks[step] = cur
    else:
        fwd = x
        bwd = x
        steps_fwd = (n - 1 + 1) // 2
        steps_bwd = (n - 1) // 2
        for s in range(1, steps_fwd + 1):
            fwd = jax.lax.ppermute(fwd, axis, _shift_perm(n, 1))
            chunks[s] = fwd
        for s in range(1, steps_bwd + 1):
            bwd = jax.lax.ppermute(bwd, axis, _shift_perm(n, -1))
            chunks[n - s] = bwd

    # chunk i currently holds data of rank (idx - i); roll into global order
    stacked = jnp.stack(chunks, axis=0)  # [n, ...]
    order = (idx - jnp.arange(n)) % n
    gathered = jnp.zeros_like(stacked).at[order].set(stacked)
    parts = [gathered[i] for i in range(n)]
    return jnp.concatenate(parts, axis=tile_axis)


def reduce_scatter_ring(x: jax.Array, axis: str, *, tile_axis: int = 0) -> jax.Array:
    """Ring reduce-scatter of `x` (full-size input, 1/n-size output)."""
    n = _axis_size(axis)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis)
    size = x.shape[tile_axis]
    assert size % n == 0, "tile axis must divide by axis size"
    chunk = size // n

    xr = x.reshape(x.shape[:tile_axis] + (n, chunk) + x.shape[tile_axis + 1:])

    def own(i):
        return jnp.take(xr, i % n, axis=tile_axis)

    # classic ring: chunk k starts at rank k+1 and travels n-1 hops
    # (k+1 → … → k), accumulating each host's chunk-k on arrival; at
    # step s rank r therefore holds chunk (r-1-s), starting from (r-1).
    acc = own(idx - 1)
    for step in range(1, n):
        acc = jax.lax.ppermute(acc, axis, _shift_perm(n, 1))
        acc = acc + own(idx - 1 - step)
    return acc


# --------------------------------------------------------------------------
# overlapped compute-communication (collective matmul)
# --------------------------------------------------------------------------


def all_gather_matmul(x: jax.Array, w: jax.Array, axis: str,
                      *, transpose_w: bool = False) -> jax.Array:
    """Compute ``all_gather(x, axis) @ w`` with per-chunk overlap.

    ``x``: [m_local, k]; the gather is along rows (m).  ``w``: [k, n]
    (already local / replicated as the caller arranged).  Instead of
    gather-then-matmul (serializing all communication before any
    compute), each ring step's chunk multiplies while the next permute
    is in flight — on TPU, XLA schedules the ppermute DMA async.

    Returns [m_local * n_axis, n].
    """
    n_dev = _axis_size(axis)
    if transpose_w:
        w = w.T
    if n_dev == 1:
        return x @ w
    idx = jax.lax.axis_index(axis)
    m_local = x.shape[0]
    out = jnp.zeros((m_local * n_dev, w.shape[1]), dtype=jnp.result_type(x, w))

    cur = x
    for step in range(n_dev):
        # chunk owned by rank (idx - step); place at its global offset
        part = cur @ w
        src = (idx - step) % n_dev
        out = jax.lax.dynamic_update_slice_in_dim(out, part.astype(out.dtype),
                                                  src * m_local, axis=0)
        if step != n_dev - 1:
            cur = jax.lax.ppermute(cur, axis, _shift_perm(n_dev, 1))
    return out


def matmul_reduce_scatter(x: jax.Array, w: jax.Array, axis: str) -> jax.Array:
    """Compute ``reduce_scatter(x @ w, axis)`` with per-chunk overlap.

    ``x``: [m, k_local]; ``w``: [k_local, n].  The logical product
    ``x @ w`` needs a sum over the axis (k is sharded); the result rows
    are scattered so each rank keeps m/n_dev rows.  The ring interleaves
    chunk matmuls with the accumulating permute.

    Returns [m // n_axis, n].
    """
    n_dev = _axis_size(axis)
    y_local = x @ w  # [m, n] partial sum
    if n_dev == 1:
        return y_local
    idx = jax.lax.axis_index(axis)
    m = y_local.shape[0]
    assert m % n_dev == 0
    chunk = m // n_dev

    yr = y_local.reshape((n_dev, chunk) + y_local.shape[1:])

    def piece(i):
        return jnp.take(yr, i % n_dev, axis=0)

    # same ring schedule as reduce_scatter_ring: chunk (r-1-s) at step s
    acc = piece(idx - 1)
    for step in range(1, n_dev):
        acc = jax.lax.ppermute(acc, axis, _shift_perm(n_dev, 1))
        acc = acc + piece(idx - 1 - step)
    return acc


def all_to_all_ppermute(x: jax.Array, axis: str, *, split_axis: int = 0) -> jax.Array:
    """All-to-all as explicit ppermute rounds (MoE dispatch).

    ``x``'s `split_axis` is divided into n_dev blocks; block j goes to
    rank j.  Equivalent to ``jax.lax.all_to_all(tiled=True)`` but
    expressed as n-1 permutes the ST way (each round is one deferred
    descriptor batch).
    """
    n = _axis_size(axis)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis)
    size = x.shape[split_axis]
    assert size % n == 0
    blk = size // n
    xr = x.reshape(x.shape[:split_axis] + (n, blk) + x.shape[split_axis + 1:])
    move = jnp.moveaxis(xr, split_axis, 0)  # [n, ..., blk, ...]

    out = jnp.zeros_like(move)
    # my own block stays
    out = out.at[idx].set(jnp.take(move, idx, axis=0))
    for delta in range(1, n):
        # send the block destined for rank (idx+delta)
        send = jnp.take(move, (idx + delta) % n, axis=0)
        recv = jax.lax.ppermute(send, axis, _shift_perm(n, delta))
        out = out.at[(idx - delta) % n].set(recv)
    back = jnp.moveaxis(out, 0, split_axis)
    return back.reshape(x.shape)


# --------------------------------------------------------------------------
# ST-queue integration helpers
# --------------------------------------------------------------------------


def triggered(fn, token):
    """Wrap a decomposed collective so its operand ties to an ST trigger
    token — lets model code schedule these under an STQueue batch."""
    @functools.wraps(fn)
    def wrapped(x, *args, **kwargs):
        _, (x,) = counters.tie(token, x)
        return fn(x, *args, **kwargs)
    return wrapped
