"""Faces — the paper's microbenchmark pattern as an ST program.

Faces (paper §V-A) is the nearest-neighbor pattern of CORAL-2 Nekbone:
each rank owns a 3-D block of spectral-element data and exchanges the
**faces (6), edges (12) and corners (8)** of its block with up to 26
neighbors, then *adds* the received contributions into its own boundary
(direct-stiffness summation).  The timed inner loop is:

1. pre-post receives;            (enqueue_recv ×26)
2. pack boundary slabs;          (pack kernels — Pallas or jnp)
3. initiate sends;               (enqueue_send ×26 + one enqueue_start)
4. interior compute (overlap);   (enqueue_kernel)
5. wait for messages;            (enqueue_wait)
6. unpack-and-add.               (unpack kernels)

This module builds that inner loop as an :class:`STQueue` program over a
3-D device grid, with the paper's variants selectable:

* ``engine``: ``fused`` (ST — one dispatch) vs ``host`` (baseline —
  per-op dispatch + host sync; Fig. 1);
* ``granularity``: ``direct26`` (paper: one message per neighbor) or
  ``staged3`` (beyond-paper: three axis sweeps, 6 larger messages, with
  corner/edge data forwarded through already-updated ghosts);
* ``batched``: one ``start`` for all messages (paper's batching) or one
  ``start`` per message (models unbatched triggering);
* ``pack``: ``jnp`` slicing or the Pallas ``halo_pack`` kernel.

For the *timed loop around* the inner exchange there are three control
paths: per-op host dispatch (:mod:`.engine_host`), one dispatch per
iteration (:mod:`.engine_fused`), and — via
:func:`run_faces_persistent` / :mod:`.engine_persistent` — one dispatch
for the whole N-iteration loop, device-resident.  On top of that,
:func:`run_faces_pipelined` splits the domain into two half-grids on
the same mesh, gives each its own queue, and composes the two
persistent loops (:mod:`.schedule`) so they interleave in ONE dispatch
— each half may even terminate on its own convergence predicate.

A pure-NumPy oracle (`faces_oracle`) computes the same update globally
for correctness tests.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .descriptors import GridOffsetPeer
from .queue import STQueue, STProgram

AXES3 = ("gx", "gy", "gz")

# all 26 neighbor direction vectors, deterministic order: faces first,
# then edges, then corners (paper packs/sends in this order).
DIRECTIONS: Tuple[Tuple[int, int, int], ...] = tuple(
    sorted(
        (d for d in itertools.product((-1, 0, 1), repeat=3) if any(d)),
        key=lambda d: (sum(map(abs, d)), d),
    )
)
FACES = tuple(d for d in DIRECTIONS if sum(map(abs, d)) == 1)
EDGES = tuple(d for d in DIRECTIONS if sum(map(abs, d)) == 2)
CORNERS = tuple(d for d in DIRECTIONS if sum(map(abs, d)) == 3)


@dataclasses.dataclass(frozen=True)
class FacesConfig:
    grid: Tuple[int, int, int] = (2, 2, 2)   # device grid (gx, gy, gz)
    points: Tuple[int, int, int] = (16, 16, 16)  # local block points
    dtype: str = "float32"
    granularity: str = "direct26"  # direct26 | staged3
    batched: bool = True           # one start per batch of sends
    pack: str = "jnp"              # jnp | pallas
    periodic: bool = False
    interior_compute: bool = True  # include the overlap kernel (step 4)
    # Relaxation factor applied to the whole field at the end of every
    # iteration (0 → off).  With 0 < damping < ~0.3 the combined
    # smooth + boundary-sum + scale update is a contraction, so the
    # field norm decays geometrically — the substrate for the
    # convergence-terminated (until-residual<tol) persistent loop.
    damping: float = 0.0

    @property
    def n_ranks(self) -> int:
        gx, gy, gz = self.grid
        return gx * gy * gz

    @property
    def n_points(self) -> int:
        return self.n_ranks * int(np.prod(self.points))


def _slab_index(side: int, n: int) -> Tuple[slice, ...]:
    """Boundary slab index along one axis: -1 → first plane, +1 → last,
    0 → everything."""
    if side == -1:
        return slice(0, 1)
    if side == 1:
        return slice(n - 1, n)
    return slice(0, n)


def _region_for(direction: Tuple[int, int, int], points) -> Tuple[slice, ...]:
    return tuple(_slab_index(s, n) for s, n in zip(direction, points))


def _slab_shape(direction, points) -> Tuple[int, ...]:
    return tuple(1 if s else n for s, n in zip(direction, points))


def _make_pack_fn(region, pack_mode: str):
    if pack_mode == "pallas":
        from repro.kernels import ops as kops

        def pack(u):  # u local view: (1,1,1,px,py,pz)
            return kops.halo_pack(u[0, 0, 0], region)[None, None, None]
    else:
        def pack(u):
            return u[0, 0, 0][region][None, None, None]
    return pack


def _make_unpack_fn(region, pack_mode: str):
    if pack_mode == "pallas":
        from repro.kernels import ops as kops

        def unpack(u, msg):
            return kops.halo_unpack_add(u[0, 0, 0], msg[0, 0, 0], region)[None, None, None]
    else:
        def unpack(u, msg):
            core = u[0, 0, 0]
            core = core.at[region].add(msg[0, 0, 0])
            return core[None, None, None]
    return unpack


def _interior_fn(u):
    """Step-4 overlap kernel: a cheap local stencil on the interior."""
    core = u[0, 0, 0]
    smoothed = core + 0.125 * (
        jnp.roll(core, 1, 0) + jnp.roll(core, -1, 0)
        + jnp.roll(core, 1, 1) + jnp.roll(core, -1, 1)
        + jnp.roll(core, 1, 2) + jnp.roll(core, -1, 2)
        - 6.0 * core
    )
    return smoothed[None, None, None]


def build_faces_program(cfg: FacesConfig, mesh,
                        name: Optional[str] = None,
                        coalesce: bool = True) -> STProgram:
    """Build the Faces inner-loop as an ST program on a (gx,gy,gz) mesh.

    ``name`` sets the program name (defaults to ``faces_{granularity}``)
    — composed programs (:func:`repro.core.schedule.compose`) need
    distinct names, since the name is the buffer namespace.

    With ``coalesce`` (default) the 26 direct26 messages are grouped at
    build time into ≤6 fused by-axis transfers — the paper's contiguous
    MPI buffer (§V-A) — with bit-identical results; pass ``False`` for
    the one-collective-per-neighbor lowering (A/B benchmarks).
    """
    gx, gy, gz = cfg.grid
    px, py, pz = cfg.points
    dtype = np.dtype(cfg.dtype)
    q = STQueue(mesh, name="faces")

    gshape = (gx, gy, gz, px, py, pz)
    q.buffer("u", gshape, dtype, pspec=AXES3)

    dirs = DIRECTIONS if cfg.granularity == "direct26" else FACES
    msg_in, msg_out = {}, {}
    for i, d in enumerate(dirs):
        sshape = _slab_shape(d, cfg.points)
        msg_out[d] = q.buffer(f"out{i}", (gx, gy, gz, *sshape), dtype, pspec=AXES3)
        msg_in[d] = q.buffer(f"in{i}", (gx, gy, gz, *sshape), dtype, pspec=AXES3)

    if cfg.granularity == "direct26":
        _emit_direct26(q, cfg, msg_in, msg_out)
    elif cfg.granularity == "staged3":
        _emit_staged3(q, cfg, msg_in, msg_out)
    else:
        raise ValueError(cfg.granularity)

    return q.build(name=name or f"faces_{cfg.granularity}", coalesce=coalesce)


def _emit_direct26(q: STQueue, cfg: FacesConfig, msg_in, msg_out):
    dirs = DIRECTIONS
    # 2. pack kernels (paper step 2; packs precede sends in stream order)
    for i, d in enumerate(dirs):
        region = _region_for(d, cfg.points)
        q.enqueue_kernel(_make_pack_fn(region, cfg.pack), ["u"], [msg_out[d]],
                         name=f"pack{i}")
    if cfg.batched:
        # 1+3. pre-post all receives, then all sends, one trigger for the
        # whole batch (the paper's batching semantics — one writeValue).
        for i, d in enumerate(dirs):
            peer = GridOffsetPeer(AXES3, tuple(-x for x in d), cfg.periodic)
            q.enqueue_recv(msg_in[d], peer, tag=i)
        for i, d in enumerate(dirs):
            q.enqueue_send(msg_out[d], GridOffsetPeer(AXES3, d, cfg.periodic), tag=i)
        q.enqueue_start()
    else:
        # unbatched: one writeValue (start) per message
        for i, d in enumerate(dirs):
            peer = GridOffsetPeer(AXES3, tuple(-x for x in d), cfg.periodic)
            q.enqueue_recv(msg_in[d], peer, tag=i)
            q.enqueue_send(msg_out[d], GridOffsetPeer(AXES3, d, cfg.periodic), tag=i)
            q.enqueue_start()
    # 4. interior compute overlapping communication (paper step 4)
    if cfg.interior_compute:
        q.enqueue_kernel(_interior_fn, ["u"], ["u"], name="interior")
    # 5. wait (paper step 5)
    q.enqueue_wait()
    # 6. unpack-and-add (paper step 6)
    for i, d in enumerate(dirs):
        region = _region_for(tuple(-x for x in d), cfg.points)
        q.enqueue_kernel(_make_unpack_fn(region, cfg.pack),
                         ["u", msg_in[d]], ["u"], name=f"unpack{i}")
    _emit_damping(q, cfg)


def _emit_staged3(q: STQueue, cfg: FacesConfig, msg_in, msg_out):
    """Beyond-paper: three axis sweeps.  Each sweep exchanges the two
    faces along one axis; because each sweep reads the ghost-updated
    block, edge and corner contributions propagate through the stages
    (standard staged halo).  6 messages instead of 26."""
    for stage, axis in enumerate((0, 1, 2)):
        dirs = [d for d in FACES if d[axis] != 0]
        for d in dirs:
            i = FACES.index(d)
            peer = GridOffsetPeer(AXES3, tuple(-x for x in d), cfg.periodic)
            q.enqueue_recv(msg_in[d], peer, tag=100 * stage + i)
        for d in dirs:
            i = FACES.index(d)
            region = _region_for(d, cfg.points)
            q.enqueue_kernel(_make_pack_fn(region, cfg.pack), ["u"], [msg_out[d]],
                             name=f"pack_s{stage}_{i}")
        for d in dirs:
            i = FACES.index(d)
            q.enqueue_send(msg_out[d], GridOffsetPeer(AXES3, d, cfg.periodic),
                           tag=100 * stage + i)
        q.enqueue_start()
        if cfg.interior_compute and stage == 0:
            q.enqueue_kernel(_interior_fn, ["u"], ["u"], name="interior")
        q.enqueue_wait()
        for d in dirs:
            region = _region_for(tuple(-x for x in d), cfg.points)
            q.enqueue_kernel(_make_unpack_fn(region, cfg.pack),
                             ["u", msg_in[d]], ["u"], name=f"unpack_s{stage}")
    _emit_damping(q, cfg)


def _emit_damping(q: STQueue, cfg: FacesConfig):
    """End-of-iteration relaxation kernel (only when cfg.damping is on)."""
    if cfg.damping:
        scale = float(cfg.damping)
        q.enqueue_kernel(lambda u: u * scale, ["u"], ["u"], name="damp")


# --------------------------------------------------------------------------
# persistent (device-resident) timed loop
# --------------------------------------------------------------------------


def global_residual_fn(cfg: FacesConfig, buf: str = "u"):
    """Build a ``reduce_fn(mem) -> scalar`` computing the *global* RMS
    norm of ``buf``: local sum of squares, ``lax.psum`` over the mesh
    axes, normalized by the global point count.  Runs inside the
    device-resident loop — the convergence residual with no host sync.
    """
    n_total = float(cfg.n_points)

    def residual(mem):
        local = jnp.sum(jnp.square(mem[buf].astype(jnp.float32)))
        return jnp.sqrt(jax.lax.psum(local, AXES3) / n_total)

    return residual


def run_faces_until_converged(cfg: FacesConfig, mesh, u0, tol: float,
                              max_iters: int, mode: str = "dataflow",
                              double_buffer: Optional[bool] = None,
                              donate: bool = True):
    """Iterate Faces until the global residual drops below ``tol`` —
    with the *device* deciding when to stop (ONE host dispatch).

    The termination predicate ``residual >= tol`` and the residual
    reduction both run inside the persistent engine's ``while_loop``;
    the host sees nothing until the converged field, the residual trace
    and the realized iteration count come back together.

    Returns ``(mem, residuals, n_done, stats)``: final buffers, the
    residual trace trimmed to the realized length, the realized
    iteration count, and the engine stats (``stats.dispatches == 1``).
    """
    from .engine_persistent import PersistentEngine

    prog = build_faces_program(cfg, mesh).persistent(
        max_iters, until=lambda r: r >= tol)
    eng = PersistentEngine(prog, mode=mode, double_buffer=double_buffer,
                           reduce_fn=global_residual_fn(cfg), donate=donate)
    mem, residuals, n_done = eng(eng.init_buffers({"u": u0}))
    n_done = int(n_done)
    return mem, np.asarray(residuals)[:n_done], n_done, eng.stats


def run_faces_persistent(cfg: FacesConfig, mesh, u0, n_iters: int,
                         mode: str = "dataflow", reduce_fn=None,
                         double_buffer: Optional[bool] = None,
                         donate: bool = True):
    """Run ``n_iters`` Faces iterations as ONE host dispatch.

    Builds the inner-loop ST program, marks it persistent, and executes
    it with :class:`~repro.core.engine_persistent.PersistentEngine` —
    the fully offloaded variant of the paper's timed loop (the host
    enqueues once; the device sequencer re-runs pack → trigger →
    exchange → wait → unpack N times).

    Returns ``(mem, stats)`` — final buffers and the engine's
    dispatch-counting stats (``stats.dispatches == 1`` however large
    ``n_iters`` is).  With ``reduce_fn`` set, returns
    ``((mem, reductions), stats)`` exactly as the engine does.
    """
    from .engine_persistent import PersistentEngine

    prog = build_faces_program(cfg, mesh).persistent(n_iters)
    eng = PersistentEngine(prog, mode=mode, reduce_fn=reduce_fn,
                           double_buffer=double_buffer, donate=donate)
    out = eng(eng.init_buffers({"u": u0}))
    return out, eng.stats


# --------------------------------------------------------------------------
# pipelined multi-queue loop (two half-grids, one dispatch)
# --------------------------------------------------------------------------


def half_config(cfg: FacesConfig) -> FacesConfig:
    """The per-half FacesConfig of an x-split domain (same device grid)."""
    px, py, pz = cfg.points
    if px % 2:
        raise ValueError(f"points[0]={px} must be even to split the domain")
    return dataclasses.replace(cfg, points=(px // 2, py, pz))


def split_halves(u0):
    """Split a (gx,gy,gz,px,py,pz) field into two x-halves."""
    px = u0.shape[3]
    if px % 2:
        raise ValueError(f"points[0]={px} must be even to split the domain")
    return u0[:, :, :, : px // 2], u0[:, :, :, px // 2:]


def merge_halves(ua, ub):
    """Inverse of :func:`split_halves`."""
    return jnp.concatenate([jnp.asarray(ua), jnp.asarray(ub)], axis=3)


PIPELINE_NAMES = ("facesA", "facesB")


def run_faces_pipelined(cfg: FacesConfig, mesh, u0, *,
                        n_iters: Optional[int] = None,
                        tols: Optional[Tuple[float, float]] = None,
                        max_iters: Optional[int] = None,
                        mode: str = "dataflow",
                        double_buffer: Optional[bool] = None,
                        donate: bool = True):
    """Two half-grid Faces queues, composed, iterated in ONE dispatch.

    The domain is split into two x-halves on the *same* mesh; each half
    gets its own STQueue program, and
    :func:`repro.core.schedule.compose` fuses them so half B's packs and
    interior compute interleave with half A's trigger→wait window — the
    pipelined multi-queue schedule, with the whole loop device-resident.

    Two regimes:

    * ``n_iters=N`` — both halves run exactly N iterations (uniform
      fixed loop).  Returns ``(mem, stats)``; the halves live at
      ``mem["facesA/u"]`` / ``mem["facesB/u"]`` (see
      :func:`merge_halves`).
    * ``tols=(tolA, tolB)`` + ``max_iters`` — each half runs until its
      OWN global residual drops below its own tolerance (device-decided,
      per-program predicates).  Returns
      ``(mem, residuals, n_done, stats)`` with ``residuals[name]``
      trimmed to the realized length and ``n_done[name]`` ints — the
      bit-exact union of two independent
      :func:`run_faces_until_converged` runs, still ONE dispatch.
    """
    from .engine_persistent import PersistentEngine
    from .schedule import compose

    if (n_iters is None) == (tols is None):
        raise ValueError("pass exactly one of n_iters= or tols=")
    cfgh = half_config(cfg)
    ua, ub = split_halves(np.asarray(u0))
    na, nb = PIPELINE_NAMES

    if tols is None:
        progs = [build_faces_program(cfgh, mesh, name=nm).persistent(n_iters)
                 for nm in (na, nb)]
        sched = compose(*progs)
        eng = PersistentEngine(sched, mode=mode, double_buffer=double_buffer,
                               donate=donate)
        mem = eng(eng.init_buffers({f"{na}/u": ua, f"{nb}/u": ub}))
        return mem, eng.stats

    if max_iters is None:
        raise ValueError("tols= requires max_iters=")
    if len(tols) != 2:
        raise ValueError(f"tols needs one tolerance per half, got {tols!r}")
    progs = [
        build_faces_program(cfgh, mesh, name=nm).persistent(
            max_iters, until=lambda r, tol=tol: r >= tol)
        for nm, tol in zip((na, nb), tols)
    ]
    sched = compose(*progs)
    eng = PersistentEngine(
        sched, mode=mode, double_buffer=double_buffer, donate=donate,
        reduce_fns={nm: global_residual_fn(cfgh, buf=f"{nm}/u")
                    for nm in (na, nb)})
    mem, reds, n_done = eng(eng.init_buffers({f"{na}/u": ua, f"{nb}/u": ub}))
    n_done = {nm: int(v) for nm, v in n_done.items()}
    reds = {nm: np.asarray(r)[: n_done[nm]] for nm, r in reds.items()}
    return mem, reds, n_done, eng.stats


# --------------------------------------------------------------------------
# NumPy oracle
# --------------------------------------------------------------------------


def faces_oracle(u: np.ndarray, cfg: FacesConfig) -> np.ndarray:
    """Reference update for one inner iteration, computed globally.

    ``u`` has shape (gx, gy, gz, px, py, pz).  Mirrors `direct26`
    semantics: interior stencil (if enabled) then the 26-direction
    boundary-sum, using the *pre-exchange* packed values (all packs
    happen before the interior kernel in stream order).
    """
    u = np.asarray(u, dtype=np.dtype(cfg.dtype))
    gx, gy, gz = cfg.grid
    out = u.copy()

    # packed messages are extracted from the original field
    packed = {
        d: u[(slice(None),) * 3 + _region_for(d, cfg.points)].copy()
        for d in DIRECTIONS
    }

    if cfg.interior_compute:
        core = out
        sm = core.copy()
        for ax in (3, 4, 5):
            sm += 0.125 * (np.roll(core, 1, ax) + np.roll(core, -1, ax))
        sm -= 0.125 * 6.0 * core
        out = sm

    for d in DIRECTIONS:
        # contribution sent by neighbor at -d arrives at my -d... each
        # rank r receives, from neighbor r - d, that neighbor's +d face,
        # deposited into r's -d region.  Global shift of packed slabs:
        msg = packed[d]
        shifted = np.zeros_like(msg)
        src = [slice(None)] * 6
        dst = [slice(None)] * 6
        ok = True
        for ax, delta, n in zip(range(3), d, (gx, gy, gz)):
            if delta == 0:
                continue
            if cfg.periodic:
                shifted_axis = None  # handled below with np.roll
            else:
                if delta > 0:
                    src[ax] = slice(0, n - delta)
                    dst[ax] = slice(delta, n)
                else:
                    src[ax] = slice(-delta, n)
                    dst[ax] = slice(0, n + delta)
        if cfg.periodic:
            shifted = np.roll(msg, shift=d, axis=(0, 1, 2))
        else:
            shifted[tuple(dst)] = msg[tuple(src)]
        region = _region_for(tuple(-x for x in d), cfg.points)
        out[(slice(None),) * 3 + region] += shifted
    if cfg.damping:
        out *= np.asarray(cfg.damping, dtype=out.dtype)
    return out
