"""Registry of every ST program the shipped benchmarks build.

Mirrors the builds in ``benchmarks/faces_bench.py`` (figs 8-12 grids,
the persistent variant, the composed half-grid pipeline, the linked
N-part full-domain solves) and ``benchmarks/serve_bench.py`` (the
prefill+decode admission schedule via
:func:`repro.launch.serve.build_admission_schedule`) — build only, no
execution, so linting the whole fleet takes seconds.

Benchmark grids assume 8 host devices (``benchmarks/run.py`` forces
them); when fewer are available the grids scale down to ``(1, 1, 1)``
so the same registry drives the fast-lane test sweep on the single real
CPU device.  Program *structure* (batches, counters, links, plans) is
what the verifier walks, and every structural rule still gets exercised
at the reduced grid.
"""

from __future__ import annotations

import warnings
from typing import Iterator, List, Optional, Tuple

#: benchmark point counts / persistent iteration depth (faces_bench)
POINTS = (12, 12, 12)
INNER = 10


def _scale(grid: Tuple[int, int, int], device_count: int):
    need = grid[0] * grid[1] * grid[2]
    return grid if device_count >= need else (1, 1, 1)


def iter_programs(device_count: Optional[int] = None) -> Iterator[Tuple[str, object]]:
    """Yield ``(name, program)`` for every benchmark-built ST program."""
    import jax

    from repro.core import (
        FacesConfig,
        STLintWarning,
        build_faces_part_program,
        build_faces_program,
        compose,
        half_config,
        part_names,
    )
    from repro.launch.serve import build_admission_schedule
    from repro.parallel import make_mesh

    if device_count is None:
        device_count = jax.device_count()

    with warnings.catch_warnings():
        # builds run with verify="off"/suppressed warnings: the CLI and
        # the test sweep collect diagnostics explicitly via
        # verify_program so a dirty program is REPORTED, not raised
        # mid-registry (one bad build must not hide the rest)
        warnings.simplefilter("ignore", STLintWarning)

        grid = _scale((8, 1, 1), device_count)
        mesh1d = make_mesh(grid, ("gx", "gy", "gz"))
        cfg1d = FacesConfig(grid=grid, points=POINTS)
        yield "faces_fig8_1d", build_faces_program(cfg1d, mesh1d)

        grid = _scale((2, 2, 2), device_count)
        mesh3d = make_mesh(grid, ("gx", "gy", "gz"))
        cfg3d = FacesConfig(grid=grid, points=POINTS)
        yield "faces_fig11_3d", build_faces_program(cfg3d, mesh3d)
        yield ("faces_fig_persistent",
               build_faces_program(cfg3d, mesh3d).persistent(INNER))

        cfgh = half_config(cfg3d)
        progA = build_faces_program(cfgh, mesh3d, name="facesA").persistent(INNER)
        progB = build_faces_program(cfgh, mesh3d, name="facesB").persistent(INNER)
        yield "faces_pipeline_halves", compose(progA, progB, verify="off")

        for n_parts in (2, 4):
            names = part_names(n_parts)
            progs = [
                build_faces_part_program(cfg3d, mesh3d, k, n_parts,
                                         names=names).persistent(INNER)
                for k in range(n_parts)
            ]
            yield (f"faces_pipeline_linked_n{n_parts}",
                   compose(*progs, verify="off"))

        serve_mesh = make_mesh((device_count,), ("x",))
        yield "serve_admission", build_admission_schedule(serve_mesh,
                                                          verify="off")

        # ST collective-matmul programs (overlap_bench's ST section):
        # ring size = the device axis, scaled down with the host grid
        from repro.core import collectives
        n = min(device_count, 4)
        cmesh = make_mesh((n,), ("x",))
        m, k, f = 8 * n * n, 4 * n, 4 * n
        yield ("overlap_ag_matmul",
               collectives.build_all_gather_matmul(
                   cmesh, "x", m, k, f, verify="off").program)
        yield ("overlap_matmul_rs",
               collectives.build_matmul_reduce_scatter(
                   cmesh, "x", m, k, f, verify="off").program)
        yield ("overlap_a2a",
               collectives.build_all_to_all(
                   cmesh, "x", m, k, verify="off").program)
        yield ("overlap_tp_chain",
               collectives.build_tp_block(
                   cmesh, "x", m, k, f, chain=True,
                   verify="off").program.persistent(INNER))


def lint_all(device_count: Optional[int] = None) -> List[Tuple[str, list]]:
    """Lint every registry program; return ``[(name, diagnostics)]``."""
    from repro.core import verify_program

    return [(name, verify_program(prog))
            for name, prog in iter_programs(device_count)]


def certificates(device_count: Optional[int] = None) -> List[Tuple[str, object]]:
    """Issue a :class:`~repro.core.effects.ProgramCertificate` for every
    registry program: ``[(name, certificate)]`` with the program's effect
    digest and its race-free verdict under the happens-before rules
    (ST015–ST018) — i.e. race-free under ANY interleave policy, not just
    the emitted stream order.  ``python -m repro.analysis --strict``
    prints this table.
    """
    from repro.core.effects import program_certificate

    return [(name, program_certificate(prog))
            for name, prog in iter_programs(device_count)]
