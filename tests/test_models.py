"""Per-architecture smoke tests (reduced same-family configs, CPU).

For every assigned arch: instantiate the REDUCED variant (≤2 layers,
d_model ≤ 512, ≤4 experts), run one forward + one train step, assert
output shapes and no NaNs; check decode-vs-forward consistency.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model

B, S = 2, 16


def make_batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32),
        "targets": jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.enc_dec:
        batch["audio_embeds"] = jnp.asarray(
            rng.randn(B, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jnp.asarray(
            rng.randn(B, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def rng():
    return np.random.RandomState(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch, rng):
    cfg = get_config(arch).smoke()
    m = Model(cfg)
    params, axes = m.init(jax.random.PRNGKey(0))
    # axes tree aligns with params tree
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    batch = make_batch(cfg, rng)
    logits = m.forward_logits(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_finite(arch, rng):
    cfg = get_config(arch).smoke()
    m = Model(cfg)
    params, _ = m.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg, rng)

    def loss_fn(p):
        return m.loss(p, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_matches_forward_last_logits(arch, rng):
    cfg = get_config(arch).smoke()
    m = Model(cfg)
    params, _ = m.init(jax.random.PRNGKey(2))
    batch = make_batch(cfg, rng)
    full = m.forward_logits(params, batch)
    caches = m.init_caches(B, S + 2 + m._prefix_len())
    pre, caches = m.prefill(params, batch, caches)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma3-1b", "mamba2-2.7b",
                                  "hymba-1.5b", "deepseek-v3-671b"])
def test_decode_matches_forward(arch, rng):
    """Prefill(S) + decode(1) logits == forward(S+1) last logits."""
    cfg = get_config(arch).smoke()
    m = Model(cfg)
    params, _ = m.init(jax.random.PRNGKey(3))
    batch = make_batch(cfg, rng)
    caches = m.init_caches(B, S + 4 + m._prefix_len())
    pre, caches = m.prefill(params, batch, caches)
    nxt = jnp.asarray(rng.randint(0, cfg.vocab, (B,)), jnp.int32)
    dec, caches = m.decode_step(params, caches, nxt)

    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nxt[:, None]], axis=1)
    full = m.forward_logits(params, batch2)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               rtol=5e-3, atol=5e-3)


def test_gemma3_local_global_pattern():
    from repro.models.transformer import layer_window_theta
    cfg = get_config("gemma3-1b")
    wins = [layer_window_theta(cfg, i)[0] for i in range(cfg.n_layers)]
    thetas = [layer_window_theta(cfg, i)[1] for i in range(cfg.n_layers)]
    # every 6th layer is global (window 0, theta 1M)
    for i in range(cfg.n_layers):
        if (i + 1) % 6 == 0:
            assert wins[i] == 0 and thetas[i] == 1_000_000.0
        else:
            assert wins[i] == 512 and thetas[i] == 10_000.0


def test_serve_window_caps_global_layers():
    from repro.models.transformer import layer_window_theta
    cfg = get_config("glm4-9b")
    w, _ = layer_window_theta(cfg, 0, serve_window=8192)
    assert w == 8192


def test_moe_capacity_drops_are_bounded():
    from repro.models.moe import apply_moe
    cfg = get_config("deepseek-v3-671b").smoke()
    m = Model(cfg)
    params, _ = m.init(jax.random.PRNGKey(4))
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(2, 8, cfg.d_model), jnp.float32)
    moe_p = params["decoder"]["segments"][1][0]["moe"]
    y, aux = apply_moe(moe_p, x, cfg)
    assert y.shape == x.shape
    assert float(aux["dropped_frac"]) <= 0.5


def test_moe_equals_dense_mixture_when_capacity_ample():
    """With capacity ≥ T·k the sort-based dispatch must equal the dense
    weighted mixture (no drops)."""
    from repro.models import moe as moe_lib
    cfg = get_config("grok-1-314b").smoke()
    m = Model(cfg)
    params, _ = m.init(jax.random.PRNGKey(6))
    p = params["decoder"]["segments"][0][0]["moe"]
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.randn(1, 6, cfg.d_model), jnp.float32)
    T = 6
    y, _ = moe_lib.apply_moe(p, x, cfg, capacity=T * cfg.top_k)

    # dense oracle: every expert computed for every token
    idx, w, _ = moe_lib._route(p, x.reshape(T, -1), cfg)
    outs = []
    for e in range(cfg.n_experts):
        xe = x.reshape(T, -1)[None]  # [1, T, D] as capacity buffer
        h = jnp.einsum("td,df->tf", x.reshape(T, -1), p["wi"][e])
        g = jnp.einsum("td,df->tf", x.reshape(T, -1), p["wg"][e]) if "wg" in p else None
        h = jax.nn.silu(g) * h if g is not None else jax.nn.gelu(h)
        outs.append(jnp.einsum("tf,fd->td", h, p["wo"][e]))
    dense = jnp.stack(outs, 1)  # [T, E, D]
    want = jnp.zeros_like(x.reshape(T, -1))
    for kk in range(cfg.top_k):
        sel = jnp.take_along_axis(dense, idx[:, kk][:, None, None], axis=1)[:, 0]
        want = want + w[:, kk][:, None] * sel
    np.testing.assert_allclose(np.asarray(y.reshape(T, -1)), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
