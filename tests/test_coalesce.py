"""Channel coalescing — fused by-axis transfers (paper §V-A contiguous
MPI buffer) must be a pure lowering optimization.

Fast lane (single device): plan structure (26 → ≤6 collectives per
start gate for direct26, recorded as :class:`CoalescedChannel`
descriptors), bit-identical execution coalesced vs uncoalesced across
modes/granularities/engines, a hypothesis property test over random
channel sets, plan recomputation under composition (never merging
channels across pids), buffer-donation semantics, and the Pallas
segment pack/unpack kernels.

Slow lane: the same bit-identity on a real 2×2×2 8-device grid where
the fused transfers actually move data between shards (subprocess, like
tests/test_distributed.py).
"""

import numpy as np
import pytest

from repro.core import (
    FacesConfig,
    FusedEngine,
    HostEngine,
    OffsetPeer,
    GridOffsetPeer,
    PersistentEngine,
    STQueue,
    build_faces_program,
    compose,
)
from repro.core.halo import AXES3
from repro.core.matching import CoalescePlan, coalesce_batch


def _mesh111():
    from repro.parallel import make_mesh
    return make_mesh((1, 1, 1), AXES3)


def _mesh11():
    from repro.parallel import make_mesh
    return make_mesh((1, 1), ("x", "y"))


def _u0(cfg, seed=0):
    rng = np.random.RandomState(seed)
    return rng.randn(*cfg.grid, *cfg.points).astype(np.float32)


def _assert_mem_bitidentical(a, b, ctx=""):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"{ctx}: buffer {k!r}")


# -- plan structure -----------------------------------------------------------


class TestPlanStructure:
    def test_direct26_plan_has_at_most_6_collectives_per_start(self):
        """The acceptance contract: 26 messages/gate lower to ≤6 fused
        by-axis transfers, asserted off the recorded plan."""
        cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True)
        prog = build_faces_program(cfg, _mesh111())
        for b in prog.batches:
            assert isinstance(b.plan, CoalescePlan)
            assert len(b.plan.transfers) <= 6
        un, low = prog.max_collectives_per_start()
        assert (un, low) == (26, 6)
        assert prog.is_coalesced

    def test_plan_members_partition_the_channels(self):
        cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True)
        prog = build_faces_program(cfg, _mesh111())
        (b,) = prog.batches
        # final hops cover every channel exactly once
        finals = [route[-1] for route in b.plan.routes]
        assert len(finals) == len(b.channels) == 26
        # each transfer's members reference valid channels, and every
        # channel appears in at least one transfer (its first hop)
        first_hops = {s.channel for t in b.plan.transfers
                      for s in t.segments if s.hop == 0}
        assert first_hops == set(range(26))
        # segment offsets tile each staging buffer exactly
        for t in b.plan.transfers:
            off = 0
            for s in t.segments:
                assert s.offset == off
                off += s.size
            assert off == t.size

    def test_staged3_plans_stay_by_axis(self):
        """staged3 already sends by-axis faces: 2 transfers per gate
        (one per direction — ppermute cannot merge opposite shifts)."""
        cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True,
                          granularity="staged3")
        prog = build_faces_program(cfg, _mesh111())
        assert prog.n_batches == 3
        for b in prog.batches:
            assert len(b.plan.transfers) == 2
        assert prog.max_collectives_per_start() == (2, 2)

    def test_coalesce_false_records_no_plan(self):
        cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True)
        prog = build_faces_program(cfg, _mesh111(), coalesce=False)
        assert all(b.plan is None for b in prog.batches)
        assert not prog.is_coalesced
        assert prog.max_collectives_per_start() == (26, 26)

    def test_build_cache_distinguishes_coalesce_flag(self):
        cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True)
        mesh = _mesh111()
        q = STQueue(mesh, name="c")
        q.buffer("a", (4, 4), np.float32, pspec=("gx",))
        q.buffer("b", (4, 4), np.float32, pspec=("gx",))
        q.enqueue_recv("b", OffsetPeer("gx", -1, periodic=True), tag=0)
        q.enqueue_send("a", OffsetPeer("gx", 1, periodic=True), tag=0)
        q.enqueue_start()
        q.enqueue_wait()
        p1 = q.build()
        p2 = q.build(coalesce=False)
        assert p1.is_coalesced and not p2.is_coalesced
        assert q.build() is not p2  # toggling back rebuilds, not stale

    def test_dead_channels_are_pruned_from_transfers(self):
        """A 1-D device grid kills 24 of the 26 directions (no pairs on
        the collapsed axes): they must ride no transfer at all — the
        fig10 regime, where coalescing must not add packing work."""
        cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=False)
        prog = build_faces_program(cfg, _mesh111())
        (b,) = prog.batches
        # non-periodic size-1 axes: every direction is dead
        assert len(b.plan.dead_channels) == 26
        assert b.plan.transfers == ()
        assert prog.max_collectives_per_start() == (26, 0)
        # and execution still matches the uncoalesced interpreter
        u0 = _u0(cfg)
        on = FusedEngine(prog, mode="dataflow")
        off = FusedEngine(prog, mode="dataflow", coalesce=False)
        _assert_mem_bitidentical(on(on.init_buffers({"u": u0})),
                                 off(off.init_buffers({"u": u0})),
                                 ctx="dead-pruned")

    def test_aliased_src_dst_batches_refuse_coalescing(self):
        """A channel sending from a buffer another channel deposits into
        must keep the sequential per-channel path (deposit visibility)."""
        mesh = _mesh11()
        q = STQueue(mesh, name="alias")
        q.buffer("a", (4,), np.float32)
        q.buffer("b", (4,), np.float32)
        q.enqueue_recv("b", OffsetPeer("x", -1, periodic=True), tag=0)
        q.enqueue_send("a", OffsetPeer("x", 1, periodic=True), tag=0)
        # second channel sends from "b" — the first channel's dst
        q.enqueue_recv("a", OffsetPeer("x", -1, periodic=True), tag=1)
        q.enqueue_send("b", OffsetPeer("x", 1, periodic=True), tag=1)
        q.enqueue_start()
        q.enqueue_wait()
        prog = q.build()
        assert all(b.plan is None for b in prog.batches)


# -- bit-identity: fused engine ----------------------------------------------


@pytest.mark.parametrize("mode", ["stream", "dataflow"])
@pytest.mark.parametrize("granularity", ["direct26", "staged3"])
@pytest.mark.parametrize("batched", [True, False])
def test_fused_coalesced_bitidentical_1dev(mode, granularity, batched):
    cfg = FacesConfig(grid=(1, 1, 1), points=(4, 3, 5), periodic=True,
                      granularity=granularity, batched=batched)
    prog = build_faces_program(cfg, _mesh111())
    u0 = _u0(cfg)
    on = FusedEngine(prog, mode=mode)
    off = FusedEngine(prog, mode=mode, coalesce=False)
    _assert_mem_bitidentical(on(on.init_buffers({"u": u0})),
                             off(off.init_buffers({"u": u0})),
                             ctx=f"{mode}/{granularity}")


@pytest.mark.parametrize("mode", ["stream", "dataflow"])
def test_persistent_coalesced_bitidentical_1dev(mode):
    n = 4
    cfg = FacesConfig(grid=(1, 1, 1), points=(4, 3, 5), periodic=True)
    prog = build_faces_program(cfg, _mesh111()).persistent(n)
    u0 = _u0(cfg)
    on = PersistentEngine(prog, mode=mode)
    off = PersistentEngine(prog, mode=mode, coalesce=False)
    _assert_mem_bitidentical(on(on.init_buffers({"u": u0})),
                             off(off.init_buffers({"u": u0})),
                             ctx=f"persistent/{mode}")
    # and the device-resident loop still matches the host baseline
    host = HostEngine(prog)
    hmem = host.init_buffers({"u": u0})
    for _ in range(n):
        hmem = host(hmem)
    out = on(on.init_buffers({"u": u0}))
    np.testing.assert_allclose(np.asarray(out["u"]), np.asarray(hmem["u"]),
                               rtol=1e-5, atol=1e-5)


def test_composed_schedule_coalesced_bitidentical_and_per_pid():
    """Composition re-derives plans per sub-program: transfers never mix
    channels across pids, and execution stays bit-identical."""
    cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True)
    mesh = _mesh111()
    pa = build_faces_program(cfg, mesh, name="qa").persistent(2)
    pb = build_faces_program(cfg, mesh, name="qb").persistent(3)
    sched = compose(pa, pb)

    for b in sched.batches:
        assert b.plan is not None
        # every member channel of every transfer belongs to THIS batch —
        # and the batch belongs to exactly one pid
        ns = {c.src_buf.split("/")[0] for c in b.plan.channels}
        ns |= {c.dst_buf.split("/")[0] for c in b.plan.channels}
        assert len(ns) == 1
        for t in b.plan.transfers:
            assert all(0 <= s.channel < len(b.plan.channels)
                       for s in t.segments)

    u0 = _u0(cfg)
    ua, ub = u0, _u0(cfg, seed=1)
    init = {"qa/u": ua, "qb/u": ub}
    on = PersistentEngine(sched, mode="dataflow")
    off = PersistentEngine(sched, mode="dataflow", coalesce=False)
    # per-sub iteration counts diverge, so the engine returns the masked
    # while_loop triple (mem, reduction traces, realized counts)
    mem_on, _, nd_on = on(on.init_buffers(dict(init)))
    mem_off, _, nd_off = off(off.init_buffers(dict(init)))
    assert {k: int(v) for k, v in nd_on.items()} == \
        {k: int(v) for k, v in nd_off.items()} == {"qa": 2, "qb": 3}
    _assert_mem_bitidentical(mem_on, mem_off, ctx="composed")


# -- property test: random channel sets ---------------------------------------


def _run_random_program(channels, coalesce, mode):
    """Build + run a queue whose batch holds ``channels`` specs."""
    mesh = _mesh11()
    q = STQueue(mesh, name="prop")
    rng = np.random.RandomState(7)
    init = {}
    for i, (peer, tag, cmode, use_region) in enumerate(channels):
        shape = (2, 3)
        q.buffer(f"s{i}", shape, np.float32)
        q.buffer(f"d{i}", shape, np.float32)
        init[f"s{i}"] = rng.randn(*shape).astype(np.float32)
        init[f"d{i}"] = rng.randn(*shape).astype(np.float32)
    for i, (peer, tag, cmode, use_region) in enumerate(channels):
        region = (slice(0, 1),) if use_region else None
        q.enqueue_recv(f"d{i}", peer.inverse(), tag=tag, mode=cmode,
                       region=region)
    for i, (peer, tag, cmode, use_region) in enumerate(channels):
        region = (slice(0, 1),) if use_region else None
        q.enqueue_send(f"s{i}", peer, tag=tag, region=region)
    q.enqueue_start()
    q.enqueue_wait()
    prog = q.build(coalesce=coalesce)
    eng = FusedEngine(prog, mode=mode)
    return prog, eng(eng.init_buffers(init))


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True

    _peer_st = st.one_of(
        st.builds(OffsetPeer,
                  axis=st.sampled_from(["x", "y"]),
                  delta=st.integers(-2, 2).filter(lambda d: d != 0),
                  periodic=st.booleans()),
        st.builds(lambda dx, dy, p: GridOffsetPeer(("x", "y"), (dx, dy), p),
                  st.integers(-1, 1), st.integers(-1, 1),
                  st.booleans()).filter(lambda g: any(g.deltas)),
    )
    _channel_st = st.tuples(_peer_st, st.integers(0, 3),
                            st.sampled_from(["replace", "add"]),
                            st.booleans())

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(_channel_st, min_size=1, max_size=8),
           st.sampled_from(["stream", "dataflow"]))
    def test_any_coalescing_partition_is_bitidentical(channels, mode):
        """Whatever (axis, perm) grouping the plan derives, running it
        must reproduce the uncoalesced interpreter bit-for-bit, in both
        ordering modes — replace masks, add-order and regions included."""
        # tag each channel uniquely per peer-key is not required: FIFO
        # matching pairs them positionally, exactly like the engines
        prog_c, mem_c = _run_random_program(channels, True, mode)
        prog_u, mem_u = _run_random_program(channels, False, mode)
        (b,) = prog_c.batches
        if b.plan is not None:
            assert len(b.plan.transfers) <= len(b.channels)
        _assert_mem_bitidentical(mem_c, mem_u, ctx=mode)

except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_any_coalescing_partition_is_bitidentical():
        pass


# -- donation -----------------------------------------------------------------


class TestDonation:
    def _prog(self):
        cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True)
        return cfg, build_faces_program(cfg, _mesh111())

    def test_donated_call_does_not_retain_input(self):
        """FusedEngine(donate=True) must actually consume its inputs —
        the zero-copy contract (regression: donate was dead in practice)."""
        cfg, prog = self._prog()
        eng = FusedEngine(prog, mode="dataflow", donate=True)
        mem = eng.init_buffers({"u": _u0(cfg)})
        held = mem["u"]
        out = eng(mem)
        assert held.is_deleted()
        assert not out["u"].is_deleted()

    def test_undonated_call_retains_input(self):
        cfg, prog = self._prog()
        eng = FusedEngine(prog, mode="dataflow")
        mem = eng.init_buffers({"u": _u0(cfg)})
        held = mem["u"]
        eng(mem)
        assert not held.is_deleted()

    def test_persistent_donated_loop(self):
        cfg, prog = self._prog()
        eng = PersistentEngine(prog.persistent(3), mode="dataflow",
                               donate=True)
        mem = eng.init_buffers({"u": _u0(cfg)})
        held = mem["u"]
        out = eng(mem)
        assert held.is_deleted()
        # donated run computes the same field as an undonated one
        ref = PersistentEngine(prog.persistent(3), mode="dataflow")
        out2 = ref(ref.init_buffers({"u": _u0(cfg)}))
        np.testing.assert_array_equal(np.asarray(out["u"]),
                                      np.asarray(out2["u"]))

    def test_run_faces_persistent_entrypoint_donates(self):
        from repro.core.halo import run_faces_persistent
        cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True)
        mem, stats = run_faces_persistent(cfg, _mesh111(), _u0(cfg), 2)
        assert stats.dispatches == 1  # donation didn't change accounting


# -- Pallas segment kernels ---------------------------------------------------


class TestSegmentKernels:
    def test_pack_segments_matches_concat(self):
        from repro.kernels.halo_pack import pack_segments_call
        rng = np.random.RandomState(0)
        slabs = [rng.randn(*s).astype(np.float32)
                 for s in [(2, 3), (1, 4), (5,)]]
        got = pack_segments_call(slabs, interpret=True)
        ref = np.concatenate([s.reshape(-1) for s in slabs])
        np.testing.assert_array_equal(np.asarray(got), ref)

    def test_unpack_segments_roundtrip(self):
        from repro.kernels.halo_pack import (pack_segments_call,
                                             unpack_segments_call)
        rng = np.random.RandomState(1)
        shapes = [(2, 2), (3,), (1, 1, 4)]
        slabs = [rng.randn(*s).astype(np.float32) for s in shapes]
        buf = pack_segments_call(slabs, interpret=True)
        outs = unpack_segments_call(buf, shapes, interpret=True)
        for o, s in zip(outs, slabs):
            np.testing.assert_array_equal(np.asarray(o), s)

    def test_mismatched_dtype_rejected(self):
        from repro.kernels.halo_pack import pack_segments_call
        with pytest.raises(ValueError, match="dtype"):
            pack_segments_call([np.zeros((2,), np.float32),
                                np.zeros((2,), np.float64)], interpret=True)

    def test_bad_segment_cover_rejected(self):
        from repro.kernels.halo_pack import unpack_segments_call
        with pytest.raises(ValueError, match="elements"):
            unpack_segments_call(np.zeros((5,), np.float32), [(2,), (2,)],
                                 interpret=True)


# -- multi-device (subprocess, slow lane) -------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("granularity", ["direct26", "staged3"])
def test_coalesced_bitidentical_8dev(subproc, granularity):
    r = subproc(f"""
import numpy as np
from repro.core import (FacesConfig, FusedEngine, PersistentEngine,
                        build_faces_program, faces_oracle)
from repro.parallel import make_mesh

mesh = make_mesh((2, 2, 2), ("gx", "gy", "gz"))
cfg = FacesConfig(grid=(2, 2, 2), points=(4, 4, 4),
                  granularity={granularity!r})
prog = build_faces_program(cfg, mesh)
if cfg.granularity == "direct26":
    assert prog.max_collectives_per_start() == (26, 6), \\
        prog.max_collectives_per_start()
u0 = np.random.RandomState(0).randn(2, 2, 2, 4, 4, 4).astype(np.float32)

for mode in ("stream", "dataflow"):
    on = FusedEngine(prog, mode=mode)
    off = FusedEngine(prog, mode=mode, coalesce=False)
    mc = on(on.init_buffers({{"u": u0}}))
    mu = off(off.init_buffers({{"u": u0}}))
    for k in mc:
        np.testing.assert_array_equal(np.asarray(mc[k]), np.asarray(mu[k]))

pp = prog.persistent(3)
on = PersistentEngine(pp, mode="dataflow", donate=True)
off = PersistentEngine(pp, mode="dataflow", coalesce=False)
mc = on(on.init_buffers({{"u": u0}}))
mu = off(off.init_buffers({{"u": u0}}))
for k in mc:
    np.testing.assert_array_equal(np.asarray(mc[k]), np.asarray(mu[k]))
if cfg.granularity == "direct26":
    ref = u0
    for _ in range(3):
        ref = faces_oracle(ref, cfg)
    np.testing.assert_allclose(np.asarray(mc["u"]), ref, rtol=1e-4, atol=1e-4)
print("coalesce 8dev OK")
""")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "coalesce 8dev OK" in r.stdout
