import os
# Benchmarks need a small multi-device grid (the Faces figures use 8
# ranks, matching the paper's 8-node experiments).  This is the bench
# entry point only — tests and the dry-run manage their own device
# counts (dryrun.py forces 512; pytest keeps the 1 real device).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Benchmark harness — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run faces      # one suite

Prints ``name,us_per_call,derived`` CSV at the end (plus human-readable
sections), and writes artifacts/bench_results.json.
"""

import json
import sys


def main() -> None:
    here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, os.path.join(here, "..", "src"))
    sys.path.insert(0, os.path.join(here, ".."))

    from benchmarks import api_overhead, faces_bench, overlap_bench
    from benchmarks import roofline as roofline_mod

    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    results = []

    if which in ("all", "api"):
        results += api_overhead.run_all()
    if which in ("all", "faces"):
        results += faces_bench.run_all()
    if which in ("all", "overlap"):
        results += overlap_bench.run_all()
    if which in ("all", "roofline"):
        rows = roofline_mod.main(None)
        for r in rows:
            if "skipped" in r:
                continue
            results.append({
                "bench": "roofline", "variant": f"{r['arch']}/{r['shape']}",
                "us_per_call": max(r["t_compute_s"], r["t_memory_s"],
                                   r["t_collective_s"]) * 1e6,
                "derived": f"dominant={r['dominant']};"
                           f"useful={r['useful_ratio']:.3f}",
            })

    print("\nname,us_per_call,derived")
    for r in results:
        print(f"{r['bench']}/{r['variant']},{r['us_per_call']:.2f},"
              f"\"{r['derived']}\"")

    out = os.path.join(here, "..", "artifacts", "bench_results.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote {out}")

    # machine-readable Faces perf trajectory (variant -> median ms,
    # dispatch counts), tracked across PRs at the repo root
    faces = {
        f"{r['bench']}/{r['variant']}": {
            "median_ms": round(r["median_ms"], 4),
            "dispatches": r["dispatches"],
        }
        for r in results
        if r["bench"].startswith("faces") and "median_ms" in r
    }
    if faces:
        fout = os.path.join(here, "..", "BENCH_faces.json")
        with open(fout, "w") as f:
            json.dump(faces, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {fout}")


if __name__ == '__main__':
    main()
