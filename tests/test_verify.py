"""STLint (repro.core.verify) — mutation suite + runtime sanitizer.

One failing test per ``ST0xx`` rule: a seeded broken program built by
mutating a clean one with ``dataclasses.replace`` (the queue API refuses
to *enqueue* most of these mistakes — which is exactly why the verifier
must catch programs no queue built, e.g. the ROADMAP's future
auto-decomposition output), plus passing coverage: the clean source
program of every mutation lints clean, and an all-green sweep asserts
every program the benchmarks build (the ``repro.analysis`` registry,
faces figs + linked N-part + serve admission) produces zero diagnostics.

The sanitizer half: ``engine(..., sanitize=True)`` must (a) be
bit-identical to the unsanitized engine on clean programs despite the
NaN-canary poisoning, and (b) catch a seeded deposit-before-wait race
that the unsanitized engine silently accepts.
"""

import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import (
    FacesConfig,
    FusedEngine,
    HostEngine,
    OffsetPeer,
    PersistentEngine,
    STLintWarning,
    STQueue,
    SanitizeError,
    VerifyError,
    build_faces_program,
    compose,
    run_verify,
    verify_program,
)
from repro.core.descriptors import (
    KernelDesc,
    RecvDesc,
    SendDesc,
    StartDesc,
    WaitDesc,
)
from repro.core.halo import AXES3
from repro.core.verify import (
    RULES,
    Diagnostic,
    canary_buffers,
    check_deposit_order,
    format_diagnostics,
)


def _meshx():
    from repro.parallel import make_mesh
    return make_mesh((1,), ("x",))


def _mesh111():
    from repro.parallel import make_mesh
    return make_mesh((1, 1, 1), AXES3)


def _exchange(mesh, n_batches=1, wait=True, kernel=True, verify="off",
              name="p"):
    """A clean n-batch self-exchange (+ unpack kernel) to mutate."""
    q = STQueue(mesh, name=name)
    q.buffer("u", (4,), np.float32, pspec=("x",))
    q.buffer("out", (4,), np.float32, pspec=("x",))
    for b in range(n_batches):
        q.buffer(f"halo{b}", (4,), np.float32, pspec=("x",))
    for b in range(n_batches):
        q.enqueue_send("u", OffsetPeer("x", 0, periodic=True), tag=b)
        q.enqueue_recv(f"halo{b}", OffsetPeer("x", 0, periodic=True), tag=b)
        q.enqueue_start()
    if wait:
        q.enqueue_wait()
    if kernel:
        q.enqueue_kernel(lambda h: h + 1.0, ["halo0"], ["out"],
                         name="unpack")
    return q.build(verify=verify)


def _codes(prog):
    return {d.rule for d in verify_program(prog)}


def _idx(prog, kind, pid=None, last=False):
    hits = [i for i, d in enumerate(prog.descriptors)
            if isinstance(d, kind) and (pid is None or d.pid == pid)]
    return hits[-1] if last else hits[0]


def _with_descs(prog, descs):
    return dataclasses.replace(prog, descriptors=tuple(descs))


def _linked_pair(mesh):
    qa = STQueue(mesh, name="A")
    qa.buffer("a", (4,), np.float32, pspec=("x",))
    qa.enqueue_send("a", OffsetPeer("x", 0, periodic=True), tag=7,
                    remote="B")
    qa.enqueue_start()
    qa.enqueue_wait()
    qb = STQueue(mesh, name="B")
    qb.buffer("slot", (4,), np.float32, pspec=("x",))
    qb.buffer("out", (4,), np.float32, pspec=("x",))
    qb.enqueue_recv("slot", OffsetPeer("x", 0, periodic=True), tag=7,
                    remote="A")
    qb.enqueue_start()
    qb.enqueue_wait()
    qb.enqueue_kernel(lambda s: s * 2.0, ["slot"], ["out"], name="double")
    return qa.build(), qb.build()


# -- per-rule mutation suite --------------------------------------------------


class TestRules:
    def test_clean_programs_lint_clean(self):
        mesh = _meshx()
        assert verify_program(_exchange(mesh)) == []
        assert verify_program(_exchange(mesh, n_batches=2)) == []
        sched = compose(*_linked_pair(mesh))
        assert verify_program(sched) == []

    def test_st001_deadlocked_wait_own_program(self):
        prog = _exchange(_meshx(), n_batches=2)
        descs = list(prog.descriptors)
        # move the wait ahead of batch 1's start: it now gates a
        # completion whose trigger is not yet emitted in stream order
        wi = _idx(prog, WaitDesc)
        w = descs.pop(wi)
        descs.insert(_idx(prog, StartDesc, last=True), w)
        bad = _with_descs(prog, descs)
        assert "ST001" in _codes(bad)
        assert "ST001" not in _codes(prog)

    def test_st001_deadlocked_wait_cross_program(self):
        sched = compose(*_linked_pair(_meshx()))
        # drop the SENDER's start (and its wait, to keep its own stream
        # balanced): the receiver's wait now gates a cross-program
        # deposit whose trigger never fires — the interleaver's local
        # cycle test cannot see this, the whole-schedule walk must
        descs = [d for d in sched.descriptors
                 if not (isinstance(d, (StartDesc, WaitDesc)) and d.pid == 0)]
        bad = _with_descs(sched, descs)
        diags = verify_program(bad)
        assert any(d.rule == "ST001" and "cross-program" in d.message
                   for d in diags)

    def test_st002_wait_before_start(self):
        prog = _exchange(_meshx())
        descs = list(prog.descriptors)
        wi, si = _idx(prog, WaitDesc), _idx(prog, StartDesc)
        descs[wi], descs[si] = descs[si], descs[wi]
        assert "ST002" in _codes(_with_descs(prog, descs))

    def test_st003_non_monotone_thresholds(self):
        prog = _exchange(_meshx(), n_batches=2)
        descs = list(prog.descriptors)
        si = _idx(prog, SendDesc)
        descs[si] = dataclasses.replace(descs[si], threshold=99)
        bad = _with_descs(prog, descs)
        diags = [d for d in verify_program(bad) if d.rule == "ST003"]
        assert diags and diags[0].severity == "error"

    def test_st004_comm_after_last_start(self):
        prog = _exchange(_meshx(), kernel=False)
        descs = [d for d in prog.descriptors
                 if not isinstance(d, (StartDesc, WaitDesc))]
        diags = verify_program(_with_descs(prog, descs))
        # both the send and the recv are uncovered
        assert [d.rule for d in diags].count("ST004") == 2

    def test_st005_unwaited_completions_warning(self):
        prog = _exchange(_meshx(), wait=False, kernel=False)
        diags = verify_program(prog)
        assert {d.rule for d in diags} == {"ST005"}
        assert diags[0].severity == "warning"

    def test_st005_escalates_to_error_when_persistent(self):
        prog = _exchange(_meshx(), kernel=False).persistent(3)
        descs = [d for d in prog.descriptors if not isinstance(d, WaitDesc)]
        diags = [d for d in verify_program(_with_descs(prog, descs))
                 if d.rule == "ST005"]
        assert diags and diags[0].severity == "error"
        assert "persistent" in diags[0].message

    def test_st006_pending_deposit_overwritten(self):
        mesh = _meshx()
        q = STQueue(mesh, name="clobber")
        q.buffer("u", (4,), np.float32, pspec=("x",))
        q.buffer("halo", (4,), np.float32, pspec=("x",))
        for tag in (0, 1):  # two deposits into one slot, no wait between
            q.enqueue_send("u", OffsetPeer("x", 0, periodic=True), tag=tag)
            q.enqueue_recv("halo", OffsetPeer("x", 0, periodic=True),
                           tag=tag)
            q.enqueue_start()
        q.enqueue_wait()
        diags = [d for d in verify_program(q.build(verify="off"))
                 if d.rule == "ST006"]
        assert diags and diags[0].severity == "warning"

    def test_st007_read_before_wait(self):
        prog = _exchange(_meshx())
        descs = list(prog.descriptors)
        ki = _idx(prog, KernelDesc)
        k = descs.pop(ki)
        descs.insert(_idx(prog, WaitDesc), k)
        diags = [d for d in verify_program(_with_descs(prog, descs))
                 if d.rule == "ST007"]
        assert diags and diags[0].severity == "error"
        assert "unpack" in diags[0].message

    def test_st008_corrupted_plan(self):
        cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True)
        prog = build_faces_program(cfg, _mesh111())
        bi, b = next((i, b) for i, b in enumerate(prog.batches)
                     if b.plan is not None)
        t0 = b.plan.transfers[0]
        seg = t0.segments[-1]
        segs = t0.segments[:-1] + (
            dataclasses.replace(seg, offset=seg.offset + 1),)
        plan = dataclasses.replace(
            b.plan,
            transfers=(dataclasses.replace(t0, segments=segs),)
            + b.plan.transfers[1:])
        batches = list(prog.batches)
        batches[bi] = dataclasses.replace(b, plan=plan)
        bad = dataclasses.replace(prog, batches=tuple(batches))
        assert "ST008" in _codes(bad)
        assert "ST008" not in _codes(prog)

    def test_st008_route_segment_mismatch(self):
        cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True)
        prog = build_faces_program(cfg, _mesh111())
        bi, b = next((i, b) for i, b in enumerate(prog.batches)
                     if b.plan is not None)
        ci, route = next((ci, r) for ci, r in enumerate(b.plan.routes) if r)
        ti, off = route[0]
        routes = list(b.plan.routes)
        routes[ci] = ((ti, off + 1),) + route[1:]
        plan = dataclasses.replace(b.plan, routes=tuple(routes))
        batches = list(prog.batches)
        batches[bi] = dataclasses.replace(b, plan=plan)
        bad = dataclasses.replace(prog, batches=tuple(batches))
        assert any(d.rule == "ST008" and "alias" in d.message
                   for d in verify_program(bad))

    def test_st009_foreign_buffer_access(self):
        sched = compose(*_linked_pair(_meshx()))
        descs = list(sched.descriptors)
        ki = next(i for i, d in enumerate(descs)
                  if isinstance(d, KernelDesc) and d.name == "double")
        descs[ki] = dataclasses.replace(descs[ki], reads=("A/a",))
        diags = [d for d in verify_program(_with_descs(sched, descs))
                 if d.rule == "ST009"]
        assert diags and diags[0].severity == "error"

    def test_st010_persistent_accumulator_drift(self):
        prog = _exchange(_meshx()).persistent(2)
        bi, b = next((i, b) for i, b in enumerate(prog.batches)
                     if b.channels)
        chans = [dataclasses.replace(b.channels[0], mode="add")] \
            + list(b.channels[1:])
        batches = list(prog.batches)
        batches[bi] = dataclasses.replace(b, channels=chans)
        bad = dataclasses.replace(prog, batches=tuple(batches))
        diags = [d for d in verify_program(bad) if d.rule == "ST010"]
        assert diags and diags[0].severity == "warning"
        # one-shot programs are exempt: drift needs the loop
        oneshot = dataclasses.replace(bad, n_iters=1)
        assert "ST010" not in _codes(oneshot)

    def test_st011_dead_channels_unpruned(self):
        # non-periodic faces on a collapsed grid: every channel's perm is
        # empty; force the "coalescing requested but declined" state
        cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=False)
        prog = build_faces_program(cfg, _mesh111())
        batches = tuple(
            dataclasses.replace(b, plan=None, coalesce=True)
            for b in prog.batches)
        bad = dataclasses.replace(prog, batches=batches)
        diags = [d for d in verify_program(bad) if d.rule == "ST011"]
        assert diags and diags[0].severity == "warning"
        # with the plan present the dead channels are pruned: clean
        assert "ST011" not in _codes(prog)

    def test_st012_open_links_at_engine_time(self):
        pa, _ = _linked_pair(_meshx())
        with pytest.raises(ValueError, match=r"\[ST012\]"):
            HostEngine(pa)

    @staticmethod
    def _ring_accumulator(steps=3):
        """A clean ring-reduce accumulator (the collectives.py
        reduce-scatter shape, hand-built on the 1-device mesh): seed,
        then per step one in-place rotation gate + one accumulate
        kernel reading AND writing ``acc``."""
        q = STQueue(_meshx(), name="ring")
        q.buffer("y", (4,), np.float32, pspec=("x",))
        q.buffer("acc", (4,), np.float32, pspec=("x",))
        q.enqueue_kernel(lambda y: y * 1.0, ["y"], ["acc"], name="seed")
        for s in range(steps):
            q.enqueue_send("acc", OffsetPeer("x", 0, periodic=True), tag=s)
            q.enqueue_recv("acc", OffsetPeer("x", 0, periodic=True), tag=s)
            q.enqueue_start()
            q.enqueue_wait()
            q.enqueue_kernel(lambda a, y: a + y, ["acc", "y"], ["acc"],
                             name=f"acc{s}")
        return q.build(verify="off")

    def test_st013_double_rotation_in_one_gate(self):
        prog = self._ring_accumulator()
        assert "ST013" not in _codes(prog)  # one rotation per gate: clean
        bi, b = next((i, b) for i, b in enumerate(prog.batches)
                     if b.channels)
        # splice the rotation channel in twice under the same start gate
        batches = list(prog.batches)
        batches[bi] = dataclasses.replace(
            b, channels=list(b.channels) + [b.channels[0]], plan=None)
        bad = dataclasses.replace(prog, batches=tuple(batches))
        diags = [d for d in verify_program(bad) if d.rule == "ST013"]
        assert diags and diags[0].severity == "error"
        assert "only one hop survives" in diags[0].message

    def test_st014_accumulator_clobbered_mid_ring(self):
        prog = self._ring_accumulator(steps=3)
        assert "ST014" not in _codes(prog)  # seed-then-accumulate: clean
        descs = list(prog.descriptors)
        # drop the middle accumulate's read of `acc`: it becomes a
        # rewrite between the first and last accumulate events
        ki = next(i for i, d in enumerate(descs)
                  if isinstance(d, KernelDesc) and d.name == "acc1")
        descs[ki] = dataclasses.replace(descs[ki], reads=("y",))
        diags = [d for d in verify_program(_with_descs(prog, descs))
                 if d.rule == "ST014"]
        assert diags and diags[0].severity == "error"
        assert "discarded mid-ring" in diags[0].message

    def test_st013_st014_collective_builders_lint_clean(self):
        # the collective-matmul builders must produce lint-clean
        # programs even on the degenerate 1-device mesh (the registry
        # sweep covers the 8-device builds)
        from repro.core import collectives as C
        mesh = _meshx()
        for cm in (C.build_all_gather_matmul(mesh, "x", 8, 4, 2),
                   C.build_matmul_reduce_scatter(mesh, "x", 8, 4, 2),
                   C.build_all_to_all(mesh, "x", 8, 2),
                   C.build_tp_block(mesh, "x", 8, 4, 4)):
            assert not verify_program(cm.program)


# -- happens-before rules (STProve, ST015-ST019) ------------------------------


def _linked_chain(persistent=0, deposits=1):
    """A composed A->B channel chain to mutate: A sends ``deposits``
    messages (one per batch), B receives each into ``slot`` and, after
    its final wait, doubles it into ``out``."""
    mesh = _meshx()
    qa = STQueue(mesh, name="A")
    qa.buffer("a", (4,), np.float32, pspec=("x",))
    for t in range(deposits):
        qa.enqueue_send("a", OffsetPeer("x", 0, periodic=True), tag=7 + t,
                        remote="B")
        qa.enqueue_start()
        qa.enqueue_wait()
    qb = STQueue(mesh, name="B")
    qb.buffer("slot", (4,), np.float32, pspec=("x",))
    qb.buffer("out", (4,), np.float32, pspec=("x",))
    for t in range(deposits):
        qb.enqueue_recv("slot", OffsetPeer("x", 0, periodic=True), tag=7 + t,
                        remote="A")
        qb.enqueue_start()
        qb.enqueue_wait()
    qb.enqueue_kernel(lambda s: s * 2.0, ["slot"], ["out"], name="double")
    pa, pb = qa.build(verify="off"), qb.build(verify="off")
    if persistent:
        pa, pb = pa.persistent(persistent), pb.persistent(persistent)
    return compose(pa, pb, verify="off")


def _move_kernel(prog, dest_index):
    """Pop the (single) kernel descriptor and reinsert it at ``dest_index``."""
    descs = list(prog.descriptors)
    ki = next(i for i, d in enumerate(descs) if isinstance(d, KernelDesc))
    k = descs.pop(ki)
    descs.insert(dest_index, k)
    return _with_descs(prog, descs)


class TestHappensBefore:
    def test_clean_linked_chains_have_no_hb_diagnostics(self):
        for prog in (_linked_chain(), _linked_chain(persistent=3),
                     _linked_chain(persistent=3, deposits=2)):
            assert not _codes(prog) & {"ST015", "ST016", "ST017", "ST018"}

    def test_st015_kernel_deposit_race(self):
        # move B's unpack kernel before B's gating wait: the kernel's
        # read of `slot` is no longer ordered against A's deposit
        prog = _linked_chain()
        bad = _move_kernel(prog, _idx(prog, WaitDesc, pid=1))
        diags = [d for d in verify_program(bad) if d.rule == "ST015"]
        assert diags and diags[0].severity == "error"
        assert "happens-before" in diags[0].message

    def test_st015_fires_where_the_stream_walk_is_blind(self):
        # kernel moved to the very FRONT of the stream: the emitted
        # order is walk-silent (no deposit is pending yet when the
        # kernel runs), but under an interleaving that runs A first the
        # deposit races the read — only the HB graph sees it
        bad = _move_kernel(_linked_chain(), 0)
        assert _codes(bad) == {"ST015"}

    def test_st016_war_on_rotated_slot(self):
        # persistent: `slot` is a rotated message slot; a read that may
        # precede the pass's first deposit hits the stale alternate copy
        prog = _linked_chain(persistent=3)
        bad = _move_kernel(prog, _idx(prog, WaitDesc, pid=1))
        diags = [d for d in verify_program(bad) if d.rule == "ST016"]
        assert diags and diags[0].severity == "error"
        # the same mutation on the one-shot program is ST015-only:
        # rotation hazards need the persistent loop
        oneshot = _move_kernel(_linked_chain(), _idx(_linked_chain(),
                                                     WaitDesc, pid=1))
        assert "ST016" not in _codes(oneshot)

    def test_st017_staging_reuse_across_overlapping_windows(self):
        # two batches in flight under ONE wait: their trigger-to-wait
        # windows overlap, so their transfers must not share a staging
        # buffer.  The default build stamps unique names (clean); the
        # mutation forces a collision.
        prog = _exchange(_meshx(), n_batches=2)
        assert "ST017" not in _codes(prog)
        batches = []
        for b in prog.batches:
            plan = dataclasses.replace(
                b.plan, transfers=tuple(
                    dataclasses.replace(t, staging="~stage/shared")
                    for t in b.plan.transfers))
            batches.append(dataclasses.replace(b, plan=plan))
        bad = dataclasses.replace(prog, batches=tuple(batches))
        diags = [d for d in verify_program(bad) if d.rule == "ST017"]
        assert diags and diags[0].severity == "error"
        assert "~stage/shared" in diags[0].message

    def test_st017_ordered_windows_may_share_staging(self):
        # wait BETWEEN the batches: window 0 provably retires before
        # window 1 triggers, so reusing the staging buffer is legal
        q = STQueue(_meshx(), name="p")
        q.buffer("u", (4,), np.float32, pspec=("x",))
        for b in range(2):
            q.buffer(f"halo{b}", (4,), np.float32, pspec=("x",))
        for b in range(2):
            q.enqueue_send("u", OffsetPeer("x", 0, periodic=True), tag=b)
            q.enqueue_recv(f"halo{b}", OffsetPeer("x", 0, periodic=True),
                           tag=b)
            q.enqueue_start()
            q.enqueue_wait()
        prog = q.build(verify="off")
        batches = tuple(
            dataclasses.replace(b, plan=dataclasses.replace(
                b.plan, transfers=tuple(
                    dataclasses.replace(t, staging="~stage/shared")
                    for t in b.plan.transfers)))
            for b in prog.batches)
        shared = dataclasses.replace(prog, batches=batches)
        assert "ST017" not in _codes(shared)

    def test_st018_donated_read_races_second_deposit(self):
        # two deposits into one slot; the kernel lands between the
        # second start and its wait: ordered after deposit 1 but racing
        # deposit 2 — the read may see either generation's copy
        prog = _linked_chain(persistent=3, deposits=2)
        bad = _move_kernel(prog, _idx(prog, WaitDesc, pid=1, last=True))
        diags = [d for d in verify_program(bad) if d.rule == "ST018"]
        assert diags and diags[0].severity == "error"
        assert "ST016" not in _codes(bad)  # ordered after the FIRST write

    def test_st019_implicit_effects_warning(self):
        q = STQueue(_meshx(), name="ic")
        q.buffer("u", (4,), np.float32, pspec=("x",))
        q.buffer("v", (4,), np.float32, pspec=("x",))
        q.enqueue_compute(lambda u: u + 1.0, writes=["v"])
        prog = q.build(verify="off")
        d = next(d for d in verify_program(prog) if d.rule == "ST019")
        assert d.severity == "warning"
        assert d.site and "test_verify.py" in d.site
        kd = next(x for x in prog.descriptors if isinstance(x, KernelDesc))
        assert kd.implicit_effects and kd.reads == ("u", "v")

    def test_st019_declared_effects_are_clean(self):
        q = STQueue(_meshx(), name="ok")
        q.buffer("u", (4,), np.float32, pspec=("x",))
        q.buffer("v", (4,), np.float32, pspec=("x",))
        q.enqueue_compute(lambda u: u + 1.0, reads=["u"], writes=["v"])
        assert "ST019" not in _codes(q.build(verify="off"))


# -- policy wiring ------------------------------------------------------------


class TestPolicy:
    def _bad(self):
        prog = _exchange(_meshx())
        descs = list(prog.descriptors)
        ki = _idx(prog, KernelDesc)
        k = descs.pop(ki)
        descs.insert(_idx(prog, WaitDesc), k)
        return _with_descs(prog, descs)  # ST007: error severity

    def test_off_skips(self):
        assert run_verify(self._bad(), "off") == []

    def test_bad_policy_rejected(self):
        with pytest.raises(ValueError, match="verify must be"):
            run_verify(self._bad(), "loud")

    def test_error_policy_raises_with_diagnostics(self):
        with pytest.raises(VerifyError) as e:
            run_verify(self._bad(), "error")
        assert e.value.diagnostics
        assert all(d.severity == "error" for d in e.value.diagnostics)

    def test_warn_policy_warns(self):
        with pytest.warns(STLintWarning, match=r"\[ST007\]"):
            run_verify(self._bad(), "warn")

    def test_error_policy_only_warns_on_warning_severity(self):
        prog = _exchange(_meshx(), wait=False, kernel=False)  # ST005 warn
        with pytest.warns(STLintWarning, match=r"\[ST005\]"):
            diags = run_verify(prog, "error")
        assert [d.rule for d in diags] == ["ST005"]

    def test_build_default_verifies_and_clean_build_is_silent(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            _exchange(_meshx(), verify="warn")

    def test_build_and_compose_reject_bad_policy(self):
        mesh = _meshx()
        q = STQueue(mesh, name="w")
        q.buffer("u", (4,), np.float32, pspec=("x",))
        q.enqueue_send("u", OffsetPeer("x", 0, periodic=True), tag=0)
        q.enqueue_recv("u", OffsetPeer("x", 0, periodic=True), tag=0)
        q.enqueue_start()
        q.enqueue_wait()
        with pytest.raises(ValueError, match="verify must be"):
            q.build(verify="loud")
        with pytest.raises(ValueError, match="verify must be"):
            compose(*_linked_pair(mesh), verify="loud")

    def test_diagnostic_formatting(self):
        d = Diagnostic(rule="ST007", severity="error", pid=1,
                       message="boom", index=4, site="a.py:9")
        s = str(d)
        assert "[ST007]" in s and "desc#4" in s and "enqueued at a.py:9" in s
        table = format_diagnostics([d])
        assert "ST007" in table and "boom" in table
        assert "clean" in format_diagnostics([])

    def test_every_rule_has_catalog_entry(self):
        import repro.core.verify as V
        for rule, (sev, _) in RULES.items():
            assert sev in ("error", "warning")
            assert rule in V.__doc__


# -- enqueue-site provenance (satellite) --------------------------------------


class TestProvenance:
    def test_descriptors_and_channels_carry_sites(self):
        prog = _exchange(_meshx())
        for d in prog.descriptors:
            assert d.site and "test_verify.py" in d.site, d
        ch = next(ch for b in prog.batches for ch in b.channels)
        assert ch.send_site and "test_verify.py" in ch.send_site
        assert ch.recv_site and "test_verify.py" in ch.recv_site

    def test_diagnostics_name_the_enqueue_site(self):
        prog = _exchange(_meshx())
        descs = list(prog.descriptors)
        ki = _idx(prog, KernelDesc)
        k = descs.pop(ki)
        descs.insert(_idx(prog, WaitDesc), k)
        d = next(d for d in verify_program(_with_descs(prog, descs))
                 if d.rule == "ST007")
        assert d.site and "test_verify.py" in d.site
        assert "enqueued at" in str(d)


# -- all-green sweep over benchmark-built programs ----------------------------


class TestBenchmarkSweep:
    def test_every_benchmark_program_lints_clean(self):
        from repro.analysis import lint_all
        results = lint_all(device_count=1)
        names = [n for n, _ in results]
        assert "faces_fig8_1d" in names
        assert "faces_pipeline_linked_n2" in names
        assert "serve_admission" in names
        dirty = {n: [str(d) for d in ds] for n, ds in results if ds}
        assert not dirty, dirty


# -- runtime sanitizer --------------------------------------------------------


class TestSanitizer:
    def _faces(self):
        cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True)
        prog = build_faces_program(cfg, _mesh111())
        u0 = np.random.RandomState(0).randn(1, 1, 1, 4, 4, 4).astype(
            np.float32)
        return prog, u0

    def _race(self, prog):
        """Move a post-wait unpack kernel ahead of the wait."""
        descs = list(prog.descriptors)
        wi = max(i for i, d in enumerate(descs) if isinstance(d, WaitDesc))
        ki = next(i for i, d in enumerate(descs)
                  if i > wi and isinstance(d, KernelDesc))
        k = descs.pop(ki)
        descs.insert(wi, k)
        return _with_descs(prog, descs)

    def test_canary_buffers_selected(self):
        prog, _ = self._faces()
        cbs = canary_buffers(prog)
        assert cbs  # the halo slots qualify
        assert "u" not in cbs  # first access is a kernel read

    def test_fused_parity_under_canaries(self):
        prog, u0 = self._faces()
        plain = FusedEngine(prog, mode="dataflow")
        poisoned = FusedEngine(prog, mode="dataflow", sanitize=True)
        a = plain(plain.init_buffers({"u": u0}))
        b = poisoned(poisoned.init_buffers({"u": u0}))
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]), err_msg=k)

    def test_persistent_parity_under_canaries(self):
        prog, u0 = self._faces()
        pp = prog.persistent(3)
        plain = PersistentEngine(pp, mode="dataflow")
        poisoned = PersistentEngine(pp, mode="dataflow", sanitize=True)
        a = plain(plain.init_buffers({"u": u0}))
        b = poisoned(poisoned.init_buffers({"u": u0}))
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]), err_msg=k)

    def test_sanitizer_catches_race_unsanitized_accepts(self):
        prog, u0 = self._faces()
        bad = self._race(prog)
        silent = FusedEngine(bad, mode="dataflow")
        silent(silent.init_buffers({"u": u0}))  # silently wrong
        loud = FusedEngine(bad, mode="dataflow", sanitize=True)
        with pytest.raises(SanitizeError, match="pending unwaited deposit"):
            loud(loud.init_buffers({"u": u0}))

    def test_host_engine_static_sanitize(self):
        prog, u0 = self._faces()
        bad = self._race(prog)
        HostEngine(bad)  # constructs fine unsanitized
        with pytest.raises(SanitizeError, match="pending unwaited deposit"):
            HostEngine(bad, sanitize=True)
        eng = HostEngine(prog, sanitize=True)
        ref = FusedEngine(prog, mode="dataflow")
        a = eng(eng.init_buffers({"u": u0}))
        b = ref(ref.init_buffers({"u": u0}))
        np.testing.assert_allclose(np.asarray(a["u"]), np.asarray(b["u"]),
                                   rtol=1e-6, atol=1e-6)

    def test_check_deposit_order_clean(self):
        prog, _ = self._faces()
        check_deposit_order(prog)  # no raise


@pytest.mark.slow
def test_sanitize_parity_8dev(subproc):
    """Canary path on a real 2×2×2 8-device grid: sanitize=True must stay
    bit-identical where the fused transfers actually move data."""
    code = """
import numpy as np
from repro.core import FacesConfig, FusedEngine, build_faces_program
from repro.parallel import make_mesh

mesh = make_mesh((2, 2, 2), ("gx", "gy", "gz"))
cfg = FacesConfig(grid=(2, 2, 2), points=(6, 6, 6))
prog = build_faces_program(cfg, mesh)
u0 = np.random.RandomState(0).randn(2, 2, 2, 6, 6, 6).astype(np.float32)
a = FusedEngine(prog, mode="dataflow")
b = FusedEngine(prog, mode="dataflow", sanitize=True)
ma = a(a.init_buffers({"u": u0}))
mb = b(b.init_buffers({"u": u0}))
for k in ma:
    np.testing.assert_array_equal(np.asarray(ma[k]), np.asarray(mb[k]),
                                  err_msg=k)
print("OK")
"""
    r = subproc(code)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout
