"""repro.analysis — STLint over every shipped benchmark program.

The verifier itself lives in :mod:`repro.core.verify`; this package is
the *fleet* face: a registry of every ST program the benchmarks build
(:mod:`.programs`) and a CLI (``python -m repro.analysis``) that lints
each one and prints a diagnostics table.  CI runs the CLI so a rule
regression — or a benchmark program that stops linting clean — fails
the build with a table naming the program, rule, and enqueue site
instead of a bare non-zero exit.

Usage::

    PYTHONPATH=src python -m repro.analysis            # lint everything
    PYTHONPATH=src python -m repro.analysis faces      # name filter
    PYTHONPATH=src python -m repro.analysis --strict   # CI mode

Exit status is non-zero on error-severity diagnostics; ``--strict``
(what CI runs) also fails warning-severity findings — shipped programs
must lint completely clean (acceptance bar) — and prints the STProve
certificate table (:func:`.programs.certificates`): per-program effect
digest plus the happens-before race-free verdict.
"""

from .programs import certificates, iter_programs, lint_all

__all__ = ["certificates", "iter_programs", "lint_all"]
