"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated
against (tests sweep shapes/dtypes and assert_allclose kernel vs ref).
No Pallas, no tiling — straight-line jnp.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# halo pack / unpack (Faces boundary slabs)
# --------------------------------------------------------------------------


def halo_pack(u: jax.Array, region: Tuple[slice, ...]) -> jax.Array:
    """Extract a boundary slab (static region) from a local block."""
    return u[region]


def halo_unpack_add(u: jax.Array, msg: jax.Array, region: Tuple[slice, ...]) -> jax.Array:
    """Add a received slab into the block's boundary region."""
    return u.at[region].add(msg.astype(u.dtype))


def pack_boundary(u: jax.Array, regions: Sequence[Tuple[slice, ...]]) -> jax.Array:
    """Paper step-2 semantics: copy faces/edges/corners into ONE
    contiguous buffer (flattened, region-major, static offsets)."""
    return jnp.concatenate([u[r].reshape(-1) for r in regions])


def unpack_boundary_add(u: jax.Array, buf: jax.Array,
                        regions: Sequence[Tuple[slice, ...]]) -> jax.Array:
    """Paper step-6 semantics: add contiguous-buffer segments back into
    their regions."""
    off = 0
    for r in regions:
        size = int(np.prod([s.stop - s.start for s in r]))
        seg = buf[off:off + size].reshape([s.stop - s.start for s in r])
        u = u.at[r].add(seg.astype(u.dtype))
        off += size
    return u


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            weight_offset: float = 0.0) -> jax.Array:
    """y = x / rms(x) * (w + offset); stats in fp32 (gemma uses offset=1)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (w.astype(jnp.float32) + weight_offset)).astype(x.dtype)


# --------------------------------------------------------------------------
# Flash attention (forward)
# --------------------------------------------------------------------------


def attention(
    q: jax.Array,           # [B, Hq, Sq, D]
    k: jax.Array,           # [B, Hkv, Skv, D]
    v: jax.Array,           # [B, Hkv, Skv, D]
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    window: Optional[int] = None,       # sliding window (tokens of lookback)
    logit_softcap: Optional[float] = None,
    q_offset: int = 0,      # global position of q[0] (decode/prefill chunk)
) -> jax.Array:
    """Reference GQA attention.  Hq must be a multiple of Hkv."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) * scale
    if logit_softcap is not None:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)

    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (can happen with windows) → zeros not NaNs
    probs = jnp.where(jnp.any(mask, -1)[None, None, :, None], probs, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vr.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# Mamba2 SSD (selective state space, scalar-identity A per head)
# --------------------------------------------------------------------------


def ssd_scan(
    x: jax.Array,    # [B, S, H, P]   head channels
    dt: jax.Array,   # [B, S, H]      softplus-ed step sizes (>0)
    A: jax.Array,    # [H]            negative decay rates
    Bm: jax.Array,   # [B, S, G, N]   input projection (G groups)
    C: jax.Array,    # [B, S, G, N]   output projection
    *,
    init_state: Optional[jax.Array] = None,  # [B, H, P, N]
    return_state: bool = False,
):
    """Reference SSD: h_t = exp(A·dt_t)·h_{t-1} + dt_t·(x_t ⊗ B_t);
    y_t = (h_t · C_t) per head.  Heads map to B/C groups by h // (H/G).
    Runs an explicit scan in fp32."""
    Bsz, S, H, P = x.shape
    _, _, G, N = Bm.shape
    assert H % G == 0
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)  # [B, S, H, N]
    Ch = jnp.repeat(C, rep, axis=2)

    decay = jnp.exp(A[None, None, :] * dt)          # [B, S, H]
    inc = dt[..., None, None] * (x[..., :, :, None] * Bh[..., None, :])
    # inc: [B, S, H, P, N]

    def step(h, inputs):
        d, i = inputs
        h = d[..., None, None] * h + i
        return h, h

    h0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    d_t = jnp.moveaxis(decay, 1, 0).astype(jnp.float32)
    i_t = jnp.moveaxis(inc, 1, 0).astype(jnp.float32)
    h_last, hs = jax.lax.scan(step, h0, (d_t, i_t))
    hs = jnp.moveaxis(hs, 0, 1)  # [B, S, H, P, N]
    y = jnp.einsum("bshpn,bshn->bshp", hs, Ch.astype(jnp.float32))
    y = y.astype(x.dtype)
    if return_state:
        return y, h_last.astype(jnp.float32)
    return y


def ssd_step(
    x: jax.Array,    # [B, H, P]
    dt: jax.Array,   # [B, H]
    A: jax.Array,    # [H]
    Bm: jax.Array,   # [B, G, N]
    C: jax.Array,    # [B, G, N]
    state: jax.Array,  # [B, H, P, N]
):
    """Single decode step of the SSD recurrence → (y, new_state)."""
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)
    Ch = jnp.repeat(C, rep, axis=1)
    decay = jnp.exp(A[None, :] * dt)  # [B, H]
    new = decay[..., None, None] * state.astype(jnp.float32) + (
        dt[..., None, None] * (x[..., :, None] * Bh[:, :, None, :])
    ).astype(jnp.float32)
    y = jnp.einsum("bhpn,bhn->bhp", new, Ch.astype(jnp.float32)).astype(x.dtype)
    return y, new
