"""Launcher: production mesh, dry-run, training and serving drivers."""
from .mesh import make_host_mesh, make_production_mesh
from .steps import (
    StepBundle,
    build_bundle,
    build_persistent_train_step,
    build_pipelined_train_step,
    build_prefill_step,
    build_serve_step,
    build_train_step,
    loss_plateau,
    persistent_steps,
    pipelined_steps,
)
from .serve import ServeEngine, serve, serve_continuous
from .tune import Knobs, TuneResult, tune

__all__ = ["make_production_mesh", "make_host_mesh", "StepBundle",
           "build_bundle", "build_train_step", "build_prefill_step",
           "build_serve_step", "build_persistent_train_step",
           "build_pipelined_train_step",
           "persistent_steps", "pipelined_steps", "loss_plateau",
           "ServeEngine", "serve", "serve_continuous",
           "Knobs", "TuneResult", "tune"]
