"""internvl2-76b [vlm] — InternViT (stub) + LLaMA3-70B-class backbone.
[arXiv:2404.16821]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    arch_type="vlm",
    source="arXiv:2404.16821",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    act="silu",
    rope_theta=500_000.0,
    frontend="vision",
    frontend_tokens=256,    # image patch tokens after pixel-shuffle
    frontend_dim=3200,      # InternViT-6B hidden size (projected to d_model)
    long_context_ok=False,  # full attention → skip long_500k
)
