"""whisper-large-v3 [audio] — enc-dec transformer backbone, conv frontend
stubbed (input_specs supplies 1500 post-conv frame embeddings).
[arXiv:2212.04356]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    arch_type="encdec",
    source="arXiv:2212.04356",
    n_layers=32,            # decoder layers
    n_enc_layers=32,        # encoder layers
    enc_dec=True,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,          # MHA
    d_ff=5120,
    vocab=51866,
    act="gelu",
    qkv_bias=True,          # whisper uses biases (no bias on k proj; modeled uniformly)
    pos_embedding="sinusoidal",
    rope_theta=0.0,
    frontend="audio",
    frontend_tokens=1500,   # 30 s of audio after the conv stack
    frontend_dim=1280,      # stub supplies post-conv d_model embeddings
    norm_eps=1e-5,
    tie_embeddings=True,
    long_context_ok=False,  # 448-token decoder spec; long_500k skipped (DESIGN §5)
)
