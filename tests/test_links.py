"""Cross-program channels (links) — halo exchange BETWEEN composed queues.

Fast lane: static structure of ``compose(..., links=...)`` (matching,
Link metadata, per-pid completion wiring, trigger-before-wait
interleaving, the error surface), a tiny linked program on all three
engines, and the PR-5 acceptance contrast: an N-way linked
``run_faces_pipelined`` is bit-identical to the single-queue
full-domain ``run_faces_persistent`` — the composed run is the TRUE
full-domain solve in ONE dispatch, including odd (uneven) splits.

Slow lane: the same contrast on a real 2×2×2 8-device grid.
"""

import numpy as np
import pytest

from repro.core import (
    FacesConfig,
    FusedEngine,
    HostEngine,
    OffsetPeer,
    PersistentEngine,
    ScheduleError,
    STQueue,
    build_faces_part_program,
    compose,
    faces_oracle,
    merge_parts,
    part_names,
    run_faces_persistent,
    run_faces_pipelined,
)
from repro.core.descriptors import StartDesc, WaitDesc
from repro.core.halo import AXES3


def _mesh111():
    from repro.parallel import make_mesh
    return make_mesh((1, 1, 1), AXES3)


def _meshx():
    from repro.parallel import make_mesh
    return make_mesh((1,), ("x",))


def _u0(cfg, seed=0):
    rng = np.random.RandomState(seed)
    return rng.randn(*cfg.grid, *cfg.points).astype(np.float32)


def _linked_pair(mesh):
    """A sends its buffer into B's slot; B doubles what it received."""
    qa = STQueue(mesh, name="A")
    qa.buffer("a", (4,), np.float32, pspec=("x",))
    qa.enqueue_send("a", OffsetPeer("x", 0, periodic=True), tag=7,
                    remote="B")
    qa.enqueue_start()
    qa.enqueue_wait()
    pa = qa.build()

    qb = STQueue(mesh, name="B")
    qb.buffer("slot", (4,), np.float32, pspec=("x",))
    qb.buffer("out", (4,), np.float32, pspec=("x",))
    qb.enqueue_recv("slot", OffsetPeer("x", 0, periodic=True), tag=7,
                    remote="A")
    qb.enqueue_start()
    qb.enqueue_wait()
    qb.enqueue_kernel(lambda s: s * 2.0, ["slot"], ["out"], name="double")
    pb = qb.build()
    return pa, pb


# -- structure ----------------------------------------------------------------


class TestLinkStructure:
    def test_open_links_counted_and_resolved(self):
        mesh = _meshx()
        pa, pb = _linked_pair(mesh)
        assert pa.open_links == 1 and pb.open_links == 1
        sched = compose(pa, pb)
        assert sched.open_links == 0
        assert len(sched.links) == 1
        l = sched.links[0]
        assert (l.src, l.dst, l.tag) == ("A", "B", 7)
        assert l.dst_buf == "B/slot"
        # the channel joined A's trigger batch, carrying B's pid
        ba = next(b for b in sched.batches if b.pid == 0)
        bb = next(b for b in sched.batches if b.pid == 1)
        cross = [c for c in ba.channels if c.dst_pid is not None]
        assert len(cross) == 1 and cross[0].dst_pid == 1
        assert cross[0].src_buf == "A/a" and cross[0].dst_buf == "B/slot"
        # ...and B's batch gates the deposit at its wait
        assert bb.cross_recv_bufs == ("B/slot",)
        assert all(c.dst_pid is None for c in bb.channels)

    def test_links_declaration_checked(self):
        mesh = _meshx()
        pa, pb = _linked_pair(mesh)
        sched = compose(pa, pb, links=[("A", "B")])
        assert len(sched.links) == 1
        pa, pb = _linked_pair(mesh)
        with pytest.raises(ScheduleError, match="links="):
            compose(pa, pb, links=[("A", "B"), ("B", "A")])

    def test_trigger_precedes_consumer_wait(self):
        """The interleaver must emit A's start before B's gating wait —
        for every resolved link (the deposit must already be in the
        stream when the consumer gates on it)."""
        mesh = _meshx()
        pa, pb = _linked_pair(mesh)
        sched = compose(pa, pb)
        descs = list(sched.descriptors)
        for l in sched.links:
            src_pid = sched.sub(l.src).pid
            dst_pid = sched.sub(l.dst).pid
            start_i = next(i for i, d in enumerate(descs)
                           if isinstance(d, StartDesc)
                           and d.pid == src_pid and d.batch == l.src_batch)
            wait_i = next((i for i, d in enumerate(descs)
                           if isinstance(d, WaitDesc)
                           and d.pid == dst_pid and d.batch >= l.dst_batch),
                          None)
            assert wait_i is None or start_i < wait_i

    def test_faces_part_links_structure(self):
        """The linked Faces split realizes the expected link topology:
        ghost ring between adjacent parts + x-crossing halos between
        the ends, triggers always ahead of the consumers' waits."""
        mesh = _mesh111()
        cfg = FacesConfig(grid=(1, 1, 1), points=(6, 3, 3), periodic=True)
        n = 3
        names = part_names(n)
        progs = [build_faces_part_program(cfg, mesh, k, n).persistent(2)
                 for k in range(n)]
        sched = compose(*progs)
        pairs = {(l.src, l.dst) for l in sched.links}
        ring = {(names[k], names[(k + 1) % n]) for k in range(n)}
        ring |= {(b, a) for a, b in ring}
        ends = {(names[0], names[-1]), (names[-1], names[0])}
        assert pairs == ring | ends
        # 9 x-crossing directions each way + 2 ghost planes per ring edge
        n_cross = sum(1 for l in sched.links if l.dst_buf.endswith("glo")
                      or l.dst_buf.endswith("ghi"))
        assert n_cross == 2 * n
        assert len(sched.links) == 2 * n + 18
        # trigger-before-wait holds across the whole stream
        descs = list(sched.descriptors)
        for l in sched.links:
            src_pid, dst_pid = sched.sub(l.src).pid, sched.sub(l.dst).pid
            start_i = next(i for i, d in enumerate(descs)
                           if isinstance(d, StartDesc)
                           and d.pid == src_pid and d.batch == l.src_batch)
            wait_i = next(i for i, d in enumerate(descs)
                          if isinstance(d, WaitDesc)
                          and d.pid == dst_pid and d.batch >= l.dst_batch)
            assert start_i < wait_i, l


# -- error surface ------------------------------------------------------------


class TestLinkErrors:
    def test_engines_reject_open_program(self):
        mesh = _meshx()
        pa, _ = _linked_pair(mesh)
        for cls in (FusedEngine, HostEngine, PersistentEngine):
            with pytest.raises(ValueError, match="compose"):
                cls(pa)

    def test_remote_to_unknown_program(self):
        mesh = _meshx()
        pa, pb = _linked_pair(mesh)
        with pytest.raises(ScheduleError, match="unknown program"):
            compose(pa)  # peer 'B' missing from the composition

    def test_remote_to_self_rejected_at_build(self):
        from repro.core import QueueError
        mesh = _meshx()
        q = STQueue(mesh, name="A")
        q.buffer("a", (4,), np.float32, pspec=("x",))
        q.enqueue_send("a", OffsetPeer("x", 0, periodic=True), tag=0,
                       remote="A")
        q.enqueue_start()
        with pytest.raises(QueueError, match="itself"):
            q.build()

    def test_unmatched_cross_send(self):
        mesh = _meshx()
        pa, _ = _linked_pair(mesh)
        qb = STQueue(mesh, name="B")  # B posts no matching remote recv
        qb.buffer("slot", (4,), np.float32, pspec=("x",))
        with pytest.raises(ScheduleError, match="unmatched cross-program"):
            compose(pa, qb.build())

    def test_unwaited_cross_recv_rejected(self):
        """A remote receive whose batch is never waited has no gate to
        order the deposit against — compose must refuse it rather than
        let the consumer race the sender's trigger."""
        mesh = _meshx()
        qa = STQueue(mesh, name="A")
        qa.buffer("a", (4,), np.float32, pspec=("x",))
        qa.enqueue_send("a", OffsetPeer("x", 0, periodic=True), tag=0,
                        remote="B")
        qa.enqueue_start()
        qa.enqueue_wait()
        qb = STQueue(mesh, name="B")
        qb.buffer("slot", (4,), np.float32, pspec=("x",))
        qb.buffer("out", (4,), np.float32, pspec=("x",))
        qb.enqueue_recv("slot", OffsetPeer("x", 0, periodic=True), tag=0,
                        remote="A")
        qb.enqueue_start()  # no wait: the deposit is never gated
        qb.enqueue_kernel(lambda s: s * 2.0, ["slot"], ["out"], name="k")
        with pytest.raises(ScheduleError, match="no following enqueue_wait"):
            compose(qa.build(), qb.build())

    def test_link_cycle_detected(self):
        """Two programs whose gating waits each precede the other's
        trigger cannot be interleaved — a composition deadlock."""
        mesh = _meshx()

        def prog(name, peer):
            q = STQueue(mesh, name=name)
            q.buffer("a", (4,), np.float32, pspec=("x",))
            q.buffer("slot", (4,), np.float32, pspec=("x",))
            q.enqueue_recv("slot", OffsetPeer("x", 0, periodic=True), tag=0,
                           remote=peer)
            q.enqueue_start()
            q.enqueue_wait()      # gates on the peer's send...
            q.enqueue_send("a", OffsetPeer("x", 0, periodic=True), tag=0,
                           remote=peer)
            q.enqueue_start()     # ...which only triggers after our wait
            q.enqueue_wait()
            return q.build()

        with pytest.raises(ScheduleError, match="cycle"):
            compose(prog("A", "B"), prog("B", "A"))


# -- numerics (tiny linked program, all engines) ------------------------------


@pytest.mark.parametrize("engine_cls", [FusedEngine, HostEngine])
def test_tiny_link_deposits_across_programs(engine_cls):
    mesh = _meshx()
    pa, pb = _linked_pair(mesh)
    sched = compose(pa, pb)
    eng = engine_cls(sched)
    a = np.arange(4, dtype=np.float32)
    out = eng(eng.init_buffers({"A/a": a}))
    np.testing.assert_array_equal(np.asarray(out["B/slot"]), a)
    np.testing.assert_array_equal(np.asarray(out["B/out"]), 2.0 * a)


@pytest.mark.parametrize("mode", ["stream", "dataflow"])
def test_tiny_link_fused_modes(mode):
    mesh = _meshx()
    pa, pb = _linked_pair(mesh)
    sched = compose(pa, pb)
    eng = FusedEngine(sched, mode=mode)
    a = np.arange(4, dtype=np.float32) + 1.0
    out = eng(eng.init_buffers({"A/a": a}))
    np.testing.assert_array_equal(np.asarray(out["B/out"]), 2.0 * a)


# -- acceptance: linked N-way split == full-domain solve ----------------------


@pytest.mark.parametrize("n_parts,points", [
    (2, (6, 4, 3)),
    (2, (5, 4, 3)),   # odd: uneven halves (3, 2) pipeline instead of erroring
    (3, (7, 3, 4)),   # uneven three-way (3, 2, 2)
    (4, (6, 3, 3)),   # parts of a single plane each ride along too
])
def test_linked_pipelined_bitmatches_full_domain(n_parts, points):
    """THE acceptance contrast: the linked composed run IS the
    full-domain run — bit-identical in stream mode (and uncoalesced
    dataflow), one dispatch.  Default dataflow+coalesce drifts only by
    the documented FMA-contraction ULPs (see test_schedule's slow lane)
    and must stay within 4 ULP x n_iters."""
    n = 3
    cfg = FacesConfig(grid=(1, 1, 1), points=points, periodic=True)
    mesh = _mesh111()
    u0 = _u0(cfg, seed=11)
    names = part_names(n_parts)

    # stream mode: bit-identical
    full, _ = run_faces_persistent(cfg, mesh, u0, n_iters=n, mode="stream")
    mem, stats = run_faces_pipelined(cfg, mesh, u0, n_iters=n,
                                     n_parts=n_parts, mode="stream")
    assert stats.dispatches == 1 and stats.sync_points == 0
    got = np.asarray(merge_parts([mem[f"{nm}/u"] for nm in names]))
    np.testing.assert_array_equal(got, np.asarray(full["u"]))

    # ...and against the NumPy oracle (the exchange is a real solve)
    ref = u0
    for _ in range(n):
        ref = faces_oracle(ref, cfg)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)

    # dataflow + coalesced (the default fast path): documented ULP bound
    fulld, _ = run_faces_persistent(cfg, mesh, u0, n_iters=n,
                                    mode="dataflow")
    memd, statsd = run_faces_pipelined(cfg, mesh, u0, n_iters=n,
                                       n_parts=n_parts, mode="dataflow")
    assert statsd.dispatches == 1
    gotd = np.asarray(merge_parts([memd[f"{nm}/u"] for nm in names]))
    np.testing.assert_array_max_ulp(gotd, np.asarray(fulld["u"]),
                                    maxulp=4 * n)


def test_linked_pipelined_uncoalesced_dataflow_exact():
    """With coalescing off the dataflow comparison is exact too — the
    ULP drift is strictly a property of the fused-transfer lowering."""
    from repro.core.halo import split_parts

    cfg = FacesConfig(grid=(1, 1, 1), points=(5, 4, 3), periodic=True)
    mesh = _mesh111()
    u0 = _u0(cfg, seed=12)
    n_parts, n = 2, 3
    names = part_names(n_parts)

    from repro.core import build_faces_program
    full = build_faces_program(cfg, mesh).persistent(n)
    ef = PersistentEngine(full, mode="dataflow", coalesce=False)
    want = np.asarray(ef(ef.init_buffers({"u": u0}))["u"])

    progs = [build_faces_part_program(cfg, mesh, k, n_parts).persistent(n)
             for k in range(n_parts)]
    eng = PersistentEngine(compose(*progs), mode="dataflow", coalesce=False)
    mem = eng(eng.init_buffers(
        {f"{nm}/u": p for nm, p in zip(names, split_parts(u0, n_parts))}))
    got = np.asarray(merge_parts([mem[f"{nm}/u"] for nm in names]))
    np.testing.assert_array_equal(got, want)


def test_linked_single_pass_engines_match_full_program():
    """One interpreted pass of the linked composition equals one pass of
    the full program — fused and host engines alike."""
    cfg = FacesConfig(grid=(1, 1, 1), points=(6, 3, 3), periodic=True)
    mesh = _mesh111()
    u0 = _u0(cfg, seed=13)
    n_parts = 3
    names = part_names(n_parts)
    from repro.core import build_faces_program
    from repro.core.halo import split_parts

    full_prog = build_faces_program(cfg, mesh)
    progs = [build_faces_part_program(cfg, mesh, k, n_parts)
             for k in range(n_parts)]
    sched = compose(*progs)
    for cls, kw in ((FusedEngine, {"mode": "stream"}), (HostEngine, {})):
        ref_eng = cls(full_prog, **kw)
        want = np.asarray(ref_eng(ref_eng.init_buffers({"u": u0}))["u"])
        eng = cls(sched, **kw)
        mem = eng(eng.init_buffers(
            {f"{nm}/u": p for nm, p in zip(names, split_parts(u0, n_parts))}))
        got = np.asarray(merge_parts([mem[f"{nm}/u"] for nm in names]))
        np.testing.assert_array_equal(got, want, err_msg=cls.__name__)


def test_linked_pipelined_with_tolerances_freezes_parts():
    """Per-part predicates still work under links: a converged part
    freezes while its neighbor keeps reading the frozen boundary
    (masked multi-queue loop), one dispatch throughout.

    Two regimes are pinned: with equal tolerances both parts converge
    normally; with a much tighter tolerance on one part, the other
    part's frozen boundary keeps injecting energy every iteration, so
    the tight part's residual plateaus at a nonzero fixed point and it
    runs to the max_iters bound — linked parts are a COUPLED system,
    not N independent solves."""
    cfg = FacesConfig(grid=(1, 1, 1), points=(6, 3, 4), periodic=True,
                      damping=0.12)
    mesh = _mesh111()
    u0 = _u0(cfg, seed=14)

    mem, reds, n_done, stats = run_faces_pipelined(
        cfg, mesh, u0, tols=(1e-1, 1e-1), max_iters=50)
    assert stats.dispatches == 1 and stats.sync_points == 0
    for nm in part_names(2):
        assert 1 <= n_done[nm] < 50
        assert reds[nm][-1] < 1e-1 <= reds[nm][:-1].min()

    mem, reds, n_done, stats = run_faces_pipelined(
        cfg, mesh, u0, tols=(1e-1, 1e-3), max_iters=50)
    assert stats.dispatches == 1
    assert n_done["facesA"] < 50 and reds["facesA"][-1] < 1e-1
    # the tight part hits the bound: its residual floor is set by the
    # frozen neighbor's boundary injection, well above its tolerance
    assert n_done["facesB"] == 50
    assert reds["facesB"][-1] >= 1e-3
    np.testing.assert_allclose(reds["facesB"][-1], reds["facesB"][-5],
                               rtol=1e-3)  # plateaued, not diverging


def test_linked_requires_direct26_and_batched():
    cfg = FacesConfig(grid=(1, 1, 1), points=(6, 3, 3),
                      granularity="staged3")
    with pytest.raises(ValueError, match="direct26"):
        build_faces_part_program(cfg, _mesh111(), 0, 2)
    cfg = FacesConfig(grid=(1, 1, 1), points=(6, 3, 3), batched=False)
    with pytest.raises(ValueError, match="batched"):
        build_faces_part_program(cfg, _mesh111(), 0, 2)
    cfg = FacesConfig(grid=(1, 1, 1), points=(6, 3, 3))
    with pytest.raises(ValueError, match="n_parts"):
        build_faces_part_program(cfg, _mesh111(), 0, 1)


def test_linked_no_interior_compute():
    """interior_compute=False drops the ghost ring (only the x-crossing
    links remain) and still bit-matches the full-domain run."""
    cfg = FacesConfig(grid=(1, 1, 1), points=(6, 3, 3), periodic=True,
                      interior_compute=False)
    mesh = _mesh111()
    u0 = _u0(cfg, seed=15)
    names = part_names(2)
    full, _ = run_faces_persistent(cfg, mesh, u0, n_iters=2, mode="stream")
    mem, stats = run_faces_pipelined(cfg, mesh, u0, n_iters=2, n_parts=2,
                                     mode="stream")
    assert stats.dispatches == 1
    got = np.asarray(merge_parts([mem[f"{nm}/u"] for nm in names]))
    np.testing.assert_array_equal(got, np.asarray(full["u"]))


# -- multi-device matrix (subprocess, slow lane) ------------------------------


@pytest.mark.slow
def test_linked_pipelined_matches_full_domain_8dev(subproc):
    r = subproc("""
import numpy as np
from repro.core import (FacesConfig, run_faces_persistent,
                        run_faces_pipelined, merge_parts, part_names)
from repro.parallel import make_mesh

mesh = make_mesh((2, 2, 2), ("gx", "gy", "gz"))
cfg = FacesConfig(grid=(2, 2, 2), points=(6, 4, 4))
u0 = np.random.RandomState(0).randn(2, 2, 2, 6, 4, 4).astype(np.float32)
N = 3

for n_parts in (2, 3):
    names = part_names(n_parts)
    # stream mode: the linked composed run IS the full-domain run, bit
    # for bit, on the real 8-device grid (x-crossing halos hop devices)
    full, _ = run_faces_persistent(cfg, mesh, u0, n_iters=N, mode="stream")
    mem, stats = run_faces_pipelined(cfg, mesh, u0, n_iters=N,
                                     n_parts=n_parts, mode="stream")
    assert stats.dispatches == 1
    got = np.asarray(merge_parts([mem[f"{nm}/u"] for nm in names]))
    np.testing.assert_array_equal(got, np.asarray(full["u"]))

    # dataflow default: only the documented coalesced-lowering FMA
    # drift (see tests/test_schedule.py slow lane) — a few eps per
    # element per iteration, amplified by the boundary accumulation;
    # rtol=1e-5 (~80 eps) holds with headroom on the 8-device grid
    fulld, _ = run_faces_persistent(cfg, mesh, u0, n_iters=N,
                                    mode="dataflow")
    memd, statsd = run_faces_pipelined(cfg, mesh, u0, n_iters=N,
                                       n_parts=n_parts, mode="dataflow")
    assert statsd.dispatches == 1
    gotd = np.asarray(merge_parts([memd[f"{nm}/u"] for nm in names]))
    np.testing.assert_allclose(gotd, np.asarray(fulld["u"]),
                               rtol=1e-5, atol=1e-6)
    print(f"n_parts={n_parts} OK")
print("linked 8dev OK")
""")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "linked 8dev OK" in r.stdout
