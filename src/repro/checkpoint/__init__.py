"""Checkpoint substrate."""
from .checkpoint import restore_pytree, save_pytree, latest_step

__all__ = ["save_pytree", "restore_pytree", "latest_step"]
