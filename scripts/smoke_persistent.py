"""Smoke the persistent engine on 8 host devices: N Faces iterations as
ONE host dispatch, vs the host engine's N × per-op dispatches."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    FacesConfig, HostEngine, PersistentEngine, build_faces_program,
    faces_oracle,
)
from repro.core.halo import AXES3

N = 5
mesh = jax.make_mesh((2, 2, 2), AXES3)
cfg = FacesConfig(grid=(2, 2, 2), points=(5, 4, 3))
prog = build_faces_program(cfg, mesh).persistent(N)
print("batches:", prog.n_batches, "channels:", prog.n_channels,
      "n_iters:", prog.n_iters)

rng = np.random.RandomState(0)
u0 = rng.randn(2, 2, 2, 5, 4, 3).astype(np.float32)

ref = u0
for _ in range(N):
    ref = faces_oracle(ref, cfg)

host = HostEngine(prog)
hmem = host.init_buffers({"u": u0})
for _ in range(N):
    hmem = host(hmem)
np.testing.assert_allclose(np.asarray(hmem["u"]), ref, rtol=1e-4, atol=1e-4)
print(f"host     OK dispatches={host.stats.dispatches} "
      f"(= {N} x {prog.dispatch_count_host()})")

for mode in ("stream", "dataflow"):
    eng = PersistentEngine(prog, mode=mode)
    out = eng(eng.init_buffers({"u": u0}))
    np.testing.assert_allclose(np.asarray(out["u"]), ref, rtol=1e-4, atol=1e-4)
    print(f"persistent[{mode}] OK dispatches={eng.stats.dispatches} "
          f"double_buffer={eng.double_buffer} slots={len(eng._slots)}")

# convergence-style loop: per-iteration residual with zero host syncs
def sq_norm(mem):
    return jax.lax.psum(jnp.sum(mem["u"].astype(jnp.float32) ** 2), AXES3)

eng = PersistentEngine(prog, mode="dataflow", reduce_fn=sq_norm)
out, residuals = eng(eng.init_buffers({"u": u0}))
print("residual trace:", [f"{float(r):.3e}" for r in np.asarray(residuals)])
assert residuals.shape == (N,)
print("PERSISTENT SMOKE PASS")
