"""Quickstart: the ST (stream-triggered) communication API in 60 lines.

Mirrors the paper's Fig. 7 usage example: enqueue kernels + batched
sends/receives on a queue, trigger them with ONE start, gate downstream
work with ONE wait — then execute the whole thing as a single fused XLA
program (the TPU analogue of GPU-CP-driven triggered operations).

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/quickstart.py
"""
import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

from repro.core import FusedEngine, HostEngine, OffsetPeer, create_queue
from repro.parallel import make_mesh

mesh = make_mesh((8,), ("rank",))
q = create_queue(mesh, "quickstart")

# Buffers (global view, sharded over the rank axis).
q.buffer("src", (8, 128), np.float32, pspec=("rank",))
q.buffer("dst", (8, 128), np.float32, pspec=("rank",))

# D1: a compute kernel producing the data to send (paper: launch kernel).
q.enqueue_kernel(lambda s: s * 2.0 + 1.0, reads=["src"], writes=["src"],
                 name="D1")

# Batched ST communication: 4 tagged sends to the right neighbor, 4
# matching receives from the left — ONE start triggers all of them.
for tag in range(4):
    q.enqueue_recv("dst", OffsetPeer("rank", -1, periodic=True), tag=tag,
                   mode="add")
for tag in range(4):
    q.enqueue_send("src", OffsetPeer("rank", +1, periodic=True), tag=tag)
q.enqueue_start()   # MPIX_Enqueue_start  (writeValue → NIC trigger)
q.enqueue_wait()    # MPIX_Enqueue_wait   (waitValue → stream gate)

# D2: consumes the received data; ordered after the wait.
q.enqueue_kernel(lambda d: d / 4.0, reads=["dst"], writes=["dst"], name="D2")

prog = q.build()
print(f"program: {prog.n_batches} trigger batch(es), {prog.n_channels} "
      f"matched channels, host dispatches {prog.dispatch_count_host()} "
      f"vs fused {prog.dispatch_count_fused()}")

# ST execution: ONE device program.
st = FusedEngine(prog, mode="stream")
mem = st.init_buffers({"src": np.ones((8, 128), np.float32)})
out_st = st(mem)

# Baseline execution: host-orchestrated per-descriptor dispatch (Fig. 1).
host = HostEngine(prog, sync="every_op")
out_host = host(host.init_buffers({"src": np.ones((8, 128), np.float32)}))

np.testing.assert_allclose(np.asarray(out_st["dst"]),
                           np.asarray(out_host["dst"]), rtol=1e-6)
print("fused ST result == host-orchestrated result ✓")
print(f"host control path: {host.stats.dispatches} dispatches, "
      f"{host.stats.sync_points} host-device syncs; ST: 1 dispatch, 1 sync")
print("dst row 0 (each rank received 4× its left neighbor's kernel output):")
print(np.asarray(out_st["dst"])[0, :6])
