"""Hypothesis property tests on the system's invariants."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.descriptors import (
    CollDesc,
    GridOffsetPeer,
    KernelDesc,
    OffsetPeer,
    RecvDesc,
    SendDesc,
    StartDesc,
    perm_for,
)
from repro.core.matching import MatchError, match_batch
from repro.parallel import RULES_DECODE, RULES_TRAIN, logical_spec_sized

SETTINGS = settings(max_examples=60, deadline=None,
                    suppress_health_check=[HealthCheck.too_slow])


# -- matching: every well-posed batch matches completely ----------------------

peer_st = st.one_of(
    st.builds(OffsetPeer,
              axis=st.sampled_from(["x", "y"]),
              delta=st.integers(-3, 3).filter(lambda d: d != 0),
              periodic=st.booleans()),
    st.builds(lambda dx, dy, p: GridOffsetPeer(("x", "y"), (dx, dy), p),
              st.integers(-2, 2), st.integers(-2, 2),
              st.booleans()).filter(lambda g: any(g.deltas)),
)


@SETTINGS
@given(st.lists(st.tuples(peer_st, st.integers(0, 5)), min_size=1, max_size=12))
def test_matching_total_when_recvs_mirror_sends(pairs):
    sends = [SendDesc(f"s{i}", p, tag=t) for i, (p, t) in enumerate(pairs)]
    recvs = [RecvDesc(f"r{i}", p.inverse(), tag=t)
             for i, (p, t) in enumerate(pairs)]
    chans = match_batch(sends, recvs)
    assert len(chans) == len(sends)
    # every send buffer appears exactly once as a channel source
    assert sorted(c.src_buf for c in chans) == sorted(s.buf for s in sends)


@SETTINGS
@given(st.lists(st.tuples(peer_st, st.integers(0, 5)), min_size=1, max_size=8),
       st.integers(0, 7))
def test_matching_incomplete_always_raises(pairs, drop_idx):
    sends = [SendDesc(f"s{i}", p, tag=t) for i, (p, t) in enumerate(pairs)]
    recvs = [RecvDesc(f"r{i}", p.inverse(), tag=t)
             for i, (p, t) in enumerate(pairs)]
    del recvs[drop_idx % len(recvs)]
    with pytest.raises(MatchError):
        match_batch(sends, recvs)


# -- perms: permutations are always injective and in-range ---------------------


@SETTINGS
@given(peer_st, st.integers(1, 5), st.integers(1, 5))
def test_perm_injective_and_in_range(peer, nx, ny):
    shape = {"x": nx, "y": ny}
    if isinstance(peer, OffsetPeer):
        n = shape[peer.axis]
    else:
        n = nx * ny
    _, pairs = perm_for(peer, shape)
    srcs = [s for s, _ in pairs]
    dsts = [d for _, d in pairs]
    assert len(set(srcs)) == len(srcs)
    assert len(set(dsts)) == len(dsts)
    assert all(0 <= s < n and 0 <= d < n for s, d in pairs)


# -- composition: per-program FIFO order and batch atomicity -------------------

# a program spec is a list of batches; each batch is (n_kernels, n_msgs,
# wait_after) — built into a real STQueue program on a shared 1-device mesh
batch_st = st.tuples(st.integers(0, 2), st.integers(1, 3), st.booleans())
program_st = st.lists(batch_st, min_size=1, max_size=4)


def _build_program(mesh, name, spec):
    from repro.core import STQueue

    q = STQueue(mesh, name=name)
    q.buffer("a", (4,), np.float32, pspec=("x",))
    q.buffer("b", (4,), np.float32, pspec=("x",))
    tag = 0
    for bi, (n_kernels, n_msgs, wait_after) in enumerate(spec):
        for k in range(n_kernels):
            q.enqueue_kernel(lambda a: a * 2.0, ["a"], ["a"],
                             name=f"k{bi}_{k}")
        for _ in range(n_msgs):
            q.enqueue_recv("b", OffsetPeer("x", -1, periodic=True), tag=tag)
            q.enqueue_send("a", OffsetPeer("x", 1, periodic=True), tag=tag)
            tag += 1
        q.enqueue_start()
        if wait_after:
            q.enqueue_wait()
    return q.build()


def _strip_ns(desc):
    """Descriptor identity modulo namespacing/renumbering, for order
    comparison."""
    if isinstance(desc, KernelDesc):
        return ("kernel", desc.name)
    if isinstance(desc, SendDesc):
        return ("send", desc.buf.split("/", 1)[-1], desc.tag)
    if isinstance(desc, RecvDesc):
        return ("recv", desc.buf.split("/", 1)[-1], desc.tag)
    if isinstance(desc, CollDesc):
        return ("coll", desc.op, desc.buf.split("/", 1)[-1])
    if isinstance(desc, StartDesc):
        return ("start",)
    return ("wait",)


@SETTINGS
@given(program_st, program_st)
def test_compose_preserves_fifo_and_batch_atomicity(spec_a, spec_b):
    from repro.core import compose
    from repro.parallel import make_mesh

    mesh = make_mesh((1,), ("x",))
    pa = _build_program(mesh, "A", spec_a)
    pb = _build_program(mesh, "B", spec_b)
    sched = compose(pa, pb)

    # 1. each program's internal FIFO order survives composition exactly
    for pid, orig in ((0, pa), (1, pb)):
        mine = [d for d in sched.descriptors if d.pid == pid]
        assert [_strip_ns(d) for d in mine] == \
            [_strip_ns(d) for d in orig.descriptors]

    # 2. no interleaving within a batch: from the first deferred comm op
    # of any batch to its covering start, every descriptor shares a pid
    open_pid = None
    for d in sched.descriptors:
        if isinstance(d, (SendDesc, RecvDesc, CollDesc)):
            assert open_pid in (None, d.pid), (
                f"batch of pid {open_pid} interleaved with pid {d.pid}")
            open_pid = d.pid
        elif isinstance(d, StartDesc):
            assert open_pid in (None, d.pid)
            open_pid = None
        elif open_pid is not None:
            # kernels/waits inside an open batch must belong to it
            assert d.pid == open_pid

    # 3. composed batches keep their per-program channel counts
    for pid, orig in ((0, pa), (1, pb)):
        mine = sorted((b for b in sched.batches if b.pid == pid),
                      key=lambda b: b.index)
        assert [len(b.channels) for b in mine] == \
            [len(b.channels) for b in orig.batches]
        assert [b.waited for b in mine] == [b.waited for b in orig.batches]


# -- sharding: resolved specs always divide the shape ---------------------------

AXES_POOL = [None, "batch", "seq", "embed", "heads", "kv_heads", "mlp",
             "vocab", "expert", "layers", "cache_seq"]


@SETTINGS
@given(st.lists(st.tuples(st.sampled_from(AXES_POOL),
                          st.integers(1, 4096)),
                min_size=1, max_size=5),
       st.sampled_from(["train", "decode"]))
def test_logical_spec_sized_always_divides(dims, regime):
    import jax
    from repro.parallel import make_mesh

    rules = RULES_TRAIN if regime == "train" else RULES_DECODE
    # a fake 16x16-shaped mesh over 1 device via abstract mesh:
    mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    shape = tuple(d for _, d in dims)
    axes = tuple(a for a, _ in dims)
    spec = logical_spec_sized(shape, axes, rules, mesh)
    sizes = dict(mesh.shape)
    used = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))):
        if entry is None:
            continue
        names = (entry,) if isinstance(entry, str) else entry
        total = int(np.prod([sizes[n] for n in names]))
        assert dim % total == 0, (shape, axes, spec)
        used.extend(names)
    # no mesh axis may shard two different dims
    assert len(used) == len(set(used))
