"""Deterministic synthetic token/feature pipeline.

Generates reproducible batches (seeded per step) shaped exactly like the
model's ``input_specs``; places them with the same shardings the step
function expects.  This is the training data path for the end-to-end
examples (the paper contributes a communication strategy, not a dataset
— synthetic streams are the appropriate substrate).

The stream is Markov-ish rather than uniform so the CE loss has signal:
token t+1 = (a·token_t + noise) mod vocab with per-sequence drift, which
a model can partially learn — loss decreases measurably over a few
hundred steps of the 100M-param example.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    seed: int = 0
    drift: int = 7          # deterministic next-token multiplier
    noise_frac: float = 0.1 # fraction of tokens replaced by noise


class SyntheticTokens:
    """Stateless batch source: batch(step) is pure in (seed, step)."""

    def __init__(self, model_cfg: ModelConfig, shape: ShapeConfig,
                 cfg: SyntheticConfig = SyntheticConfig()):
        self.model_cfg = model_cfg
        self.shape = shape
        self.cfg = cfg

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        mc, sh, cfg = self.model_cfg, self.shape, self.cfg
        rng = np.random.RandomState((cfg.seed * 100003 + step) % (2**31 - 1))
        B, S = sh.global_batch, sh.seq_len
        start = rng.randint(0, mc.vocab, (B, 1))
        steps = np.arange(S + 1)[None, :]
        seq = (start + cfg.drift * steps) % mc.vocab
        noise_mask = rng.rand(B, S + 1) < cfg.noise_frac
        noise = rng.randint(0, mc.vocab, (B, S + 1))
        seq = np.where(noise_mask, noise, seq).astype(np.int32)
        out = {"tokens": seq[:, :S], "targets": seq[:, 1:]}
        if mc.enc_dec:
            out["audio_embeds"] = rng.randn(
                B, mc.frontend_tokens, mc.frontend_dim).astype(np.float32)
        if mc.frontend == "vision":
            out["vision_embeds"] = rng.randn(
                B, mc.frontend_tokens, mc.frontend_dim).astype(np.float32)
        return out

    def device_batch(self, step: int, shardings: Optional[Dict] = None):
        host = self.batch(step)
        if shardings is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        return {k: jax.device_put(v, shardings[k]) for k, v in host.items()}


def make_batch_specs(model_cfg: ModelConfig, shape: ShapeConfig):
    """Logical axes for each batch entry (resolved by the launcher)."""
    specs = {"tokens": ("batch", "seq"), "targets": ("batch", "seq")}
    if model_cfg.enc_dec:
        specs["audio_embeds"] = ("batch", None, "frontend")
    if model_cfg.frontend == "vision":
        specs["vision_embeds"] = ("batch", None, "frontend")
    return specs
