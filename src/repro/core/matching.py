"""Static two-sided message matching.

MPI two-sided semantics normally require runtime matching of
(source, tag, communicator) against posted receives — the part of the
paper's design that Slingshot 11 could *not* offload (no triggered
receives) and that forced the per-process progress thread.

The ST interface forbids ``MPI_ANY_SOURCE`` / ``MPI_ANY_TAG``
(paper §III-D), which makes the match function *static*: every send's
peer and tag are known when the program is built.  On TPU we exploit
this fully — matching happens **at trace time**, and each matched
(send, recv) pair lowers to one ``ppermute`` channel.  There is no
runtime matching engine and therefore no progress thread; the paper's
progress-thread cost reappears only in the host-orchestrated engine as
per-descriptor dispatch overhead.

Matching rules (mirroring MPI ordering guarantees):

* within one trigger batch, sends and recvs with equal tags match in
  FIFO order (non-overtaking);
* a send with peer ``OffsetPeer(axis, +d)`` matches a recv with peer
  ``OffsetPeer(axis, -d)`` (the receiver names where the data comes
  *from*); same for grid offsets;
* ``PairListPeer`` sends/recvs match when their (src → dst) pair sets
  are identical;
* unmatched descriptors inside a batch are a program error, raised at
  build time — the paper's equivalent would be a hang;
* sends/recvs marked ``remote=<program>`` are *cross-program*: the
  queue's own build leaves them open, and
  :func:`repro.core.schedule.compose` matches them across the composed
  programs (:func:`match_cross_program`) into channels that deposit
  into the peer program's memory and bump the peer's completion
  counter.  A program with open descriptors that is never composed is
  an error at engine construction (it, too, would hang).

Channel coalescing (paper §V-A contiguous-buffer step)
------------------------------------------------------
The paper's Faces kernel packs all 26 faces/edges/corners into **one
contiguous MPI buffer** before triggering — many small messages are the
latency killer.  :func:`coalesce_batch` recovers that at build time for
*any* matched batch: channels are grouped by ``(stage, axis,
permutation, dtype)`` after decomposing each multi-axis offset into
single-axis hops (:func:`~repro.core.descriptors.hop_decomposition`),
and each group lowers to ONE fused transfer — member slabs packed at
static offsets into one staging buffer, one wide ``ppermute``, payloads
relayed verbatim between stages, and per-channel deposits replayed in
the original channel order so results are **bit-identical** to the
uncoalesced interpreter.  Direct26 drops from 26 collectives per start
gate to 6 (one per axis × direction); an axis-aligned staged exchange
keeps 2 per gate.  The plan is recorded on the
:class:`Batch` (``plan``) so engines, stats and tests all see the same
:class:`CoalescedChannel` descriptors.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .descriptors import (
    CollDesc,
    GridOffsetPeer,
    OffsetPeer,
    PairListPeer,
    RecvDesc,
    SendDesc,
    hop_decomposition,
    perm_for,
)


@dataclasses.dataclass
class Channel:
    """A matched (send, recv) pair lowered to one ppermute channel."""

    src_buf: str
    dst_buf: str
    axis: Any  # axis name or tuple of axis names
    peer: Any  # the *send-side* peer spec (canonical direction)
    tag: int
    send_region: Optional[Tuple[slice, ...]]
    recv_region: Optional[Tuple[slice, ...]]
    mode: str  # replace | add
    # Cross-program channel (see repro.core.schedule.compose): the pid
    # whose buffer the deposit lands in — and whose completion counter
    # the transfer bumps.  None = the owning batch's own program.
    dst_pid: Optional[int] = None
    # Enqueue-site provenance of the matched send/recv descriptors
    # ("file:line"; threaded into verify.py diagnostics).
    send_site: Optional[str] = None
    recv_site: Optional[str] = None

    def perm(self, mesh_shape: dict) -> Sequence[Tuple[int, int]]:
        return perm_for(self.peer, mesh_shape)[1]


class MatchError(RuntimeError):
    pass


def _site_of(d) -> str:
    """Enqueue-site suffix for error messages ('' when not captured)."""
    site = getattr(d, "site", None)
    return f" [enqueued at {site}]" if site else ""


def _peer_key(peer) -> Tuple:
    """Canonical direction key: send(+d) and recv(-d) share a key."""
    if isinstance(peer, OffsetPeer):
        return ("off", peer.axis, peer.delta, peer.periodic)
    if isinstance(peer, GridOffsetPeer):
        return ("grid", peer.axes, peer.deltas, peer.periodic)
    if isinstance(peer, PairListPeer):
        return ("pairs", peer.axis, tuple(sorted(peer.pairs)))
    raise TypeError(f"unknown peer: {peer!r}")


def _recv_key_as_send(peer) -> Tuple:
    """Key a recv descriptor under the *sender's* direction."""
    if isinstance(peer, (OffsetPeer, GridOffsetPeer)):
        return _peer_key(peer.inverse())
    return _peer_key(peer)


def _match_fifo(sends, recvs, make_channel, kind: str) -> List:
    """Shared FIFO matcher: pair each send with the first queued recv
    under the same (peer-direction, tag) key, build a result via
    ``make_channel(send, recv)``, and raise on any leftover.

    ``sends``/``recvs`` may carry bookkeeping payloads: each element is
    either a bare descriptor or a ``(descriptor, extra)`` pair, and
    ``make_channel`` receives the elements unmodified.
    """
    desc = lambda x: x[0] if isinstance(x, tuple) else x
    recv_queues: dict = defaultdict(list)
    for r in recvs:
        recv_queues[(_recv_key_as_send(desc(r).peer), desc(r).tag)].append(r)

    out: List = []
    for s in sends:
        d = desc(s)
        q = recv_queues.get((_peer_key(d.peer), d.tag))
        if not q:
            raise MatchError(
                f"unmatched {kind} send: buf={d.buf!r} tag={d.tag} "
                f"peer={d.peer}"
                + (f" remote={d.remote!r}" if d.remote else "")
                + " (no matching posted receive; ST forbids wildcards so "
                  "this would hang at runtime)"
                + _site_of(d)
            )
        out.append(make_channel(s, q.pop(0)))

    leftovers = [desc(r) for q in recv_queues.values() for r in q]
    if leftovers:
        r = leftovers[0]
        raise MatchError(
            f"unmatched {kind} recv: buf={r.buf!r} tag={r.tag} peer={r.peer}"
            + (f" remote={r.remote!r}" if r.remote else "")
            + f" ({len(leftovers)} receive(s) never matched by a send)"
            + _site_of(r)
        )
    return out


def _channel_for(s: SendDesc, r: RecvDesc,
                 dst_pid: Optional[int] = None) -> Channel:
    """Lower one matched (send, recv) pair to its ppermute channel."""
    axis = (
        s.peer.axis
        if isinstance(s.peer, (OffsetPeer, PairListPeer))
        else s.peer.axes
    )
    return Channel(
        src_buf=s.buf,
        dst_buf=r.buf,
        axis=axis,
        peer=s.peer,
        tag=s.tag,
        send_region=s.region,
        recv_region=r.region,
        mode=r.mode,
        dst_pid=dst_pid,
        send_site=s.site,
        recv_site=r.site,
    )


def match_batch(
    sends: Sequence[SendDesc], recvs: Sequence[RecvDesc]
) -> List[Channel]:
    """Match one trigger batch's sends against its recvs (FIFO per key)."""
    return _match_fifo(sends, recvs, _channel_for, "ST")


def match_cross_program(
    sends: Sequence[Tuple[SendDesc, int]],
    recvs: Sequence[Tuple[RecvDesc, int]],
    dst_pid: int,
) -> List[Tuple[Channel, int, int]]:
    """Match one program's *open* (``remote=``) sends against a peer
    program's open recvs — the cross-program half of the static match.

    ``sends``/``recvs`` are ``(descriptor, batch_index)`` pairs in the
    owning program's enqueue order (batch indices are the *composed*
    schedule's global indices); matching follows the same FIFO
    non-overtaking rules as :func:`match_batch`, pooled across the
    programs' batches (keys are (peer-direction, tag), so distinct
    batches use distinct tags or distinct directions).

    Returns ``[(channel, src_batch, dst_batch), ...]`` where each
    channel carries ``dst_pid`` — the receiving program's identity: the
    engines trigger it off the *sender's* counter bank but bump the
    *receiver's* completion counter, so the receiver's wait gate
    observes the sender's completion (the cross-stream chaining of
    triggered operations).  Raises :class:`MatchError` if any open
    descriptor of the pair stays unmatched.
    """
    return _match_fifo(
        sends, recvs,
        lambda s, r: (_channel_for(s[0], r[0], dst_pid=dst_pid), s[1], r[1]),
        "cross-program",
    )


@dataclasses.dataclass
class Batch:
    """Everything triggered by one `start` (paper: one writeValue)."""

    index: int
    kernels_before: List[Any]  # KernelDescs enqueued before this start
    channels: List[Channel]
    colls: List[CollDesc]
    waited: bool = False
    # Program identity under composition (see repro.core.schedule):
    # batches keep their owning program's pid so engines can bank
    # counters per program.
    pid: int = 0
    # Build-time coalescing plan (see coalesce_batch); None when the
    # batch was built with coalescing off or declined the batch.
    plan: Optional["CoalescePlan"] = None
    # Whether coalescing was *requested* at build time (compose() must
    # re-derive plans after cross-program channels join the batch, and
    # a None plan alone cannot distinguish "declined" from "off").
    coalesce: bool = False
    # Cross-program descriptors (remote= sends/recvs) this batch holds
    # that are still UNRESOLVED: queue.build() records them here and
    # compose() consumes them.  A program with open descriptors cannot
    # run on an engine — it must be composed with its peer program(s).
    open_sends: List[Any] = dataclasses.field(default_factory=list)
    open_recvs: List[Any] = dataclasses.field(default_factory=list)
    # Resolved cross-program receives: destination buffers deposited
    # into this batch's slot(s) by another program's trigger, which this
    # batch's wait must gate (filled by compose()).
    cross_recv_bufs: Tuple[str, ...] = ()
    # Declared effect set (repro.core.effects.batch_effects): every
    # memory access this batch performs — pack reads, staging traffic,
    # deposits — recorded at build time and re-recorded by compose()
    # once cross-program channels join the batch.  The happens-before
    # analysis and the equivalence certifier consume it.
    effects: Tuple[Any, ...] = ()


# --------------------------------------------------------------------------
# Channel coalescing
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    """One member channel's slab inside a fused transfer's staging buffer."""

    channel: int  # index into the batch's channel list
    hop: int      # hop index along the channel's route
    offset: int   # static element offset into the staging buffer
    size: int     # flattened slab size (local/per-shard elements)


@dataclasses.dataclass(frozen=True)
class CoalescedChannel:
    """One fused transfer: member slabs in one staging buffer, one ppermute.

    The analogue of the paper's single contiguous MPI buffer: every
    member channel whose (current) hop shares this ``(axis, perm)``
    contributes one segment at a static offset; the whole buffer moves
    as ONE collective instead of one per member.
    """

    axis: str
    perm: Tuple[Tuple[int, int], ...]
    dtype: Any
    stage: int  # execution stage (by-axis round) within the batch
    segments: Tuple[Segment, ...]
    # Declared staging-buffer identity (repro.core.effects.stamp_staging
    # fills it in at build/compose time, unique per batch/transfer).
    # Two transfers sharing one identity across happens-before-unordered
    # trigger→wait windows is rule ST017 — reuse is a declared fact
    # here, never inferred from (axis, perm, dtype) coincidence.
    staging: Optional[str] = None

    @property
    def size(self) -> int:
        return sum(s.size for s in self.segments)

    @property
    def members(self) -> Tuple[int, ...]:
        """Member channel indices (for stats/tests)."""
        return tuple(s.channel for s in self.segments)


@dataclasses.dataclass(frozen=True)
class CoalescePlan:
    """A batch's complete coalescing plan, recorded on the program.

    ``transfers`` run in order (later stages relay earlier stages'
    payloads); ``routes[ci][k] = (transfer_index, offset)`` locates
    channel ``ci``'s payload at hop ``k``; deposits replay in original
    channel order so accumulation order — and therefore every result
    bit — matches the uncoalesced interpreter.
    """

    channels: Tuple[Channel, ...]                    # original batch order
    transfers: Tuple[CoalescedChannel, ...]          # execution order
    routes: Tuple[Tuple[Tuple[int, int], ...], ...]  # per channel, per hop
    shapes: Tuple[Tuple[int, ...], ...]              # local slab shape per channel

    @property
    def n_collectives(self) -> int:
        return len(self.transfers)

    @property
    def dead_channels(self) -> Tuple[int, ...]:
        """Channels whose peer permutation is statically empty (an empty
        route): every rank receives zeros, so they ride no transfer —
        the engine deposits a zeros slab directly, which is exactly what
        their per-channel ppermute would have delivered."""
        return tuple(ci for ci, r in enumerate(self.routes) if not r)


class _NoCoalesce(Exception):
    """Internal: this batch cannot be coalesced; fall back silently."""


def _local_shape(spec, mesh_shape: Dict[str, int]) -> Tuple[int, ...]:
    """Per-shard shape of a buffer (engines interpret local views)."""
    pspec = tuple(spec.pspec) + (None,) * (len(spec.shape) - len(spec.pspec))
    out = []
    for dim, entry in zip(spec.shape, pspec):
        if entry is None or entry == ():
            axes: Tuple[str, ...] = ()
        elif isinstance(entry, str):
            axes = (entry,)
        else:
            axes = tuple(entry)
        k = 1
        for a in axes:
            k *= mesh_shape[a]
        if k <= 0 or dim % k:
            raise _NoCoalesce(f"dim {dim} not divisible by mesh factor {k}")
        out.append(dim // k)
    return tuple(out)


def _send_shape(ch: Channel, buffers, mesh_shape) -> Tuple[int, ...]:
    """Static local shape of the slab a channel sends."""
    local = _local_shape(buffers[ch.src_buf], mesh_shape)
    if ch.send_region is None:
        return local
    region = tuple(ch.send_region)
    if len(region) > len(local):
        raise _NoCoalesce("send_region ranks exceed buffer rank")
    region = region + tuple(slice(None) for _ in local[len(region):])
    shape = []
    for sl, dim in zip(region, local):
        if not isinstance(sl, slice):
            raise _NoCoalesce("non-slice region entries are not coalescable")
        start, stop, step = sl.indices(dim)
        if step != 1:
            raise _NoCoalesce("strided send regions are not coalescable")
        shape.append(max(0, stop - start))
    return tuple(shape)


def _channel_hops(ch: Channel, axis_order) -> List[Tuple]:
    """Ordered hop keys for one channel: (axis, perm-key, periodic-ish)."""
    hops = hop_decomposition(ch.peer, axis_order)
    if hops is not None:
        return [("off", axis, delta, periodic) for axis, delta, periodic in hops]
    if isinstance(ch.peer, PairListPeer):
        return [("pairs", ch.peer.axis, tuple(ch.peer.pairs), False)]
    raise _NoCoalesce(f"peer {ch.peer!r} has no hop decomposition")


def coalesce_batch(channels: Sequence[Channel], buffers,
                   mesh_shape: Dict[str, int]) -> Optional[CoalescePlan]:
    """Group one batch's channels into fused by-axis transfers.

    Returns ``None`` (batch stays uncoalesced) when the batch is empty,
    when a channel's slab shape/route cannot be derived statically, or
    when a channel sends from a buffer another channel deposits into
    (the per-channel interpreter would observe the deposit; a coalesced
    pack reads every source before any deposit, so such batches must
    keep the sequential path to stay bit-identical).
    """
    if not channels:
        return None
    if {c.src_buf for c in channels} & {c.dst_buf for c in channels}:
        return None
    axis_order = tuple(mesh_shape)

    try:
        shapes = [_send_shape(ch, buffers, mesh_shape) for ch in channels]
        hops_per_channel = [_channel_hops(ch, axis_order) for ch in channels]
    except _NoCoalesce:
        return None

    axis_rank = {a: i for i, a in enumerate(axis_order)}

    def stage_of(hop) -> int:
        _, axis, *_ = hop
        return axis_rank.get(axis, 0)

    # group hops into transfers; first-seen order breaks ties inside a stage
    order: Dict[Tuple, int] = {}
    groups: Dict[Tuple, List[Segment]] = {}
    sizes: Dict[Tuple, int] = {}
    route_keys: List[List[Tuple[Tuple, int]]] = []
    for ci, (ch, hops) in enumerate(zip(channels, hops_per_channel)):
        if not perm_for(ch.peer, mesh_shape)[1]:
            # statically dead channel (no (src, dst) pairs on this mesh —
            # e.g. a diagonal offset on a collapsed axis): every rank
            # receives zeros, so don't pack/relay its payload at all
            route_keys.append([])
            continue
        size = int(np.prod(shapes[ci], dtype=np.int64))
        dtype = np.dtype(buffers[ch.src_buf].dtype)
        route = []
        for k, hop in enumerate(hops):
            key = (stage_of(hop),) + hop + (dtype.str,)
            if key not in order:
                order[key] = len(order)
                groups[key] = []
                sizes[key] = 0
            off = sizes[key]
            groups[key].append(Segment(channel=ci, hop=k, offset=off, size=size))
            sizes[key] += size
            route.append((key, off))
        route_keys.append(route)

    keys = sorted(order, key=lambda k: (k[0], order[k]))
    index_of = {k: i for i, k in enumerate(keys)}
    transfers = []
    for key in keys:
        stage, kind, axis, payload, periodic, dtype_str = key
        if kind == "off":
            perm = perm_for(OffsetPeer(axis, payload, periodic), mesh_shape)[1]
        else:
            perm = list(payload)
        transfers.append(CoalescedChannel(
            axis=axis, perm=tuple(perm), dtype=np.dtype(dtype_str),
            stage=stage, segments=tuple(groups[key]),
        ))

    routes = tuple(
        tuple((index_of[key], off) for key, off in route)
        for route in route_keys
    )
    return CoalescePlan(
        channels=tuple(channels),
        transfers=tuple(transfers),
        routes=routes,
        shapes=tuple(shapes),
    )


def validate_program_order(descs: Sequence[Any]) -> None:
    """Queue-level FIFO invariants (raised at build, not at run).

    * every send/recv/coll must be covered by a later `start`;
    * `wait` must reference a batch that has a `start`;
    * thresholds must be monotonically non-decreasing (DWQ contract).

    The same invariants are re-checked on *built* programs as the
    ``ST002``/``ST003``/``ST004`` rules of :mod:`repro.core.verify`
    (with full diagnostics); this pre-build pass exists to fail fast
    with a hard :class:`MatchError` before matching even starts.
    """
    from .descriptors import StartDesc, WaitDesc  # local to avoid cycle

    open_comm = 0
    open_site = None
    started = 0
    waits_seen = 0
    last_threshold = 0
    for d in descs:
        if isinstance(d, (SendDesc, RecvDesc, CollDesc)):
            open_comm += 1
            open_site = getattr(d, "site", None) or open_site
            if d.threshold >= 0 and d.threshold < last_threshold:
                raise MatchError(
                    "[ST003] descriptor thresholds must be monotone"
                    + _site_of(d))
            last_threshold = max(last_threshold, d.threshold)
        elif isinstance(d, StartDesc):
            started += 1
            open_comm = 0
            open_site = None
        elif isinstance(d, WaitDesc):
            waits_seen += 1
            if waits_seen > started:
                raise MatchError(
                    "[ST002] MPIX_Enqueue_wait before any matching "
                    "MPIX_Enqueue_start" + _site_of(d)
                )
    if open_comm:
        raise MatchError(
            f"[ST004] {open_comm} enqueued communication op(s) not covered "
            f"by an MPIX_Enqueue_start — they would never trigger"
            + (f" [last enqueued at {open_site}]" if open_site else "")
        )
