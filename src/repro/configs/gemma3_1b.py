"""gemma3-1b [dense] — 5:1 local:global attention, 512-token window,
qk-norm, tied embeddings, 262k vocab. [hf:google/gemma-3-1b-pt]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    arch_type="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    act="gelu",
    rope_theta=10_000.0,        # local layers
    rope_theta_global=1_000_000.0,
    sliding_window=512,
    global_every=6,             # every 6th layer global (5:1)
    qk_norm=True,
    norm_offset=1.0,            # rmsnorm weight + 1
    embed_scale=True,
    tie_embeddings=True,
    long_context_ok=True,       # 5:1 SWA; global-layer KV sharded over data
)
