"""Property tests of the Faces oracle + ST program structure (no devices)."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import DIRECTIONS, CORNERS, EDGES, FACES, FacesConfig, faces_oracle
from repro.core.halo import _region_for, _slab_shape

SET = settings(max_examples=25, deadline=None)

grid_st = st.tuples(st.integers(1, 3), st.integers(1, 3), st.integers(1, 3))
pts_st = st.tuples(st.integers(2, 5), st.integers(2, 5), st.integers(2, 5))


def test_direction_taxonomy():
    assert len(DIRECTIONS) == 26
    assert len(FACES) == 6 and len(EDGES) == 12 and len(CORNERS) == 8
    assert len(set(DIRECTIONS)) == 26
    # closed under negation (symmetric exchange)
    assert all(tuple(-x for x in d) in DIRECTIONS for d in DIRECTIONS)


@SET
@given(grid_st, pts_st, st.booleans())
def test_oracle_is_linear(grid, pts, periodic):
    cfg = FacesConfig(grid=grid, points=pts, periodic=periodic,
                      interior_compute=False)
    rng = np.random.RandomState(0)
    a = rng.randn(*grid, *pts).astype(np.float32)
    b = rng.randn(*grid, *pts).astype(np.float32)
    lhs = faces_oracle(a + b, cfg)
    rhs = faces_oracle(a, cfg) + faces_oracle(b, cfg)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-5)


@SET
@given(pts_st)
def test_oracle_interior_untouched_by_exchange(pts):
    """Without the stencil, interior points receive no contributions."""
    cfg = FacesConfig(grid=(2, 2, 2), points=pts, interior_compute=False)
    rng = np.random.RandomState(1)
    u = rng.randn(2, 2, 2, *pts).astype(np.float32)
    out = faces_oracle(u, cfg)
    interior = tuple([slice(None)] * 3 + [slice(1, -1)] * 3)
    np.testing.assert_array_equal(out[interior], u[interior])


@SET
@given(grid_st, pts_st)
def test_periodic_conserves_boundary_mass(grid, pts):
    """Periodic halo-sum conserves the total sum (every packed value is
    deposited exactly once somewhere)."""
    cfg = FacesConfig(grid=grid, points=pts, periodic=True,
                      interior_compute=False, dtype="float64")
    rng = np.random.RandomState(2)
    u = rng.randn(*grid, *pts).astype(np.float64)
    out = faces_oracle(u, cfg)
    added = out - u
    # total added mass = sum over all 26 packed slabs
    expect = sum(u[(slice(None),) * 3 + _region_for(d, pts)].sum()
                 for d in DIRECTIONS)
    np.testing.assert_allclose(added.sum(), expect, rtol=1e-7, atol=1e-6)


def test_slab_shapes():
    pts = (7, 5, 3)
    for d in FACES:
        assert np.prod(_slab_shape(d, pts)) in (5 * 3, 7 * 3, 7 * 5)
    for d in CORNERS:
        assert _slab_shape(d, pts) == (1, 1, 1)


def test_program_channel_counts():
    import jax
    from repro.core import build_faces_program
    from repro.parallel import make_mesh
    # mesh build on 1 device: 1x1x1 grid
    mesh = make_mesh((1, 1, 1), ("gx", "gy", "gz"))
    cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True)
    prog = build_faces_program(cfg, mesh)
    assert prog.n_channels == 26
    assert prog.n_batches == 1
    # staged variant: 6 channels over 3 batches
    cfg3 = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True,
                       granularity="staged3")
    prog3 = build_faces_program(cfg3, mesh)
    assert prog3.n_channels == 6
    assert prog3.n_batches == 3


class TestShardingCtx:
    def test_act_shard_noop_without_ctx(self):
        import jax.numpy as jnp
        from repro.parallel import act_shard
        x = jnp.ones((4, 4))
        assert act_shard(x, "batch", None) is x

    def test_ctx_nesting_restores(self):
        from repro.parallel import RULES_TRAIN, current_ctx, make_mesh, sharding_ctx
        mesh = make_mesh((1,), ("model",))
        assert current_ctx() is None
        with sharding_ctx(RULES_TRAIN, mesh):
            assert current_ctx() is not None
            with sharding_ctx(RULES_TRAIN, mesh):
                pass
            assert current_ctx() is not None
        assert current_ctx() is None
