"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8 (sigmoid
router, aux-free), MTP. [arXiv:2412.19437]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    source="arXiv:2412.19437",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,         # MLA: all heads share the compressed KV
    d_ff=18432,             # dense-layer FFN (first_k_dense layers)
    vocab=129280,
    act="silu",
    rope_theta=10_000.0,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    d_ff_expert=2048,
    router="sigmoid",
    routed_scaling=2.5,
    first_k_dense=3,
    mtp_depth=1,
    capacity_factor=1.25,
    moe_impl="ep",          # shard_map expert-parallel dispatch (§Perf iter 2)
    long_context_ok=True,   # MLA compressed KV keeps the 500k cache small
)
