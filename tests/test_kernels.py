"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


def rand(rng, shape, dtype):
    x = rng.randn(*shape)
    return jnp.asarray(x, dtype)


# --------------------------------------------------------------------------
# halo pack family
# --------------------------------------------------------------------------

REGION_CASES = [
    ((4, 4, 4), (slice(0, 1), slice(0, 4), slice(0, 4))),      # face
    ((4, 4, 4), (slice(3, 4), slice(0, 1), slice(0, 4))),      # edge
    ((4, 4, 4), (slice(3, 4), slice(3, 4), slice(3, 4))),      # corner
    ((7, 5, 3), (slice(0, 7), slice(4, 5), slice(0, 3))),      # odd sizes
    ((2, 9, 6), (slice(1, 2), slice(0, 9), slice(5, 6))),
]


@pytest.mark.parametrize("shape,region", REGION_CASES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_halo_pack_matches_ref(shape, region, dtype):
    rng = np.random.RandomState(0)
    u = rand(rng, shape, dtype)
    got = ops.halo_pack(u, region)
    want = ref.halo_pack(u, region)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@pytest.mark.parametrize("shape,region", REGION_CASES)
def test_halo_unpack_add_matches_ref(shape, region):
    rng = np.random.RandomState(1)
    u = rand(rng, shape, "float32")
    msg_shape = tuple(s.stop - s.start for s in region)
    msg = rand(rng, msg_shape, "float32")
    np.testing.assert_allclose(
        np.asarray(ops.halo_unpack_add(u, msg, region)),
        np.asarray(ref.halo_unpack_add(u, msg, region)), rtol=1e-6)


def _all26_regions(p):
    from repro.core.halo import DIRECTIONS, _region_for
    return [_region_for(d, (p, p, p)) for d in DIRECTIONS]


@pytest.mark.parametrize("p", [3, 5])
def test_pack_boundary_contiguous_26(p):
    rng = np.random.RandomState(2)
    u = rand(rng, (p, p, p), "float32")
    regions = _all26_regions(p)
    got = ops.pack_boundary(u, regions)
    want = ref.pack_boundary(u, regions)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # unpack roundtrip accumulates exactly like the oracle
    buf = rand(rng, got.shape, "float32")
    np.testing.assert_allclose(
        np.asarray(ops.unpack_boundary_add(u, buf, regions)),
        np.asarray(ref.unpack_boundary_add(u, buf, regions)), rtol=1e-6)


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------


@pytest.mark.parametrize("rows,d", [(1, 64), (37, 256), (128, 128), (5, 1024)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("offset", [0.0, 1.0])
def test_rmsnorm_sweep(rows, d, dtype, offset):
    rng = np.random.RandomState(3)
    x = rand(rng, (rows, d), dtype)
    w = rand(rng, (d,), dtype)
    got = ops.rmsnorm(x, w, weight_offset=offset, block_rows=32)
    want = ref.rmsnorm(x, w, weight_offset=offset)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2 if dtype == "bfloat16" else 2e-5,
                               atol=1e-2 if dtype == "bfloat16" else 1e-5)


def test_rmsnorm_leading_dims():
    rng = np.random.RandomState(4)
    x = rand(rng, (2, 3, 5, 64), "float32")
    w = rand(rng, (64,), "float32")
    np.testing.assert_allclose(np.asarray(ops.rmsnorm(x, w)),
                               np.asarray(ref.rmsnorm(x, w)), rtol=2e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

ATTN_CASES = [
    dict(B=1, Hq=2, Hkv=1, Sq=64, Skv=64, D=32, causal=True),
    dict(B=2, Hq=4, Hkv=4, Sq=48, Skv=48, D=16, causal=False),
    dict(B=1, Hq=8, Hkv=2, Sq=32, Skv=96, D=64, causal=True, q_offset=64),
    dict(B=1, Hq=2, Hkv=2, Sq=64, Skv=64, D=32, causal=True, window=19),
    dict(B=1, Hq=2, Hkv=1, Sq=64, Skv=64, D=32, causal=True,
         logit_softcap=15.0),
    dict(B=2, Hq=4, Hkv=1, Sq=1, Skv=80, D=32, causal=True, q_offset=79),
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_sweep(case):
    case = dict(case)
    rng = np.random.RandomState(5)
    B, Hq, Hkv, Sq, Skv, D = (case.pop(k) for k in
                              ("B", "Hq", "Hkv", "Sq", "Skv", "D"))
    q = rand(rng, (B, Hq, Sq, D), "float32")
    k = rand(rng, (B, Hkv, Skv, D), "float32")
    v = rand(rng, (B, Hkv, Skv, D), "float32")
    got = ops.flash_attention(q, k, v, block_q=32, block_k=32, **case)
    want = ref.attention(q, k, v, **case)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=3e-5)


@pytest.mark.parametrize("dtype", ["bfloat16"])
def test_flash_attention_bf16(dtype):
    rng = np.random.RandomState(6)
    q = rand(rng, (1, 2, 64, 32), dtype)
    k = rand(rng, (1, 2, 64, 32), dtype)
    v = rand(rng, (1, 2, 64, 32), dtype)
    got = ops.flash_attention(q, k, v, block_q=32, block_k=32)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=3e-2)


def test_flash_attention_unaligned_padding():
    """Sq/Skv not multiples of the block — wrapper pads and un-pads."""
    rng = np.random.RandomState(7)
    q = rand(rng, (1, 2, 50, 32), "float32")
    k = rand(rng, (1, 1, 70, 32), "float32")
    v = rand(rng, (1, 1, 70, 32), "float32")
    got = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    want = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=3e-5)


# --------------------------------------------------------------------------
# SSD scan
# --------------------------------------------------------------------------

SSD_CASES = [
    dict(B=1, S=32, H=2, P=8, G=1, N=8, chunk=8),
    dict(B=2, S=80, H=4, P=16, G=2, N=24, chunk=32),   # padding (80 % 32)
    dict(B=1, S=128, H=2, P=32, G=1, N=16, chunk=128),  # single chunk
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_sweep(case):
    rng = np.random.RandomState(8)
    B, S, H, P, G, N, chunk = (case[k] for k in
                               ("B", "S", "H", "P", "G", "N", "chunk"))
    x = rand(rng, (B, S, H, P), "float32")
    dt = jnp.abs(rand(rng, (B, S, H), "float32")) * 0.1
    A = -jnp.abs(rand(rng, (H,), "float32"))
    Bm = rand(rng, (B, S, G, N), "float32")
    C = rand(rng, (B, S, G, N), "float32")
    y, h = ops.ssd_scan(x, dt, A, Bm, C, chunk=chunk, return_state=True)
    yr, hr = ref.ssd_scan(x, dt, A, Bm, C, return_state=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=3e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=2e-4, atol=3e-5)


def test_ssd_with_initial_state():
    rng = np.random.RandomState(9)
    B, S, H, P, G, N = 1, 40, 2, 8, 1, 8
    x = rand(rng, (B, S, H, P), "float32")
    dt = jnp.abs(rand(rng, (B, S, H), "float32")) * 0.1
    A = -jnp.abs(rand(rng, (H,), "float32"))
    Bm = rand(rng, (B, S, G, N), "float32")
    C = rand(rng, (B, S, G, N), "float32")
    h0 = rand(rng, (B, H, P, N), "float32")
    y, h = ops.ssd_scan(x, dt, A, Bm, C, init_state=h0, chunk=8,
                        return_state=True)
    yr, hr = ref.ssd_scan(x, dt, A, Bm, C, init_state=h0, return_state=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=3e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-4,
                               atol=3e-5)


def test_ssd_step_consistent_with_scan():
    """Decode recurrence step-by-step == full scan (serving invariant)."""
    rng = np.random.RandomState(10)
    B, S, H, P, G, N = 1, 12, 2, 4, 1, 6
    x = rand(rng, (B, S, H, P), "float32")
    dt = jnp.abs(rand(rng, (B, S, H), "float32")) * 0.2
    A = -jnp.abs(rand(rng, (H,), "float32"))
    Bm = rand(rng, (B, S, G, N), "float32")
    C = rand(rng, (B, S, G, N), "float32")
    y_scan = ref.ssd_scan(x, dt, A, Bm, C)
    state = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        y, state = ref.ssd_step(x[:, t], dt[:, t], A, Bm[:, t], C[:, t], state)
        ys.append(y)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_scan),
                               rtol=2e-4, atol=3e-5)
