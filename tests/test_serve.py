"""Serving-path tests (repro.launch.serve).

Covers the device-resident decode contract: the whole greedy-decode
loop as ONE host dispatch, bit-identical to the host-stepped reference;
per-sequence EOS masking stopping exactly at the host oracle's stop
step; continuous-batching admission reproducing serial serving's tokens
per request; and dispatch accounting for the composed prefill+decode
admission program.

The model is a dense (non-MoE) smoke config on purpose: MoE expert
capacity couples batch rows, which would break the continuous == serial
token equality these tests assert.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.launch.serve import (
    PAD_TOKEN,
    ServeEngine,
    serve,
    serve_continuous,
    synthetic_batch,
)
from repro.models import Model
from repro.parallel import make_mesh

PROMPT, GEN = 8, 6


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen1.5-0.5b").smoke()


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def eng4(cfg, mesh):
    return ServeEngine(cfg, mesh, slots=4, prompt_len=PROMPT, max_new=GEN,
                       chunk=GEN - 1, eos_id=-1)


@pytest.fixture(scope="module")
def params(cfg, mesh, eng4):
    with mesh:
        p, _ = eng4.model.init(jax.random.PRNGKey(0))
        return jax.device_put(p, eng4.pre.in_shardings[0])


@pytest.fixture(scope="module")
def prompts4(cfg):
    return synthetic_batch(cfg, np.random.RandomState(0), 4, PROMPT)


@pytest.fixture(scope="module")
def fixed_len(cfg, mesh, eng4, params, prompts4):
    """(gen, stats) per mode for a fixed-length batch-of-4 serve."""
    out = {}
    for mode in (True, False):
        out[mode] = serve(cfg, mesh, batch=4, prompt_len=PROMPT,
                          gen_len=GEN, params=params, batch_in=prompts4,
                          engine=eng4, device_resident=mode)
    return out


class TestDeviceResident:
    def test_bit_identical_to_host_stepped(self, fixed_len):
        gen_d, _ = fixed_len[True]
        gen_h, _ = fixed_len[False]
        np.testing.assert_array_equal(gen_d, gen_h)
        assert gen_d.shape == (4, GEN)
        assert (gen_d != PAD_TOKEN).all()   # no EOS: everyone runs to length

    def test_fixed_length_is_one_dispatch(self, fixed_len):
        _, st_d = fixed_len[True]
        # the whole decode loop is ONE host dispatch (plus the jitted
        # prefill): the serve-path analogue of the persistent engine
        assert st_d["decode_dispatches"] == 1
        assert st_d["dispatches"] == 2
        assert st_d["sync_points"] == 1

    def test_host_stepped_dispatch_count(self, fixed_len):
        _, st_h = fixed_len[False]
        assert st_h["decode_dispatches"] == GEN - 1
        assert st_h["dispatches"] == GEN  # prefill + one per decode token

    def test_token_accounting(self, fixed_len):
        for mode in (True, False):
            _, st = fixed_len[mode]
            assert st["decode_tokens"] == 4 * (GEN - 1)


class TestEosMasking:
    @pytest.fixture(scope="class")
    def eos_runs(self, cfg, mesh, params, prompts4, fixed_len):
        gen_h, _ = fixed_len[False]
        # an EOS id that actually occurs mid-stream in the oracle run
        eos = int(gen_h[0, GEN // 2])
        eng = ServeEngine(cfg, mesh, slots=4, prompt_len=PROMPT,
                          max_new=GEN, chunk=GEN - 1, eos_id=eos)
        runs = {mode: serve(cfg, mesh, batch=4, prompt_len=PROMPT,
                            gen_len=GEN, params=params, batch_in=prompts4,
                            engine=eng, device_resident=mode, eos_id=eos)
                for mode in (True, False)}
        return eos, gen_h, runs

    def test_device_matches_host_oracle(self, eos_runs):
        _, _, runs = eos_runs
        np.testing.assert_array_equal(runs[True][0], runs[False][0])

    def test_stops_exactly_at_oracle_stop_step(self, eos_runs):
        eos, gen_h, runs = eos_runs
        gen_d, _ = runs[True]
        for b in range(4):
            hits = np.nonzero(gen_h[b] == eos)[0]
            stop = int(hits[0]) + 1 if hits.size else GEN
            # emissions match the unmasked oracle up to and incl. EOS...
            np.testing.assert_array_equal(gen_d[b, :stop], gen_h[b, :stop])
            # ...and the slot is frozen (PAD) past its stop step
            assert (gen_d[b, stop:] == PAD_TOKEN).all()

    def test_emitted_token_count_reflects_early_eos(self, eos_runs):
        _, _, runs = eos_runs
        gen_d, st = runs[True]
        emitted = int((gen_d[:, 1:] != PAD_TOKEN).sum())
        assert st["decode_tokens"] == emitted
        assert emitted < 4 * (GEN - 1)   # at least one row stopped early


class TestContinuousBatching:
    @pytest.fixture(scope="class")
    def continuous(self, cfg, mesh):
        n, slots, chunk = 5, 2, 3
        eng = ServeEngine(cfg, mesh, slots=slots, prompt_len=PROMPT,
                          max_new=GEN, chunk=chunk, eos_id=-1)
        with mesh:
            params, _ = eng.model.init(jax.random.PRNGKey(0))
            params = jax.device_put(params, eng.pre.in_shardings[0])
        prompts = synthetic_batch(cfg, np.random.RandomState(1), n, PROMPT)
        results, stats = serve_continuous(
            cfg, mesh, slots=slots, prompt_len=PROMPT, max_new=GEN,
            n_requests=n, chunk=chunk, arrival_rate=0.0, seed=0,
            params=params, prompts=prompts, engine=eng)
        return n, params, prompts, results, stats

    def test_tokens_match_serial_serving(self, cfg, mesh, continuous):
        n, params, prompts, results, _ = continuous
        # serial reference: each request served entirely alone (batch=1,
        # host-stepped) — admission into a shared running batch must not
        # change a single emitted token
        eng1 = ServeEngine(cfg, mesh, slots=1, prompt_len=PROMPT,
                           max_new=GEN, chunk=GEN - 1, eos_id=-1)
        p1 = jax.device_put(params, eng1.pre.in_shardings[0])
        for r in results:
            row = {k: jnp.asarray(np.asarray(v)[r.rid:r.rid + 1])
                   for k, v in prompts.items()}
            gen, _ = serve(cfg, mesh, batch=1, prompt_len=PROMPT,
                           gen_len=GEN, params=p1, batch_in=row,
                           engine=eng1, device_resident=False)
            np.testing.assert_array_equal(r.tokens, gen[0])

    def test_all_requests_complete_full_budget(self, continuous):
        n, _, _, results, stats = continuous
        assert len(results) == n
        assert all(len(r.tokens) == GEN for r in results)
        assert stats["total_tokens"] == n * GEN

    def test_composed_admission_is_one_dispatch(self, continuous):
        _, _, _, _, stats = continuous
        # prefill never runs as its own dispatch: admission rounds are
        # the composed prefill+decode program, ONE dispatch each
        assert stats["prefill_dispatches"] == 0
        assert stats["admit_dispatches"] >= 1
        assert stats["dispatches"] == (stats["admit_dispatches"]
                                       + stats["decode_dispatches"])
        # one host sync per round — the admission point
        assert stats["sync_points"] == stats["dispatches"]


class TestSelectSlots:
    def test_masked_merge_per_leaf(self, cfg):
        model = Model(cfg)
        old = model.init_caches(3, 16, per_sequence=True)
        new = jax.tree.map(lambda x: jnp.ones_like(x), old)
        mask = jnp.asarray([True, False, True])
        merged = model.select_slots(mask, new, old)
        axes = model.cache_axes(per_sequence=True)

        def check(ax, m, o):
            b = ax.index("batch")
            m_np, o_np = np.asarray(m), np.asarray(o)
            for s, keep_new in enumerate([True, False, True]):
                got = np.take(m_np, s, axis=b)
                want = (np.ones_like(got) if keep_new
                        else np.take(o_np, s, axis=b))
                np.testing.assert_array_equal(got, want)

        jax.tree.map(check, axes, merged, old,
                     is_leaf=lambda x: isinstance(x, tuple) and not any(
                         hasattr(e, "shape") for e in x))
