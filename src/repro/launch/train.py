"""End-to-end training driver.

Runs real steps on the host mesh (CPU here; the same code path drives a
TPU slice — only the mesh differs).  Used by ``examples/train_tiny.py``
(≈100M params, a few hundred steps) and by integration tests.

Dispatch regimes (``inner_steps``):

* ``inner_steps=1`` — classic loop, one host dispatch per step;
* ``inner_steps=N`` — :func:`repro.launch.steps.persistent_steps`
  folds N steps into ONE dispatch: the host stacks N batches (leading
  step axis, indexed on-device), the device loop carries
  params/optimizer state, and a stacked metrics carry brings every
  inner step's metrics back (the single host sync per dispatch reads
  the realized step count);
* ``plateau_eps`` — with ``inner_steps>1``, the device loop stops early
  once the loss trace plateaus (``|Δloss| <= eps``): loss-plateau
  termination with no host round-trip per step.

Checkpoints hold ``{"params", "opt_state"}`` so a resumed run keeps its
AdamW moments and its LR-schedule position; shardings are re-applied on
restore from the live (device-placed) state trees.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \\
      --smoke --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import latest_step, restore_pytree, save_pytree
from repro.configs.base import ModelConfig, ShapeConfig, get_config
from repro.data.synthetic import SyntheticConfig, SyntheticTokens
from repro.launch.steps import build_train_step, loss_plateau, persistent_steps
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init


def _restore_state(directory: str, step: int, params, opt_state):
    """Restore params AND optimizer state from a checkpoint.

    The ``like`` trees are the live, device-placed state, so
    ``restore_pytree`` re-applies their shardings leaf by leaf.  Legacy
    params-only checkpoints restore what they have (with a warning —
    AdamW moments and the LR schedule restart in that case).
    """
    like = {"params": params, "opt_state": opt_state}
    try:
        restored = restore_pytree(directory, step, like)
        return restored["params"], restored["opt_state"]
    except KeyError:
        print(f"warning: checkpoint step_{step} predates optimizer-state "
              "checkpointing; resuming params only", flush=True)
        return restore_pytree(directory, step, params), opt_state


def train(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
          steps: int = 100, opt: Optional[AdamWConfig] = None,
          checkpoint_dir: Optional[str] = None,
          checkpoint_every: int = 0,
          log_every: int = 10,
          seed: int = 0,
          inner_steps: int = 1,
          plateau_eps: Optional[float] = None):
    if inner_steps < 1:
        raise ValueError(f"inner_steps must be >= 1, got {inner_steps}")
    if plateau_eps is not None and inner_steps < 2:
        raise ValueError(
            "plateau_eps needs inner_steps >= 2: a 1-step device loop is "
            "bounded before the plateau predicate can ever stop it")
    opt = opt or AdamWConfig(lr=1e-3)
    bundle = build_train_step(cfg, shape, mesh, opt=opt, total_steps=steps)
    model = bundle.model
    until = loss_plateau(plateau_eps) if plateau_eps is not None else None

    with mesh:
        param_sh, opt_sh, batch_sh = bundle.in_shardings
        # stacked batches carry a leading (replicated) step axis
        stacked_batch_sh = {
            k: NamedSharding(mesh, P(None, *sh.spec)) for k, sh in batch_sh.items()
        }
        jit_cache = {}

        def jitted_for(k: int):
            if k not in jit_cache:
                wrapped = persistent_steps(bundle, k, until=until, stacked=True)
                jit_cache[k] = jax.jit(
                    wrapped.step_fn,
                    in_shardings=(param_sh, opt_sh, stacked_batch_sh),
                    out_shardings=(param_sh, opt_sh, None),
                    donate_argnums=(0, 1))
            return jit_cache[k]

        params, _ = model.init(jax.random.PRNGKey(seed))
        params = jax.device_put(params, param_sh)
        opt_state = adamw_init(params, opt)
        opt_state = jax.device_put(opt_state, opt_sh)

        start = 0
        if checkpoint_dir and (ck := latest_step(checkpoint_dir)) is not None:
            params, opt_state = _restore_state(checkpoint_dir, ck,
                                               params, opt_state)
            start = ck

        source = SyntheticTokens(cfg, shape, SyntheticConfig(seed=seed))
        history = []
        t0 = time.time()
        step = start
        while step < steps:
            k = min(inner_steps, steps - step)
            host = [source.batch(step + j) for j in range(k)]
            batch = {
                key: jax.device_put(np.stack([h[key] for h in host]),
                                    stacked_batch_sh[key])
                for key in host[0]
            }
            params, opt_state, metrics = jitted_for(k)(params, opt_state, batch)
            # the one host sync per dispatch: how far did the device get?
            done = int(metrics["steps_done"])
            for j in range(done):
                gstep = step + j
                if gstep % log_every == 0 or gstep == steps - 1:
                    m = {key: float(np.asarray(v)[j])
                         for key, v in metrics.items() if key != "steps_done"}
                    m["step"] = gstep
                    m["wall_s"] = round(time.time() - t0, 2)
                    history.append(m)
                    print(f"step {gstep:5d} loss={m['loss']:.4f} "
                          f"ce={m.get('ce', 0):.4f} gnorm={m['grad_norm']:.3f} "
                          f"lr={m['lr']:.2e} t={m['wall_s']}s", flush=True)
            prev, step = step, step + done
            if (checkpoint_dir and checkpoint_every
                    and step // checkpoint_every > prev // checkpoint_every):
                save_pytree(checkpoint_dir, step,
                            {"params": params, "opt_state": opt_state})
            if done < k:
                print(f"loss plateaued after {step} steps "
                      f"(eps={plateau_eps:g}); stopping", flush=True)
                break
        jax.block_until_ready(params)
    return params, opt_state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1x1", help="data x model, e.g. 2x2")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--inner-steps", type=int, default=1,
                    help="train steps folded into one device dispatch")
    ap.add_argument("--plateau-eps", type=float, default=None,
                    help="stop a dispatch early when |dloss| <= eps "
                         "(device-resident; needs --inner-steps > 1)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    shape = ShapeConfig("custom_train", args.seq, args.batch, "train")

    dm, tm = (int(x) for x in args.mesh.split("x"))
    n_needed = dm * tm
    if len(jax.devices()) < n_needed:
        raise SystemExit(
            f"need {n_needed} devices; run with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_needed}")
    from repro.parallel import make_mesh
    mesh = make_mesh((dm, tm), ("data", "model"))

    train(cfg, shape, mesh, steps=args.steps,
          opt=AdamWConfig(lr=args.lr),
          checkpoint_dir=args.checkpoint_dir,
          checkpoint_every=args.checkpoint_every,
          inner_steps=args.inner_steps,
          plateau_eps=args.plateau_eps)


if __name__ == "__main__":
    main()
