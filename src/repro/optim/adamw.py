"""AdamW with global-norm clipping and configurable moment dtypes.

Moment dtype matters at 671B scale: fp32 m/v is 8 bytes/param of
optimizer state; bf16 m/v halves it (the deepseek-v3 train_4k dry-run
uses bf16 moments to fit the single-pod HBM budget — see EXPERIMENTS.md
§Dry-run).  States shard exactly like their parameters (ZeRO-style via
the same logical axes).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"   # float32 | bfloat16


def adamw_init(params, cfg: AdamWConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr: Optional[jax.Array] = None
                 ) -> Tuple[Any, Dict, Dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.lr if lr is None else lr
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))
    mdt = jnp.dtype(cfg.moment_dtype)

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)

    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": jnp.asarray(lr)}
