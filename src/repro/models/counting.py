"""Analytic parameter / FLOP counting per config (roofline inputs).

MODEL_FLOPS uses the standard 6·N·D (train) / 2·N·D (inference) rule
with N = *active* parameters (MoE counts shared + top-k routed experts
only) and D = processed tokens.  Attention's S² term is added separately
(it matters at 32k+).
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.ssm import ssm_dims


def _attn_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    if cfg.use_mla:
        ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        h = cfg.n_heads
        return (d * ql + ql * h * (dn + dr) + d * (kl + dr)
                + kl * h * (dn + dv) + h * dv * d)
    hd = cfg.resolved_head_dim()
    n = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    if cfg.qkv_bias:
        n += (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
    return n


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    mats = 3 if cfg.act == "silu" else 2
    return mats * cfg.d_model * d_ff


def _ssm_params(cfg: ModelConfig) -> int:
    d_inner, H, conv_dim = ssm_dims(cfg)
    d_in_proj = 2 * d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + H
    return (cfg.d_model * d_in_proj + cfg.ssm_conv * conv_dim + conv_dim
            + 3 * H + d_inner + d_inner * cfg.d_model)


def _layer_params(cfg: ModelConfig, layer: int, active_only: bool) -> int:
    kind_moe = cfg.is_moe_layer(layer)
    n = 0
    if cfg.arch_type == "ssm":
        return _ssm_params(cfg) + cfg.d_model
    n += _attn_params(cfg) + 2 * cfg.d_model  # attn + 2 norms
    if cfg.hybrid:
        n += _ssm_params(cfg) + cfg.d_model
        n += _mlp_params(cfg, cfg.d_ff)
        return n
    if kind_moe:
        experts = cfg.top_k if active_only else cfg.n_experts
        n += experts * _mlp_params(cfg, cfg.d_ff_expert)
        n += cfg.d_model * cfg.n_experts  # router
        if cfg.n_shared_experts:
            n += _mlp_params(cfg, cfg.d_ff_expert * cfg.n_shared_experts)
    else:
        n += _mlp_params(cfg, cfg.d_ff)
    return n


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    n = cfg.vocab * cfg.d_model  # embedding
    if not cfg.tie_embeddings:
        n += cfg.d_model * cfg.vocab
    for i in range(cfg.n_layers):
        n += _layer_params(cfg, i, active_only)
    if cfg.enc_dec:
        for i in range(cfg.n_enc_layers):
            n += _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + 2 * cfg.d_model
        # cross attention in every decoder layer
        n += cfg.n_layers * (_attn_params(cfg) + cfg.d_model)
    if cfg.frontend == "vision":
        n += cfg.frontend_dim * cfg.d_model + cfg.d_model * cfg.d_model
    if cfg.frontend == "audio":
        n += cfg.frontend_dim * cfg.d_model
    if cfg.n_meta_tokens:
        n += cfg.n_meta_tokens * cfg.d_model
    if cfg.mtp_depth:
        n += 2 * cfg.d_model * cfg.d_model + _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff)
    return n


def _attn_flops_quadratic(cfg: ModelConfig, tokens_q: int, tokens_kv: int,
                          batch: int) -> float:
    """2·(QK) + 2·(PV) per head-dim — the S² term, per forward."""
    if cfg.arch_type == "ssm":
        return 0.0
    hd = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
          if cfg.use_mla else cfg.resolved_head_dim())
    h = cfg.n_heads
    causal_frac = 0.5 if tokens_q == tokens_kv else 1.0
    per_layer = 4.0 * h * hd * tokens_q * tokens_kv * causal_frac * batch
    n_layers = cfg.n_layers + (cfg.n_enc_layers if cfg.enc_dec else 0)
    return per_layer * n_layers


def model_memory_bytes(cfg: ModelConfig, shape: ShapeConfig, *,
                       chips: int = 256, data_shards: int = 16) -> float:
    """Analytic per-device HBM traffic LOWER BOUND (fused-TPU model).

    Components: parameter reads (weights stream from HBM once per pass;
    training adds grad + AdamW moment read/write), activation traffic at
    layer boundaries (intra-layer intermediates assumed fused; ~10
    d_model-sized tensors r/w per layer), logits, and for decode the KV/
    state cache read+write.  The HLO ``bytes accessed`` number is the
    matching UPPER bound (no fusion).  Real TPU traffic lies between.
    """
    p_bytes = {"float32": 4, "bfloat16": 2}[cfg.param_dtype]
    a_bytes = {"float32": 4, "bfloat16": 2}[cfg.dtype]
    n_active = count_params(cfg, active_only=True)
    params_dev = n_active * p_bytes / chips

    B, S = shape.global_batch, shape.seq_len
    L = cfg.n_layers + (cfg.n_enc_layers if cfg.enc_dec else 0)

    if shape.kind == "train":
        tokens_dev = B * S / data_shards
        param_traffic = params_dev * (2 + 1 + 4 + 1)  # fwd+bwd reads, grad w, m/v rw, param w
        act_traffic = tokens_dev * cfg.d_model * a_bytes * L * 10 * 2  # fwd+bwd
        logits = 3 * tokens_dev * cfg.vocab / 16 * 4  # vocab-sharded, f32
        return param_traffic + act_traffic + logits
    if shape.kind == "prefill":
        tokens_dev = B * S / data_shards
        return (params_dev + tokens_dev * cfg.d_model * a_bytes * L * 10
                + tokens_dev * cfg.vocab / 16 * a_bytes / S)  # last-pos logits
    # decode: one token; weights + the whole cache stream per step
    if cfg.use_mla:
        cache_row = cfg.kv_lora_rank + cfg.qk_rope_head_dim
    elif cfg.arch_type == "ssm":
        cache_row = 0
    else:
        hkv = max(cfg.n_kv_heads, 1)
        cache_row = 2 * hkv * cfg.resolved_head_dim()
    window = cfg.sliding_window or cfg.serve_window
    cache_dev = 0.0
    if cache_row:
        if cfg.global_every:
            n_glob = cfg.n_layers // cfg.global_every
            n_loc = cfg.n_layers - n_glob
            rows = n_glob * S + n_loc * min(window or S, S)
        elif window:
            rows = cfg.n_layers * min(window, S)
        else:
            rows = cfg.n_layers * S
        cache_dev = B * rows * cache_row * a_bytes / chips * 1.0
    ssm_dev = 0.0
    if cfg.arch_type in ("ssm", "hybrid"):
        d_inner = cfg.ssm_expand * cfg.d_model
        H = d_inner // cfg.ssm_head_dim
        ssm_dev = (cfg.n_layers * B * H * cfg.ssm_head_dim * cfg.ssm_state * 4
                   * 2 / chips)  # state read+write, fp32
    return params_dev + cache_dev + ssm_dev


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, float]:
    """Returns {"model_flops", "n_params", "n_active"} for the shape."""
    n_total = count_params(cfg)
    n_active = count_params(cfg, active_only=True)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        factor = 6.0
        quad = 3.0 * _attn_flops_quadratic(cfg, S, S, B)
    elif shape.kind == "prefill":
        tokens = B * S
        factor = 2.0
        quad = _attn_flops_quadratic(cfg, S, S, B)
    else:  # decode: one token per sequence against an S cache
        tokens = B
        factor = 2.0
        quad = _attn_flops_quadratic(cfg, 1, S, B)
    return {
        "model_flops": factor * n_active * tokens + quad,
        "n_params": float(n_total),
        "n_active": float(n_active),
        "tokens": float(tokens),
    }
