"""Config system: architecture + run configuration.

Every assigned architecture is a ``ModelConfig`` instance in its own
module (``repro/configs/<id>.py``), registered under its ``--arch`` id.
``smoke()`` derives the reduced variant used by CPU smoke tests
(≤2 layers, d_model ≤ 512, ≤4 experts, tiny vocab).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str = "model"
    arch_type: str = "dense"     # dense | moe | ssm | hybrid | encdec | vlm
    source: str = ""             # paper / model-card citation

    # trunk
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0            # 0 → d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1000
    act: str = "silu"            # silu (swiglu) | gelu (plain 2-mat mlp)
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    norm_offset: float = 0.0     # gemma: weight + 1
    embed_scale: bool = False    # gemma: x * sqrt(d_model)
    qk_norm: bool = False

    # rope / attention
    rope_theta: float = 10000.0
    rope_theta_global: float = 0.0   # gemma3: separate theta for global layers
    rotary_frac: float = 1.0         # glm4 uses 0.5
    sliding_window: int = 0          # 0 → full attention
    global_every: int = 0            # gemma3: every Nth layer is global (1-based)
    attn_softcap: float = 0.0        # grok-style tanh cap; 0 → off
    attn_output_multiplier: float = 0.0  # grok; 0 → default 1/sqrt(head_dim)

    # MLA (deepseek)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    router: str = "softmax"      # softmax | sigmoid (deepseek v3)
    routed_scaling: float = 1.0
    first_k_dense: int = 0       # deepseek: first k layers stay dense
    capacity_factor: float = 1.25
    mtp_depth: int = 0           # deepseek multi-token prediction heads
    moe_impl: str = "gather"     # gather (auto-partitioned) | ep (shard_map
                                 # expert-parallel; falls back if indivisible)

    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # hybrid (hymba)
    hybrid: bool = False         # parallel attn + ssm heads per layer
    n_meta_tokens: int = 0

    # enc-dec (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    pos_embedding: str = "rope"  # rope | sinusoidal | learned

    # modality frontend (stub — embeddings supplied by input_specs)
    frontend: str = "none"       # none | audio | vision
    frontend_tokens: int = 0     # frames / patches per sample
    frontend_dim: int = 0        # raw frontend embedding dim (projected)

    # numerics / execution
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "block"         # none | block (checkpoint each layer block)
    scan_layers: bool = True
    use_flash_kernel: bool = False  # Pallas attention in prefill path
    use_ssd_kernel: bool = False    # Pallas SSD in ssm fwd path

    # long-context serving: archs that can run long_500k
    long_context_ok: bool = False
    serve_window: int = 0        # beyond-paper windowed-serving variant

    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def is_moe_layer(self, i: int) -> bool:
        return self.n_experts > 0 and i >= self.first_k_dense

    def smoke(self) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2,
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, max(1, min(self.n_heads, 4) // 2)),
            head_dim=64 if self.head_dim else 0,
            d_ff=min(self.d_ff, 512),
            d_ff_expert=min(self.d_ff_expert, 256) if self.d_ff_expert else 0,
            vocab=512,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            first_k_dense=min(self.first_k_dense, 1),
            q_lora_rank=min(self.q_lora_rank, 64) if self.q_lora_rank else 0,
            kv_lora_rank=min(self.kv_lora_rank, 32) if self.kv_lora_rank else 0,
            qk_nope_head_dim=32 if self.qk_nope_head_dim else 0,
            qk_rope_head_dim=16 if self.qk_rope_head_dim else 0,
            v_head_dim=32 if self.v_head_dim else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            n_meta_tokens=min(self.n_meta_tokens, 8),
            frontend_tokens=min(self.frontend_tokens, 16) if self.frontend_tokens else 0,
            frontend_dim=min(self.frontend_dim, 64) if self.frontend_dim else 0,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
            global_every=self.global_every,
            mtp_depth=min(self.mtp_depth, 1),
            dtype="float32",
            param_dtype="float32",
            remat="none",
            scan_layers=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "whisper-large-v3",
    "qwen1.5-110b",
    "qwen1.5-0.5b",
    "internvl2-76b",
    "deepseek-v3-671b",
    "mamba2-2.7b",
    "grok-1-314b",
    "glm4-9b",
    "hymba-1.5b",
    "gemma3-1b",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {list(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
