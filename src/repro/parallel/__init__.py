"""Sharding rules: logical axes → mesh axes (pod, data, model)."""

from .sharding import (
    LogicalRules,
    RULES_DECODE,
    RULES_LONG_DECODE,
    RULES_TRAIN,
    logical_spec,
    logical_spec_sized,
    logical_sharding,
    act_shard,
    current_ctx,
    sharding_ctx,
    make_mesh,
    shard_constraint,
)

__all__ = [
    "LogicalRules", "RULES_TRAIN", "RULES_DECODE", "RULES_LONG_DECODE",
    "logical_spec", "logical_spec_sized", "logical_sharding", "act_shard", "current_ctx", "sharding_ctx", "make_mesh", "shard_constraint",
]
