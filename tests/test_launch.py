"""Launcher-layer tests: HLO analysis, step builders, mesh, counting."""

import numpy as np
import pytest

from repro.launch.hlo_analysis import (
    analyze_collectives,
    analyze_dots,
    _tensor_bytes,
)


class TestTensorBytes:
    def test_simple(self):
        assert _tensor_bytes("bf16[2,3]") == 12
        assert _tensor_bytes("f32[128]") == 512
        assert _tensor_bytes("f32[]") == 4

    def test_tuple(self):
        assert _tensor_bytes("(bf16[2,2], f32[4])") == 8 + 16

    def test_unknown_dtype_ignored(self):
        assert _tensor_bytes("token[]") == 0


HLO_SAMPLE = """
ENTRY %main (p0: f32[64,32]) -> f32[64,32] {
  %p0 = f32[64,32] parameter(0)
  %ag = f32[64,32] all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[64,32] all-reduce(%ag), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[64,32] collective-permute(%ar), source_target_pairs={{0,1},{1,0}}
  ROOT %rs = f32[64,32] reduce-scatter(%cp), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""


class TestCollectives:
    def test_kinds_and_counts(self):
        stats = analyze_collectives(HLO_SAMPLE, 4)
        assert stats.count_by_kind == {
            "all-gather": 1, "all-reduce": 1, "collective-permute": 1,
            "reduce-scatter": 1}

    def test_wire_byte_conventions(self):
        stats = analyze_collectives(HLO_SAMPLE, 4)
        nbytes = 64 * 32 * 4
        frac = 3 / 4
        assert np.isclose(stats.bytes_by_kind["all-gather"], nbytes * frac)
        assert np.isclose(stats.bytes_by_kind["all-reduce"], 2 * nbytes * frac)
        assert np.isclose(stats.bytes_by_kind["reduce-scatter"], nbytes * frac)
        assert np.isclose(stats.bytes_by_kind["collective-permute"], nbytes)


DOT_SAMPLE = """
ENTRY %main (p0: f32[8,16], p1: f32[16,4]) -> f32[8,4] {
  %p0 = f32[8,16] parameter(0)
  %p1 = f32[16,4] parameter(1)
  ROOT %dot.1 = f32[8,4] dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
%other (a: f32[2,3]) -> f32[2,2] {
  %a = f32[2,3] parameter(0)
  ROOT %dot.2 = f32[2,2] dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={1}
}
"""


class TestDots:
    def test_flops_and_scoping(self):
        stats = analyze_dots(DOT_SAMPLE)
        assert stats.n_dots == 2
        # 2*8*4*16 + 2*2*2*3
        assert stats.total_flops == 2 * 8 * 4 * 16 + 2 * 2 * 2 * 3


class TestCounting:
    def test_param_counts_match_published(self):
        from repro.configs import get_config
        from repro.models.counting import count_params
        expect = {
            "qwen1.5-0.5b": (0.46e9, 0.47e9),
            "deepseek-v3-671b": (6.6e11, 6.8e11),
            "grok-1-314b": (3.0e11, 3.3e11),
            "glm4-9b": (9.0e9, 9.6e9),
            "mamba2-2.7b": (2.6e9, 2.8e9),
        }
        for arch, (lo, hi) in expect.items():
            n = count_params(get_config(arch))
            assert lo <= n <= hi, (arch, n)

    def test_active_less_than_total_for_moe(self):
        from repro.configs import get_config
        from repro.models.counting import count_params
        for arch in ("deepseek-v3-671b", "grok-1-314b"):
            cfg = get_config(arch)
            assert count_params(cfg, True) < 0.5 * count_params(cfg)

    def test_model_flops_monotone_in_shape(self):
        from repro.configs import get_config
        from repro.configs.base import SHAPES
        from repro.models.counting import model_flops
        cfg = get_config("glm4-9b")
        train = model_flops(cfg, SHAPES["train_4k"])["model_flops"]
        prefill = model_flops(cfg, SHAPES["prefill_32k"])["model_flops"]
        decode = model_flops(cfg, SHAPES["decode_32k"])["model_flops"]
        assert train > prefill > decode > 0


@pytest.mark.slow
def test_step_bundle_lowers_on_small_mesh(subproc):
    """build_bundle lowers train/prefill/serve for a smoke config on a
    4-device data×model mesh (mini version of the 512-chip dry-run)."""
    r = subproc("""
import dataclasses, jax
from repro.configs.base import ShapeConfig, get_config
from repro.launch.steps import build_bundle
from repro.parallel import make_mesh
cfg = dataclasses.replace(get_config("qwen1.5-0.5b").smoke(), vocab=512)
mesh = make_mesh((2, 2), ("data", "model"))
for shape in (ShapeConfig("t", 32, 4, "train"),
              ShapeConfig("p", 32, 4, "prefill"),
              ShapeConfig("d", 64, 4, "decode")):
    bundle = build_bundle(cfg, shape, mesh)
    compiled = bundle.lower().compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax wraps the dict in a list
        cost = cost[0]
    assert cost["flops"] > 0
    print(shape.kind, "ok")
""", devices=4)
    assert r.returncode == 0, r.stdout + r.stderr
    assert r.stdout.count("ok") == 3


def test_production_mesh_requires_512_devices():
    """make_production_mesh fails cleanly without forced device count
    (this test runs with the single real device)."""
    from repro.launch.mesh import make_production_mesh
    with pytest.raises(ValueError):
        make_production_mesh()


@pytest.mark.slow
def test_persistent_step_bundle_matches_sequential(subproc):
    """persistent_steps folds N train steps into one dispatch whose
    result matches N sequential jitted steps (same batch regime)."""
    r = subproc("""
import numpy as np, jax
from repro.configs.base import ShapeConfig, get_config
from repro.data.synthetic import SyntheticTokens
from repro.launch import build_persistent_train_step, build_train_step
from repro.optim import AdamWConfig, adamw_init
from repro.parallel import make_mesh

cfg = get_config("qwen1.5-0.5b").smoke()
mesh = make_mesh((1,), ("data",))
shape = ShapeConfig("t", 8, 4, "train")
b1 = build_train_step(cfg, shape, mesh)
bN = build_persistent_train_step(cfg, shape, mesh, n_iters=3)
params, _ = b1.model.init(jax.random.PRNGKey(0))
opt_state = adamw_init(params, AdamWConfig())
batch = {k: jax.numpy.asarray(v)
         for k, v in SyntheticTokens(cfg, shape).batch(0).items()}
with mesh:
    p, o = params, opt_state
    j1 = jax.jit(b1.step_fn)
    losses = []
    for _ in range(3):
        p, o, met = j1(p, o, batch)
        losses.append(float(met["loss"]))
    pN, oN, metN = jax.jit(bN.step_fn)(params, opt_state, batch)
assert int(metN["steps_done"]) == 3
# stacked metrics carry: the whole per-step loss trace comes back
np.testing.assert_allclose(np.asarray(metN["loss"], np.float64), losses,
                           rtol=1e-4)
for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(pN)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=2e-3, atol=2e-3)
print("persistent bundle ok")
""", devices=1)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "persistent bundle ok" in r.stdout


def _toy_bundle():
    import jax.numpy as jnp

    from repro.launch.steps import StepBundle

    def toy_step(params, opt_state, batch):
        new_p = params + batch
        return new_p, opt_state + 1, {"loss": jnp.sum(new_p)}

    return StepBundle(cfg=None, shape=None, mesh=None, rules=None,
                      model=None, step_fn=toy_step, in_shardings=None,
                      out_shardings=None, input_sds=()), toy_step


def _toy_split_bundle():
    """A toy bundle with the grad/apply phase split pipelined_steps
    needs (mirrors build_train_step's two ST queues)."""
    import jax.numpy as jnp

    from repro.launch.steps import StepBundle

    def toy_grad(params, batch):
        return batch * 2.0, {"loss": jnp.sum(params)}

    def toy_apply(params, opt_state, grads):
        return params - 0.1 * grads, opt_state + 1, {"gnorm": jnp.sum(grads)}

    def toy_step(p, o, b):
        g, m = toy_grad(p, b)
        p, o, om = toy_apply(p, o, g)
        return p, o, {**m, **om}

    bundle = StepBundle(cfg=None, shape=None, mesh=None, rules=None,
                        model=None, step_fn=toy_step, in_shardings=None,
                        out_shardings=None, input_sds=(),
                        grad_fn=toy_grad, apply_fn=toy_apply)
    return bundle, toy_grad, toy_apply


def test_persistent_steps_validates_and_wraps():
    """Fast checks: n_iters guard + the fori_loop wrap itself, on a toy
    StepBundle (no model compile) — N wrapped steps == N sequential,
    with the full per-step metrics trace in the stacked carry."""
    import jax
    import jax.numpy as jnp

    from repro.launch.steps import persistent_steps

    bundle, toy_step = _toy_bundle()

    with pytest.raises(ValueError):
        persistent_steps(bundle, 0)

    wrapped = persistent_steps(bundle, 3)
    assert wrapped is not bundle and wrapped.model is bundle.model
    p0, o0, b = jnp.zeros(4), jnp.int32(0), jnp.ones(4)
    pN, oN, met = jax.jit(wrapped.step_fn)(p0, o0, b)
    p, o = p0, o0
    want_losses = []
    for _ in range(3):
        p, o, want = toy_step(p, o, b)
        want_losses.append(float(want["loss"]))
    np.testing.assert_allclose(np.asarray(pN), np.asarray(p))
    assert int(oN) == int(o) == 3
    # stacked per-step metrics + realized count, not last-step-only
    assert met["loss"].shape == (3,)
    np.testing.assert_allclose(np.asarray(met["loss"]), want_losses)
    assert int(met["steps_done"]) == 3

    p1, o1, met1 = persistent_steps(bundle, 1).step_fn(p0, o0, b)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p0 + b))
    assert met1["loss"].shape == (1,) and int(met1["steps_done"]) == 1


def test_persistent_steps_indexes_stacked_batch():
    """Regression: a stacked batch (leading n_iters axis) feeds one
    slice per inner step — not the identical batch every step."""
    import jax
    import jax.numpy as jnp

    from repro.launch.steps import persistent_steps

    bundle, toy_step = _toy_bundle()
    wrapped = persistent_steps(bundle, 3)
    p0, o0 = jnp.zeros(4), jnp.int32(0)
    stacked = jnp.stack([jnp.full(4, 1.0), jnp.full(4, 2.0),
                         jnp.full(4, 3.0)])

    pN, oN, met = jax.jit(wrapped.step_fn)(p0, o0, stacked)
    p, o = p0, o0
    want_losses = []
    for j in range(3):
        p, o, want = toy_step(p, o, stacked[j])
        want_losses.append(float(want["loss"]))
    np.testing.assert_allclose(np.asarray(pN), np.asarray(p))  # 1+2+3 = 6
    np.testing.assert_allclose(np.asarray(met["loss"]), want_losses)

    # explicit override forces the broadcast interpretation
    forced = persistent_steps(bundle, 4, stacked=False)
    pB, _, _ = jax.jit(forced.step_fn)(p0, o0, jnp.ones(4))
    np.testing.assert_allclose(np.asarray(pB), 4.0)


def test_persistent_steps_until_plateau():
    """loss_plateau until= stops the device loop early and reports the
    realized step count (metrics zero-padded past it)."""
    import jax
    import jax.numpy as jnp

    from repro.launch.steps import loss_plateau, persistent_steps

    bundle, _ = _toy_bundle()
    wrapped = persistent_steps(bundle, 6, until=loss_plateau(1e-6))
    p0, o0 = jnp.zeros(4), jnp.int32(0)
    # steps 1-2 move the loss; batches 3+ are zero -> plateau
    stacked = jnp.stack([jnp.ones(4), jnp.ones(4)] + [jnp.zeros(4)] * 4)
    pN, oN, met = jax.jit(wrapped.step_fn)(p0, o0, stacked)
    done = int(met["steps_done"])
    assert done == 3  # first flat delta observed after step 3
    assert int(oN) == done
    np.testing.assert_allclose(np.asarray(met["loss"]),
                               [4.0, 8.0, 8.0, 0.0, 0.0, 0.0])

    # an always-true predicate runs to the n_iters bound
    full = persistent_steps(bundle, 4, until=lambda m, i: jnp.asarray(True),
                            stacked=False)
    _, oF, metF = jax.jit(full.step_fn)(p0, o0, jnp.ones(4))
    assert int(metF["steps_done"]) == 4 and int(oF) == 4


def test_pipelined_steps_matches_staleness1_reference():
    """pipelined_steps overlaps apply(i-1) with grad(i): the realized
    schedule is the classic staleness-1 pipeline, checked against a
    hand-rolled python reference."""
    import jax
    import jax.numpy as jnp

    from repro.launch.steps import pipelined_steps

    bundle, toy_grad, toy_apply = _toy_split_bundle()
    n = 4
    stacked = jnp.stack([jnp.full(3, float(i + 1)) for i in range(n)])
    wrapped = pipelined_steps(bundle, n)
    assert wrapped is not bundle
    pN, oN, met = jax.jit(wrapped.step_fn)(jnp.zeros(3), jnp.int32(0),
                                           stacked)

    # reference: same software-pipelined schedule, sequentially
    p, o = jnp.zeros(3), 0
    g_prev, m = toy_grad(p, stacked[0])
    losses, gnorms = [float(m["loss"])], []
    for i in range(1, n):
        g_i, m = toy_grad(p, stacked[i])        # pre-apply params
        p, o, om = toy_apply(p, o, g_prev)      # apply step i-1
        losses.append(float(m["loss"]))
        gnorms.append(float(om["gnorm"]))
        g_prev = g_i
    p, o, om = toy_apply(p, o, g_prev)          # drain
    gnorms.append(float(om["gnorm"]))

    np.testing.assert_allclose(np.asarray(pN), np.asarray(p))
    assert int(oN) == n and int(met["steps_done"]) == n
    # slot i: step i's grad metrics AND step i's own apply metrics
    np.testing.assert_allclose(np.asarray(met["loss"]), losses)
    np.testing.assert_allclose(np.asarray(met["gnorm"]), gnorms)


def test_pipelined_steps_single_step_is_sequential():
    """n_iters=1 degenerates to the exact sequential step (no
    staleness: grad then apply)."""
    import jax
    import jax.numpy as jnp

    from repro.launch.steps import pipelined_steps

    bundle, _, _ = _toy_split_bundle()
    p0, o0, b = jnp.ones(3), jnp.int32(0), jnp.full(3, 2.0)
    p1, o1, met1 = jax.jit(pipelined_steps(bundle, 1).step_fn)(p0, o0, b)
    ps, os_, mets = bundle.step_fn(p0, o0, b)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(ps))
    assert int(o1) == int(os_) == 1
    np.testing.assert_allclose(float(met1["loss"][0]), float(mets["loss"]))
    np.testing.assert_allclose(float(met1["gnorm"][0]), float(mets["gnorm"]))


def test_pipelined_steps_validates():
    import jax.numpy as jnp

    from repro.launch.steps import pipelined_steps

    split_bundle, _, _ = _toy_split_bundle()
    with pytest.raises(ValueError, match="n_iters"):
        pipelined_steps(split_bundle, 0)
    # a bundle without the grad/apply split (e.g. serve) is rejected
    plain_bundle, _ = _toy_bundle()
    with pytest.raises(ValueError, match="grad/apply"):
        pipelined_steps(plain_bundle, 2)
    # colliding metric keys between the two phases are rejected
    bad, _, _ = _toy_split_bundle()
    bad.apply_fn = lambda p, o, g: (p, o, {"loss": jnp.sum(g)})
    with pytest.raises(ValueError, match="collide"):
        bad = pipelined_steps(bad, 2)
        bad.step_fn(jnp.zeros(3), jnp.int32(0), jnp.ones(3))


def test_build_pipelined_train_step_on_real_model():
    """The real-model pipeline: staleness-1 schedule against an explicit
    two-phase python loop using the bundle's own grad/apply split."""
    import jax

    from repro.configs.base import ModelConfig, ShapeConfig
    from repro.launch.steps import build_pipelined_train_step, build_train_step
    from repro.optim import AdamWConfig, adamw_init
    from repro.parallel import make_mesh

    cfg = ModelConfig(name="tiny", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=64, remat="none",
                      scan_layers=False)
    shape = ShapeConfig("t", 16, 2, "train")
    mesh = make_mesh((1,), ("data",))
    opt = AdamWConfig(lr=1e-3)
    n = 3

    b1 = build_train_step(cfg, shape, mesh, opt=opt)
    assert b1.grad_fn is not None and b1.apply_fn is not None
    bN = build_pipelined_train_step(cfg, shape, mesh, n_iters=n, opt=opt,
                                    stacked=False)
    params, _ = b1.model.init(jax.random.PRNGKey(0))
    opt_state = adamw_init(params, opt)
    from repro.data.synthetic import SyntheticTokens
    batch = {k: jax.numpy.asarray(v)
             for k, v in SyntheticTokens(cfg, shape).batch(0).items()}

    with mesh:
        pN, oN, metN = jax.jit(bN.step_fn)(params, opt_state, batch)
        # reference: the same pipeline, phase by phase on the host
        p, o = params, opt_state
        g_prev, m = b1.grad_fn(p, batch)
        losses = [float(m["loss"])]
        for _ in range(1, n):
            g_i, m = b1.grad_fn(p, batch)
            p, o, _ = b1.apply_fn(p, o, g_prev)
            losses.append(float(m["loss"]))
            g_prev = g_i
        p, o, _ = b1.apply_fn(p, o, g_prev)

    assert int(metN["steps_done"]) == n and int(oN["step"]) == n
    np.testing.assert_allclose(np.asarray(metN["loss"], np.float64), losses,
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(pN)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-3)


def test_train_rejects_plateau_without_inner_steps():
    """plateau_eps can only fire inside a multi-step device loop; a
    silent no-op config is rejected up front."""
    from repro.configs.base import ModelConfig, ShapeConfig
    from repro.launch.train import train
    from repro.parallel import make_mesh

    cfg = ModelConfig(name="tiny")
    shape = ShapeConfig("t", 16, 2, "train")
    mesh = make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="inner_steps"):
        train(cfg, shape, mesh, steps=2, plateau_eps=1e-4)
    with pytest.raises(ValueError, match="inner_steps"):
        train(cfg, shape, mesh, steps=2, inner_steps=0)


def test_train_resume_restores_opt_state(tmp_path):
    """Regression: an interrupted+resumed run must match an
    uninterrupted one bit-for-bit — AdamW moments and the LR-schedule
    position live in the checkpoint, not just params."""
    import jax

    from repro.configs.base import ModelConfig, ShapeConfig
    from repro.launch.train import train
    from repro.optim import AdamWConfig
    from repro.parallel import make_mesh

    cfg = ModelConfig(name="tiny", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=64, remat="none",
                      scan_layers=False)
    shape = ShapeConfig("t", 16, 2, "train")
    mesh = make_mesh((1,), ("data",))
    opt = AdamWConfig(lr=1e-3)
    da, db = str(tmp_path / "a"), str(tmp_path / "b")

    pa, oa, _ = train(cfg, shape, mesh, steps=4, opt=opt, checkpoint_dir=da,
                      checkpoint_every=2, log_every=100)
    # interrupt at step 2, then resume to 4 from the checkpoint
    train(cfg, shape, mesh, steps=2, opt=opt, checkpoint_dir=db,
          checkpoint_every=2, log_every=100)
    pb, ob, _ = train(cfg, shape, mesh, steps=4, opt=opt, checkpoint_dir=db,
                      checkpoint_every=2, log_every=100)

    assert int(oa["step"]) == int(ob["step"]) == 4  # schedule position kept
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(oa), jax.tree.leaves(ob)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_train_inner_steps_matches_per_step(subproc):
    """train(inner_steps=N) — stacked real batches, one dispatch per N
    steps — reproduces the per-step driver's loss trace and params."""
    r = subproc("""
import numpy as np, jax
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.train import train
from repro.optim import AdamWConfig
from repro.parallel import make_mesh

cfg = ModelConfig(name="tiny", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, remat="none",
                  scan_layers=False)
shape = ShapeConfig("t", 16, 2, "train")
mesh = make_mesh((1,), ("data",))
opt = AdamWConfig(lr=1e-3)
p1, o1, h1 = train(cfg, shape, mesh, steps=6, opt=opt, log_every=1)
pN, oN, hN = train(cfg, shape, mesh, steps=6, opt=opt, log_every=1,
                   inner_steps=3)
np.testing.assert_allclose([m["loss"] for m in h1],
                           [m["loss"] for m in hN], rtol=1e-5)
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(pN)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=1e-5, atol=1e-6)
print("inner steps ok")
""", devices=1)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "inner steps ok" in r.stdout
