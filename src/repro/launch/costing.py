import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Calibrated roofline costing (companion to dryrun.py).

``cost_analysis()`` on a scanned-layers program counts the loop body
ONCE, undercounting FLOPs/bytes/collectives by ~n_layers.  This module
compiles small **unrolled** variants and extrapolates:

* ``unrolled`` mode (shallow/narrow archs): unroll the real depth — the
  costs are exact.
* ``calibrated`` mode (80-layer giants): unroll L₂ and L₄ layers
  (L₄ = 2·L₂); per-layer cost = (C(L₄) − C(L₂)) / (L₄ − L₂); total =
  C(L₂) + per_layer × (L − L₂).  Linear in depth by construction of the
  stacks (every layer is structurally identical within a segment).

Artifacts land in ``artifacts/costing/*.json``; benchmarks/roofline.py
prefers them over the scanned dry-run numbers.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch.dryrun import SKIPS
from repro.launch.hlo_analysis import analyze_collectives, analyze_dots
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_bundle

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "costing")


def _pattern_unit(cfg) -> int:
    """Smallest depth that preserves the layer pattern (gemma 5:1 etc.).

    Sparse-global patterns with a long period (hymba: global every 16)
    are calibrated on local-only layers — the 2-of-32 global layers are
    approximated as local ones (documented in EXPERIMENTS.md)."""
    if cfg.global_every and cfg.global_every <= 8:
        return cfg.global_every
    return 1


def _with_depth(cfg, L: int):
    updates = dict(n_layers=L, scan_layers=False)
    if cfg.enc_dec:
        updates["n_enc_layers"] = L
    if cfg.first_k_dense:
        # calibrate the homogeneous MoE layer; the 3 dense layers are
        # approximated as MoE layers (overestimates <5% of depth)
        updates["first_k_dense"] = 0
    if cfg.mtp_depth:
        updates["mtp_depth"] = cfg.mtp_depth  # stays outside the depth scaling
    return dataclasses.replace(cfg, **updates)


def _compile_costs(cfg, shape, mesh):
    bundle = build_bundle(cfg, shape, mesh)
    lowered = bundle.lower()
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    colls = analyze_collectives(hlo, mesh.devices.size)
    dots = analyze_dots(hlo)
    mem = {}
    try:
        m = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes"):
            mem[attr] = int(getattr(m, attr))
    except Exception:
        pass
    return {
        "flops": float(cost.get("flops", 0.0)),
        "dot_flops": dots.total_flops,
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": colls.total_bytes,
        "coll_by_kind": colls.bytes_by_kind,
        "memory": mem,
        "top_dots": dots.largest[:8],
    }


def _lin(c2, c4, L2, L4, L, key):
    per_layer = (c4[key] - c2[key]) / (L4 - L2)
    return c2[key] + per_layer * (L - L2), per_layer


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            save: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if (arch, shape_name) in SKIPS:
        rec.update(status="skipped", reason=SKIPS[(arch, shape_name)])
        _save(rec, save)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        unit = _pattern_unit(cfg)
        L = cfg.n_layers
        eff_L = L + (cfg.n_enc_layers if cfg.enc_dec else 0)
        full_unroll = (eff_L <= 28 and cfg.d_model <= 4096) or eff_L <= 8

        if full_unroll:
            costs = _compile_costs(dataclasses.replace(
                cfg, scan_layers=False), shape, mesh)
            rec.update(status="ok", mode="unrolled",
                       flops=costs["flops"], dot_flops=costs["dot_flops"],
                       bytes=costs["bytes"],
                       coll_bytes=costs["coll_bytes"],
                       coll_by_kind=costs["coll_by_kind"],
                       memory=costs["memory"], top_dots=costs["top_dots"])
        else:
            L2, L4 = 2 * unit, 4 * unit
            c2 = _compile_costs(_with_depth(cfg, L2), shape, mesh)
            c4 = _compile_costs(_with_depth(cfg, L4), shape, mesh)
            out = {}
            for key in ("flops", "dot_flops", "bytes", "coll_bytes"):
                total, per_layer = _lin(c2, c4, L2, L4, L, key)
                out[key] = total
                out[f"{key}_per_layer"] = per_layer
            kinds = {}
            for k in set(c2["coll_by_kind"]) | set(c4["coll_by_kind"]):
                a, b = c2["coll_by_kind"].get(k, 0.0), c4["coll_by_kind"].get(k, 0.0)
                kinds[k] = a + (b - a) / (L4 - L2) * (L - L2)
            rec.update(status="ok", mode=f"calibrated(L{L2},L{L4})",
                       flops=out["flops"], dot_flops=out["dot_flops"],
                       bytes=out["bytes"],
                       coll_bytes=out["coll_bytes"], coll_by_kind=kinds,
                       per_layer={k: out[f"{k}_per_layer"]
                                  for k in ("flops", "dot_flops", "bytes",
                                            "coll_bytes")},
                       memory=c4["memory"], top_dots=c4["top_dots"])
        rec["n_devices"] = int(mesh.devices.size)
        rec["wall_s"] = round(time.time() - t0, 1)
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-3000:])
    _save(rec, save)
    return rec


def _save(rec, save):
    if not save:
        return
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(
            ARTIFACTS, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"),
            "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    results = []
    for arch in archs:
        for shape in shapes:
            mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
            path = os.path.join(ARTIFACTS, f"{arch}__{shape}__{mesh_name}.json")
            if args.skip_existing and os.path.exists(path):
                rec = json.load(open(path))
                if rec.get("status") in ("ok", "skipped"):
                    print(f"[cached ] {arch} {shape} {rec['status']}", flush=True)
                    results.append(rec)
                    continue
            rec = run_one(arch, shape, args.multi_pod)
            extra = ""
            if rec["status"] == "ok":
                extra = (f"mode={rec['mode']} flops={rec['flops']:.3e} "
                         f"coll={rec['coll_bytes']:.3e}B t={rec['wall_s']}s")
            elif rec["status"] == "error":
                extra = rec["error"][:140]
            print(f"[{rec['status']:7s}] {arch} {shape} {extra}", flush=True)
            results.append(rec)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"COSTING SUMMARY: {len(results)-n_err} ok/skip, {n_err} errors")


if __name__ == "__main__":
    main()
