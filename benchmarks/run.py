import os
# Benchmarks need a small multi-device grid (the Faces figures use 8
# ranks, matching the paper's 8-node experiments).  This is the bench
# entry point only — tests and the dry-run manage their own device
# counts (dryrun.py forces 512; pytest keeps the 1 real device).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Benchmark harness — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run faces      # one suite

Prints ``name,us_per_call,derived`` CSV at the end (plus human-readable
sections), and writes artifacts/bench_results.json.

Perf-regression gate::

  PYTHONPATH=src python -m benchmarks.run faces --check-against BENCH_faces.json

re-measures and exits non-zero if (a) any tracked Faces variant's
median regressed more than 20% vs the recorded file after normalizing
by the run-wide speed factor (machines differ; one variant drifting
beyond the rest of its own run is what counts), or (b) the
device-resident ``faces_figP/persistent`` loop measures slower than
re-dispatching ``fused_per_iter`` — the contract this repo's headline
depends on.  In gate mode BENCH_faces.json is *not* rewritten (CI must
not publish the numbers it is judging).

The serving and overlap suites have their own files and gates (see
benchmarks/serve_bench.py, benchmarks/overlap_bench.py)::

  PYTHONPATH=src python -m benchmarks.run serve --check-against BENCH_serve.json
  PYTHONPATH=src python -m benchmarks.run overlap --check-against BENCH_overlap.json

``--noise-factor F`` (or env BENCH_NOISE_FACTOR) widens every gate's
median tolerance by F for noisy 1-core runners; the same-run invariants
are never relaxed.
"""

import json
import sys

# medians on the CPU grid jitter run-to-run; >20% is a regression, not noise
CHECK_TOLERANCE = 1.20


def _noise_factor() -> float:
    """Explicit median-tolerance widening for noisy runners (set by
    ``--noise-factor`` / BENCH_NOISE_FACTOR); clamped at >= 1.0 so it
    can only relax, never tighten, the recorded pin."""
    return max(1.0, float(os.environ.get("BENCH_NOISE_FACTOR", "1")))


def check_against(faces: dict, path: str) -> int:
    """Compare fresh Faces medians to a recorded BENCH_faces.json.

    The comparison is normalized by the run-wide speed factor — the
    median of fresh/stored ratios across all tracked variants — so a
    uniformly slower/faster machine does not read as a regression, a
    single variant drifting >20% beyond the rest of the run does, and
    one variant *improving* cannot fail its unchanged siblings (with
    ~24 tracked variants the median barely moves).  Cross-run medians
    are only compared when the run's loop settings (``_meta``) match
    the recorded file's, and they assume a reasonably quiet machine
    (host-dispatch-bound baselines are very sensitive to CPU
    contention).  The same-run invariants — persistent beats
    per-iteration re-dispatch, the auto-tuner never publishes a slower
    number — are enforced unconditionally; they are what CI's
    small-grid run gates (its settings never match the recorded file,
    so the median path never runs there).
    """
    with open(path) as f:
        stored = json.load(f)

    # per-variant median comparison is only meaningful when this run
    # used the same loop settings the file was recorded with (a smaller
    # FACES_INNER rescales host-dispatch-bound and fused variants
    # differently); otherwise only the absolute invariants below apply.
    # A file WITHOUT a _meta stamp never gets median-compared either —
    # its loop settings are unknown, so a stale file could fail CI
    # spuriously (or pass wrongly) at arbitrary mismatched settings.
    stored_meta = stored.get("_meta", {})
    fresh_meta = faces.get("_meta", {})
    # the tuner's chosen knobs ride in _meta but are advisory: only the
    # LOOP settings decide whether medians are comparable.  Knob drift
    # (a re-tune at like-for-like settings now picks differently) is a
    # warning row, never a failure — the recorded file stays the pin
    # until someone re-records it.
    stored_knobs = stored_meta.get("tuned_knobs", {})
    fresh_knobs = fresh_meta.get("tuned_knobs", {})
    stored_settings = {k: v for k, v in stored_meta.items()
                       if k != "tuned_knobs"}
    fresh_settings = {k: v for k, v in fresh_meta.items()
                      if k != "tuned_knobs"}
    if not stored_settings:
        compare_medians = False
        print("note: recorded file has no _meta settings stamp — median "
              "checks skipped (invariants only); re-record it to enable them")
    elif stored_settings != fresh_settings:
        compare_medians = False
        print(f"note: settings differ from recorded ({fresh_settings} vs "
              f"{stored_settings}) — median checks skipped, invariants "
              f"enforced")
    else:
        compare_medians = True
    if compare_medians and stored_knobs:
        for row in sorted(set(stored_knobs) | set(fresh_knobs)):
            if stored_knobs.get(row) != fresh_knobs.get(row):
                print(f"WARNING knob-drift {row}: recorded "
                      f"{stored_knobs.get(row)} vs re-tuned "
                      f"{fresh_knobs.get(row)} — a re-tune now picks "
                      f"differently; re-record {path} to pin the new choice")

    def tracked(key):
        f, s = faces.get(key), stored.get(key)
        return (isinstance(f, dict) and f.get("median_ms")
                and isinstance(s, dict) and s.get("median_ms"))

    ratios = sorted(faces[k]["median_ms"] / stored[k]["median_ms"]
                    for k in faces if compare_medians and tracked(k))
    speed = ratios[len(ratios) // 2] if ratios else 1.0
    failures = []
    tol = CHECK_TOLERANCE * _noise_factor()
    if compare_medians:
        for key, fresh in sorted(faces.items()):
            if not tracked(key):
                continue
            bound = stored[key]["median_ms"] * speed * tol
            if fresh["median_ms"] > bound:
                failures.append(
                    f"{key}: median {fresh['median_ms']:.1f}ms > bound "
                    f"{bound:.1f}ms (recorded "
                    f"{stored[key]['median_ms']:.1f}ms x run speed-factor "
                    f"{speed:.2f} x tolerance {tol:.2f}: "
                    f">{(tol-1)*100:.0f}% regression)")
    # absolute same-run invariants: these pairs are measured back-to-back
    # in one process, so machine speed and loop settings cancel out
    pers = faces.get("faces_figP/persistent")
    fused = faces.get("faces_figP/fused_per_iter")
    if pers and fused and pers["median_ms"] > fused["median_ms"]:
        failures.append(
            f"faces_figP/persistent ({pers['median_ms']:.1f}ms) is slower "
            f"than fused_per_iter ({fused['median_ms']:.1f}ms): the "
            f"1-dispatch path must also be the fastest path")
    tuned = faces.get("faces_fig12/st_tuned")
    offl = faces.get("faces_fig12/st_offload")
    if tuned and offl and tuned["median_ms"] > offl["median_ms"] * 1.05:
        failures.append(
            f"faces_fig12/st_tuned ({tuned['median_ms']:.1f}ms) is slower "
            f"than untuned st_offload ({offl['median_ms']:.1f}ms): the "
            f"auto-tuner must never publish a slower number")
    for n in (2, 4):
        t = faces.get(f"faces_pipeline/linked_1q_n{n}")
        u = faces.get(f"faces_pipeline/linked_1q_n{n}_untuned")
        if t and u and t["median_ms"] > u["median_ms"] * 1.05:
            failures.append(
                f"faces_pipeline/linked_1q_n{n} ({t['median_ms']:.1f}ms) is "
                f"slower than its untuned reference "
                f"({u['median_ms']:.1f}ms): the auto-tuner must never "
                f"publish a slower linked row")
    if failures:
        # stderr + flush: the non-zero exit must never be near-silent in
        # CI logs — name every failing row, then a one-line summary
        print(f"\nPERF GATE FAILED ({len(failures)} failing row(s)):",
              file=sys.stderr, flush=True)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr, flush=True)
        names = ", ".join(msg.split(":", 1)[0] for msg in failures)
        print(f"PERF GATE FAILED rows: {names}", file=sys.stderr, flush=True)
        return 1
    checked = sum(1 for k in faces if tracked(k)) if compare_medians else 0
    print(f"\nperf gate OK: {checked} tracked medians within "
          f"{(tol-1)*100:.0f}% of {path} "
          f"(speed-normalized x{speed:.2f}); invariants hold "
          f"(persistent <= fused, tuned <= offload, "
          f"tuned linked <= untuned)")
    return 0


def main() -> None:
    here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, os.path.join(here, "..", "src"))
    sys.path.insert(0, os.path.join(here, ".."))

    from benchmarks import api_overhead, faces_bench, overlap_bench, \
        serve_bench
    from benchmarks import roofline as roofline_mod

    argv = sys.argv[1:]
    check_path = None
    if "--check-against" in argv:
        i = argv.index("--check-against")
        check_path = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    if "--noise-factor" in argv:
        i = argv.index("--noise-factor")
        os.environ["BENCH_NOISE_FACTOR"] = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    which = argv[0] if argv else "all"
    results = []

    if which in ("all", "api"):
        results += api_overhead.run_all()
    if which in ("all", "faces"):
        results += faces_bench.run_all()
    if which in ("all", "overlap"):
        results += overlap_bench.run_all()
    if which in ("all", "serve"):
        results += serve_bench.run_all()
    if which in ("all", "roofline"):
        rows = roofline_mod.main(None)
        for r in rows:
            if "skipped" in r:
                continue
            if "st_program" in r:  # cost-model rows carry their own shape
                meas = r.get("measured_ms")
                results.append({
                    "bench": "roofline_st", "variant": r["st_program"],
                    "us_per_call": r["predicted_us"],
                    "derived": f"predicted_us={r['predicted_us']:.0f};"
                               f"measured_ms="
                               f"{'-' if meas is None else f'{meas:.2f}'};"
                               f"bench_row={r['bench_row']}",
                })
                continue
            results.append({
                "bench": "roofline", "variant": f"{r['arch']}/{r['shape']}",
                "us_per_call": max(r["t_compute_s"], r["t_memory_s"],
                                   r["t_collective_s"]) * 1e6,
                "derived": f"dominant={r['dominant']};"
                           f"useful={r['useful_ratio']:.3f}",
            })

    print("\nname,us_per_call,derived")
    for r in results:
        print(f"{r['bench']}/{r['variant']},{r['us_per_call']:.2f},"
              f"\"{r['derived']}\"")

    out = os.path.join(here, "..", "artifacts", "bench_results.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote {out}")

    # machine-readable Faces perf trajectory (variant -> median ms,
    # dispatch counts), tracked across PRs at the repo root
    faces = {
        f"{r['bench']}/{r['variant']}": {
            "median_ms": round(r["median_ms"], 4),
            "dispatches": r["dispatches"],
        }
        for r in results
        if r["bench"].startswith("faces") and "median_ms" in r
    }
    if faces:
        # loop settings stamp: median checks only compare like-for-like
        faces["_meta"] = {
            "faces_inner": int(os.environ.get("FACES_INNER", 10)),
            "faces_max_iters": int(os.environ.get("FACES_MAX_ITERS", 64)),
        }
        if faces_bench.TUNED_KNOBS:
            # tuner-chosen knobs per published row: pinned by the gate's
            # knob-drift warning above
            faces["_meta"]["tuned_knobs"] = faces_bench.TUNED_KNOBS
    # machine-readable serve + overlap trajectories (medians, dispatch
    # counts), tracked at the repo root like BENCH_faces.json
    serve = serve_bench.collect(results)
    ovl = overlap_bench.collect(results)

    if check_path is not None:
        # the gate matching the suite that ran: `serve`/`overlap`
        # --check-against judge their own file's invariants/medians,
        # every other selection keeps judging the Faces file
        if which == "serve":
            sys.exit(serve_bench.check_against(serve, check_path))
        if which == "overlap":
            sys.exit(overlap_bench.check_against(ovl, check_path))
        sys.exit(check_against(faces, check_path))
    if faces:
        fout = os.path.join(here, "..", "BENCH_faces.json")
        with open(fout, "w") as f:
            json.dump(faces, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {fout}")
    if serve:
        fout = os.path.join(here, "..", "BENCH_serve.json")
        with open(fout, "w") as f:
            json.dump(serve, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {fout}")
    if ovl:
        fout = os.path.join(here, "..", "BENCH_overlap.json")
        with open(fout, "w") as f:
            json.dump(ovl, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {fout}")


if __name__ == '__main__':
    main()
