"""repro.core — stream-triggered (ST) communication for JAX.

The paper's contribution as a composable JAX module:

* :mod:`.queue`        — ``STQueue``/``create_queue``: the MPIX_Queue API
* :mod:`.descriptors`  — deferred command descriptors + peer specs
* :mod:`.matching`     — trace-time two-sided tag matching
* :mod:`.counters`     — trigger/completion counters as data dependencies
* :mod:`.engine_fused` — ST execution: one fused XLA program
* :mod:`.engine_host`  — baseline: host-orchestrated per-op dispatch
* :mod:`.engine_persistent` — fully offloaded: N iterations, one dispatch,
  the device owns the loop (double-buffered slots, carried counters)
* :mod:`.schedule`     — ``compose``/``STSchedule``: N concurrent queues
  fused into one pipelined device-resident program (per-program counter
  banks, round-robin batch interleaving, per-program predicates)
* :mod:`.halo`         — the Faces 26-neighbor pattern as an ST program
* :mod:`.overlap`      — decomposed overlap-friendly collectives
* :mod:`.verify`       — STLint: static verifier + runtime sanitizer,
  including the happens-before race rules (ST015–ST018)
* :mod:`.effects`      — STProve: declared read/write effect sets per
  descriptor, per-buffer effect traces, and transform-equivalence
  certificates
"""

from .counters import (
    CompletionCounter,
    TriggerCounter,
    bump,
    completion_from,
    fresh_token,
    gate,
    tie,
)
from .descriptors import (
    BufferSpec,
    CollDesc,
    GridOffsetPeer,
    KernelDesc,
    OffsetPeer,
    PairListPeer,
    RecvDesc,
    SendDesc,
    StartDesc,
    WaitDesc,
)
from .effects import (
    Effect,
    EquivalenceCertificate,
    ProgramCertificate,
    batch_effects,
    certify_equivalence,
    effect_trace,
    program_certificate,
    program_digest,
    stamp_staging,
)
from .engine_fused import FusedEngine
from .engine_host import HostEngine, HostStats
from .engine_persistent import PersistentEngine
from .halo import (
    CORNERS,
    DIRECTIONS,
    EDGES,
    FACES,
    FacesConfig,
    build_faces_part_program,
    build_faces_program,
    faces_oracle,
    global_residual_fn,
    half_config,
    merge_halves,
    merge_parts,
    part_configs,
    part_names,
    part_points,
    run_faces_persistent,
    run_faces_pipelined,
    run_faces_until_converged,
    split_halves,
    split_parts,
)
from .matching import (
    Batch,
    Channel,
    CoalescedChannel,
    CoalescePlan,
    MatchError,
    coalesce_batch,
    match_batch,
)
from .queue import QueueError, STProgram, STQueue, create_queue
from .schedule import (INTERLEAVE_POLICIES, InterleavePolicy, Link,
                       ScheduleError, STSchedule, SubProgram, compose)
from .verify import (
    Diagnostic,
    SanitizeError,
    STLintWarning,
    VerifyError,
    build_happens_before,
    format_diagnostics,
    hb_race_diagnostics,
    run_verify,
    verify_program,
)

__all__ = [
    "STQueue", "STProgram", "create_queue", "QueueError",
    "STSchedule", "SubProgram", "compose", "ScheduleError", "Link",
    "InterleavePolicy", "INTERLEAVE_POLICIES",
    "FusedEngine", "HostEngine", "HostStats", "PersistentEngine",
    "OffsetPeer", "GridOffsetPeer", "PairListPeer",
    "SendDesc", "RecvDesc", "CollDesc", "KernelDesc", "StartDesc", "WaitDesc",
    "BufferSpec", "Batch", "Channel", "MatchError", "match_batch",
    "CoalescedChannel", "CoalescePlan", "coalesce_batch",
    "TriggerCounter", "CompletionCounter", "fresh_token", "bump", "tie",
    "gate", "completion_from",
    "FacesConfig", "build_faces_program", "build_faces_part_program",
    "faces_oracle",
    "run_faces_persistent", "run_faces_until_converged",
    "run_faces_pipelined", "half_config", "split_halves", "merge_halves",
    "part_configs", "part_names", "part_points", "split_parts",
    "merge_parts",
    "global_residual_fn",
    "DIRECTIONS", "FACES", "EDGES", "CORNERS",
    "Diagnostic", "STLintWarning", "VerifyError", "SanitizeError",
    "verify_program", "run_verify", "format_diagnostics",
    "build_happens_before", "hb_race_diagnostics",
    "Effect", "EquivalenceCertificate", "ProgramCertificate",
    "batch_effects", "certify_equivalence", "effect_trace",
    "program_certificate", "program_digest", "stamp_staging",
]
