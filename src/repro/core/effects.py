"""STProve effect sets — the memory-effect model under every descriptor.

STLint (:mod:`repro.core.verify`) walks the *emitted* stream order; the
transform layers above it (coalescing, interleave policies, unroll,
double-buffer rotation, the tuner's whole knob space) re-order and
re-lower that stream.  To reason about a program under **every** legal
interleaving — and to prove that a transformed program still touches
memory the same way — each descriptor needs a declared read/write
effect set, not just a position in one particular stream.

This module is that effect substrate:

* :func:`batch_effects` derives one :class:`Effect` per memory access a
  trigger batch performs — pack reads (send sources, collective
  inputs), deposit writes (recv destinations, collective outputs,
  add-mode accumulations) and coalesce staging-buffer traffic — and
  ``queue.build()`` / ``schedule.compose()`` record the result on
  :class:`~repro.core.matching.Batch.effects` (compose re-records after
  cross-program channels join their trigger batches);
* :func:`stamp_staging` gives every fused transfer of a
  :class:`~repro.core.matching.CoalescePlan` a *declared* staging-buffer
  identity (unique per batch/transfer by construction, so reuse across
  overlapping trigger→wait windows — rule ST017 — is a statement about
  declared identities, never an inference);
* :func:`effect_trace` flattens a whole program into per-buffer effect
  sequences in per-pid program order.  The trace is **invariant under
  every transform that preserves semantics** — interleave policy,
  coalescing on/off, trigger mode, double-buffer/unroll — because it
  never looks at the merged stream: per-pid order is FIFO by the queue
  contract, and cross-program deposits are recorded at the *receiver's
  gating wait*, the only point the receiver may observe them;
* :func:`certify_equivalence` compares two programs' traces (plus
  buffer specs) and checks the candidate race-free under the
  happens-before analysis (:func:`repro.core.verify.build_happens_before`),
  returning an :class:`EquivalenceCertificate`.  ``launch/tune.py``
  consumes it — certified candidates skip the per-candidate allclose
  check, uncertified ones are disqualified before timing — and
  ``repro.analysis`` prints one :class:`ProgramCertificate` per
  registry program in CI.

What the trace can and cannot see: kernels are identified by their
``name`` plus read/write signature (two kernels with one name and one
signature but different bodies are indistinguishable statically — the
builders name kernels uniquely per role, which is the contract), and
regions are compared as canonical ``(start, stop, step)`` triples.
Structural changes (``n_parts``, different kernels, added channels)
always change the trace; execution-configuration knobs never do.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .descriptors import KernelDesc, StartDesc, WaitDesc
from .matching import _peer_key


@dataclasses.dataclass(frozen=True)
class Effect:
    """One declared memory access of a descriptor or batch.

    ``kind`` is ``"read" | "write" | "accum"`` (add-mode deposits
    accumulate: they read AND write, and commute with each other but
    with nothing else).  ``source`` names the access class —
    ``"kernel"`` (enqueued compute), ``"pack"`` (send source /
    collective input read at trigger), ``"deposit"`` (recv destination /
    collective output) or ``"stage"`` (coalesce staging buffer).
    ``pid`` is the pid of the stream that *triggers* the access;
    ``region`` is a canonical region key (:func:`region_key`).
    """

    buf: str
    kind: str
    source: str
    pid: int
    region: Optional[Tuple] = None
    site: Optional[str] = None


def region_key(region) -> Optional[Tuple]:
    """Canonical, hashable key for a send/recv region.

    ``None`` (whole buffer) stays ``None``; slices become
    ``(start, stop, step)`` triples; anything fancier is keyed by repr
    (compared conservatively as opaque-but-equal-by-spelling).
    """
    if region is None:
        return None
    try:
        return tuple(
            (s.start, s.stop, s.step) if isinstance(s, slice)
            else ("ix", repr(s))
            for s in tuple(region))
    except TypeError:
        return ("opaque", repr(region))


def stamp_staging(plan, batch_index: int):
    """Fill in declared staging identities on a plan's fused transfers.

    Build-time stamps are unique per (batch, transfer) — engines
    allocate exactly one staging temporary per fused transfer, so no
    two trigger→wait windows ever share one.  A transfer that already
    declares a ``staging`` name keeps it (hand-built plans and the
    ST017 mutation tests declare collisions on purpose).
    """
    if plan is None:
        return None
    transfers = tuple(
        t if t.staging is not None
        else dataclasses.replace(t, staging=f"~stage/b{batch_index}.t{ti}")
        for ti, t in enumerate(plan.transfers))
    return dataclasses.replace(plan, transfers=transfers)


def batch_effects(batch) -> Tuple[Effect, ...]:
    """Derive one batch's declared effect set, in execution order.

    Order mirrors the engines' per-batch lowering: every pack read
    (send sources, collective inputs) happens at the trigger, staging
    buffers are written (packed) then read (deposited from), and
    deposits land last — recv destinations and collective outputs.
    """
    pid = batch.pid
    effs: List[Effect] = []
    for ch in batch.channels:
        effs.append(Effect(buf=ch.src_buf, kind="read", source="pack",
                           pid=pid, region=region_key(ch.send_region),
                           site=ch.send_site))
    for coll in batch.colls:
        effs.append(Effect(buf=coll.buf, kind="read", source="pack",
                           pid=pid, site=coll.site))
    if batch.plan is not None:
        for t in batch.plan.transfers:
            if t.staging is not None:
                effs.append(Effect(buf=t.staging, kind="write",
                                   source="stage", pid=pid))
                effs.append(Effect(buf=t.staging, kind="read",
                                   source="stage", pid=pid))
    for ch in batch.channels:
        effs.append(Effect(
            buf=ch.dst_buf, kind="accum" if ch.mode == "add" else "write",
            source="deposit", pid=pid, region=region_key(ch.recv_region),
            site=ch.recv_site))
    for coll in batch.colls:
        effs.append(Effect(buf=coll.out, kind="write", source="deposit",
                           pid=pid, site=coll.site))
    return tuple(effs)


def cross_gate_map(prog) -> Dict[Tuple[int, str], List[Tuple[int, int]]]:
    """``(src_batch, dst_buf) -> [(dst_pid, dst_batch), ...]`` for every
    resolved cross-program channel (from ``STSchedule.links``; falls
    back to scanning ``cross_recv_bufs`` for hand-built schedules)."""
    gates: Dict[Tuple[int, str], List[Tuple[int, int]]] = defaultdict(list)
    links = getattr(prog, "links", ()) or ()
    if links:
        subs = getattr(prog, "subs", ())
        pid_of = {s.name: s.pid for s in subs}
        for l in links:
            gates[(l.src_batch, l.dst_buf)].append(
                (pid_of.get(l.dst, 0), l.dst_batch))
        return gates
    for b in prog.batches:
        for buf in b.cross_recv_bufs:
            for src in prog.batches:
                for ch in src.channels:
                    if ch.dst_pid == b.pid and ch.dst_buf == buf:
                        gates[(src.index, buf)].append((b.pid, b.index))
    return gates


def effect_trace(prog) -> Dict[str, Tuple[Tuple, ...]]:
    """Per-buffer effect sequences, in per-pid program order.

    The trace is the program's memory-effect *semantics* stripped of
    scheduling: each pid's records appear in that pid's own FIFO order
    (invariant under every interleave policy — policies merge streams,
    they never reorder within one), and a cross-program deposit is
    recorded at the **receiver's gating wait** in the receiver's walk —
    the earliest point the receiving stream may observe it, identical
    under every legal schedule of the same links.
    """
    batches = {b.index: b for b in prog.batches}
    gates = cross_gate_map(prog)
    cursor: Dict[Tuple[int, str], int] = defaultdict(int)

    # Resolve every cross-program deposit to its gate once, walking the
    # full stream so the per-key FIFO cursor advances exactly as the
    # engines' (and verify's) walk does.  Which *gate* a deposit
    # resolves to depends only on per-batch channel order — interleave
    # policies cannot change it (each batch has one StartDesc).
    pending_cross: Dict[Tuple[int, int], List[Tuple[str, Tuple]]] = \
        defaultdict(list)
    for d in prog.descriptors:
        if not isinstance(d, StartDesc):
            continue
        batch = batches.get(d.batch)
        if batch is None:
            continue
        for ch in batch.channels:
            dpid = d.pid if ch.dst_pid is None else ch.dst_pid
            if dpid == d.pid:
                continue
            key = (d.batch, ch.dst_buf)
            opts = gates.get(key, [])
            cur = cursor[key]
            gate = (opts[min(cur, len(opts) - 1)] if opts
                    else (dpid, d.batch))
            cursor[key] = cur + 1
            pending_cross[gate].append((ch.dst_buf, (
                "deposit", ch.tag, ch.mode, region_key(ch.recv_region),
                "from_pid", d.pid)))

    trace: Dict[str, List[Tuple]] = defaultdict(list)
    pids = sorted({d.pid for d in prog.descriptors}) or [0]
    for pid in pids:
        flushed: set = set()
        for d in prog.descriptors:
            if d.pid != pid:
                continue
            if isinstance(d, KernelDesc):
                for r in d.reads:
                    trace[r].append(("kread", d.name, d.reads, d.writes))
                for w in d.writes:
                    trace[w].append(("kwrite", d.name, d.reads, d.writes))
            elif isinstance(d, StartDesc):
                batch = batches.get(d.batch)
                if batch is None:
                    continue
                for ch in batch.channels:
                    trace[ch.src_buf].append((
                        "send", ch.tag, _peer_key(ch.peer),
                        region_key(ch.send_region)))
                for coll in batch.colls:
                    trace[coll.buf].append(("collread", coll.op,
                                            repr(coll.axis)))
                for ch in batch.channels:
                    dpid = pid if ch.dst_pid is None else ch.dst_pid
                    if dpid != pid:
                        continue  # cross deposit: receiver's wait records it
                    trace[ch.dst_buf].append((
                        "deposit", ch.tag, ch.mode,
                        region_key(ch.recv_region)))
                for coll in batch.colls:
                    trace[coll.out].append(("collout", coll.op,
                                            repr(coll.axis)))
            elif isinstance(d, WaitDesc):
                for gate, recs in pending_cross.items():
                    gpid, gbatch = gate
                    if gpid != pid or gbatch > d.batch or gate in flushed:
                        continue
                    flushed.add(gate)
                    for buf, rec in recs:
                        trace[buf].append(rec)
    return {buf: tuple(recs) for buf, recs in trace.items()}


def _buffer_specs(prog) -> Dict[str, Tuple]:
    return {
        name: (tuple(spec.shape), np.dtype(spec.dtype).str,
               tuple(repr(p) for p in spec.pspec))
        for name, spec in prog.buffers.items()
    }


def program_digest(prog) -> str:
    """Stable hash of a program's effect trace + buffer specs."""
    h = hashlib.sha256()
    for name, spec in sorted(_buffer_specs(prog).items()):
        h.update(repr((name, spec)).encode())
    for buf, recs in sorted(effect_trace(prog).items()):
        h.update(repr((buf, recs)).encode())
    return h.hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class EquivalenceCertificate:
    """Proof record that a transformed program preserves effect
    semantics — per-buffer effect traces and buffer specs match the
    baseline's, and the candidate is race-free under happens-before
    (no ST015–ST018 findings).  ``reason`` names the first mismatch
    when ``equivalent`` is False."""

    equivalent: bool
    baseline: str
    candidate: str
    baseline_digest: str
    candidate_digest: str
    race_free: bool
    n_buffers: int
    reason: Optional[str] = None


def certify_equivalence(baseline, candidate) -> EquivalenceCertificate:
    """Certify ``candidate``'s memory-effect semantics match ``baseline``.

    Three checks, all static: (1) identical buffer specs, (2) identical
    per-buffer effect traces (:func:`effect_trace`), (3) the candidate
    is race-free under the happens-before analysis.  A certificate with
    ``equivalent=True`` licenses skipping per-candidate bit-identity
    measurement: same buffers, same per-stream access sequences, and no
    interleaving can expose an unordered conflict.
    """
    from .verify import hb_race_diagnostics  # lazy: verify imports us

    base_digest = program_digest(baseline)
    cand_digest = program_digest(candidate)
    races = hb_race_diagnostics(candidate)
    race_free = not races

    def cert(equivalent: bool, reason: Optional[str] = None):
        return EquivalenceCertificate(
            equivalent=equivalent, baseline=baseline.name,
            candidate=candidate.name, baseline_digest=base_digest,
            candidate_digest=cand_digest, race_free=race_free,
            n_buffers=len(candidate.buffers), reason=reason)

    sb, sc = _buffer_specs(baseline), _buffer_specs(candidate)
    if sb != sc:
        changed = sorted(set(sb) ^ set(sc)) or sorted(
            n for n in sb if sb[n] != sc.get(n))
        return cert(False, f"buffer specs differ: {changed[:4]}")
    tb, tc = effect_trace(baseline), effect_trace(candidate)
    if set(tb) != set(tc):
        return cert(False, "touched-buffer sets differ: "
                           f"{sorted(set(tb) ^ set(tc))[:4]}")
    for buf in sorted(tb):
        if tb[buf] != tc[buf]:
            return cert(False, f"effect trace diverges on {buf!r} "
                               f"({len(tb[buf])} vs {len(tc[buf])} records)")
    if not race_free:
        return cert(False, "candidate is not race-free under "
                           "happens-before: "
                    + "; ".join(d.rule for d in races[:4]))
    return cert(True)


@dataclasses.dataclass(frozen=True)
class ProgramCertificate:
    """Per-program summary for ``python -m repro.analysis --strict``:
    the effect-trace digest and the happens-before race verdict."""

    name: str
    digest: str
    race_free: bool
    n_races: int
    n_effects: int


def program_certificate(prog) -> ProgramCertificate:
    """Digest + race-free-under-all-interleavings verdict for ``prog``."""
    from .verify import hb_race_diagnostics  # lazy: verify imports us

    races = hb_race_diagnostics(prog)
    trace = effect_trace(prog)
    return ProgramCertificate(
        name=prog.name, digest=program_digest(prog),
        race_free=not races, n_races=len(races),
        n_effects=sum(len(r) for r in trace.values()))
