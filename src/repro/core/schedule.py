"""STSchedule — compose concurrent STQueues into ONE device program.

The paper's ST model keeps one deferred-work queue per GPU stream.  Real
Nekbone-style solves want *several* queues in flight, so one queue's
communication overlaps another queue's compute — the multi-DWQ schedule
of "Understanding GPU Triggering APIs for MPI+X Communication"
(arXiv:2406.05594) and the fully offloaded follow-on (arXiv:2306.15773).
Running each queue's persistent loop as its own host dispatch pays one
dispatch per queue and gives the device no chance to interleave them.

:func:`compose` fuses N *matched* :class:`~repro.core.queue.STProgram`\\ s
into one :class:`STSchedule` (an ``STProgram`` subclass), with

* **namespaced buffers** — program ``p``'s buffer ``b`` becomes
  ``"p/b"``, so no memory is shared between sub-programs (static
  analysis rejects cross-program buffer aliasing: composing two
  programs with the same name — e.g. a program with itself — is an
  error);
* **program identity** — every descriptor, batch and buffer carries the
  sub-program's ``pid``, which the engines use to keep one
  trigger/completion counter bank *per program* (the multi-queue
  analogue of one counter pair per ``MPIX_Queue``) and to scope
  stream-FIFO ordering per program instead of serializing the whole
  composition;
* **round-robin batch interleaving** — each program's descriptor stream
  is split into *segments* at its trigger/wait gates (a segment ends
  after each ``start``, and after each ``wait`` that does not fall
  inside an open batch), and the segments are merged round-robin.
  Program B's packs and kernels therefore sit *between* program A's
  ``start`` and A's ``wait`` in the fused stream: software pipelining
  of the queues.  A batch's descriptors are never split across
  segments, and each program's internal FIFO order is preserved
  exactly (property-tested).

Per-program iteration counts and termination predicates ride along:
``compose(pA.persistent(50, until=predA), pB.persistent(40, until=predB))``
yields a schedule the persistent engine runs until **all** programs'
predicates terminate, freezing each program's state at its own
convergence point and reporting a per-program realized iteration count
(see :class:`~repro.core.engine_persistent.PersistentEngine`).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from .descriptors import (
    CollDesc,
    KernelDesc,
    RecvDesc,
    SendDesc,
    StartDesc,
    WaitDesc,
)
from .matching import Batch, coalesce_batch
from .queue import STProgram


class ScheduleError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class SubProgram:
    """Composition metadata for one fused program."""

    name: str
    pid: int
    buffers: Tuple[str, ...]     # namespaced buffer names owned by this pid
    n_iters: int                 # per-program iteration count / bound
    until: Optional[Any]         # per-program termination predicate
    batch_lo: int                # first (renumbered) batch index
    n_batches: int


@dataclasses.dataclass
class STSchedule(STProgram):
    """N concurrent STPrograms fused into one device-resident program.

    ``n_iters`` on the schedule is the max over the sub-programs (the
    global loop bound); per-program counts/predicates live in ``subs``.
    """

    subs: Tuple[SubProgram, ...] = ()

    def buffers_by_pid(self) -> Dict[int, Tuple[str, ...]]:
        return {s.pid: s.buffers for s in self.subs}

    def sub(self, name: str) -> SubProgram:
        for s in self.subs:
            if s.name == name:
                return s
        raise KeyError(name)

    def buffer_name(self, sub: str, buf: str) -> str:
        """The namespaced name of ``buf`` inside sub-program ``sub``."""
        ns = f"{sub}/{buf}"
        if ns not in self.buffers:
            raise KeyError(ns)
        return ns

    def persistent(self, n_iters, until=None) -> "STProgram":
        raise ScheduleError(
            "persistence is per-program under composition: call "
            ".persistent(...) on each program BEFORE compose(), so every "
            "queue keeps its own iteration count and predicate"
        )


def _segments(descs) -> List[List[Any]]:
    """Split one program's descriptor stream at its trigger/wait gates.

    A segment ends after each ``StartDesc``, and after each ``WaitDesc``
    that is not inside an open batch (i.e. no send/recv/coll enqueued
    since the last start) — so a batch's deferred ops and its trigger
    always land in the same segment and can never be interleaved with
    another program's descriptors.
    """
    segs: List[List[Any]] = []
    cur: List[Any] = []
    open_batch = False
    for d in descs:
        cur.append(d)
        if isinstance(d, (SendDesc, RecvDesc, CollDesc)):
            open_batch = True
        elif isinstance(d, StartDesc):
            open_batch = False
            segs.append(cur)
            cur = []
        elif isinstance(d, WaitDesc) and not open_batch:
            segs.append(cur)
            cur = []
    if cur:
        segs.append(cur)
    return segs


def _interleave(per_prog_segments: List[List[List[Any]]]) -> Tuple[Any, ...]:
    """Round-robin merge of the programs' segment lists."""
    out: List[Any] = []
    rounds = max((len(s) for s in per_prog_segments), default=0)
    for r in range(rounds):
        for segs in per_prog_segments:
            if r < len(segs):
                out.extend(segs[r])
    return tuple(out)


def compose(*programs: STProgram, name: Optional[str] = None) -> STSchedule:
    """Fuse N matched STPrograms into one :class:`STSchedule`.

    Buffers are namespaced ``"{program.name}/{buffer}"``; descriptors and
    batches are tagged with their program's ``pid``; batch indices are
    renumbered to be globally unique; and the programs' descriptor
    streams are interleaved round-robin at trigger/wait-gate granularity
    (see :func:`_segments`).  Every engine accepts the result: the fused
    engine runs one interleaved pass, the persistent engine runs the
    whole multi-queue loop — per-program counts and predicates included
    — as ONE host dispatch.

    Raises :class:`ScheduleError` for programs on different meshes,
    duplicate program names (cross-program buffer aliasing — composing
    a program with itself is the canonical offender), or nested
    schedules (compose all leaves in one call instead).
    """
    if not programs:
        raise ScheduleError("compose() needs at least one program")
    mesh = programs[0].mesh
    names = [p.name for p in programs]
    if len(set(names)) != len(names):
        dupes = sorted({n for n in names if names.count(n) > 1})
        raise ScheduleError(
            f"cross-program buffer aliasing: duplicate program name(s) "
            f"{dupes} would map distinct programs onto the same buffer "
            f"namespace (build each queue with a distinct name)"
        )
    for p in programs:
        if isinstance(p, STSchedule):
            raise ScheduleError(
                f"nested composition: {p.name!r} is already a schedule — "
                f"compose all leaf programs in a single compose() call"
            )
        if p.mesh is not mesh and p.mesh != mesh:
            raise ScheduleError(
                f"program {p.name!r} lives on a different mesh than "
                f"{programs[0].name!r}; composed queues share one device grid"
            )

    buffers: Dict[str, Any] = {}
    batches: List[Batch] = []
    subs: List[SubProgram] = []
    per_prog_segments: List[List[List[Any]]] = []
    batch_lo = 0

    for pid, prog in enumerate(programs):
        ns = prog.name
        rename = {b: f"{ns}/{b}" for b in prog.buffers}
        for b, spec in prog.buffers.items():
            new = rename[b]
            if new in buffers:  # unreachable given the name check; belt+braces
                raise ScheduleError(f"buffer alias {new!r}")
            buffers[new] = dataclasses.replace(spec, name=new)

        memo: Dict[int, Any] = {}

        def rn(d, _rename=rename, _pid=pid, _lo=batch_lo, _memo=memo,
               _ns=ns):
            got = _memo.get(id(d))
            if got is not None:
                return got
            if isinstance(d, KernelDesc):
                new = dataclasses.replace(
                    d, reads=tuple(_rename[r] for r in d.reads),
                    writes=tuple(_rename[w] for w in d.writes), pid=_pid)
            elif isinstance(d, SendDesc):
                new = dataclasses.replace(d, buf=_rename[d.buf], pid=_pid)
            elif isinstance(d, RecvDesc):
                new = dataclasses.replace(d, buf=_rename[d.buf], pid=_pid)
            elif isinstance(d, CollDesc):
                new = dataclasses.replace(d, buf=_rename[d.buf],
                                          out=_rename[d.out], pid=_pid)
            elif isinstance(d, StartDesc):
                new = dataclasses.replace(d, batch=d.batch + _lo, pid=_pid)
            elif isinstance(d, WaitDesc):
                new = dataclasses.replace(d, batch=d.batch + _lo, pid=_pid)
            else:
                raise ScheduleError(
                    f"program {_ns!r} holds an unknown descriptor {d!r}")
            _memo[id(d)] = new
            return new

        descs = [rn(d) for d in prog.descriptors]
        mesh_shape = dict(mesh.shape)
        for b in prog.batches:
            renamed_channels = [dataclasses.replace(
                ch, src_buf=rename[ch.src_buf],
                dst_buf=rename[ch.dst_buf]) for ch in b.channels]
            # re-derive the coalescing plan over the renamed channels:
            # batches are per-pid, so a plan can never merge channels
            # across programs — each queue keeps its own fused transfers
            plan = (coalesce_batch(renamed_channels, buffers, mesh_shape)
                    if b.plan is not None else None)
            batches.append(Batch(
                index=b.index + batch_lo,
                kernels_before=[rn(k) for k in b.kernels_before],
                channels=renamed_channels,
                colls=[rn(c) for c in b.colls],
                waited=b.waited,
                pid=pid,
                plan=plan,
            ))
        subs.append(SubProgram(
            name=ns, pid=pid, buffers=tuple(rename.values()),
            n_iters=prog.n_iters, until=prog.until,
            batch_lo=batch_lo, n_batches=prog.n_batches,
        ))
        per_prog_segments.append(_segments(descs))
        batch_lo += prog.n_batches

    return STSchedule(
        buffers=buffers,
        descriptors=_interleave(per_prog_segments),
        batches=tuple(batches),
        mesh=mesh,
        name=name or "+".join(names),
        n_iters=max(p.n_iters for p in programs),
        until=None,
        subs=tuple(subs),
    )
