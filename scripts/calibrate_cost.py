"""Fit the cost model's unit constants from recorded bench medians.

The analytic schedule cost model (:mod:`repro.launch.costing`) prices a
program as a sum of per-component unit costs (host dispatches, fired
collectives, kernel ops, bytes moved/staged, ...).  Those constants
were hand-anchored once; this script re-fits them against whatever the
repo's recorded benchmark files say about THIS machine:

* ``BENCH_faces.json`` medians, via the registry-program → bench-row
  mapping ``benchmarks/roofline.py`` maintains (each row pairs a
  priced ST program with a measured median at like-for-like settings);
* ``BENCH_overlap.json``'s persistent transformer-block chain, rebuilt
  at the recorded ``_meta`` workload and priced the same way.

Every component is linear in its unit cost, so a measured median is a
linear equation in per-component *scales*: component µs under the
default params form the design matrix, and a least-squares solve (3
grouped scales — dispatch, communication, compute — keeps the system
overdetermined with a handful of rows) yields the re-fitted constants.
The fit is printed as a ready-to-paste ``CostParams(...)`` block plus
the before/after rank agreement; it never edits source files — the
constants in ``costing.py`` stay the pin until a human moves them
(``benchmarks/roofline.py`` warns when the ranking has drifted enough
to make that worthwhile).

Usage::

  PYTHONPATH=src python scripts/calibrate_cost.py
"""
import json
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.join(HERE, "..")

# grouped scales: few enough unknowns that ~7 medians overdetermine
# them.  Each group scales the CostParams constants listed with it.
GROUPS = {
    "dispatch": (("dispatch_us",), ("dispatch_us",)),
    "comm": (("collective_us", "bytes_us"), ("collective_us", "byte_us")),
    "compute": (("kernel_us", "staging_us", "slot_us", "exposed_us",
                 "switch_us"),
                ("kernel_us", "compute_byte_us", "stage_byte_us",
                 "slot_byte_us", "switch_us")),
}


def _rows():
    """(name, ScheduleCost-with-default-params, measured_ms) triples."""
    import sys
    sys.path.insert(0, ROOT)
    from benchmarks import roofline
    from repro.launch.costing import schedule_cost

    out = []
    for r in roofline.st_table():
        if r.get("measured_ms") is not None:
            # re-price to get the itemized components (st_table only
            # keeps the total)
            from repro.analysis.programs import INNER, iter_programs
            progs = dict(iter_programs())
            n_iters = INNER if r["engine"] == "fused" else None
            cost = schedule_cost(progs[r["st_program"]], engine=r["engine"],
                                 mode=r["mode"], n_iters=n_iters)
            out.append((r["st_program"], cost, r["measured_ms"]))

    ovl_path = os.path.join(ROOT, "BENCH_overlap.json")
    if os.path.exists(ovl_path):
        with open(ovl_path) as f:
            stored = json.load(f)
        meta = stored.get("_meta", {})
        row = stored.get("overlap/tp_st_persistent")
        if meta and isinstance(row, dict) and row.get("median_ms"):
            import jax
            if jax.device_count() >= meta["devices"]:
                from repro.core import collectives
                from repro.parallel import make_mesh
                mesh = make_mesh((meta["devices"],), ("x",))
                tp = collectives.build_tp_block(
                    mesh, "x", meta["m"], meta["k"], meta["f"], chain=True)
                cost = schedule_cost(tp.program.persistent(meta["layers"]),
                                     engine="persistent", mode="dataflow")
                out.append(("overlap_tp_chain", cost, row["median_ms"]))
    return out


def fit(rows):
    """Least-squares per-group scales; returns ({group: scale}, resid)."""
    names = list(GROUPS)
    A = np.array([[sum(getattr(cost, c) for c in GROUPS[g][0])
                   for g in names] for _, cost, _ in rows])
    y = np.array([ms * 1e3 for _, _, ms in rows])   # µs
    sol, *_ = np.linalg.lstsq(A, y, rcond=None)
    # a negative scale means the rows can't attribute that group's cost
    # (collinear components) — keep the hand-anchored constant instead
    scales = {g: (float(s) if s > 0 else 1.0)
              for g, s in zip(names, sol)}
    pred = A @ np.array([scales[g] for g in names])
    return scales, pred


def main():
    from repro.launch.costing import DEFAULT_PARAMS

    rows = _rows()
    if len(rows) < len(GROUPS):
        print(f"calibration needs >= {len(GROUPS)} measured rows, have "
              f"{len(rows)} — record BENCH_faces.json / BENCH_overlap.json "
              f"on this machine first (PYTHONPATH=src python -m "
              f"benchmarks.run)")
        return

    scales, pred = fit(rows)
    print(f"fitted {len(rows)} rows:")
    print(f"{'row':28s} {'measured':>10s} {'default':>10s} {'fitted':>10s}")
    for (name, cost, ms), p in zip(rows, pred):
        print(f"{name:28s} {ms*1e3:>8.0f}us {cost.total_us:>8.0f}us "
              f"{p:>8.0f}us")
    print(f"\nscales: " + ", ".join(f"{g}={s:.3f}"
                                    for g, s in scales.items()))

    # concordant-pair agreement, default vs fitted
    def agreement(preds):
        both = list(zip(preds, [ms for _, _, ms in rows]))
        conc = pairs = 0
        for i in range(len(both)):
            for j in range(i + 1, len(both)):
                pairs += 1
                if ((both[i][0] - both[j][0])
                        * (both[i][1] - both[j][1])) > 0:
                    conc += 1
        return conc, pairs

    c0, p0 = agreement([cost.total_us for _, cost, _ in rows])
    c1, _ = agreement(list(pred))
    print(f"rank agreement: default {c0}/{p0} -> fitted {c1}/{p0}")

    print("\nsuggested CostParams (paste into repro/launch/costing.py "
          "if the fitted ranking is better):\n")
    print("CostParams(")
    for group, (_, params) in GROUPS.items():
        for pname in params:
            print(f"    {pname}={getattr(DEFAULT_PARAMS, pname) * scales[group]:.6g},")
    print(f"    overlap_eff={DEFAULT_PARAMS.overlap_eff},")
    print(")")

    out = os.path.join(ROOT, "artifacts", "costing")
    os.makedirs(out, exist_ok=True)
    with open(os.path.join(out, "calibration.json"), "w") as f:
        json.dump({"scales": scales,
                   "rows": [{"row": n, "measured_ms": ms,
                             "default_us": cost.total_us,
                             "fitted_us": float(p)}
                            for (n, cost, ms), p in zip(rows, pred)],
                   "agreement": {"default": [c0, p0], "fitted": [c1, p0]}},
                  f, indent=1)
    print(f"\nwrote artifacts/costing/calibration.json")


if __name__ == "__main__":
    main()
