"""Pallas TPU kernels for the perf-critical compute hot-spots.

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
a jit'd wrapper in ops.py, and a pure-jnp oracle in ref.py.  Validated on
CPU with interpret=True; TPU is the compile target.
"""
from . import ops, ref
from .ops import (
    flash_attention,
    halo_pack,
    halo_unpack_add,
    pack_boundary,
    rmsnorm,
    ssd_scan,
    unpack_boundary_add,
)

__all__ = [
    "ops", "ref", "flash_attention", "halo_pack", "halo_unpack_add",
    "pack_boundary", "rmsnorm", "ssd_scan", "unpack_boundary_add",
]
