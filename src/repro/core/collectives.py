"""Collective-matmul unification — ring collectives as ST programs.

`core/overlap.py` expresses the decomposed ("collective matmul")
family as plain shard_map functions: ring steps are inline
``lax.ppermute`` calls, invisible to the ST machinery.  This module
re-expresses the same decompositions as **first-class ST descriptors**:
each ring step is an ordinary trigger→wait channel
(``enqueue_send``/``enqueue_recv`` + ``enqueue_start``/``enqueue_wait``)
and each per-chunk matmul/copy is an ``enqueue_compute`` kernel — so
the collectives inherit, with zero extra code:

* trace-time matching + channel coalescing (:mod:`.matching`);
* STLint static verification (:mod:`.verify`, incl. the ring-specific
  rules ST013/ST014 added with this module);
* analytic pricing (:func:`repro.launch.costing.schedule_cost`) and
  knob tuning (:func:`repro.launch.tune.tune`);
* composition into multi-queue schedules (:func:`.schedule.compose`) —
  matmul chunks land in other queues' trigger→wait windows;
* persistent 1-dispatch execution (:mod:`.engine_persistent`).

Bit-identity contract: every builder reproduces the *exact* op
sequence of its `overlap.py` reference (same rotation direction, same
deposit offsets, same accumulate operand order), so results are
bitwise equal to the decomposed shard_map path — and to ``jax.lax``
for the pure-copy collectives (all-gather, all-to-all).  The in-place
ring rotation (send and recv on the SAME buffer, replace mode) is the
descriptor-level spelling of ``cur = ppermute(cur, +1)``: the fused
engine reads the pre-trigger value for the send and the full-ring
replace deposit overwrites every rank, which is exactly a permute.

Layout conventions (mirroring the `overlap_bench.py` reference specs):

``enqueue_all_gather``      buf global [n*m, ...] pspec (axis,);
                            out replicated () — final value is
                            rank-invariant (or per-chunk ``compute``
                            output rows, caller-chosen pspec).
``enqueue_reduce_scatter``  buf = per-rank full partials, global
                            [n*(n*c), ...] pspec (axis,); out global
                            [n*c, ...] pspec (axis,).
``enqueue_all_to_all``      buf/out global [n*(n*b), ...] pspec
                            (axis,): local block j goes to rank j.

The high-level builders (:func:`build_all_gather_matmul`,
:func:`build_matmul_reduce_scatter`, :func:`build_all_to_all`,
:func:`build_tp_block`) return ready STPrograms for the three
collective-matmul patterns plus the headline "transformer block as ST
schedule" (Megatron MLP with sequence parallelism: all-gather-matmul →
relu → matmul-reduce-scatter), each with a pure-jax ``reference``
companion for bit-identity checks.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .descriptors import OffsetPeer
from .queue import QueueError, STProgram, STQueue


def _update_rows(buf, piece, row0):
    """Deposit ``piece`` at row offset ``row0`` (traced index OK)."""
    return jax.lax.dynamic_update_slice_in_dim(
        buf, piece.astype(buf.dtype), row0, axis=0)


class CollectiveQueue(STQueue):
    """STQueue + ring-collective enqueue verbs.

    Every verb below is sugar: it appends ordinary kernel / send /
    recv / start / wait descriptors to the queue, one trigger→wait
    gate per ring step.  Nothing engine- or verifier-visible is new —
    which is the point: the built program is matched, coalesced,
    linted, priced, tuned, composed, and persisted exactly like any
    hand-written ST program.
    """

    def _axis_n(self, axis: str) -> int:
        shape = dict(self.mesh.shape)
        if axis not in shape:
            raise QueueError(f"mesh has no axis {axis!r}")
        return shape[axis]

    def _ring_pair(self, buf: str, axis: str, delta: int, tag: int) -> None:
        """One in-place ring rotation channel: ``buf = ppermute(buf, delta)``
        once the surrounding start fires (send reads the pre-trigger
        value; the full-ring replace deposit overwrites every rank)."""
        self.enqueue_send(buf, OffsetPeer(axis, delta, periodic=True), tag)
        self.enqueue_recv(buf, OffsetPeer(axis, -delta, periodic=True), tag,
                          mode="replace")

    def _stage(self, base: str, shape: Sequence[int], dtype, pspec) -> str:
        """Declare an internal staging buffer with a non-colliding name."""
        name, i = base, 0
        while name in self._buffers:
            i += 1
            name = f"{base}_{i}"
        return self.buffer(name, shape, dtype, pspec)

    # -- the collective verbs ------------------------------------------------

    def enqueue_all_gather(self, buf: str, out: str, axis: str, *,
                           compute: Optional[Callable] = None,
                           reads: Sequence[str] = (),
                           bidirectional: bool = False,
                           tag_base: int = 0) -> None:
        """Ring all-gather of ``buf`` (sharded over ``axis``, dim 0) into
        ``out``, one trigger→wait gate per ring step.

        With ``compute`` set, each arriving chunk is transformed before
        its deposit — ``compute(chunk, *extra)`` where ``extra`` are the
        local values of ``reads`` — which makes this the
        ``all_gather_matmul`` pattern: the per-chunk matmul is enqueued
        INTO the ring's trigger→wait window, so under composition other
        queues' transfers overlap it.  ``bidirectional=True`` runs two
        counter-rotating rings (ceil((n-1)/2) gates instead of n-1),
        the torus-friendly schedule of ``overlap.all_gather_ring``.
        """
        n = self._axis_n(axis)
        spec = self._buffers[buf]
        if n > 1 and (not spec.pspec or spec.pspec[0] != axis):
            raise QueueError(
                f"all_gather buffer {buf!r} must shard dim 0 over {axis!r}, "
                f"got pspec {spec.pspec}")
        out_spec = self._buffers[out]
        # per-chunk deposit rows: the LOCAL out rows split n ways (out
        # may be replicated — pure gather — or axis-sharded, as when a
        # compute hook leaves a per-rank column block)
        sharded_out = bool(out_spec.pspec) and out_spec.pspec[0] == axis
        local_rows = out_spec.shape[0] // n if sharded_out else out_spec.shape[0]
        if local_rows % n:
            raise QueueError(
                f"all_gather out {out!r}: local dim 0 ({local_rows}) must "
                f"divide by axis size {n}")
        m_out = local_rows // n
        extra = tuple(reads)

        def deposit(step: int, delta: int, src_buf: str) -> None:
            def k(cur, o, *xs):
                idx = jax.lax.axis_index(axis)
                piece = compute(cur, *xs) if compute is not None else cur
                src = (idx - delta * step) % n
                return _update_rows(o, piece, src * m_out)
            self.enqueue_compute(k, reads=(src_buf, out) + extra,
                                 writes=(out,),
                                 name=f"ag_chunk{step:+d}" if delta > 0
                                 else f"ag_chunk{-step:+d}")

        deposit(0, 1, buf)  # own chunk: no communication needed
        if n == 1:
            return
        if not bidirectional:
            for step in range(1, n):
                self._ring_pair(buf, axis, +1, tag_base + step)
                self.enqueue_start()
                self.enqueue_wait()
                deposit(step, +1, buf)
            return
        # two counter-rotating rings sharing each start gate
        bwd = self._stage(f"{buf}@bwd", spec.shape, spec.dtype, spec.pspec)
        self.enqueue_compute(lambda v: v, reads=(buf,), writes=(bwd,),
                             name="ag_seed_bwd")
        steps_fwd = (n - 1 + 1) // 2
        steps_bwd = (n - 1) // 2
        for step in range(1, steps_fwd + 1):
            self._ring_pair(buf, axis, +1, tag_base + 2 * step)
            if step <= steps_bwd:
                self._ring_pair(bwd, axis, -1, tag_base + 2 * step + 1)
            self.enqueue_start()
            self.enqueue_wait()
            deposit(step, +1, buf)
            if step <= steps_bwd:
                deposit(step, -1, bwd)

    def enqueue_reduce_scatter(self, buf: str, out: str, axis: str, *,
                               tag_base: int = 0) -> None:
        """Ring reduce-scatter: ``buf`` holds per-rank full partial sums
        (local rows = n * chunk); ``out`` (local rows = chunk) receives
        the summed chunk owned by this rank.

        Same schedule as ``overlap.reduce_scatter_ring``: the
        accumulator seeds with own piece (idx-1), then n-1 gates each
        rotate it one hop (+1) and add the next local piece — the
        accumulate kernel sits inside the ring's trigger→wait window.
        """
        n = self._axis_n(axis)
        spec = self._buffers[buf]
        out_spec = self._buffers[out]
        if n > 1 and (not spec.pspec or spec.pspec[0] != axis):
            raise QueueError(
                f"reduce_scatter buffer {buf!r} must shard dim 0 over "
                f"{axis!r}, got pspec {spec.pspec}")
        m_local = spec.shape[0] // n  # local partial rows
        if m_local % n:
            raise QueueError(
                f"reduce_scatter {buf!r}: local rows ({m_local}) must "
                f"divide by axis size {n}")
        chunk = m_local // n
        if n > 1 and out_spec.shape[0] // n != chunk:
            raise QueueError(
                f"reduce_scatter out {out!r}: expected local rows {chunk}, "
                f"got {out_spec.shape[0] // n}")

        def piece(y, i):
            yr = y.reshape((n, chunk) + y.shape[1:])
            return jnp.take(yr, i % n, axis=0)

        def seed(y):
            idx = jax.lax.axis_index(axis)
            return piece(y, idx - 1)

        self.enqueue_compute(seed, reads=(buf,), writes=(out,),
                             name="rs_seed")
        for step in range(1, n):
            self._ring_pair(out, axis, +1, tag_base + step)
            self.enqueue_start()
            self.enqueue_wait()

            def acc(a, y, _s=step):
                idx = jax.lax.axis_index(axis)
                return a + piece(y, idx - 1 - _s)
            self.enqueue_compute(acc, reads=(out, buf), writes=(out,),
                                 name=f"rs_acc{step}")

    def enqueue_all_to_all(self, buf: str, out: str, axis: str, *,
                           tag_base: int = 0) -> None:
        """All-to-all: local block j of ``buf`` goes to rank j (tiled,
        ``split_axis=0``), as ONE start gate carrying n-1 staged
        channels — the descriptor-level spelling of
        ``overlap.all_to_all_ppermute``'s n-1 permute rounds, batched so
        coalescing/interleaving see the whole exchange at once.
        """
        n = self._axis_n(axis)
        spec = self._buffers[buf]
        if n > 1 and (not spec.pspec or spec.pspec[0] != axis):
            raise QueueError(
                f"all_to_all buffer {buf!r} must shard dim 0 over {axis!r}, "
                f"got pspec {spec.pspec}")
        rows_local = spec.shape[0] // n
        if rows_local % n:
            raise QueueError(
                f"all_to_all {buf!r}: local rows ({rows_local}) must divide "
                f"by axis size {n}")
        blk = rows_local // n

        def block(x, i):
            mv = x.reshape((n, blk) + x.shape[1:])
            return jnp.take(mv, i % n, axis=0)

        def own(x, o):
            idx = jax.lax.axis_index(axis)
            return _update_rows(o, block(x, idx), idx * blk)

        self.enqueue_compute(own, reads=(buf, out), writes=(out,),
                             name="a2a_own")
        if n == 1:
            return
        stages = []
        for delta in range(1, n):
            st = self._stage(f"{buf}@a2a{delta}",
                             (n * blk,) + tuple(spec.shape[1:]),
                             spec.dtype, spec.pspec)
            stages.append(st)

            def pack(x, _d=delta):
                idx = jax.lax.axis_index(axis)
                return block(x, idx + _d)
            self.enqueue_compute(pack, reads=(buf,), writes=(st,),
                                 name=f"a2a_pack{delta}")
        for delta, st in enumerate(stages, start=1):
            self._ring_pair(st, axis, delta, tag_base + delta)
        self.enqueue_start()
        self.enqueue_wait()
        for delta, st in enumerate(stages, start=1):
            def drop(s, o, _d=delta):
                idx = jax.lax.axis_index(axis)
                return _update_rows(o, s, ((idx - _d) % n) * blk)
            self.enqueue_compute(drop, reads=(st, out), writes=(out,),
                                 name=f"a2a_drop{delta}")


# --------------------------------------------------------------------------
# built-program builders (collective-matmul family + TP block)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CollectiveMatmul:
    """A built collective-matmul ST program + its pure-jax oracles.

    ``program`` is engine-ready; ``inputs`` names the buffers the
    caller seeds; ``output`` names the result buffer.

    ``reference`` is the BIT-IDENTITY oracle: the decomposed
    ``overlap.py`` lowering inside shard_map, whose op sequence the ST
    program reproduces exactly — results must match with
    ``assert_array_equal``.  ``reference_stock`` is the stock
    ``jax.lax`` collective lowering (the perf baseline the bench races
    against); it is ALSO bitwise for the pure-copy collectives
    (all-gather, all-to-all) but only allclose where a ring sum
    reorders floating-point accumulation (reduce-scatter — the same
    tolerance `overlap.py`'s own tests use against ``psum_scatter``).
    """

    program: STProgram
    inputs: Tuple[str, ...]
    output: str
    reference: Callable[..., Any]
    reference_stock: Optional[Callable[..., Any]] = None


def _smap_ref(mesh, fn, in_specs, out_specs):
    from repro.compat import jit_shard_map
    return jit_shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs)


def build_all_gather_matmul(mesh, axis: str, m: int, k: int, n_out: int,
                            dtype=np.float32, *, bidirectional: bool = False,
                            compute: Optional[Callable] = None,
                            verify: str = "warn",
                            name: str = "st_ag_matmul") -> CollectiveMatmul:
    """``all_gather(x) @ w`` as an ST program (x row-sharded, w
    replicated, out replicated — `overlap_bench`'s reference specs).

    ``m`` is the GLOBAL gathered row count; per-chunk matmuls are
    enqueued into the ring's trigger→wait windows.  ``compute``
    overrides the per-chunk op (default ``chunk @ w``) — e.g. add a
    fused nonlinearity.
    """
    n = dict(mesh.shape)[axis]
    if m % n:
        raise QueueError(f"m ({m}) must divide by axis size {n}")
    out_dtype = jnp.result_type(dtype, dtype)
    q = CollectiveQueue(mesh, name)
    q.buffer("x", (m, k), dtype, pspec=(axis,))
    q.buffer("w", (k, n_out), dtype, pspec=())
    q.buffer("out", (m, n_out), out_dtype, pspec=())
    q.enqueue_all_gather(
        "x", "out", axis,
        compute=compute or (lambda chunk, w: chunk @ w),
        reads=("w",), bidirectional=bidirectional)
    prog = q.build(verify=verify)

    from . import overlap

    def ref_body(x, w):
        # custom per-chunk hooks must be row-wise (fn(concat(chunks)) ==
        # concat(fn(chunk))) for the gathered-then-applied oracle to
        # stay bitwise — true of matmul + elementwise ops
        fn = compute or (lambda chunk, ww: chunk @ ww)
        if compute is None:
            return overlap.all_gather_matmul(x, w, axis)
        gathered = overlap.all_gather_ring(x, axis, bidirectional=False)
        return fn(gathered, w)

    from jax.sharding import PartitionSpec as P
    reference = _smap_ref(mesh, ref_body, (P(axis), P()), P())
    stock = _smap_ref(
        mesh,
        lambda x, w: jax.lax.all_gather(x, axis, axis=0, tiled=True) @ w
        if compute is None else ref_body(x, w),
        (P(axis), P()), P())
    return CollectiveMatmul(prog, ("x", "w"), "out", reference, stock)


def build_matmul_reduce_scatter(mesh, axis: str, m: int, k: int, n_out: int,
                                dtype=np.float32, *, verify: str = "warn",
                                name: str = "st_matmul_rs") -> CollectiveMatmul:
    """``reduce_scatter(x @ w)`` as an ST program (x column-sharded over
    k, w row-sharded over k, out row-sharded — `overlap_bench` specs).

    The partial matmul is one compute kernel; the accumulate kernels
    ride the ring gates (`overlap.matmul_reduce_scatter` schedule).
    """
    n = dict(mesh.shape)[axis]
    if m % n:
        raise QueueError(f"m ({m}) must divide by axis size {n}")
    q = CollectiveQueue(mesh, name)
    q.buffer("x", (m, k), dtype, pspec=(None, axis))
    q.buffer("w", (k, n_out), dtype, pspec=(axis,))
    # per-rank full partials: local rows = m, so global rows = n*m
    q.buffer("y", (n * m, n_out), dtype, pspec=(axis,))
    q.buffer("out", (m, n_out), dtype, pspec=(axis,))
    q.enqueue_compute(lambda x, w: x @ w, reads=("x", "w"), writes=("y",),
                      name="partial_matmul")
    q.enqueue_reduce_scatter("y", "out", axis)
    prog = q.build(verify=verify)

    from . import overlap
    from jax.sharding import PartitionSpec as P
    reference = _smap_ref(
        mesh, lambda x, w: overlap.matmul_reduce_scatter(x, w, axis),
        (P(None, axis), P(axis)), P(axis))
    stock = _smap_ref(
        mesh,
        lambda x, w: jax.lax.psum_scatter(x @ w, axis, scatter_dimension=0,
                                          tiled=True),
        (P(None, axis), P(axis)), P(axis))
    return CollectiveMatmul(prog, ("x", "w"), "out", reference, stock)


def build_all_to_all(mesh, axis: str, rows: int, cols: int,
                     dtype=np.float32, *, verify: str = "warn",
                     name: str = "st_a2a") -> CollectiveMatmul:
    """Tiled all-to-all (MoE dispatch building block) as an ST program.

    ``rows`` is the GLOBAL row count (local rows = rows/n, split into n
    blocks of rows/n² — the `lax.all_to_all(tiled=True)` layout).
    """
    n = dict(mesh.shape)[axis]
    if rows % (n * n):
        raise QueueError(f"rows ({rows}) must divide by axis size² {n * n}")
    q = CollectiveQueue(mesh, name)
    q.buffer("x", (rows, cols), dtype, pspec=(axis,))
    q.buffer("out", (rows, cols), dtype, pspec=(axis,))
    q.enqueue_all_to_all("x", "out", axis)
    prog = q.build(verify=verify)

    from . import overlap
    from jax.sharding import PartitionSpec as P
    reference = _smap_ref(
        mesh, lambda x: overlap.all_to_all_ppermute(x, axis),
        (P(axis),), P(axis))
    stock = _smap_ref(
        mesh,
        lambda x: jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                                     tiled=True),
        (P(axis),), P(axis))
    return CollectiveMatmul(prog, ("x",), "out", reference, stock)


def build_tp_block(mesh, axis: str, m: int, k: int, f: int,
                   dtype=np.float32, *, bidirectional: bool = False,
                   chain: bool = False, verify: str = "warn",
                   name: str = "st_tp_block") -> CollectiveMatmul:
    """The headline "transformer block as ST schedule": a Megatron MLP
    with sequence parallelism, entirely as one ST program.

    ``x`` row-sharded [m, k] → all-gather-matmul with column-sharded
    ``w1`` [k, f] → relu → matmul-reduce-scatter with row-sharded
    ``w2`` [f, k] → ``out`` row-sharded [m, k].  The relu rides inside
    the all-gather's per-chunk compute hook (bit-exact nonlinearity),
    and every ring step of both collectives is a trigger→wait channel —
    so the whole block coalesces, prices, tunes, composes, and runs
    persistent like any other ST program.

    Reference: the stock shard_map lowering
    ``psum_scatter(relu(all_gather(x) @ w1) @ w2)``.

    ``chain=True`` appends a feedback kernel (``x = out`` — both are
    row-sharded [m, k]) so ``program.persistent(N)`` computes the
    N-deep chain ``x_{i+1} = block(x_i)`` in ONE dispatch — the
    "transformer stack as ST schedule" the overlap bench gates.
    """
    n = dict(mesh.shape)[axis]
    if m % n or f % n:
        raise QueueError(f"m ({m}) and f ({f}) must divide by axis size {n}")
    q = CollectiveQueue(mesh, name)
    q.buffer("x", (m, k), dtype, pspec=(axis,))
    q.buffer("w1", (k, f), dtype, pspec=(None, axis))
    q.buffer("w2", (f, k), dtype, pspec=(axis,))
    # h: full m rows of this rank's f/n hidden columns
    q.buffer("h", (n * m, f // n), dtype, pspec=(axis,))
    # y: per-rank full partials of the down-projection (rows = m each)
    q.buffer("y", (n * m, k), dtype, pspec=(axis,))
    q.buffer("out", (m, k), dtype, pspec=(axis,))
    q.enqueue_all_gather(
        "x", "h", axis,
        compute=lambda chunk, w1: jnp.maximum(chunk @ w1, 0.0),
        reads=("w1",), bidirectional=bidirectional)
    q.enqueue_compute(lambda h, w2: h @ w2, reads=("h", "w2"), writes=("y",),
                      name="down_proj")
    q.enqueue_reduce_scatter("y", "out", axis, tag_base=100)
    if chain:
        # persistent iterations re-run the whole descriptor walk, but
        # x has been rotated n-1 hops in place by the gather ring —
        # feeding out back in both restores a defined x AND makes the
        # persistent program the N-layer chain x_{i+1} = block(x_i)
        q.enqueue_compute(lambda o: o, reads=("out",), writes=("x",),
                          name="feedback")
    prog = q.build(verify=verify)

    from . import overlap

    def ref_decomposed(x, w1, w2):
        # bitwise oracle: relu commutes with the chunk deposits, and
        # the ring reduce-scatter repeats the ST accumulate order
        h = jnp.maximum(overlap.all_gather_matmul(x, w1, axis), 0.0)
        return overlap.reduce_scatter_ring(h @ w2, axis)

    def ref_stock(x, w1, w2):
        h = jnp.maximum(
            jax.lax.all_gather(x, axis, axis=0, tiled=True) @ w1, 0.0)
        return jax.lax.psum_scatter(h @ w2, axis, scatter_dimension=0,
                                    tiled=True)

    from jax.sharding import PartitionSpec as P
    specs = ((P(axis), P(None, axis), P(axis)), P(axis))
    reference = _smap_ref(mesh, ref_decomposed, *specs)
    stock = _smap_ref(mesh, ref_stock, *specs)
    return CollectiveMatmul(prog, ("x", "w1", "w2"), "out", reference, stock)
