"""PersistentEngine — device-resident N-iteration execution.

Fast lane: single-device (1,1,1 periodic grid — every neighbor is the
rank itself, so real channels fire) correctness vs N sequential
HostEngine executions and an N-step oracle loop, dispatch accounting,
the queue-reuse guards, and the static slot analysis.

Slow lane: the same contrasts on a real 2×2×2 8-device grid across
granularity × batched (subprocess, like tests/test_distributed.py).
"""

import numpy as np
import pytest

from repro.core import (
    FacesConfig,
    HostEngine,
    PersistentEngine,
    QueueError,
    build_faces_program,
    faces_oracle,
)
from repro.core.engine_persistent import slot_buffers
from repro.core.halo import AXES3, run_faces_persistent


def _mesh111():
    from repro.parallel import make_mesh
    return make_mesh((1, 1, 1), AXES3)


def _u0(cfg, seed=0):
    rng = np.random.RandomState(seed)
    return rng.randn(*cfg.grid, *cfg.points).astype(np.float32)


def _host_n_iters(prog, u0, n):
    host = HostEngine(prog)
    mem = host.init_buffers({"u": u0})
    for _ in range(n):
        mem = host(mem)
    return mem, host.stats


def _oracle_n_iters(u0, cfg, n):
    ref = np.asarray(u0)
    for _ in range(n):
        ref = faces_oracle(ref, cfg)
    return ref


# -- correctness (fast, single device) ---------------------------------------


@pytest.mark.parametrize("mode", ["stream", "dataflow"])
@pytest.mark.parametrize("batched", [True, False])
def test_persistent_matches_host_and_oracle_1dev(mode, batched):
    n = 4
    cfg = FacesConfig(grid=(1, 1, 1), points=(4, 3, 5), periodic=True,
                      batched=batched)
    prog = build_faces_program(cfg, _mesh111()).persistent(n)
    u0 = _u0(cfg)

    eng = PersistentEngine(prog, mode=mode)
    out = eng(eng.init_buffers({"u": u0}))

    host_mem, _ = _host_n_iters(prog, u0, n)
    np.testing.assert_allclose(np.asarray(out["u"]),
                               np.asarray(host_mem["u"]),
                               rtol=1e-5, atol=1e-5)
    ref = _oracle_n_iters(u0, cfg, n)
    np.testing.assert_allclose(np.asarray(out["u"]), ref,
                               rtol=1e-4, atol=1e-4)


def test_persistent_single_iteration_equals_host():
    cfg = FacesConfig(grid=(1, 1, 1), points=(3, 3, 3), periodic=True)
    prog = build_faces_program(cfg, _mesh111())
    u0 = _u0(cfg)
    eng = PersistentEngine(prog, n_iters=1)
    out = eng(eng.init_buffers({"u": u0}))
    host_mem, _ = _host_n_iters(prog, u0, 1)
    np.testing.assert_allclose(np.asarray(out["u"]),
                               np.asarray(host_mem["u"]),
                               rtol=1e-5, atol=1e-5)


def test_persistent_double_buffer_equivalent():
    """Double-buffered slots must not change results (dataflow mode)."""
    n = 5
    cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True)
    prog = build_faces_program(cfg, _mesh111()).persistent(n)
    u0 = _u0(cfg, seed=3)
    a = PersistentEngine(prog, mode="dataflow", double_buffer=True)
    b = PersistentEngine(prog, mode="dataflow", double_buffer=False)
    out_a = a(a.init_buffers({"u": u0}))
    out_b = b(b.init_buffers({"u": u0}))
    for k in out_a:
        np.testing.assert_allclose(np.asarray(out_a[k]),
                                   np.asarray(out_b[k]),
                                   rtol=1e-5, atol=1e-5, err_msg=k)


# -- dispatch accounting ------------------------------------------------------


def test_one_dispatch_for_n_iterations():
    n = 6
    cfg = FacesConfig(grid=(1, 1, 1), points=(3, 3, 3), periodic=True)
    prog = build_faces_program(cfg, _mesh111()).persistent(n)
    eng = PersistentEngine(prog)
    assert eng.stats.dispatches == 0
    eng(eng.init_buffers({"u": _u0(cfg)}))
    assert eng.stats.dispatches == 1          # ONE dispatch, N iterations
    assert eng.stats.sync_points == 0         # no host sync inside the loop
    assert prog.dispatch_count_persistent() == 1

    # the same N iterations cost the host engine N * per-iter dispatches
    _, host_stats = _host_n_iters(prog, _u0(cfg), n)
    assert host_stats.dispatches == n * prog.dispatch_count_host()
    assert host_stats.dispatches > eng.stats.dispatches


# -- per-iteration reduction (no host sync) -----------------------------------


def test_per_iteration_reduction_trace():
    import jax
    import jax.numpy as jnp

    n = 4
    cfg = FacesConfig(grid=(1, 1, 1), points=(3, 4, 2), periodic=True)
    prog = build_faces_program(cfg, _mesh111()).persistent(n)
    u0 = _u0(cfg, seed=7)

    def sq_norm(mem):
        return jax.lax.psum(jnp.sum(mem["u"].astype(jnp.float32) ** 2), AXES3)

    eng = PersistentEngine(prog, reduce_fn=sq_norm)
    out, red = eng(eng.init_buffers({"u": u0}))
    assert red.shape == (n,)

    # reference: host engine, norm recorded after every iteration
    host = HostEngine(prog)
    mem = host.init_buffers({"u": u0})
    want = []
    for _ in range(n):
        mem = host(mem)
        want.append(float(np.sum(np.asarray(mem["u"], np.float64) ** 2)))
    np.testing.assert_allclose(np.asarray(red, np.float64), want, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(out["u"]), np.asarray(mem["u"]),
                               rtol=1e-5, atol=1e-5)


def test_reduction_may_read_slot_buffers():
    """reduce_fn sees the full buffer dict, message slots included, even
    when those slots are double-buffered (dataflow mode default)."""
    import jax
    import jax.numpy as jnp

    n = 3
    cfg = FacesConfig(grid=(1, 1, 1), points=(3, 3, 3), periodic=True)
    prog = build_faces_program(cfg, _mesh111()).persistent(n)
    u0 = _u0(cfg, seed=5)

    def recv_norm(mem):
        return jax.lax.psum(jnp.sum(mem["in0"] ** 2), AXES3)

    vals = {}
    for db in (True, False):
        eng = PersistentEngine(prog, mode="dataflow", double_buffer=db,
                               reduce_fn=recv_norm)
        assert (len(eng._slots) > 0) == db
        _, red = eng(eng.init_buffers({"u": u0}))
        vals[db] = np.asarray(red)
    np.testing.assert_allclose(vals[True], vals[False], rtol=1e-5)


# -- predicate-terminated loop (while_loop engine) ----------------------------


@pytest.mark.parametrize("granularity", ["direct26", "staged3"])
@pytest.mark.parametrize("double_buffer", [True, False])
def test_while_loop_matches_fixed_engine(granularity, double_buffer):
    """An always-true cond_fn for N iterations must reproduce the fixed
    fori_loop engine exactly (carried-parity slots included)."""
    import jax.numpy as jnp

    from repro.core import global_residual_fn

    n = 5
    cfg = FacesConfig(grid=(1, 1, 1), points=(4, 3, 5), periodic=True,
                      granularity=granularity)
    prog = build_faces_program(cfg, _mesh111()).persistent(n)
    u0 = _u0(cfg, seed=2)

    fixed = PersistentEngine(prog, mode="dataflow",
                             double_buffer=double_buffer,
                             reduce_fn=global_residual_fn(cfg))
    out_f, red_f = fixed(fixed.init_buffers({"u": u0}))

    looped = PersistentEngine(prog, mode="dataflow",
                              double_buffer=double_buffer,
                              reduce_fn=global_residual_fn(cfg),
                              cond_fn=lambda r: jnp.asarray(True))
    out_w, red_w, n_done = looped(looped.init_buffers({"u": u0}))

    assert int(n_done) == n
    assert looped.stats.dispatches == 1 and looped.stats.sync_points == 0
    np.testing.assert_allclose(np.asarray(red_w), np.asarray(red_f),
                               rtol=1e-6)
    for k in out_f:
        np.testing.assert_allclose(np.asarray(out_w[k]),
                                   np.asarray(out_f[k]),
                                   rtol=1e-5, atol=1e-6, err_msg=k)


def test_cond_fn_requires_reduce_fn():
    cfg = FacesConfig(grid=(1, 1, 1), points=(3, 3, 3), periodic=True)
    prog = build_faces_program(cfg, _mesh111()).persistent(3)
    with pytest.raises(ValueError, match="reduce_fn"):
        PersistentEngine(prog, cond_fn=lambda r: r >= 0.1)
    with pytest.raises(ValueError, match="max_iters"):
        PersistentEngine(prog, max_iters=5)


def test_until_metadata_roundtrip():
    """STProgram.persistent(n, until=...) carries the predicate to the
    engine; the bound becomes max_iters."""
    from repro.core import global_residual_fn

    cfg = FacesConfig(grid=(1, 1, 1), points=(3, 3, 3), periodic=True)
    base = build_faces_program(cfg, _mesh111())
    assert base.until is None and not base.is_persistent

    pred = lambda r: r >= 1e-3  # noqa: E731
    prog = base.persistent(7, until=pred)
    assert prog.until is pred and prog.n_iters == 7
    assert prog.is_persistent  # predicate loops count as persistent

    eng = PersistentEngine(prog, reduce_fn=global_residual_fn(cfg))
    assert eng.cond_fn is pred and eng.max_iters == 7
    out = eng(eng.init_buffers({"u": _u0(cfg)}))
    assert len(out) == 3  # (mem, reductions, n_done)
    assert out[1].shape == (7,)
    assert 1 <= int(out[2]) <= 7


def test_until_triggers_quiescence_guard_even_at_bound_1():
    """A predicate loop may always re-execute, so a non-quiescent queue
    is rejected even when the safety bound is 1."""
    from repro.core import OffsetPeer, STQueue
    from repro.parallel import make_mesh

    q = STQueue(make_mesh((1,), ("x",)), name="nq")
    q.buffer("a", (4,), np.float32, pspec=("x",))
    q.buffer("b", (4,), np.float32, pspec=("x",))
    q.enqueue_send("a", OffsetPeer("x", 1, periodic=True), tag=0)
    q.enqueue_recv("b", OffsetPeer("x", -1, periodic=True), tag=0)
    q.enqueue_start()          # no enqueue_wait: non-quiescent
    prog = q.build()
    with pytest.raises(QueueError, match="quiescent"):
        prog.persistent(1, until=lambda r: r >= 0.0)
    assert prog.persistent(1).n_iters == 1  # fixed single pass still fine


# -- queue-reuse guards & metadata -------------------------------------------


def test_persistent_metadata_roundtrip():
    cfg = FacesConfig(grid=(1, 1, 1), points=(3, 3, 3))
    prog = build_faces_program(cfg, _mesh111())
    assert prog.n_iters == 1 and not prog.is_persistent
    p = prog.persistent(8)
    assert p.n_iters == 8 and p.is_persistent
    assert prog.n_iters == 1  # original untouched (immutable metadata)
    # engine picks the program's count up when not overridden
    assert PersistentEngine(p).n_iters == 8
    assert PersistentEngine(p, n_iters=3).n_iters == 3


def test_persistent_rejects_bad_iteration_count():
    cfg = FacesConfig(grid=(1, 1, 1), points=(3, 3, 3))
    prog = build_faces_program(cfg, _mesh111())
    with pytest.raises(QueueError):
        prog.persistent(0)
    with pytest.raises(ValueError):
        PersistentEngine(prog, n_iters=0)


def test_persistent_rejects_non_quiescent_queue():
    """A started-but-never-waited batch cannot be re-executed on-device:
    the counters would disagree across iterations."""
    from repro.core import OffsetPeer, STQueue
    from repro.parallel import make_mesh

    q = STQueue(make_mesh((1,), ("x",)), name="nq")
    q.buffer("a", (4,), np.float32, pspec=("x",))
    q.buffer("b", (4,), np.float32, pspec=("x",))
    q.enqueue_send("a", OffsetPeer("x", 1, periodic=True), tag=0)
    q.enqueue_recv("b", OffsetPeer("x", -1, periodic=True), tag=0)
    q.enqueue_start()          # no enqueue_wait: non-quiescent
    prog = q.build()
    with pytest.raises(QueueError, match="quiescent"):
        prog.persistent(4)
    # the engine-level n_iters override goes through the same guard
    with pytest.raises(QueueError, match="quiescent"):
        PersistentEngine(prog, n_iters=4)
    assert prog.persistent(1).n_iters == 1  # single pass is always fine
    assert PersistentEngine(prog, n_iters=1).n_iters == 1


# -- static slot analysis -----------------------------------------------------


def test_slot_analysis_picks_message_buffers_only():
    cfg = FacesConfig(grid=(1, 1, 1), points=(3, 3, 3))
    prog = build_faces_program(cfg, _mesh111())
    slots = slot_buffers(prog)
    assert "u" not in slots                   # the field carries state
    # every message staging buffer qualifies (packed before sent;
    # replace-deposited before unpacked)
    msg_bufs = {b for b in prog.buffers if b.startswith(("in", "out"))}
    assert set(slots) == msg_bufs


def test_slot_analysis_excludes_add_mode_and_carried_state():
    from repro.core import OffsetPeer, STQueue
    from repro.parallel import make_mesh

    q = STQueue(make_mesh((1,), ("x",)), name="addq")
    q.buffer("state", (4,), np.float32, pspec=("x",))
    q.buffer("src", (4,), np.float32, pspec=("x",))
    q.buffer("acc", (4,), np.float32, pspec=("x",))
    # pack-style: src is produced fresh from state every pass
    q.enqueue_kernel(lambda s: s * 2.0, ["state"], ["src"], name="pack")
    q.enqueue_recv("acc", OffsetPeer("x", -1, periodic=True), tag=0, mode="add")
    q.enqueue_send("src", OffsetPeer("x", 1, periodic=True), tag=0)
    q.enqueue_start()
    q.enqueue_wait()
    slots = slot_buffers(q.build())
    assert "acc" not in slots    # add-mode: accumulates across iterations
    assert "state" not in slots  # read first: carries state
    assert "src" in slots        # rewritten by the pack before the send


# -- halo front-end -----------------------------------------------------------


def test_run_faces_persistent_front_end():
    cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True)
    u0 = _u0(cfg, seed=11)
    mem, stats = run_faces_persistent(cfg, _mesh111(), u0, n_iters=3)
    assert stats.dispatches == 1
    ref = _oracle_n_iters(u0, cfg, 3)
    np.testing.assert_allclose(np.asarray(mem["u"]), ref, rtol=1e-4, atol=1e-4)


# -- multi-device matrix (subprocess, slow lane) ------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("granularity", ["direct26", "staged3"])
@pytest.mark.parametrize("batched", [True, False])
def test_persistent_matches_host_8dev(subproc, granularity, batched):
    r = subproc(f"""
import numpy as np
from repro.core import (FacesConfig, HostEngine, PersistentEngine,
                        build_faces_program, faces_oracle)
from repro.parallel import make_mesh

N = 3
mesh = make_mesh((2, 2, 2), ("gx", "gy", "gz"))
cfg = FacesConfig(grid=(2, 2, 2), points=(4, 4, 4),
                  granularity={granularity!r}, batched={batched})
prog = build_faces_program(cfg, mesh).persistent(N)
u0 = np.random.RandomState(0).randn(2, 2, 2, 4, 4, 4).astype(np.float32)

host = HostEngine(prog)
hmem = host.init_buffers({{"u": u0}})
for _ in range(N):
    hmem = host(hmem)

for mode in ("stream", "dataflow"):
    eng = PersistentEngine(prog, mode=mode)
    out = eng(eng.init_buffers({{"u": u0}}))
    np.testing.assert_allclose(np.asarray(out["u"]), np.asarray(hmem["u"]),
                               rtol=1e-4, atol=1e-4)
    assert eng.stats.dispatches == 1

if cfg.granularity == "direct26":
    ref = u0
    for _ in range(N):
        ref = faces_oracle(ref, cfg)
    np.testing.assert_allclose(np.asarray(hmem["u"]), ref,
                               rtol=1e-4, atol=1e-4)
assert host.stats.dispatches == N * prog.dispatch_count_host()
print("persistent 8dev OK")
""")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "persistent 8dev OK" in r.stdout
