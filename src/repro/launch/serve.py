"""Serving driver: batched prefill + decode loop.

Greedy decoding over a batch of synthetic prompts; drives exactly the
``prefill_step``/``serve_step`` the dry-run lowers for the big meshes.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \\
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig, get_config
from repro.launch.steps import build_prefill_step, build_serve_step
from repro.models import Model


def serve(cfg: ModelConfig, mesh, *, batch: int, prompt_len: int,
          gen_len: int, seed: int = 0, serve_window: int = 0):
    model = Model(cfg)
    max_len = prompt_len + gen_len + model._prefix_len()

    pre_shape = ShapeConfig("serve_prefill", prompt_len, batch, "prefill")
    dec_shape = ShapeConfig("serve_decode", max_len, batch, "decode")

    with mesh:
        pre = build_prefill_step(cfg, pre_shape, mesh, serve_window=serve_window)
        dec = build_serve_step(cfg, dec_shape, mesh, serve_window=serve_window)
        # serving shares one cache set sized to max_len: rebuild prefill's
        # cache shardings against dec's (max_len) caches
        params, _ = model.init(jax.random.PRNGKey(seed))
        params = jax.device_put(params, pre.in_shardings[0])

        rng = np.random.RandomState(seed)
        prompts = rng.randint(0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
        batch_in = {"tokens": jnp.asarray(prompts)}
        if cfg.enc_dec:
            batch_in["audio_embeds"] = jnp.asarray(
                rng.randn(batch, cfg.frontend_tokens, cfg.frontend_dim),
                jnp.float32)
        if cfg.frontend == "vision":
            batch_in["vision_embeds"] = jnp.asarray(
                rng.randn(batch, cfg.frontend_tokens, cfg.frontend_dim),
                jnp.float32)

        caches = model.init_caches(batch, max_len)
        caches = jax.device_put(caches, dec.in_shardings[1])

        t0 = time.time()
        logits, caches = model.prefill(params, batch_in, caches,
                                       serve_window=serve_window)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens = [np.asarray(tok)]

        jitted_dec = jax.jit(dec.step_fn, in_shardings=dec.in_shardings,
                             out_shardings=dec.out_shardings,
                             donate_argnums=(1,))
        t_prefill = time.time() - t0
        t0 = time.time()
        for _ in range(gen_len - 1):
            logits, caches = jitted_dec(params, caches, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out_tokens.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    return gen, {"prefill_s": t_prefill, "decode_s": t_decode,
                 "tok_per_s": batch * (gen_len - 1) / max(t_decode, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    dm, tm = (int(x) for x in args.mesh.split("x"))
    from repro.parallel import make_mesh
    mesh = make_mesh((dm, tm), ("data", "model"))

    gen, stats = serve(cfg, mesh, batch=args.batch,
                       prompt_len=args.prompt_len, gen_len=args.gen)
    print("generated tokens (first row):", gen[0][:16])
    print({k: round(v, 4) for k, v in stats.items()})


if __name__ == "__main__":
    main()
