"""Public jit'd wrappers for the Pallas kernels.

Every op auto-selects ``interpret=True`` on non-TPU backends (this
container is CPU-only; the kernels are *written for* TPU and *validated*
in interpret mode against :mod:`.ref`).  Set ``REPRO_PALLAS_INTERPRET=0``
to force compiled mode (on TPU), ``=1`` to force interpretation.
"""

from __future__ import annotations

import functools
import os
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_call
from .halo_pack import (
    halo_pack_call,
    halo_unpack_add_call,
    pack_boundary_call,
    unpack_boundary_add_call,
)
from .rmsnorm import rmsnorm_call
from .ssd_scan import ssd_scan_call


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _hashable_region(region):
    return tuple((s.start or 0, s.stop) for s in region)


def _region_from_hashable(hr):
    return tuple(slice(a, b) for a, b in hr)


# -- halo pack family ---------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(1, 2))
def _halo_pack(u, hregion, interpret):
    return halo_pack_call(u, _region_from_hashable(hregion), interpret=interpret)


def halo_pack(u: jax.Array, region: Tuple[slice, ...], *,
              interpret: Optional[bool] = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    return _halo_pack(u, _hashable_region(region), interpret)


@functools.partial(jax.jit, static_argnums=(2, 3))
def _halo_unpack_add(u, msg, hregion, interpret):
    return halo_unpack_add_call(u, msg, _region_from_hashable(hregion),
                                interpret=interpret)


def halo_unpack_add(u: jax.Array, msg: jax.Array, region: Tuple[slice, ...], *,
                    interpret: Optional[bool] = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    return _halo_unpack_add(u, msg, _hashable_region(region), interpret)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _pack_boundary(u, hregions, interpret):
    return pack_boundary_call(u, tuple(map(_region_from_hashable, hregions)),
                              interpret=interpret)


def pack_boundary(u: jax.Array, regions: Sequence[Tuple[slice, ...]], *,
                  interpret: Optional[bool] = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    return _pack_boundary(u, tuple(map(_hashable_region, regions)), interpret)


@functools.partial(jax.jit, static_argnums=(2, 3))
def _unpack_boundary_add(u, buf, hregions, interpret):
    return unpack_boundary_add_call(
        u, buf, tuple(map(_region_from_hashable, hregions)), interpret=interpret)


def unpack_boundary_add(u: jax.Array, buf: jax.Array,
                        regions: Sequence[Tuple[slice, ...]], *,
                        interpret: Optional[bool] = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    return _unpack_boundary_add(u, buf, tuple(map(_hashable_region, regions)),
                                interpret)


# -- rmsnorm -------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(2, 3, 4, 5))
def _rmsnorm2d(x, w, eps, weight_offset, block_rows, interpret):
    return rmsnorm_call(x, w, eps=eps, weight_offset=weight_offset,
                        block_rows=block_rows, interpret=interpret)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            weight_offset: float = 0.0, block_rows: int = 128,
            interpret: Optional[bool] = None) -> jax.Array:
    """Fused RMSNorm over the last dim; any leading dims."""
    interpret = _interpret_default() if interpret is None else interpret
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _rmsnorm2d(x2, w, eps, weight_offset, block_rows, interpret)
    return y.reshape(*lead, x.shape[-1])


# -- flash attention -----------------------------------------------------------


@functools.partial(jax.jit, static_argnums=tuple(range(3, 11)))
def _flash(q, k, v, causal, scale, window, logit_softcap, q_offset,
           block_q, block_k, interpret):
    return flash_attention_call(
        q, k, v, causal=causal, scale=scale, window=window,
        logit_softcap=logit_softcap, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True,
                    scale: Optional[float] = None,
                    window: Optional[int] = None,
                    logit_softcap: Optional[float] = None,
                    q_offset: int = 0, block_q: int = 128,
                    block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    return _flash(q, k, v, causal, scale, window, logit_softcap, q_offset,
                  block_q, block_k, interpret)


# -- SSD scan -------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(6, 7, 8))
def _ssd(x, dt, A, Bm, C, init_state, chunk, return_state, interpret):
    return ssd_scan_call(x, dt, A, Bm, C, init_state=init_state, chunk=chunk,
                         return_state=return_state, interpret=interpret)


def ssd_scan(x, dt, A, Bm, C, *, init_state=None, chunk: int = 128,
             return_state: bool = False, interpret: Optional[bool] = None):
    interpret = _interpret_default() if interpret is None else interpret
    if init_state is None:
        B, _, H, P = x.shape
        N = Bm.shape[-1]
        init_state = jnp.zeros((B, H, P, N), jnp.float32)
    return _ssd(x, dt, A, Bm, C, init_state, chunk, return_state, interpret)


__all__ = [
    "halo_pack", "halo_unpack_add", "pack_boundary", "unpack_boundary_add",
    "rmsnorm", "flash_attention", "ssd_scan", "ref",
]
