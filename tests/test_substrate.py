"""Substrate tests: optimizer, schedules, data pipeline, checkpointing."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import latest_step, restore_pytree, save_pytree
from repro.configs.base import ShapeConfig, get_config
from repro.data.synthetic import SyntheticConfig, SyntheticTokens
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    global_norm,
    linear_warmup_cosine,
)


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
        state = adamw_init(params, cfg)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(params, grads, state, cfg)
        assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2

    def test_clip_bounds_update(self):
        cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0)
        params = {"w": jnp.zeros(3)}
        state = adamw_init(params, cfg)
        grads = {"w": jnp.asarray([1e6, -1e6, 1e6])}
        _, _, metrics = adamw_update(params, grads, state, cfg)
        assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip

    def test_moment_dtype_bf16(self):
        cfg = AdamWConfig(moment_dtype="bfloat16")
        params = {"w": jnp.ones((4, 4))}
        state = adamw_init(params, cfg)
        assert state["m"]["w"].dtype == jnp.bfloat16
        new_p, new_s, _ = adamw_update(params, {"w": jnp.ones((4, 4))},
                                       state, cfg)
        assert new_s["v"]["w"].dtype == jnp.bfloat16
        assert new_p["w"].dtype == params["w"].dtype

    def test_no_decay_on_1d_params(self):
        cfg = AdamWConfig(lr=0.0, weight_decay=1.0)  # lr=0 → no change at all
        params = {"scale": jnp.ones(8), "w": jnp.ones((4, 4))}
        state = adamw_init(params, cfg)
        new_p, _, _ = adamw_update(params, jax.tree.map(jnp.zeros_like, params),
                                   state, cfg)
        np.testing.assert_allclose(np.asarray(new_p["scale"]), 1.0)

    def test_global_norm(self):
        t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
        assert abs(float(global_norm(t)) - 5.0) < 1e-6


class TestSchedule:
    def test_warmup_then_decay(self):
        lrs = [float(linear_warmup_cosine(jnp.asarray(s), base_lr=1.0,
                                          warmup_steps=10, total_steps=100))
               for s in range(100)]
        assert lrs[0] < lrs[9] <= 1.0 + 1e-6
        assert lrs[50] < lrs[10]
        assert lrs[99] >= 0.1 - 1e-6  # final_frac floor


class TestSyntheticData:
    def test_deterministic_per_step(self):
        cfg = get_config("qwen1.5-0.5b").smoke()
        shape = ShapeConfig("t", 32, 4, "train")
        src = SyntheticTokens(cfg, shape, SyntheticConfig(seed=3))
        a = src.batch(7)
        b = src.batch(7)
        c = src.batch(8)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_targets_are_shifted_stream(self):
        cfg = get_config("qwen1.5-0.5b").smoke()
        shape = ShapeConfig("t", 16, 2, "train")
        src = SyntheticTokens(cfg, shape)
        b = src.batch(0)
        assert b["tokens"].shape == (2, 16)
        assert b["targets"].shape == (2, 16)
        assert b["tokens"].dtype == np.int32
        assert (b["tokens"] >= 0).all() and (b["tokens"] < cfg.vocab).all()

    def test_modality_entries(self):
        cfg = get_config("whisper-large-v3").smoke()
        shape = ShapeConfig("t", 8, 2, "train")
        b = SyntheticTokens(cfg, shape).batch(0)
        assert b["audio_embeds"].shape == (2, cfg.frontend_tokens,
                                           cfg.frontend_dim)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "nested": {"b": jnp.ones(4, jnp.bfloat16)},
                "lst": [jnp.zeros(2), jnp.ones(2)]}
        d = str(tmp_path)
        save_pytree(d, 5, tree)
        assert latest_step(d) == 5
        got = restore_pytree(d, 5, jax.tree.map(jnp.zeros_like, tree))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_latest_of_many(self, tmp_path):
        d = str(tmp_path)
        for s in (1, 10, 3):
            save_pytree(d, s, {"x": jnp.zeros(1)})
        assert latest_step(d) == 10

    def test_latest_empty(self, tmp_path):
        assert latest_step(str(tmp_path / "nope")) is None
