"""``python -m repro.analysis`` — lint every benchmark-built ST program.

Prints one diagnostics table per program (rule id, severity, pid,
descriptor index, message, enqueue site) and a final summary line.
Exit status 0 only if every program lints clean — the CI lint job runs
exactly this.
"""

import os

# benchmark grids assume 8 host devices (same default as benchmarks/run.py);
# must be set before jax initialises
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="STLint every ST program the benchmarks build")
    ap.add_argument("filter", nargs="?", default="",
                    help="only lint programs whose name contains this")
    args = ap.parse_args(argv)

    from repro.core.verify import format_diagnostics

    from .programs import lint_all

    results = [(name, diags) for name, diags in lint_all()
               if args.filter in name]
    if not results:
        print(f"no programs match {args.filter!r}", file=sys.stderr)
        return 2

    total = 0
    for name, diags in results:
        total += len(diags)
        print(f"== {name}")
        print(format_diagnostics(diags))
    dirty = [name for name, diags in results if diags]
    if dirty:
        print(f"\nSTLint: {total} diagnostic(s) across "
              f"{len(dirty)}/{len(results)} program(s): {', '.join(dirty)}",
              file=sys.stderr)
        return 1
    print(f"\nSTLint: {len(results)} program(s) clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
