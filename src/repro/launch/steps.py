"""Step builders: train / prefill / serve with resolved shardings.

Bridges the model zoo and the launcher: for a (ModelConfig, ShapeConfig,
Mesh) triple this module resolves every pytree (params, optimizer state,
batch, caches) to ``NamedSharding`` via the logical rules, and returns
jit-ready step callables plus ShapeDtypeStruct input stand-ins for the
dry-run (``.lower(...).compile()`` with zero allocation).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.synthetic import make_batch_specs
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update, linear_warmup_cosine
from repro.parallel import (
    RULES_DECODE,
    RULES_LONG_DECODE,
    RULES_TRAIN,
    LogicalRules,
    logical_spec,
    logical_spec_sized,
    sharding_ctx,
)


def rules_for(shape: ShapeConfig) -> LogicalRules:
    if shape.kind == "train" or shape.kind == "prefill":
        return RULES_TRAIN if shape.kind == "train" else RULES_DECODE
    return RULES_LONG_DECODE if shape.global_batch == 1 else RULES_DECODE


def _tree_shardings(sds_tree, axes_tree, rules: LogicalRules, mesh: Mesh):
    """Shape-aware sharding resolution (indivisible dims fall back)."""
    return jax.tree.map(
        lambda sd, axes: NamedSharding(
            mesh, logical_spec_sized(sd.shape, axes, rules, mesh)),
        sds_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and not any(
            hasattr(e, "shape") for e in x),
    )


def _sds_like(shape_dtype_tree, shardings_tree):
    return jax.tree.map(
        lambda sd, sh: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=sh),
        shape_dtype_tree, shardings_tree)


@dataclasses.dataclass
class StepBundle:
    """Everything the launcher / dry-run needs for one (arch × shape)."""

    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    rules: LogicalRules
    model: Model
    step_fn: Callable          # jit-able python callable
    in_shardings: Any
    out_shardings: Any
    input_sds: Tuple           # ShapeDtypeStructs for .lower(*input_sds)

    def lower(self):
        jitted = jax.jit(self.step_fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings)
        with self.mesh:
            return jitted.lower(*self.input_sds)


# --------------------------------------------------------------------------
# train
# --------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     opt: Optional[AdamWConfig] = None,
                     total_steps: int = 10_000) -> StepBundle:
    assert shape.kind == "train"
    rules = RULES_TRAIN
    model = Model(cfg)
    opt = opt or AdamWConfig()

    params_sd, axes = model.abstract_init()
    param_shardings = _tree_shardings(params_sd, axes, rules, mesh)
    opt_sd = jax.eval_shape(lambda p: adamw_init(p, opt), params_sd)
    opt_shardings = {
        "m": param_shardings, "v": param_shardings,
        "step": NamedSharding(mesh, P()),
    }
    batch_axes = make_batch_specs(cfg, shape)
    raw_sds = model.input_specs(shape)
    batch_shardings = {
        k: NamedSharding(mesh, logical_spec_sized(
            raw_sds[k].shape, batch_axes[k], rules, mesh))
        for k in raw_sds
    }
    batch_sds = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=batch_shardings[k])
        for k, v in raw_sds.items()
    }

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            with sharding_ctx(rules, mesh):
                return model.loss(p, batch)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        lr = linear_warmup_cosine(opt_state["step"], base_lr=opt.lr,
                                  warmup_steps=max(total_steps // 50, 10),
                                  total_steps=total_steps)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, opt_state, opt, lr=lr)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    metrics_sh = None  # let jit infer (scalars)
    in_sh = (param_shardings, opt_shardings, batch_shardings)
    out_sh = (param_shardings, opt_shardings, metrics_sh)

    input_sds = (
        _sds_like(params_sd, param_shardings),
        _sds_like(opt_sd, opt_shardings),
        batch_sds,
    )
    return StepBundle(cfg, shape, mesh, rules, model, train_step,
                      in_sh, out_sh, input_sds)


# --------------------------------------------------------------------------
# prefill / decode
# --------------------------------------------------------------------------


def _cache_shardings(caches_sd, model: Model, rules: LogicalRules, mesh: Mesh):
    axes = model.cache_axes()
    return jax.tree.map(
        lambda sd, a: NamedSharding(
            mesh, logical_spec_sized(sd.shape, a, rules, mesh)),
        caches_sd, axes,
        is_leaf=lambda x: isinstance(x, tuple) and not any(
            hasattr(e, "shape") for e in x))


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       serve_window: int = 0) -> StepBundle:
    assert shape.kind == "prefill"
    rules = RULES_DECODE
    model = Model(cfg)

    params_sd, axes = model.abstract_init()
    param_shardings = _tree_shardings(params_sd, axes, rules, mesh)

    B, S = shape.global_batch, shape.seq_len
    max_len = S + model._prefix_len()
    caches_sd = jax.eval_shape(lambda: model.init_caches(B, max_len))
    cache_shardings = _cache_shardings(caches_sd, model, rules, mesh)

    batch_axes = make_batch_specs(cfg, shape)
    raw_sds = model.input_specs(shape)
    batch_shardings = {
        k: NamedSharding(mesh, logical_spec_sized(
            raw_sds[k].shape, batch_axes[k], rules, mesh))
        for k in raw_sds
    }
    batch_sds = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=batch_shardings[k])
        for k, v in raw_sds.items()
    }

    def prefill_step(params, batch, caches):
        with sharding_ctx(rules, mesh):
            return model.prefill(params, batch, caches,
                                 serve_window=serve_window)

    in_sh = (param_shardings, batch_shardings, cache_shardings)
    out_sh = (NamedSharding(mesh, logical_spec_sized(
                  (B, cfg.vocab), ("batch", "act_vocab"), rules, mesh)),
              _prefill_out_cache_shardings(cache_shardings))
    input_sds = (
        _sds_like(params_sd, param_shardings),
        batch_sds,
        _sds_like(caches_sd, cache_shardings),
    )
    return StepBundle(cfg, shape, mesh, rules, model, prefill_step,
                      in_sh, out_sh, input_sds)


def _prefill_out_cache_shardings(cache_shardings):
    return cache_shardings


def build_serve_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     serve_window: int = 0) -> StepBundle:
    assert shape.kind == "decode"
    rules = rules_for(shape)
    model = Model(cfg)

    params_sd, axes = model.abstract_init()
    param_shardings = _tree_shardings(params_sd, axes, rules, mesh)

    B, S = shape.global_batch, shape.seq_len
    caches_sd = jax.eval_shape(lambda: model.init_caches(B, S))
    cache_shardings = _cache_shardings(caches_sd, model, rules, mesh)

    token_sh = NamedSharding(mesh, logical_spec_sized((B,), ("batch",),
                                                       rules, mesh))
    token_sds = jax.ShapeDtypeStruct((B,), jnp.int32, sharding=token_sh)

    def serve_step(params, caches, token):
        with sharding_ctx(rules, mesh):
            return model.decode_step(params, caches, token,
                                     serve_window=serve_window)

    logits_sh = NamedSharding(mesh, logical_spec_sized(
        (B, cfg.vocab), ("batch", "act_vocab"), rules, mesh))
    in_sh = (param_shardings, cache_shardings, token_sh)
    out_sh = (logits_sh, cache_shardings)
    input_sds = (
        _sds_like(params_sd, param_shardings),
        _sds_like(caches_sd, cache_shardings),
        token_sds,
    )
    return StepBundle(cfg, shape, mesh, rules, model, serve_step,
                      in_sh, out_sh, input_sds)


def persistent_steps(bundle: StepBundle, n_iters: int) -> StepBundle:
    """Device-resident multi-step bundle: ONE host dispatch for
    ``n_iters`` train steps.

    The training-loop analogue of
    :mod:`repro.core.engine_persistent`: the returned bundle's
    ``step_fn`` wraps the original step in an on-device
    ``jax.lax.fori_loop``, so params/optimizer state round-trip through
    device memory — never the host — between inner steps.  The same
    batch feeds every inner step (the synthetic-data regime the
    dry-run/benchmarks use); metrics are the last step's.  Shardings and
    input stand-ins are unchanged — the loop carries exactly the
    step's (params, opt_state, metrics) signature.
    """
    if n_iters < 1:
        raise ValueError(f"n_iters must be >= 1, got {n_iters}")
    inner = bundle.step_fn

    def persistent_step(params, opt_state, batch):
        if n_iters == 1:
            return inner(params, opt_state, batch)

        # seed the metrics carry abstractly so the step traces ONCE (in
        # the loop body), not twice in the compiled program
        met_sd = jax.eval_shape(inner, params, opt_state, batch)[2]
        met0 = jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), met_sd)

        def body(_, c):
            p, o, _m = c
            return inner(p, o, batch)

        return jax.lax.fori_loop(0, n_iters, body,
                                 (params, opt_state, met0))

    return dataclasses.replace(bundle, step_fn=persistent_step)


def build_persistent_train_step(cfg: ModelConfig, shape: ShapeConfig,
                                mesh: Mesh, n_iters: int,
                                **kwargs) -> StepBundle:
    """:func:`build_train_step`, then fold ``n_iters`` steps into one
    dispatch via :func:`persistent_steps`."""
    return persistent_steps(build_train_step(cfg, shape, mesh, **kwargs),
                            n_iters)


def build_bundle(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                 **kwargs) -> StepBundle:
    serve_window = cfg.serve_window if (shape.name == "long_500k") else 0
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, **kwargs)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, serve_window=serve_window,
                                  **kwargs)
    return build_serve_step(cfg, shape, mesh, serve_window=serve_window,
                            **kwargs)
