"""Fused RMSNorm Pallas kernel.

RMSNorm runs once per sub-block per layer on every architecture in the
zoo; fusing the statistics + scale avoids one HBM round-trip of the
activations.  Tiling: rows (tokens) are tiled by ``block_rows``; the
model dimension stays whole in VMEM (d_model ≤ 8192 ⇒ ≤ 8192·4 B per
row, a few MB per tile — fits VMEM comfortably).  Statistics in fp32
regardless of input dtype; optional ``weight_offset`` (gemma's ``w+1``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_body(x_ref, w_ref, out_ref, *, eps: float, weight_offset: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32) + weight_offset
    out_ref[...] = (y * w[None, :]).astype(out_ref.dtype)


def rmsnorm_call(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
                 weight_offset: float = 0.0, block_rows: int = 128,
                 interpret: bool = False) -> jax.Array:
    """x: [rows, d]; w: [d] → [rows, d] (use vmap/reshape for batches)."""
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    # pad rows to a multiple of the block
    pad = (-rows) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    grid = ((rows + pad) // block_rows,)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_body, eps=eps, weight_offset=weight_offset),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        interpret=interpret,
    )(x, w)
    return out[:rows] if pad else out
