"""Batched serving example: prefill + greedy decode with KV caches.

Serves a small gemma3-family model (sliding-window + global layers,
tied embeddings) for a batch of 8 requests on a 2×2 mesh — the same
prefill_step/serve_step the 256-chip dry-run lowers.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import dataclasses

from repro.configs.base import get_config
from repro.launch.serve import serve
from repro.parallel import make_mesh

cfg = dataclasses.replace(
    get_config("gemma3-1b"),
    name="gemma3-tiny",
    n_layers=6, d_model=256, n_heads=4, n_kv_heads=1, head_dim=64,
    d_ff=768, vocab=32768, sliding_window=64, global_every=6,
    dtype="float32", param_dtype="float32", scan_layers=False, remat="none",
)
mesh = make_mesh((2, 2), ("data", "model"))
gen, stats = serve(cfg, mesh, batch=8, prompt_len=64, gen_len=32)
print("generated (first request):", gen[0][:16], "...")
print(f"prefill {stats['prefill_s']:.2f}s | decode {stats['decode_s']:.2f}s "
      f"| {stats['tok_per_s']:.1f} tok/s")
