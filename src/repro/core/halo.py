"""Faces — the paper's microbenchmark pattern as an ST program.

Faces (paper §V-A) is the nearest-neighbor pattern of CORAL-2 Nekbone:
each rank owns a 3-D block of spectral-element data and exchanges the
**faces (6), edges (12) and corners (8)** of its block with up to 26
neighbors, then *adds* the received contributions into its own boundary
(direct-stiffness summation).  The timed inner loop is:

1. pre-post receives;            (enqueue_recv ×26)
2. pack boundary slabs;          (pack kernels — Pallas or jnp)
3. initiate sends;               (enqueue_send ×26 + one enqueue_start)
4. interior compute (overlap);   (enqueue_kernel)
5. wait for messages;            (enqueue_wait)
6. unpack-and-add.               (unpack kernels)

This module builds that inner loop as an :class:`STQueue` program over a
3-D device grid, with the paper's variants selectable:

* ``engine``: ``fused`` (ST — one dispatch) vs ``host`` (baseline —
  per-op dispatch + host sync; Fig. 1);
* ``granularity``: ``direct26`` (paper: one message per neighbor) or
  ``staged3`` (beyond-paper: three axis sweeps, 6 larger messages, with
  corner/edge data forwarded through already-updated ghosts);
* ``batched``: one ``start`` for all messages (paper's batching) or one
  ``start`` per message (models unbatched triggering);
* ``pack``: ``jnp`` slicing or the Pallas ``halo_pack`` kernel.

For the *timed loop around* the inner exchange there are three control
paths: per-op host dispatch (:mod:`.engine_host`), one dispatch per
iteration (:mod:`.engine_fused`), and — via
:func:`run_faces_persistent` / :mod:`.engine_persistent` — one dispatch
for the whole N-iteration loop, device-resident.  On top of that,
:func:`run_faces_pipelined` splits the domain into N x-parts (uneven
sizes OK) on the same mesh, gives each its own queue, and composes the
persistent loops (:mod:`.schedule`) so they interleave in ONE dispatch.
By default the parts are *linked* through cross-program channels
(:func:`build_faces_part_program`): every iteration they exchange their
shared interior faces and the stencil's ghost planes, so the composed
run is the TRUE full-domain solve — bit-identical to the single-queue
:func:`run_faces_persistent` in ``stream`` mode (and in uncoalesced
``dataflow``; the default dataflow+coalesce path agrees to a few
documented FMA-contraction ULPs — see tests/test_links.py) — while one
part's communication window still overlaps another's compute.  With
``exchange=False`` the parts iterate independently (each may terminate
on its own convergence predicate).

A pure-NumPy oracle (`faces_oracle`) computes the same update globally
for correctness tests.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .descriptors import GridOffsetPeer
from .queue import STQueue, STProgram

AXES3 = ("gx", "gy", "gz")

# all 26 neighbor direction vectors, deterministic order: faces first,
# then edges, then corners (paper packs/sends in this order).
DIRECTIONS: Tuple[Tuple[int, int, int], ...] = tuple(
    sorted(
        (d for d in itertools.product((-1, 0, 1), repeat=3) if any(d)),
        key=lambda d: (sum(map(abs, d)), d),
    )
)
FACES = tuple(d for d in DIRECTIONS if sum(map(abs, d)) == 1)
EDGES = tuple(d for d in DIRECTIONS if sum(map(abs, d)) == 2)
CORNERS = tuple(d for d in DIRECTIONS if sum(map(abs, d)) == 3)


@dataclasses.dataclass(frozen=True)
class FacesConfig:
    grid: Tuple[int, int, int] = (2, 2, 2)   # device grid (gx, gy, gz)
    points: Tuple[int, int, int] = (16, 16, 16)  # local block points
    dtype: str = "float32"
    granularity: str = "direct26"  # direct26 | staged3
    batched: bool = True           # one start per batch of sends
    pack: str = "jnp"              # jnp | pallas
    periodic: bool = False
    interior_compute: bool = True  # include the overlap kernel (step 4)
    # Relaxation factor applied to the whole field at the end of every
    # iteration (0 → off).  With 0 < damping < ~0.3 the combined
    # smooth + boundary-sum + scale update is a contraction, so the
    # field norm decays geometrically — the substrate for the
    # convergence-terminated (until-residual<tol) persistent loop.
    damping: float = 0.0

    @property
    def n_ranks(self) -> int:
        gx, gy, gz = self.grid
        return gx * gy * gz

    @property
    def n_points(self) -> int:
        return self.n_ranks * int(np.prod(self.points))


def _slab_index(side: int, n: int) -> Tuple[slice, ...]:
    """Boundary slab index along one axis: -1 → first plane, +1 → last,
    0 → everything."""
    if side == -1:
        return slice(0, 1)
    if side == 1:
        return slice(n - 1, n)
    return slice(0, n)


def _region_for(direction: Tuple[int, int, int], points) -> Tuple[slice, ...]:
    return tuple(_slab_index(s, n) for s, n in zip(direction, points))


def _slab_shape(direction, points) -> Tuple[int, ...]:
    return tuple(1 if s else n for s, n in zip(direction, points))


def _make_pack_fn(region, pack_mode: str):
    if pack_mode == "pallas":
        from repro.kernels import ops as kops

        def pack(u):  # u local view: (1,1,1,px,py,pz)
            return kops.halo_pack(u[0, 0, 0], region)[None, None, None]
    else:
        def pack(u):
            return u[0, 0, 0][region][None, None, None]
    return pack


def _make_unpack_fn(region, pack_mode: str):
    if pack_mode == "pallas":
        from repro.kernels import ops as kops

        def unpack(u, msg):
            return kops.halo_unpack_add(u[0, 0, 0], msg[0, 0, 0], region)[None, None, None]
    else:
        def unpack(u, msg):
            core = u[0, 0, 0]
            core = core.at[region].add(msg[0, 0, 0])
            return core[None, None, None]
    return unpack


def _interior_fn(u):
    """Step-4 overlap kernel: a cheap local stencil on the interior."""
    core = u[0, 0, 0]
    smoothed = core + 0.125 * (
        jnp.roll(core, 1, 0) + jnp.roll(core, -1, 0)
        + jnp.roll(core, 1, 1) + jnp.roll(core, -1, 1)
        + jnp.roll(core, 1, 2) + jnp.roll(core, -1, 2)
        - 6.0 * core
    )
    return smoothed[None, None, None]


def build_faces_program(cfg: FacesConfig, mesh,
                        name: Optional[str] = None,
                        coalesce: bool = True) -> STProgram:
    """Build the Faces inner-loop as an ST program on a (gx,gy,gz) mesh.

    ``name`` sets the program name (defaults to ``faces_{granularity}``)
    — composed programs (:func:`repro.core.schedule.compose`) need
    distinct names, since the name is the buffer namespace.

    With ``coalesce`` (default) the 26 direct26 messages are grouped at
    build time into ≤6 fused by-axis transfers — the paper's contiguous
    MPI buffer (§V-A) — with bit-identical results; pass ``False`` for
    the one-collective-per-neighbor lowering (A/B benchmarks).
    """
    gx, gy, gz = cfg.grid
    px, py, pz = cfg.points
    dtype = np.dtype(cfg.dtype)
    q = STQueue(mesh, name="faces")

    gshape = (gx, gy, gz, px, py, pz)
    q.buffer("u", gshape, dtype, pspec=AXES3)

    dirs = DIRECTIONS if cfg.granularity == "direct26" else FACES
    msg_in, msg_out = {}, {}
    for i, d in enumerate(dirs):
        sshape = _slab_shape(d, cfg.points)
        msg_out[d] = q.buffer(f"out{i}", (gx, gy, gz, *sshape), dtype, pspec=AXES3)
        msg_in[d] = q.buffer(f"in{i}", (gx, gy, gz, *sshape), dtype, pspec=AXES3)

    if cfg.granularity == "direct26":
        _emit_direct26(q, cfg, msg_in, msg_out)
    elif cfg.granularity == "staged3":
        _emit_staged3(q, cfg, msg_in, msg_out)
    else:
        raise ValueError(cfg.granularity)

    return q.build(name=name or f"faces_{cfg.granularity}", coalesce=coalesce)


def _emit_direct26(q: STQueue, cfg: FacesConfig, msg_in, msg_out):
    # NOTE: build_faces_part_program emits the same structure filtered
    # by direction ownership; the two must stay in lockstep (tag scheme,
    # recvs-before-sends order, global-direction unpack replay) for the
    # linked split's bit-identity with the full-domain run — enforced by
    # tests/test_links.py::test_linked_pipelined_bitmatches_full_domain.
    dirs = DIRECTIONS
    # 2. pack kernels (paper step 2; packs precede sends in stream order)
    for i, d in enumerate(dirs):
        region = _region_for(d, cfg.points)
        q.enqueue_kernel(_make_pack_fn(region, cfg.pack), ["u"], [msg_out[d]],
                         name=f"pack{i}")
    if cfg.batched:
        # 1+3. pre-post all receives, then all sends, one trigger for the
        # whole batch (the paper's batching semantics — one writeValue).
        for i, d in enumerate(dirs):
            peer = GridOffsetPeer(AXES3, tuple(-x for x in d), cfg.periodic)
            q.enqueue_recv(msg_in[d], peer, tag=i)
        for i, d in enumerate(dirs):
            q.enqueue_send(msg_out[d], GridOffsetPeer(AXES3, d, cfg.periodic), tag=i)
        q.enqueue_start()
    else:
        # unbatched: one writeValue (start) per message
        for i, d in enumerate(dirs):
            peer = GridOffsetPeer(AXES3, tuple(-x for x in d), cfg.periodic)
            q.enqueue_recv(msg_in[d], peer, tag=i)
            q.enqueue_send(msg_out[d], GridOffsetPeer(AXES3, d, cfg.periodic), tag=i)
            q.enqueue_start()
    # 4. interior compute overlapping communication (paper step 4)
    if cfg.interior_compute:
        q.enqueue_kernel(_interior_fn, ["u"], ["u"], name="interior")
    # 5. wait (paper step 5)
    q.enqueue_wait()
    # 6. unpack-and-add (paper step 6)
    for i, d in enumerate(dirs):
        region = _region_for(tuple(-x for x in d), cfg.points)
        q.enqueue_kernel(_make_unpack_fn(region, cfg.pack),
                         ["u", msg_in[d]], ["u"], name=f"unpack{i}")
    _emit_damping(q, cfg)


def _emit_staged3(q: STQueue, cfg: FacesConfig, msg_in, msg_out):
    """Beyond-paper: three axis sweeps.  Each sweep exchanges the two
    faces along one axis; because each sweep reads the ghost-updated
    block, edge and corner contributions propagate through the stages
    (standard staged halo).  6 messages instead of 26."""
    for stage, axis in enumerate((0, 1, 2)):
        dirs = [d for d in FACES if d[axis] != 0]
        for d in dirs:
            i = FACES.index(d)
            peer = GridOffsetPeer(AXES3, tuple(-x for x in d), cfg.periodic)
            q.enqueue_recv(msg_in[d], peer, tag=100 * stage + i)
        for d in dirs:
            i = FACES.index(d)
            region = _region_for(d, cfg.points)
            q.enqueue_kernel(_make_pack_fn(region, cfg.pack), ["u"], [msg_out[d]],
                             name=f"pack_s{stage}_{i}")
        for d in dirs:
            i = FACES.index(d)
            q.enqueue_send(msg_out[d], GridOffsetPeer(AXES3, d, cfg.periodic),
                           tag=100 * stage + i)
        q.enqueue_start()
        if cfg.interior_compute and stage == 0:
            q.enqueue_kernel(_interior_fn, ["u"], ["u"], name="interior")
        q.enqueue_wait()
        for d in dirs:
            region = _region_for(tuple(-x for x in d), cfg.points)
            q.enqueue_kernel(_make_unpack_fn(region, cfg.pack),
                             ["u", msg_in[d]], ["u"], name=f"unpack_s{stage}")
    _emit_damping(q, cfg)


def _emit_damping(q: STQueue, cfg: FacesConfig):
    """End-of-iteration relaxation kernel (only when cfg.damping is on)."""
    if cfg.damping:
        scale = float(cfg.damping)
        q.enqueue_kernel(lambda u: u * scale, ["u"], ["u"], name="damp")


# --------------------------------------------------------------------------
# persistent (device-resident) timed loop
# --------------------------------------------------------------------------


def global_residual_fn(cfg: FacesConfig, buf: str = "u"):
    """Build a ``reduce_fn(mem) -> scalar`` computing the *global* RMS
    norm of ``buf``: local sum of squares, ``lax.psum`` over the mesh
    axes, normalized by the global point count.  Runs inside the
    device-resident loop — the convergence residual with no host sync.
    """
    n_total = float(cfg.n_points)

    def residual(mem):
        local = jnp.sum(jnp.square(mem[buf].astype(jnp.float32)))
        return jnp.sqrt(jax.lax.psum(local, AXES3) / n_total)

    return residual


def run_faces_until_converged(cfg: FacesConfig, mesh, u0, tol: float,
                              max_iters: int, mode: str = "dataflow",
                              double_buffer: Optional[bool] = None,
                              donate: bool = True):
    """Iterate Faces until the global residual drops below ``tol`` —
    with the *device* deciding when to stop (ONE host dispatch).

    The termination predicate ``residual >= tol`` and the residual
    reduction both run inside the persistent engine's ``while_loop``;
    the host sees nothing until the converged field, the residual trace
    and the realized iteration count come back together.

    Returns ``(mem, residuals, n_done, stats)``: final buffers, the
    residual trace trimmed to the realized length, the realized
    iteration count, and the engine stats (``stats.dispatches == 1``).
    """
    from .engine_persistent import PersistentEngine

    prog = build_faces_program(cfg, mesh).persistent(
        max_iters, until=lambda r: r >= tol)
    eng = PersistentEngine(prog, mode=mode, double_buffer=double_buffer,
                           reduce_fn=global_residual_fn(cfg), donate=donate)
    mem, residuals, n_done = eng(eng.init_buffers({"u": u0}))
    n_done = int(n_done)
    return mem, np.asarray(residuals)[:n_done], n_done, eng.stats


def run_faces_persistent(cfg: FacesConfig, mesh, u0, n_iters: int,
                         mode: str = "dataflow", reduce_fn=None,
                         double_buffer: Optional[bool] = None,
                         donate: bool = True):
    """Run ``n_iters`` Faces iterations as ONE host dispatch.

    Builds the inner-loop ST program, marks it persistent, and executes
    it with :class:`~repro.core.engine_persistent.PersistentEngine` —
    the fully offloaded variant of the paper's timed loop (the host
    enqueues once; the device sequencer re-runs pack → trigger →
    exchange → wait → unpack N times).

    Returns ``(mem, stats)`` — final buffers and the engine's
    dispatch-counting stats (``stats.dispatches == 1`` however large
    ``n_iters`` is).  With ``reduce_fn`` set, returns
    ``((mem, reductions), stats)`` exactly as the engine does.
    """
    from .engine_persistent import PersistentEngine

    prog = build_faces_program(cfg, mesh).persistent(n_iters)
    eng = PersistentEngine(prog, mode=mode, reduce_fn=reduce_fn,
                           double_buffer=double_buffer, donate=donate)
    out = eng(eng.init_buffers({"u": u0}))
    return out, eng.stats


# --------------------------------------------------------------------------
# pipelined multi-queue loop (N x-split domain parts, one dispatch)
# --------------------------------------------------------------------------


def part_points(px: int, n: int) -> Tuple[int, ...]:
    """Sizes of an N-way (possibly uneven) split of ``px`` points.

    The first ``px % n`` parts take one extra plane (``numpy.array_split``
    convention), so odd-sized domains pipeline instead of erroring.
    """
    if not 1 <= n <= px:
        raise ValueError(
            f"cannot split {px} x-planes into {n} part(s): need "
            f"1 <= n_parts <= points[0]")
    base, extra = divmod(px, n)
    return tuple(base + (1 if k < extra else 0) for k in range(n))


def part_configs(cfg: FacesConfig, n: int) -> Tuple[FacesConfig, ...]:
    """Per-part FacesConfigs of an N-way x-split domain (same device
    grid); parts may be uneven — see :func:`part_points`."""
    px, py, pz = cfg.points
    return tuple(dataclasses.replace(cfg, points=(p, py, pz))
                 for p in part_points(px, n))


def split_parts(u0, n: int):
    """Split a (gx,gy,gz,px,py,pz) field into N x-parts (uneven OK)."""
    u0 = np.asarray(u0)
    sizes = part_points(u0.shape[3], n)
    offs = np.concatenate([[0], np.cumsum(sizes)])
    return [u0[:, :, :, offs[k]:offs[k + 1]] for k in range(n)]


def merge_parts(parts):
    """Inverse of :func:`split_parts`."""
    return jnp.concatenate([jnp.asarray(p) for p in parts], axis=3)


def half_config(cfg: FacesConfig, part: int = 0) -> FacesConfig:
    """The per-half FacesConfig of a 2-way x-split domain.

    For even ``points[0]`` both halves are identical; for odd sizes the
    halves are uneven (first half takes the extra plane) and ``part``
    selects which one — see :func:`part_configs` for the N-way form.
    """
    return part_configs(cfg, 2)[part]


def split_halves(u0):
    """Split a (gx,gy,gz,px,py,pz) field into two x-halves (uneven OK)."""
    return tuple(split_parts(u0, 2))


def merge_halves(ua, ub):
    """Inverse of :func:`split_halves`."""
    return merge_parts([ua, ub])


PIPELINE_NAMES = ("facesA", "facesB")


def part_names(n: int) -> Tuple[str, ...]:
    """Program names of an N-way split (2-way keeps the legacy pair)."""
    if n == 2:
        return PIPELINE_NAMES
    return tuple(f"faces{k}" for k in range(n))


# Ghost-plane exchange tags (cross-program, peer offset (0,0,0)):
# _GHOST_TAG_LO carries part k's LAST plane up into part k+1's "glo"
# slot; _GHOST_TAG_HI carries part k's FIRST plane down into part
# k-1's "ghi" slot (ring over the parts, matching the full block's
# local wrap-around stencil).
_GHOST_TAG_LO, _GHOST_TAG_HI = 0, 1


def _part_interior_fn(u, glo, ghi):
    """Step-4 overlap stencil of one x-part, ghost planes substituted.

    Bit-identical to :func:`_interior_fn` on the unsplit block: the
    x-rolls become concat-with-ghost shifts (pure copies — the
    neighbor-part planes exchanged this iteration), and the elementwise
    addition order is kept exactly, so every float op matches the
    full-domain kernel's.
    """
    core = u[0, 0, 0]
    lo = glo[0, 0, 0]   # last plane of the part below (ring)
    hi = ghi[0, 0, 0]   # first plane of the part above (ring)
    xm = jnp.concatenate([lo, core[:-1]], axis=0)   # == roll(full, +1, 0)
    xp = jnp.concatenate([core[1:], hi], axis=0)    # == roll(full, -1, 0)
    smoothed = core + 0.125 * (
        xm + xp
        + jnp.roll(core, 1, 1) + jnp.roll(core, -1, 1)
        + jnp.roll(core, 1, 2) + jnp.roll(core, -1, 2)
        - 6.0 * core
    )
    return smoothed[None, None, None]


def build_faces_part_program(cfg: FacesConfig, mesh, part: int, n_parts: int,
                             names: Optional[Tuple[str, ...]] = None,
                             coalesce: bool = True) -> STProgram:
    """Build part ``part`` of an N-way x-split Faces domain, with
    cross-program links so the composed parts reproduce the FULL-domain
    iteration bit for bit (``cfg`` is the *full* domain's config).

    Two kinds of links (all declared via ``remote=`` and resolved by
    :func:`repro.core.schedule.compose`):

    * **ghost planes** — each part's interior stencil reads its ring
      neighbors' boundary planes (the full block's local wrap), fetched
      pre-iteration in a dedicated start/wait batch;
    * **x-crossing halo messages** — the 18 directions with an x
      component pack at one end of the split (part 0 for ``-x``, part
      N-1 for ``+x``), hop the device grid, and deposit into the
      *opposite end's* in-slots, whose unpack-adds replay in global
      direction order.  The 8 x-neutral directions stay per-part (each
      part exchanges exactly its own x-slice).

    The result must be composed with its sibling parts
    (``compose(*[build_faces_part_program(cfg, mesh, k, n) ...])``) —
    engines reject the open program.  Requires ``direct26`` granularity
    and batched triggering (the linked split is defined against that
    lowering).

    The emission below mirrors :func:`_emit_direct26` filtered by
    direction ownership; any structural change there (tags, recv/send
    order, unpack replay order) must be mirrored here — the bit-identity
    acceptance test fails loudly if the two drift.
    """
    if cfg.granularity != "direct26":
        raise ValueError(
            f"linked domain split supports granularity='direct26' only "
            f"(got {cfg.granularity!r})")
    if not cfg.batched:
        raise ValueError("linked domain split requires batched triggering")
    if n_parts < 2:
        raise ValueError("a linked split needs n_parts >= 2 "
                         "(use build_faces_program for the unsplit domain)")
    names = tuple(names) if names is not None else part_names(n_parts)
    if len(names) != n_parts:
        raise ValueError(f"need {n_parts} names, got {len(names)}")
    cfgp = part_configs(cfg, n_parts)[part]
    gx, gy, gz = cfg.grid
    px, py, pz = cfgp.points
    dtype = np.dtype(cfg.dtype)
    prev_name = names[(part - 1) % n_parts]
    next_name = names[(part + 1) % n_parts]

    # direction ownership under the split (see docstring)
    own = [d for d in DIRECTIONS if d[0] == 0]
    cross_out = [d for d in DIRECTIONS
                 if (d[0] == 1 and part == n_parts - 1)
                 or (d[0] == -1 and part == 0)]
    cross_in = [d for d in DIRECTIONS
                if (d[0] == 1 and part == 0)
                or (d[0] == -1 and part == n_parts - 1)]
    out_dst = {d: (names[0] if d[0] == 1 else names[n_parts - 1])
               for d in cross_out}
    in_src = {d: (names[n_parts - 1] if d[0] == 1 else names[0])
              for d in cross_in}

    q = STQueue(mesh, name=names[part])
    q.buffer("u", (gx, gy, gz, px, py, pz), dtype, pspec=AXES3)
    msg_in, msg_out = {}, {}
    for i, d in enumerate(DIRECTIONS):
        sshape = _slab_shape(d, cfgp.points)
        if d in own or d in cross_out:
            msg_out[d] = q.buffer(f"out{i}", (gx, gy, gz, *sshape), dtype,
                                  pspec=AXES3)
        if d in own or d in cross_in:
            msg_in[d] = q.buffer(f"in{i}", (gx, gy, gz, *sshape), dtype,
                                 pspec=AXES3)

    here = GridOffsetPeer(AXES3, (0, 0, 0))  # same-device cross-part hop
    if cfg.interior_compute:
        # ghost-plane ring exchange (dedicated batch: the stencil needs
        # the planes BEFORE the overlap kernel, so this one is waited
        # immediately — the compose interleave keeps each sender's
        # trigger ahead of this wait)
        q.buffer("glo", (gx, gy, gz, 1, py, pz), dtype, pspec=AXES3)
        q.buffer("ghi", (gx, gy, gz, 1, py, pz), dtype, pspec=AXES3)
        q.enqueue_recv("glo", here, tag=_GHOST_TAG_LO, remote=prev_name)
        q.enqueue_recv("ghi", here, tag=_GHOST_TAG_HI, remote=next_name)
        q.enqueue_send("u", here, tag=_GHOST_TAG_LO, remote=next_name,
                       region=(slice(0, 1),) * 3
                       + (slice(px - 1, px), slice(0, py), slice(0, pz)))
        q.enqueue_send("u", here, tag=_GHOST_TAG_HI, remote=prev_name,
                       region=(slice(0, 1),) * 3
                       + (slice(0, 1), slice(0, py), slice(0, pz)))
        q.enqueue_start()
        q.enqueue_wait()

    # 2. pack (own slabs + the x-crossing slabs this end owns)
    for i, d in enumerate(DIRECTIONS):
        if d in msg_out:
            region = _region_for(d, cfgp.points)
            q.enqueue_kernel(_make_pack_fn(region, cfg.pack), ["u"],
                             [msg_out[d]], name=f"pack{i}")
    # 1+3. pre-post all receives, then all sends, one trigger (batched)
    for i, d in enumerate(DIRECTIONS):
        if d not in msg_in:
            continue
        peer = GridOffsetPeer(AXES3, tuple(-x for x in d), cfg.periodic)
        q.enqueue_recv(msg_in[d], peer, tag=i,
                       remote=in_src.get(d))
    for i, d in enumerate(DIRECTIONS):
        if d not in msg_out:
            continue
        q.enqueue_send(msg_out[d], GridOffsetPeer(AXES3, d, cfg.periodic),
                       tag=i, remote=out_dst.get(d))
    q.enqueue_start()
    # 4. interior compute overlapping communication (ghost-substituted)
    if cfg.interior_compute:
        q.enqueue_kernel(_part_interior_fn, ["u", "glo", "ghi"], ["u"],
                         name="interior")
    # 5. wait
    q.enqueue_wait()
    # 6. unpack-and-add, replayed in GLOBAL direction order so the
    # add-accumulation order per element matches the unsplit program
    for i, d in enumerate(DIRECTIONS):
        if d not in msg_in:
            continue
        region = _region_for(tuple(-x for x in d), cfgp.points)
        q.enqueue_kernel(_make_unpack_fn(region, cfg.pack),
                         ["u", msg_in[d]], ["u"], name=f"unpack{i}")
    _emit_damping(q, cfg)
    return q.build(name=names[part], coalesce=coalesce)


def run_faces_pipelined(cfg: FacesConfig, mesh, u0, *,
                        n_iters: Optional[int] = None,
                        tols: Optional[Tuple[float, ...]] = None,
                        max_iters: Optional[int] = None,
                        mode: str = "dataflow",
                        double_buffer: Optional[bool] = None,
                        donate: bool = True,
                        n_parts: int = 2,
                        exchange: bool = True,
                        tune: bool = False,
                        tune_space: Optional[Dict[str, Sequence]] = None,
                        tune_repeats: int = 3,
                        tune_measure_top: int = 3):
    """N x-split Faces queues, composed, iterated in ONE dispatch.

    The domain is split into ``n_parts`` x-parts (uneven sizes OK) on
    the *same* mesh; each part gets its own STQueue program, and
    :func:`repro.core.schedule.compose` fuses them so one part's packs
    and interior compute interleave with another's trigger→wait window
    — the pipelined multi-queue schedule, with the whole loop
    device-resident.

    With ``exchange=True`` (default) the parts are *linked*: they trade
    their shared interior faces (and the stencil's ghost planes) every
    iteration through cross-program channels, so the composed run is
    the TRUE full-domain solve — identical to the single-queue
    :func:`run_faces_persistent` on the whole domain, still one
    dispatch.  With ``exchange=False`` each part iterates independently
    (the PR-3 behaviour: N separate solves sharing a dispatch, each
    matching its own standalone run).  "Identical" is bit-exact in
    ``stream`` mode and in uncoalesced ``dataflow``; the default
    dataflow+coalesce lowering agrees to within a few ULPs (XLA FMA
    contraction differs between the two compilations — bounds and
    analysis in tests/test_links.py and tests/test_schedule.py).

    Two regimes:

    * ``n_iters=N`` — every part runs exactly N iterations (uniform
      fixed loop).  Returns ``(mem, stats)``; part k's field lives at
      ``mem[f"{part_names(n_parts)[k]}/u"]`` (see :func:`merge_parts`).
    * ``tols=(tol0, ..., tol{n-1})`` + ``max_iters`` — each part runs
      until its OWN subdomain residual drops below its own tolerance
      (device-decided, per-program predicates).  Returns
      ``(mem, residuals, n_done, stats)`` with ``residuals[name]``
      trimmed to the realized length and ``n_done[name]`` ints.  With
      ``exchange=False`` this is the bit-exact union of N independent
      :func:`run_faces_until_converged` runs; with ``exchange=True`` a
      converged part freezes while its neighbors keep reading its
      frozen boundary (the masked multi-queue loop), so the combined
      field is a staged, not simultaneous, solve.

    With ``tune=True`` the execution configuration is auto-tuned by
    :func:`repro.launch.tune.tune` before the real run: candidates over
    ``tune_space`` (default: interleave policy × trigger mode, seeded
    from the ``mode``/``double_buffer`` arguments) are priced by the
    cost model, the ``tune_measure_top`` cheapest are measured
    (``tune_repeats`` medians each), and the winner runs.  The knobs
    never change numerics — only lowering and schedule — so the
    returned fields are the same solve either way.  The return value
    grows a trailing :class:`~repro.launch.tune.TuneResult`:
    ``(mem, stats, tuned)`` / ``(mem, residuals, n_done, stats,
    tuned)``.
    """
    from .engine_persistent import PersistentEngine
    from .schedule import compose

    if (n_iters is None) == (tols is None):
        raise ValueError("pass exactly one of n_iters= or tols=")
    names = part_names(n_parts)
    cfgs = part_configs(cfg, n_parts)
    parts = split_parts(np.asarray(u0), n_parts)
    if exchange:
        # the x-crossing halo links tie the split's two ends; the
        # stencil's ghost-plane ring links every adjacent pair
        links = [(names[0], names[-1]), (names[-1], names[0])]
        if cfg.interior_compute:
            ring = [(names[k], names[(k + 1) % n_parts])
                    for k in range(n_parts)]
            links += ring + [(b, a) for a, b in ring]
        builders = [build_faces_part_program(cfg, mesh, k, n_parts,
                                             names=names)
                    for k in range(n_parts)]
        links = sorted(set(links))
    else:
        links = None
        builders = [build_faces_program(cfgs[k], mesh, name=names[k])
                    for k in range(n_parts)]
    init = {f"{nm}/u": p for nm, p in zip(names, parts)}

    if tols is None:
        progs = [b.persistent(n_iters) for b in builders]
        reduce_fns = None
    else:
        if max_iters is None:
            raise ValueError("tols= requires max_iters=")
        if len(tols) != n_parts:
            raise ValueError(
                f"tols needs one tolerance per part ({n_parts}), got {tols!r}")
        progs = [
            b.persistent(max_iters, until=lambda r, tol=tol: r >= tol)
            for b, tol in zip(builders, tols)
        ]
        reduce_fns = {nm: global_residual_fn(cfgk, buf=f"{nm}/u")
                      for nm, cfgk in zip(names, cfgs)}

    def make_engine(interleave=None, **engine_kw):
        sched = compose(*progs, links=links, interleave=interleave)
        kw = dict(mode=mode, double_buffer=double_buffer)
        kw.update(engine_kw)
        return PersistentEngine(sched, donate=donate,
                                reduce_fns=reduce_fns, **kw)

    tuned = None
    if tune:
        from repro.launch.tune import Knobs, tune as tune_search

        def build(knobs: "Knobs"):
            eng = make_engine(interleave=knobs.interleave_policy(),
                              **knobs.engine_kwargs())
            return eng, (lambda e=eng: e.init_buffers(init))

        tuned = tune_search(
            build,
            tune_space or {"interleave": ["round_robin", "sequential", 2],
                           "mode": ["dataflow", "stream"]},
            base=Knobs(mode=mode, double_buffer=double_buffer),
            repeats=tune_repeats, measure_top=tune_measure_top)
        eng = tuned.best.engine
        eng.stats.reset()  # returned stats cover the real solve only
    else:
        eng = make_engine()

    if tols is None:
        mem = eng(eng.init_buffers(init))
        return (mem, eng.stats, tuned) if tune else (mem, eng.stats)

    mem, reds, n_done = eng(eng.init_buffers(init))
    n_done = {nm: int(v) for nm, v in n_done.items()}
    reds = {nm: np.asarray(r)[: n_done[nm]] for nm, r in reds.items()}
    return ((mem, reds, n_done, eng.stats, tuned) if tune
            else (mem, reds, n_done, eng.stats))


# --------------------------------------------------------------------------
# NumPy oracle
# --------------------------------------------------------------------------


def faces_oracle(u: np.ndarray, cfg: FacesConfig) -> np.ndarray:
    """Reference update for one inner iteration, computed globally.

    ``u`` has shape (gx, gy, gz, px, py, pz).  Mirrors `direct26`
    semantics: interior stencil (if enabled) then the 26-direction
    boundary-sum, using the *pre-exchange* packed values (all packs
    happen before the interior kernel in stream order).
    """
    u = np.asarray(u, dtype=np.dtype(cfg.dtype))
    gx, gy, gz = cfg.grid
    out = u.copy()

    # packed messages are extracted from the original field
    packed = {
        d: u[(slice(None),) * 3 + _region_for(d, cfg.points)].copy()
        for d in DIRECTIONS
    }

    if cfg.interior_compute:
        core = out
        sm = core.copy()
        for ax in (3, 4, 5):
            sm += 0.125 * (np.roll(core, 1, ax) + np.roll(core, -1, ax))
        sm -= 0.125 * 6.0 * core
        out = sm

    for d in DIRECTIONS:
        # contribution sent by neighbor at -d arrives at my -d... each
        # rank r receives, from neighbor r - d, that neighbor's +d face,
        # deposited into r's -d region.  Global shift of packed slabs:
        msg = packed[d]
        shifted = np.zeros_like(msg)
        src = [slice(None)] * 6
        dst = [slice(None)] * 6
        ok = True
        for ax, delta, n in zip(range(3), d, (gx, gy, gz)):
            if delta == 0:
                continue
            if cfg.periodic:
                shifted_axis = None  # handled below with np.roll
            else:
                if delta > 0:
                    src[ax] = slice(0, n - delta)
                    dst[ax] = slice(delta, n)
                else:
                    src[ax] = slice(-delta, n)
                    dst[ax] = slice(0, n + delta)
        if cfg.periodic:
            shifted = np.roll(msg, shift=d, axis=(0, 1, 2))
        else:
            shifted[tuple(dst)] = msg[tuple(src)]
        region = _region_for(tuple(-x for x in d), cfg.points)
        out[(slice(None),) * 3 + region] += shifted
    if cfg.damping:
        out *= np.asarray(cfg.damping, dtype=out.dtype)
    return out
