"""ST API host-side overhead (paper §III: enqueue must be cheap and
non-blocking — the whole point is that the CPU only appends descriptors).

Measures µs/call for enqueue_send/recv/start/wait, trace-time matching,
program build for batches of N descriptors, multi-queue composition
(``compose`` + building the programs being composed), and the channel-
coalescing layer: build time with/without plan derivation, and the
collective count per start gate before/after coalescing (the paper's
26 → ≤6 reduction, *measured* off the recorded plan rather than
asserted) — regressions on any enqueue-path stay visible here.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

RESULTS: List[Dict] = []


def _bench(fn, n: int = 2000) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def run_all():
    from repro.core import OffsetPeer, STQueue
    from repro.parallel import make_mesh

    mesh = make_mesh((1,), ("x",))
    print("ST API overhead (host-side, µs/call)")

    def fresh_queue(n_bufs=2):
        q = STQueue(mesh, "bench")
        for i in range(n_bufs):
            q.buffer(f"b{i}", (64, 64), np.float32, pspec=("x",))
        return q

    q = fresh_queue()
    t_send = _bench(lambda: q.enqueue_send("b0", OffsetPeer("x", 1), tag=0))
    q2 = fresh_queue()
    t_recv = _bench(lambda: q2.enqueue_recv("b1", OffsetPeer("x", -1), tag=0))

    q3 = fresh_queue()
    def send_recv_start():
        q3.enqueue_recv("b1", OffsetPeer("x", -1), tag=0)
        q3.enqueue_send("b0", OffsetPeer("x", 1), tag=0)
        q3.enqueue_start()
    t_batch = _bench(send_recv_start, n=500)

    for name, us in [("enqueue_send", t_send), ("enqueue_recv", t_recv),
                     ("send+recv+start", t_batch)]:
        RESULTS.append({"bench": "api_overhead", "variant": name,
                        "us_per_call": us, "derived": "host_nonblocking"})
        print(f"  {name:18s} {us:8.2f} us/call")

    # build (matching) cost vs batch size
    for n in (26, 260, 1040):
        q4 = fresh_queue()
        for i in range(n):
            q4.enqueue_recv("b1", OffsetPeer("x", -1), tag=i)
        for i in range(n):
            q4.enqueue_send("b0", OffsetPeer("x", 1), tag=i)
        q4.enqueue_start()
        q4.enqueue_wait()
        t0 = time.perf_counter()
        prog = q4.build()
        dt = (time.perf_counter() - t0) * 1e6
        RESULTS.append({"bench": "api_overhead",
                        "variant": f"build_match_n{n}",
                        "us_per_call": dt,
                        "derived": f"us_per_descriptor={dt/(2*n):.2f}"})
        print(f"  build+match n={n:5d} {dt:10.1f} us "
              f"({dt/(2*n):.2f} us/descriptor)")

    # multi-queue composition cost (schedule layer, host-side only)
    from repro.core import compose

    def matched_program(name, n):
        q = STQueue(mesh, name)
        q.buffer("a", (64, 64), np.float32, pspec=("x",))
        q.buffer("b", (64, 64), np.float32, pspec=("x",))
        for i in range(n):
            q.enqueue_recv("b", OffsetPeer("x", -1), tag=i)
        for i in range(n):
            q.enqueue_send("a", OffsetPeer("x", 1), tag=i)
        q.enqueue_start()
        q.enqueue_wait()
        return q.build()

    for n in (26, 260):
        pa = matched_program("qa", n)
        pb = matched_program("qb", n)
        t_comp = _bench(lambda: compose(pa, pb), n=200)
        RESULTS.append({"bench": "api_overhead",
                        "variant": f"compose_2x{n}",
                        "us_per_call": t_comp,
                        "derived": f"us_per_descriptor={t_comp/(2*(2*n+2)):.2f}"})
        print(f"  compose 2x n={n:4d} {t_comp:10.1f} us/call "
              f"({t_comp/(2*(2*n+2)):.2f} us/descriptor)")

        # composed end-to-end: build both programs + compose them
        def build_and_compose(_n=n):
            return compose(matched_program("qa", _n), matched_program("qb", _n))
        t_bc = _bench(build_and_compose, n=50)
        RESULTS.append({"bench": "api_overhead",
                        "variant": f"composed_build_2x{n}",
                        "us_per_call": t_bc,
                        "derived": "build_both+compose"})
        print(f"  composed-build 2x n={n:4d} {t_bc:10.1f} us/call")

    # -- channel coalescing: build cost + collective-count reduction -------
    import jax

    from repro.core import FacesConfig, build_faces_program

    def faces_builds(grid):
        from repro.parallel import make_mesh
        m3 = make_mesh(grid, ("gx", "gy", "gz"))
        cfg = FacesConfig(grid=grid, points=(8, 8, 8),
                          periodic=(grid == (1, 1, 1)))
        for coalesce in (False, True):
            t0 = time.perf_counter()
            reps = 20
            for i in range(reps):
                # distinct names defeat the build cache: each call pays
                # full matching (+ plan derivation when coalescing)
                prog = build_faces_program(cfg, m3, name=f"b{coalesce}{i}",
                                           coalesce=coalesce)
            dt = (time.perf_counter() - t0) / reps * 1e6
            un, low = prog.max_collectives_per_start()
            tag = "coalesced" if coalesce else "uncoalesced"
            RESULTS.append({
                "bench": "api_overhead",
                "variant": f"faces_build_{tag}",
                "us_per_call": dt,
                "derived": f"collectives_per_start={low};"
                           f"uncoalesced={un}",
            })
            print(f"  faces build ({tag:11s}) {dt:10.1f} us/call "
                  f"collectives/start={low} (uncoalesced {un})")

    # the Faces figures' 2x2x2 grid when 8 devices are up (benchmarks
    # force 8); a single-device periodic grid otherwise
    faces_builds((2, 2, 2) if len(jax.devices()) >= 8 else (1, 1, 1))
    return RESULTS
