"""Blockwise (flash) attention forward — Pallas TPU kernel.

The prefill/serve hot-spot.  Online-softmax blockwise attention with:

* GQA head mapping (q-head → kv-head via BlockSpec index map, no
  repeat-materialization of K/V in HBM);
* causal masking with a global ``q_offset`` (chunked prefill / decode);
* optional sliding window (gemma3 local layers, windowed serving);
* optional logit soft-capping (grok-style);
* fp32 accumulation in VMEM scratch.

Tiling: grid = (batch·q_heads, Sq/block_q, Skv/block_k), kv innermost
(sequential accumulation; TPU grids execute serially so scratch carries
state across the kv dimension).  Q/K/V tiles are (block_q|k, head_dim)
in VMEM; MXU dims are multiples of 128 when block sizes are (the
wrapper defaults to 128/256 and pads the sequence).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
from repro.compat import tpu_compiler_params
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_body(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                scale: float, causal: bool, window: Optional[int],
                logit_softcap: Optional[float], q_offset: int,
                kv_valid: int, block_q: int, block_k: int, n_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q + q_offset
    k_start = ik * block_k

    # Block-level skip: fully-masked (causal / window / padding) kv tiles.
    run = k_start < kv_valid
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)      # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)      # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)      # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if logit_softcap is not None:
            s = logit_softcap * jnp.tanh(s / logit_softcap)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_valid
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...][:, :1]                      # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)      # [bq, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                 # [bq, 1]
        p = jnp.exp(s - m_new)                          # [bq, bk]
        p = jnp.where(mask, p, 0.0)

        l_prev = l_ref[...][:, :1]
        l_new = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_ref[...][:, :1]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention_call(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Skv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0, "GQA requires Hq % Hkv == 0"
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_q = (Sq + pad_q) // block_q
    n_k = (Skv + pad_k) // block_k

    body = functools.partial(
        _flash_body, scale=scale, causal=causal, window=window,
        logit_softcap=logit_softcap, q_offset=q_offset, kv_valid=Skv,
        block_q=block_q, block_k=block_k, n_k=n_k)

    out = pl.pallas_call(
        body,
        grid=(B * Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda bh, iq, ik: (bh // Hq, bh % Hq, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda bh, iq, ik: (bh // Hq, (bh % Hq) // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda bh, iq, ik: (bh // Hq, (bh % Hq) // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda bh, iq, ik: (bh // Hq, bh % Hq, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq + pad_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),    # acc
            pltpu.VMEM((block_q, 128), jnp.float32),  # running max
            pltpu.VMEM((block_q, 128), jnp.float32),  # running denom
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq] if pad_q else out
