"""Trigger / completion counters — the ST synchronization primitives.

The paper's ST design synchronizes three agents (CPU, GPU control
processor, NIC) through two hardware counters per ``MPIX_Queue``:

* a **trigger counter**: the GPU CP bumps it with a stream-memory
  ``writeValue``; every deferred NIC descriptor whose threshold is met
  fires;
* a **completion counter**: the NIC bumps it as operations complete; the
  GPU CP blocks the *stream* on it with ``waitValue``.

On TPU there is no user-visible NIC command queue, so counters cannot be
(and need not be) hardware objects.  Inside a fused XLA program the same
ordering contract is expressed as *data dependencies*: a counter is a
scalar value threaded through the program, and "bump then fire" becomes
"make the communication op's operand depend on the bumped scalar".
``jax.lax.optimization_barrier`` is the lowering-level tool that pins a
value and a counter together without adding arithmetic to either.

This module provides the counter objects plus the two primitives used by
the engines:

``tie(token, *arrays)``
    writeValue analogue: returns ``(token', arrays')`` such that nothing
    consuming ``arrays'`` may be scheduled before every producer of
    ``token`` — and vice versa.

``gate(token, *arrays)``
    waitValue analogue: identical mechanics, used on the *consumer* side
    to make downstream kernels depend on a completion counter.

Both are implemented with ``optimization_barrier`` so they survive XLA
simplification (a ``+0`` style fake dependency would be DCE'd away).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Sequence

import jax
import jax.numpy as jnp

_counter_ids = itertools.count()


def fresh_token() -> jax.Array:
    """A new trigger-counter value (the counter starts at 0)."""
    return jnp.zeros((), dtype=jnp.int32)


def bump(token: jax.Array, amount: int = 1) -> jax.Array:
    """``writeValue``: advance the counter.  Pure arithmetic; ordering
    comes from `tie`/`gate` around it."""
    return token + jnp.int32(amount)


def tie(token: jax.Array, *arrays: Any):
    """Tie ``arrays`` to ``token`` (writeValue / trigger edge).

    Returns ``(token, arrays)`` where each leaf of ``arrays`` is ordered
    with respect to the token by an optimization barrier.  Consumers of
    the returned arrays observe program points at-or-after the token's
    producers (the enqueued `start`), which is exactly the deferred
    "do not execute until triggered" contract of a DWQ descriptor.
    """
    flat, treedef = jax.tree.flatten(arrays)
    out = jax.lax.optimization_barrier((token, *flat))
    token_out, flat_out = out[0], list(out[1:])
    arrs = jax.tree.unflatten(treedef, flat_out)
    return token_out, arrs


def gate(token: jax.Array, *arrays: Any):
    """``waitValue``: gate downstream consumers of ``arrays`` on the
    completion counter ``token``.  Mechanically identical to `tie`; kept
    separate so lowered programs read like the paper's stream
    (write → trigger → ... → wait → kernel)."""
    return tie(token, *arrays)


def completion_from(token: jax.Array, *results: Any) -> jax.Array:
    """Derive a completion-counter value from communication results.

    The NIC bumps the completion counter once per finished descriptor;
    here, the counter becomes data-dependent on every result array, so
    anything gated on it observes the received data.
    """
    flat = jax.tree.leaves(results)
    out = jax.lax.optimization_barrier((token, *flat))
    return bump(out[0], len(flat))


@dataclasses.dataclass
class TriggerCounter:
    """Host-side handle for a queue's trigger counter.

    ``threshold`` bookkeeping mirrors the SS11 DWQ descriptor fields: a
    descriptor enqueued when the counter's *scheduled* value is ``v``
    gets threshold ``v + 1`` and fires on the matching `start`.
    """

    name: str = ""
    scheduled: int = 0  # value the counter will have reached after all
    # currently-enqueued starts have executed.

    def __post_init__(self):
        if not self.name:
            self.name = f"trig{next(_counter_ids)}"

    def next_threshold(self) -> int:
        return self.scheduled + 1

    def record_start(self) -> int:
        """A `start` was enqueued: the counter will be bumped once."""
        self.scheduled += 1
        return self.scheduled


@dataclasses.dataclass
class CompletionCounter:
    """Host-side handle for a queue's completion counter.

    ``expected`` counts descriptors whose completion the next `wait`
    must observe (the waitValue threshold).
    """

    name: str = ""
    expected: int = 0

    def __post_init__(self):
        if not self.name:
            self.name = f"comp{next(_counter_ids)}"

    def record_op(self, n: int = 1) -> int:
        self.expected += n
        return self.expected


def chain_strict(token: jax.Array, arrays: Sequence[Any]):
    """Strict stream order: pin *every* array to the token in sequence.

    Used by the engines' ``strict`` mode to reproduce literal GPU-stream
    FIFO semantics (each op ordered after the previous one), trading
    away XLA's freedom to overlap independent ops.
    """
    out = []
    for a in arrays:
        token, a = tie(token, a)
        out.append(a)
    return token, out
