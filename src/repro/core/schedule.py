"""STSchedule — compose concurrent STQueues into ONE device program.

The paper's ST model keeps one deferred-work queue per GPU stream.  Real
Nekbone-style solves want *several* queues in flight, so one queue's
communication overlaps another queue's compute — the multi-DWQ schedule
of "Understanding GPU Triggering APIs for MPI+X Communication"
(arXiv:2406.05594) and the fully offloaded follow-on (arXiv:2306.15773).
Running each queue's persistent loop as its own host dispatch pays one
dispatch per queue and gives the device no chance to interleave them.

:func:`compose` fuses N *matched* :class:`~repro.core.queue.STProgram`\\ s
into one :class:`STSchedule` (an ``STProgram`` subclass), with

* **namespaced buffers** — program ``p``'s buffer ``b`` becomes
  ``"p/b"``, so no memory is shared between sub-programs (static
  analysis rejects cross-program buffer aliasing: composing two
  programs with the same name — e.g. a program with itself — is an
  error);
* **program identity** — every descriptor, batch and buffer carries the
  sub-program's ``pid``, which the engines use to keep one
  trigger/completion counter bank *per program* (the multi-queue
  analogue of one counter pair per ``MPIX_Queue``) and to scope
  stream-FIFO ordering per program instead of serializing the whole
  composition;
* **round-robin batch interleaving** — each program's descriptor stream
  is split into *segments* at its trigger/wait gates (a segment ends
  after each ``start``, and after each ``wait`` that does not fall
  inside an open batch), and the segments are merged round-robin.
  Program B's packs and kernels therefore sit *between* program A's
  ``start`` and A's ``wait`` in the fused stream: software pipelining
  of the queues.  A batch's descriptors are never split across
  segments, and each program's internal FIFO order is preserved
  exactly (property-tested).

Per-program iteration counts and termination predicates ride along:
``compose(pA.persistent(50, until=predA), pB.persistent(40, until=predB))``
yields a schedule the persistent engine runs until **all** programs'
predicates terminate, freezing each program's state at its own
convergence point and reporting a per-program realized iteration count
(see :class:`~repro.core.engine_persistent.PersistentEngine`).

Cross-program channels (links)
------------------------------
Sub-programs need not iterate independently: a send enqueued with
``remote="B"`` in program A is matched (at compose time, same static
rules) against a recv enqueued with ``remote="A"`` in program B, and
becomes a **cross-program channel** — A's trigger fires it, the
payload deposits into B's memory, and the completion is wired into
*B's* counter bank so B's wait gate observes A's completion.  That is
how triggered operations chain *across* concurrent streams (the
fully-offloaded follow-on of arXiv:2306.15773 / the MPI+X taxonomy of
arXiv:2406.05594): the composed halves of a split domain exchange their
shared faces each iteration instead of drifting apart.  The segment
interleaver becomes link-aware — a link's trigger (the sender's
``start``) is always emitted before the consumer's gating ``wait``; a
cycle of such constraints is a composition deadlock and raises
:class:`ScheduleError`.  ``compose(..., links=[("A", "B"), ...])``
optionally *declares* the expected program pairs, and the realized link
set must match the declaration exactly.  Matched links are recorded on
``STSchedule.links`` for introspection.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .descriptors import (
    CollDesc,
    KernelDesc,
    RecvDesc,
    SendDesc,
    StartDesc,
    WaitDesc,
)
from .effects import batch_effects, stamp_staging
from .matching import Batch, MatchError, coalesce_batch, match_cross_program
from .queue import STProgram


class ScheduleError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class SubProgram:
    """Composition metadata for one fused program."""

    name: str
    pid: int
    buffers: Tuple[str, ...]     # namespaced buffer names owned by this pid
    n_iters: int                 # per-program iteration count / bound
    until: Optional[Any]         # per-program termination predicate
    batch_lo: int                # first (renumbered) batch index
    n_batches: int


@dataclasses.dataclass(frozen=True)
class Link:
    """One resolved cross-program channel (introspection metadata).

    ``src_batch``/``dst_batch`` are *global* (schedule) batch indices:
    the sender's trigger batch and the batch whose wait gates the
    deposit on the receiving side.  ``dst_buf`` is the namespaced
    destination buffer the sender deposits into.
    """

    src: str
    dst: str
    tag: int
    src_batch: int
    dst_batch: int
    dst_buf: str


@dataclasses.dataclass
class STSchedule(STProgram):
    """N concurrent STPrograms fused into one device-resident program.

    ``n_iters`` on the schedule is the max over the sub-programs (the
    global loop bound); per-program counts/predicates live in ``subs``.
    """

    subs: Tuple[SubProgram, ...] = ()
    # Resolved cross-program channels (empty when the sub-programs
    # iterate independently).
    links: Tuple[Link, ...] = ()

    def buffers_by_pid(self) -> Dict[int, Tuple[str, ...]]:
        return {s.pid: s.buffers for s in self.subs}

    def sub(self, name: str) -> SubProgram:
        for s in self.subs:
            if s.name == name:
                return s
        raise KeyError(name)

    def buffer_name(self, sub: str, buf: str) -> str:
        """The namespaced name of ``buf`` inside sub-program ``sub``."""
        ns = f"{sub}/{buf}"
        if ns not in self.buffers:
            raise KeyError(ns)
        return ns

    def persistent(self, n_iters, until=None) -> "STProgram":
        raise ScheduleError(
            "persistence is per-program under composition: call "
            ".persistent(...) on each program BEFORE compose(), so every "
            "queue keeps its own iteration count and predicate"
        )


def _segments(descs) -> List[List[Any]]:
    """Split one program's descriptor stream at its trigger/wait gates.

    A segment ends after each ``StartDesc``, and after each ``WaitDesc``
    that is not inside an open batch (i.e. no send/recv/coll enqueued
    since the last start) — so a batch's deferred ops and its trigger
    always land in the same segment and can never be interleaved with
    another program's descriptors.
    """
    segs: List[List[Any]] = []
    cur: List[Any] = []
    open_batch = False
    for d in descs:
        cur.append(d)
        if isinstance(d, (SendDesc, RecvDesc, CollDesc)):
            open_batch = True
        elif isinstance(d, StartDesc):
            open_batch = False
            segs.append(cur)
            cur = []
        elif isinstance(d, WaitDesc) and not open_batch:
            segs.append(cur)
            cur = []
    if cur:
        segs.append(cur)
    return segs


@dataclasses.dataclass(frozen=True)
class InterleavePolicy:
    """How :func:`_interleave` merges the programs' segment lists.

    ``order`` is the program visitation order per round (a permutation
    of pids; ``None`` means ``0..N-1``).  ``granularity`` is how many
    segments one program emits per turn before yielding — 1 is the
    classic fine-grained round-robin, larger values trade interleaving
    depth for fewer context switches in the fused stream, and a value
    >= every program's segment count degenerates to sequential
    concatenation (each program runs to completion, links permitting).
    Both are discrete tuner knobs (see :mod:`repro.launch.tune`).
    """

    order: Optional[Tuple[int, ...]] = None
    granularity: int = 1

    def visit_order(self, n_programs: int) -> Tuple[int, ...]:
        if self.order is None:
            return tuple(range(n_programs))
        if sorted(self.order) != list(range(n_programs)):
            raise ScheduleError(
                f"interleave order {self.order} is not a permutation of "
                f"0..{n_programs - 1}")
        return self.order


#: Named policies accepted anywhere an :class:`InterleavePolicy` is
#: (``compose(interleave=...)``): ``"round_robin"`` is the historical
#: default; ``"sequential"`` concatenates programs whole.
INTERLEAVE_POLICIES: Dict[str, InterleavePolicy] = {
    "round_robin": InterleavePolicy(),
    "sequential": InterleavePolicy(granularity=1_000_000_000),
}


def _resolve_policy(policy) -> InterleavePolicy:
    if policy is None:
        return INTERLEAVE_POLICIES["round_robin"]
    if isinstance(policy, InterleavePolicy):
        if policy.granularity < 1:
            raise ScheduleError(
                f"interleave granularity must be >= 1, got "
                f"{policy.granularity}")
        return policy
    if isinstance(policy, str):
        try:
            return INTERLEAVE_POLICIES[policy]
        except KeyError:
            raise ScheduleError(
                f"unknown interleave policy {policy!r} (named policies: "
                f"{sorted(INTERLEAVE_POLICIES)}; or pass an "
                f"InterleavePolicy)") from None
    raise ScheduleError(
        f"interleave= takes a policy name or InterleavePolicy, got "
        f"{type(policy).__name__}")


def _interleave(
    per_prog_segments: List[List[List[Any]]],
    constraints: Optional[Dict[Tuple[int, int], set]] = None,
    policy: Optional[InterleavePolicy] = None,
) -> Tuple[Any, ...]:
    """Policy-driven merge of the programs' segment lists.

    The default policy is the classic fine-grained round-robin (each
    program emits one segment per turn, in pid order).  ``policy``
    varies the visitation ``order`` and per-turn ``granularity`` — see
    :class:`InterleavePolicy`.

    ``constraints`` maps a segment ``(pid, seg_idx)`` to the set of
    segments that must be emitted *before* it — used to keep every
    cross-program link's trigger (the sender's ``start`` segment) ahead
    of the consumer's gating ``wait`` segment.  A blocked segment is
    deferred to a later round (per-program FIFO order is never
    reordered — the program simply yields its turn); with no
    constraints this degenerates to the policy's plain merge.  An
    unsatisfiable cycle raises :class:`ScheduleError`.
    """
    constraints = constraints or {}
    policy = _resolve_policy(policy)
    order = policy.visit_order(len(per_prog_segments))
    out: List[Any] = []
    ptr = [0] * len(per_prog_segments)
    emitted: set = set()
    remaining = sum(len(s) for s in per_prog_segments)
    while remaining:
        progress = False
        for p in order:
            segs = per_prog_segments[p]
            for _ in range(policy.granularity):
                if ptr[p] >= len(segs):
                    break
                need = constraints.get((p, ptr[p]), ())
                if any(pre not in emitted for pre in need):
                    break  # blocked on a link's trigger — yield this round
                out.extend(segs[ptr[p]])
                emitted.add((p, ptr[p]))
                ptr[p] += 1
                remaining -= 1
                progress = True
        if not progress:
            stuck = [(p, ptr[p]) for p in range(len(per_prog_segments))
                     if ptr[p] < len(per_prog_segments[p])]
            raise ScheduleError(
                f"cross-program link cycle: segments {stuck} each wait on a "
                f"trigger that can only be emitted after them (two programs "
                f"may not each gate a wait on the other's *later* start)"
            )
    return tuple(out)


def compose(*programs: STProgram, name: Optional[str] = None,
            links: Optional[Sequence[Tuple[str, str]]] = None,
            interleave: Any = None,
            verify: str = "error") -> STSchedule:
    """Fuse N matched STPrograms into one :class:`STSchedule`.

    Buffers are namespaced ``"{program.name}/{buffer}"``; descriptors and
    batches are tagged with their program's ``pid``; batch indices are
    renumbered to be globally unique; and the programs' descriptor
    streams are interleaved round-robin at trigger/wait-gate granularity
    (see :func:`_segments`).  Every engine accepts the result: the fused
    engine runs one interleaved pass, the persistent engine runs the
    whole multi-queue loop — per-program counts and predicates included
    — as ONE host dispatch.

    Open (``remote=``) sends/recvs are matched *across* the composed
    programs into cross-program channels: the sender's trigger fires
    them, the deposit lands in the receiver's memory, and the
    completion bumps the receiver's counter bank (the receiver's wait
    gate observes the sender's completion).  Coalescing plans are
    re-derived per batch after cross channels join it, so fused
    transfers may carry cross payloads but never merge two *triggering*
    programs' batches (plans stay per-batch, batches stay per-pid).
    The interleaving keeps every link's trigger ahead of its consumer's
    gating wait.  ``links=[(src, dst), ...]`` optionally declares the
    expected program pairs; the realized pairs must match exactly.

    ``interleave`` selects the segment-merge policy: a name from
    :data:`INTERLEAVE_POLICIES` (``"round_robin"`` — the default —
    or ``"sequential"``) or an :class:`InterleavePolicy` with an
    explicit program visitation ``order`` and per-turn ``granularity``.
    The policy is a tuner knob (:mod:`repro.launch.tune`); whatever the
    policy, link constraints and per-program FIFO order always hold,
    and the finished schedule still passes through ``verify`` below —
    an invalid interleaving can never leave this function silently.

    Raises :class:`ScheduleError` for programs on different meshes,
    duplicate program names (cross-program buffer aliasing — composing
    a program with itself is the canonical offender), nested schedules
    (compose all leaves in one call instead), unmatched or undeclared
    cross-program descriptors, and link cycles the interleaver cannot
    order.

    ``verify`` runs the :mod:`repro.core.verify` static pass on the
    finished schedule — default ``"error"`` (a composed schedule is
    engine-ready, so error-severity diagnostics raise
    :class:`~repro.core.verify.VerifyError` here rather than hang
    later); ``"warn"`` downgrades to :class:`~repro.core.verify
    .STLintWarning`, ``"off"`` skips the pass.
    """
    if not programs:
        raise ScheduleError("compose() needs at least one program")
    mesh = programs[0].mesh
    names = [p.name for p in programs]
    if len(set(names)) != len(names):
        dupes = sorted({n for n in names if names.count(n) > 1})
        raise ScheduleError(
            f"cross-program buffer aliasing: duplicate program name(s) "
            f"{dupes} would map distinct programs onto the same buffer "
            f"namespace (build each queue with a distinct name)"
        )
    for p in programs:
        if isinstance(p, STSchedule):
            raise ScheduleError(
                f"nested composition: {p.name!r} is already a schedule — "
                f"compose all leaf programs in a single compose() call"
            )
        if p.mesh is not mesh and p.mesh != mesh:
            raise ScheduleError(
                f"program {p.name!r} lives on a different mesh than "
                f"{programs[0].name!r}; composed queues share one device grid"
            )

    buffers: Dict[str, Any] = {}
    batches: List[Batch] = []
    subs: List[SubProgram] = []
    per_prog_segments: List[List[List[Any]]] = []
    # open cross-program descriptors, pooled per (src_name, dst_name):
    # (renamed descriptor, global batch index) in enqueue order
    open_send_pool: Dict[Tuple[str, str], List[Tuple[Any, int]]] = \
        defaultdict(list)
    open_recv_pool: Dict[Tuple[str, str], List[Tuple[Any, int]]] = \
        defaultdict(list)
    batch_lo = 0
    mesh_shape = dict(mesh.shape)

    for pid, prog in enumerate(programs):
        ns = prog.name
        rename = {b: f"{ns}/{b}" for b in prog.buffers}
        for b, spec in prog.buffers.items():
            new = rename[b]
            if new in buffers:  # unreachable given the name check; belt+braces
                raise ScheduleError(f"buffer alias {new!r}")
            buffers[new] = dataclasses.replace(spec, name=new)

        memo: Dict[int, Any] = {}

        def rn(d, _rename=rename, _pid=pid, _lo=batch_lo, _memo=memo,
               _ns=ns):
            got = _memo.get(id(d))
            if got is not None:
                return got
            if isinstance(d, KernelDesc):
                new = dataclasses.replace(
                    d, reads=tuple(_rename[r] for r in d.reads),
                    writes=tuple(_rename[w] for w in d.writes), pid=_pid)
            elif isinstance(d, SendDesc):
                new = dataclasses.replace(d, buf=_rename[d.buf], pid=_pid)
            elif isinstance(d, RecvDesc):
                new = dataclasses.replace(d, buf=_rename[d.buf], pid=_pid)
            elif isinstance(d, CollDesc):
                new = dataclasses.replace(d, buf=_rename[d.buf],
                                          out=_rename[d.out], pid=_pid)
            elif isinstance(d, StartDesc):
                new = dataclasses.replace(d, batch=d.batch + _lo, pid=_pid)
            elif isinstance(d, WaitDesc):
                new = dataclasses.replace(d, batch=d.batch + _lo, pid=_pid)
            else:
                raise ScheduleError(
                    f"program {_ns!r} holds an unknown descriptor {d!r}")
            _memo[id(d)] = new
            return new

        descs = [rn(d) for d in prog.descriptors]
        for b in prog.batches:
            renamed_channels = [dataclasses.replace(
                ch, src_buf=rename[ch.src_buf],
                dst_buf=rename[ch.dst_buf]) for ch in b.channels]
            gidx = b.index + batch_lo
            for s in b.open_sends:
                if s.remote not in names:
                    raise ScheduleError(
                        f"program {ns!r} sends to unknown program "
                        f"{s.remote!r} (composing {sorted(names)})")
                open_send_pool[(ns, s.remote)].append((rn(s), gidx))
            for r in b.open_recvs:
                if r.remote not in names:
                    raise ScheduleError(
                        f"program {ns!r} receives from unknown program "
                        f"{r.remote!r} (composing {sorted(names)})")
                open_recv_pool[(r.remote, ns)].append((rn(r), gidx))
            batches.append(Batch(
                index=gidx,
                kernels_before=[rn(k) for k in b.kernels_before],
                channels=renamed_channels,
                colls=[rn(c) for c in b.colls],
                waited=b.waited,
                pid=pid,
                plan=None,          # (re)derived below, links included
                coalesce=b.coalesce or b.plan is not None,
            ))
        subs.append(SubProgram(
            name=ns, pid=pid, buffers=tuple(rename.values()),
            n_iters=prog.n_iters, until=prog.until,
            batch_lo=batch_lo, n_batches=prog.n_batches,
        ))
        per_prog_segments.append(_segments(descs))
        batch_lo += prog.n_batches

    # -- cross-program matching (links) ------------------------------------
    pid_of_name = {s.name: s.pid for s in subs}
    batch_by_index = {b.index: b for b in batches}
    links_meta: List[Link] = []
    link_sites: List[Optional[str]] = []  # recv-side provenance per link
    for pair in sorted(set(open_send_pool) | set(open_recv_pool)):
        src_name, dst_name = pair
        try:
            matched = match_cross_program(
                open_send_pool.get(pair, []), open_recv_pool.get(pair, []),
                dst_pid=pid_of_name[dst_name])
        except MatchError as e:
            raise ScheduleError(
                f"cross-program matching {src_name!r} -> {dst_name!r} "
                f"failed: {e}") from e
        for ch, src_batch, dst_batch in matched:
            # the channel executes at the *sender's* trigger: it joins
            # the sender's batch (after the batch's own channels); the
            # receiver's batch records the deposited buffer so its wait
            # gates it (per-pid boundary: trigger side vs wait side)
            batch_by_index[src_batch].channels.append(ch)
            db = batch_by_index[dst_batch]
            db.cross_recv_bufs = db.cross_recv_bufs + (ch.dst_buf,)
            links_meta.append(Link(
                src=src_name, dst=dst_name, tag=ch.tag,
                src_batch=src_batch, dst_batch=dst_batch,
                dst_buf=ch.dst_buf))
            link_sites.append(ch.recv_site)

    if links is not None:
        declared = {tuple(p) for p in links}
        realized = {(l.src, l.dst) for l in links_meta}
        if declared != realized:
            raise ScheduleError(
                f"links= declares {sorted(declared)} but the programs' "
                f"remote descriptors realize {sorted(realized)}")

    # coalescing plans — and declared effect sets — re-derived now that
    # cross channels joined their trigger batches (per-batch, so two
    # programs' *triggers* never merge); staging identities re-stamped
    # per (batch, transfer) so no two trigger→wait windows share one
    for b in batches:
        if b.coalesce:
            b.plan = stamp_staging(
                coalesce_batch(b.channels, buffers, mesh_shape), b.index)
        b.effects = batch_effects(b)

    # -- link-aware interleaving -------------------------------------------
    # a link's trigger (sender's start segment) must be emitted before
    # the consumer's gating wait segment (the first wait at-or-after the
    # receiving batch — completion counters are cumulative)
    start_seg: Dict[Tuple[int, int], int] = {}
    waits_of: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
    for p, segs in enumerate(per_prog_segments):
        for si, seg in enumerate(segs):
            for d in seg:
                if isinstance(d, StartDesc):
                    start_seg[(p, d.batch)] = si
                elif isinstance(d, WaitDesc):
                    waits_of[p].append((d.batch, si))
    constraints: Dict[Tuple[int, int], set] = defaultdict(set)
    for l, l_site in zip(links_meta, link_sites):
        src_pid, dst_pid = pid_of_name[l.src], pid_of_name[l.dst]
        gate_si = next((si for wb, si in waits_of[dst_pid]
                        if wb >= l.dst_batch), None)
        if gate_si is None:
            # with no covering wait there is nothing to order the
            # deposit against: a consumer kernel could be interleaved
            # ahead of the sender's trigger and silently read stale data
            raise ScheduleError(
                f"program {l.dst!r} posts a remote receive (tag {l.tag}, "
                f"from {l.src!r}) in a batch with no following "
                f"enqueue_wait: the cross-program deposit could never be "
                f"observed deterministically"
                + (f" [receive enqueued at {l_site}]" if l_site else ""))
        constraints[(dst_pid, gate_si)].add(
            (src_pid, start_seg[(src_pid, l.src_batch)]))

    sched = STSchedule(
        buffers=buffers,
        descriptors=_interleave(per_prog_segments, constraints,
                                policy=_resolve_policy(interleave)),
        batches=tuple(batches),
        mesh=mesh,
        name=name or "+".join(names),
        n_iters=max(p.n_iters for p in programs),
        until=None,
        subs=tuple(subs),
        links=tuple(links_meta),
    )
    from .verify import run_verify  # local import: verify imports queue
    run_verify(sched, verify)
    return sched
