"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer,
meta tokens, mostly-SWA attention. [arXiv:2411.13676]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    source="arXiv:2411.13676",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    act="silu",
    rope_theta=10_000.0,
    sliding_window=1024,
    global_every=16,        # layers 16, 32 global (plus layer 1 in the paper)
    hybrid=True,
    n_meta_tokens=128,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,        # 2*1600/64 = 50 SSD heads
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=128,
    tie_embeddings=True,
    long_context_ok=True,   # SSM + SWA → long_500k runs
)
