"""Persistent ST engine — the device owns the iteration loop.

:class:`~repro.core.engine_fused.FusedEngine` offloads the control path
of one communication batch, but the *host* still re-dispatches the
program every iteration of a timed loop (N iterations → N dispatches).
The follow-up work on fully offloaded stream triggering moves the whole
loop onto the device: the host enqueues once, and a device-resident
sequencer re-runs trigger → communicate → wait → compute until the
iteration count (or a convergence predicate) says stop.

This engine is that execution model for an :class:`STProgram`: the
fused interpreter (:func:`~repro.core.engine_fused._interpret_program`)
runs inside an on-device ``jax.lax.fori_loop`` whose carry holds

* every program buffer (the Faces field ``u`` survives on-device across
  iterations — no host round-trip between them);
* the **trigger and completion counters**, threaded through every pass
  so the MPIX_Queue-reuse semantics of :mod:`.queue` hold literally:
  iteration i+1's thresholds sit above iteration i's counter values
  instead of restarting from zero;
* optionally a per-iteration scalar reduction (residual norms etc.), so
  convergence-style loops can report progress without a host sync.

Double buffering
----------------
In ``dataflow`` mode the wait gates only the buffers a batch received
into.  Message *slot* buffers (pure staging: packed faces out, received
faces in) are therefore the only serialization between iterations that
is not a real data dependency.  With ``double_buffer=True`` each slot
buffer gets two copies and iteration i uses copy ``i % 2``: combined
with ``unroll=2`` on the loop, iteration i+1's packs write slot B while
iteration i's waits still gate slot A, recovering the pack/wait overlap
a NIC-offloaded persistent queue gets from alternating DWQ entries.

The two copies are **zero-copy rotated**: the loop carry holds them as
separate ``(cur, alt)`` pytree leaves and each iteration returns
``(alt, written)`` — a pure reference swap.  No stacked ``[2, ...]``
slot arrays, no ``dynamic_update_index`` re-materialization per
iteration, and no parity arithmetic: after the loop the last write
always sits in the ``alt`` position (even under a predicate-terminated
``while_loop``, where the realized count is dynamic).

Slot safety is decided statically: a buffer is double-buffered only if
it is touched by a channel/collective and its first access in execution
order is a write (replace-mode deposits count as writes; add-mode
deposits accumulate across iterations and disqualify the buffer).

Convergence termination (``cond_fn`` / ``until``)
-------------------------------------------------
A convergence-style solver (the Nekbone/Faces regime) cannot know
``n_iters`` up front — the classic implementation round-trips a
residual to the host every iteration to decide when to stop, which is
exactly the host-in-the-control-path cost the ST model removes.  With
``cond_fn`` set (or ``STProgram.persistent(n, until=...)``), the fixed
``fori_loop`` becomes a ``jax.lax.while_loop``:

* each iteration evaluates ``reduce_fn`` (required) into a scalar and
  feeds it to ``cond_fn(reduction) -> bool``; the loop continues while
  the predicate holds (e.g. ``residual >= tol``), bounded by
  ``max_iters``.  The first iteration always runs (there is no
  reduction to test before it).
* double buffering needs no parity bookkeeping: the ``(cur, alt)``
  rotation leaves the last realized write in the ``alt`` carry position
  regardless of how many iterations the predicate allowed (a
  ``while_loop`` has no induction variable and no static unroll, but
  the rotation is induction-free anyway).
* ``__call__`` returns ``(mem, reductions, n_done)``: the reduction
  trace padded with zeros to ``max_iters`` plus the realized iteration
  count — still ONE host dispatch and zero host syncs until converged.

Multi-queue schedules (``STSchedule``)
--------------------------------------
A composed :class:`~repro.core.schedule.STSchedule` (see
:func:`repro.core.schedule.compose`) runs here too — N concurrent
queues' persistent loops fused into ONE host dispatch.  The loop carry
banks the trigger/completion counters *per program*, and per-program
iteration counts / termination predicates are honored by a masked
``while_loop``: each iteration interprets the whole interleaved
program, then a per-program *active* flag decides whether that
program's buffers (and slot copies) take the new values or stay frozen
at the program's own termination point.  The loop runs until every
program's predicate has terminated (bounded by the max per-program
count), and ``__call__`` returns per-program reduction traces and
realized iteration counts — the device-resident equivalent of N
independent ``run_until_converged`` loops, in one dispatch, with each
queue's communication overlapping the others' compute.  Per-program
reductions are supplied as ``reduce_fns={sub_name: fn}``; each fn sees
the full (namespaced) buffer dict but must only read its own program's
buffers — a frozen program's buffers hold their converged values, but
cross-program reads would still observe in-flight state.

Schedules with **cross-program channels** (``compose(..., links=...)``)
run here unchanged: the interpreter banks each deposit's completion on
the *receiving* program's counter, and the masked loop composes with
links naturally — when a link's peer has already converged (inactive),
its descriptors still execute each pass, so its packs keep publishing
its FROZEN boundary to the still-active neighbors (deposits into the
frozen program's own buffers are discarded by its mask).  Linked
neighbors therefore see a converged part as a constant boundary
condition, not stale in-flight data.

The same masked-loop idiom also runs at *per-sequence* grain: the
serving engine (:class:`repro.launch.serve.ServeEngine`) decodes a
batch of requests as one resident ``while_loop`` whose per-sequence
active flags freeze a finished request's cache position (EOS/budget/
capacity termination) exactly as the per-program flags here freeze a
converged program's buffers — with masked per-slot *re-admission*
(``Model.select_slots``) layered on top for continuous batching.

Dispatch accounting
-------------------
``stats`` is a :class:`~repro.core.engine_host.HostStats`: one call =
one dispatch, zero host sync points, regardless of ``n_iters`` (or of
how many iterations a ``cond_fn`` loop realizes) — the contrast
:mod:`benchmarks.faces_bench` reports against the host
(``n_iters × dispatch_count_host()``) and fused (``n_iters × 1``)
engines.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Set, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from .descriptors import KernelDesc, StartDesc
from .engine_fused import FusedEngine, _interpret_program, fresh_token_banks
from .queue import STProgram
from .schedule import STSchedule


def slot_buffers(prog: STProgram) -> Tuple[str, ...]:
    """Statically identify message-slot buffers safe to double-buffer.

    A buffer qualifies when (a) a channel or collective touches it and
    (b) its first access in *execution* order is a write — so its value
    at iteration start never reaches the result.  Replace-mode channel
    deposits count as writes (non-receiving ranks preserve a value both
    slots share); add-mode deposits read the accumulator and disqualify.
    """
    comm_bufs: Set[str] = set()
    for b in prog.batches:
        for ch in b.channels:
            comm_bufs.add(ch.src_buf)
            comm_bufs.add(ch.dst_buf)
        for coll in b.colls:
            comm_bufs.add(coll.buf)
            comm_bufs.add(coll.out)

    first_access: Dict[str, str] = {}  # buffer -> "read" | "write"

    def see(buf: str, kind: str):
        first_access.setdefault(buf, kind)

    for d in prog.descriptors:
        if isinstance(d, KernelDesc):
            for r in d.reads:
                see(r, "read")
            for w in d.writes:
                see(w, "write")
        elif isinstance(d, StartDesc):
            batch = next(b for b in prog.batches if b.index == d.batch)
            for ch in batch.channels:
                see(ch.src_buf, "read")
            for coll in batch.colls:
                see(coll.buf, "read")
            for ch in batch.channels:
                see(ch.dst_buf, "read" if ch.mode == "add" else "write")
            for coll in batch.colls:
                see(coll.out, "write")

    return tuple(sorted(
        b for b in comm_bufs if first_access.get(b) == "write"
    ))


class PersistentEngine(FusedEngine):
    """Run an STProgram for ``n_iters`` iterations as ONE host dispatch.

    Inherits the buffer/compile surface (``shardings``, ``init_buffers``,
    ``compile``, ``lower``) from :class:`FusedEngine`; only the lowered
    body (the device-resident loop) and the dispatch accounting differ.

    Parameters
    ----------
    program:
        The matched program; ``program.n_iters`` (see
        :meth:`STProgram.persistent`) supplies the iteration count when
        ``n_iters`` is not given.
    n_iters:
        Device-resident iteration count (>= 1).  Values > 1 are subject
        to the same quiescence reuse-guard as ``STProgram.persistent``.
    mode:
        ``stream`` / ``dataflow`` — same ordering semantics as
        :class:`FusedEngine`, applied to every pass.
    double_buffer:
        Alternate message-slot copies between iterations (default: on in
        ``dataflow`` mode).  The loop is unrolled ×2 so consecutive
        iterations coexist in the loop body and XLA may overlap them.
    unroll:
        Explicit ``fori_loop`` unroll factor for the fixed-count
        persistent loop (a :mod:`repro.launch.tune` knob).  ``None``
        (default) derives it from ``double_buffer`` as above; the value
        never changes numerics, only how many iteration bodies XLA
        schedules together.
    reduce_fn:
        Optional ``fn(mem) -> scalar`` evaluated after every iteration
        *inside* the device loop (use ``jax.lax.psum`` over the mesh
        axes for a global value).  ``__call__`` then returns
        ``(mem, reductions)`` with ``reductions.shape == (n_iters,)`` —
        convergence traces without any host sync inside the loop.
        Required when ``cond_fn`` is set.
    cond_fn:
        Optional termination predicate ``fn(reduction) -> bool`` (e.g.
        ``lambda residual: residual >= tol``) evaluated on each
        iteration's reduction *inside* the device loop; the loop
        continues while it returns True, bounded by ``max_iters``.
        Defaults to ``program.until``.  ``__call__`` then returns
        ``(mem, reductions, n_done)`` with ``reductions`` zero-padded to
        ``max_iters`` and ``n_done`` the realized iteration count.
    max_iters:
        Safety bound for ``cond_fn`` loops (defaults to
        ``n_iters`` / ``program.n_iters``).  Only meaningful with a
        predicate.
    reduce_fns:
        Multi-queue only: per-sub-program reductions for a composed
        :class:`~repro.core.schedule.STSchedule`, keyed by sub-program
        name.  Required for every sub with an ``until`` predicate;
        optional for the rest (their traces are simply recorded).
        ``__call__`` then returns ``(mem, reductions, n_done)`` where
        ``reductions`` maps each reduced sub to its ``(max_iters,)``
        trace (zero-padded past the sub's realized count) and ``n_done``
        maps every sub to its realized iteration count.
    """

    def __init__(
        self,
        program: STProgram,
        n_iters: Optional[int] = None,
        mode: str = "stream",
        double_buffer: Optional[bool] = None,
        reduce_fn: Optional[Callable[[Dict[str, jax.Array]], jax.Array]] = None,
        cond_fn: Optional[Callable[[jax.Array], jax.Array]] = None,
        max_iters: Optional[int] = None,
        reduce_fns: Optional[Dict[str, Callable]] = None,
        donate: bool = False,
        coalesce: bool = True,
        sanitize: bool = False,
        unroll: Optional[int] = None,
    ):
        super().__init__(program, mode=mode, donate=donate, coalesce=coalesce,
                         sanitize=sanitize)
        self.reduce_fns: Dict[str, Callable] = dict(reduce_fns or {})

        if isinstance(program, STSchedule):
            # composed multi-queue schedule: iteration counts and
            # predicates are per-program (set via .persistent on each
            # program before compose); the global-loop knobs make no
            # sense here.
            for arg, nm in ((n_iters, "n_iters"), (reduce_fn, "reduce_fn"),
                            (cond_fn, "cond_fn"), (max_iters, "max_iters")):
                if arg is not None:
                    raise ValueError(
                        f"{nm} does not apply to a composed STSchedule: "
                        "iteration counts/predicates are per-program "
                        "(program.persistent(...) before compose) and "
                        "reductions go through reduce_fns={name: fn}")
            names = {s.name for s in program.subs}
            for nm in self.reduce_fns:
                if nm not in names:
                    raise ValueError(
                        f"reduce_fns names unknown sub-program {nm!r} "
                        f"(have {sorted(names)})")
            for s in program.subs:
                if s.until is not None and s.name not in self.reduce_fns:
                    raise ValueError(
                        f"sub-program {s.name!r} has an until-predicate "
                        f"but no reduce_fns[{s.name!r}] to evaluate it on")
            self.cond_fn = None
            self.reduce_fn = None
            self.n_iters = self.max_iters = max(
                s.n_iters for s in program.subs)
            # the masked while path is needed whenever the subs diverge
            # (different counts or any predicate) or traces are wanted
            self._schedule_while = (
                bool(self.reduce_fns)
                or any(s.until is not None for s in program.subs)
                or len({s.n_iters for s in program.subs}) > 1
            )
        else:
            if self.reduce_fns:
                raise ValueError(
                    "reduce_fns is for composed STSchedules; a plain "
                    "program takes the single reduce_fn")
            self._schedule_while = False
            self.cond_fn = cond_fn if cond_fn is not None else program.until
            if max_iters is not None and self.cond_fn is None:
                raise ValueError(
                    "max_iters is only meaningful with cond_fn/until")
            if max_iters is None:
                max_iters = program.n_iters if n_iters is None else n_iters
            self.n_iters = self.max_iters = int(max_iters)
            if self.n_iters < 1:
                raise ValueError(f"n_iters must be >= 1, got {self.n_iters}")
            if self.cond_fn is not None and reduce_fn is None:
                raise ValueError(
                    "cond_fn requires reduce_fn: the termination predicate "
                    "is evaluated on the per-iteration scalar reduction")
            # an explicit n_iters/cond_fn override must pass the same
            # quiescence reuse-guard STProgram.persistent() enforces
            # (raises QueueError)
            program.persistent(self.n_iters, until=self.cond_fn)
            self.reduce_fn = reduce_fn
        self.double_buffer = (mode == "dataflow") if double_buffer is None \
            else bool(double_buffer)
        self._slots: Tuple[str, ...] = (
            slot_buffers(program) if self.double_buffer else ()
        )
        # persistent-loop unroll (fori_loop path only): default pairs
        # consecutive iterations exactly when double buffering gives
        # them independent slots; an explicit value is a tuner knob
        # (repro.launch.tune) — numerics are unaffected either way.
        if unroll is not None and int(unroll) < 1:
            raise ValueError(f"unroll must be >= 1, got {unroll}")
        self.unroll = None if unroll is None else int(unroll)

    # (__call__ inherited: FusedEngine already counts one dispatch per
    # call — which here covers ALL n_iters iterations.)

    # -- lowering -------------------------------------------------------------

    def _build_jit(self):
        prog = self.program
        specs = {n: P(*s.pspec) for n, s in prog.buffers.items()}

        if self._schedule_while:
            out_specs = (specs,
                         {nm: P() for nm in self.reduce_fns},
                         {s.name: P() for s in prog.subs})
            body = functools.partial(
                _run_schedule_while,
                sched=prog,
                mode=self.mode,
                mesh_shape=self._mesh_shape,
                slots=self._slots,
                reduce_fns=self.reduce_fns,
                coalesce=self.coalesce,
                sanitize=self.sanitize,
            )
        elif self.cond_fn is not None:
            out_specs = (specs, P(), P())
            body = functools.partial(
                _run_persistent_while,
                prog=prog,
                mode=self.mode,
                mesh_shape=self._mesh_shape,
                max_iters=self.max_iters,
                slots=self._slots,
                reduce_fn=self.reduce_fn,
                cond_fn=self.cond_fn,
                coalesce=self.coalesce,
                sanitize=self.sanitize,
            )
        else:
            out_specs = (specs, P()) if self.reduce_fn is not None else specs
            body = functools.partial(
                _run_persistent,
                prog=prog,
                mode=self.mode,
                mesh_shape=self._mesh_shape,
                n_iters=self.n_iters,
                slots=self._slots,
                reduce_fn=self.reduce_fn,
                unroll=self.unroll if self.unroll is not None
                else (2 if (self.double_buffer and self.n_iters > 1) else 1),
                coalesce=self.coalesce,
                sanitize=self.sanitize,
            )
        sharded = shard_map(
            body, mesh=self.mesh, in_specs=(specs,), out_specs=out_specs,
            check_vma=False,
        )
        donate = (0,) if self.donate else ()
        return jax.jit(sharded, donate_argnums=donate)


# -- device-resident loop body (runs inside shard_map, traced once) ----------


def _run_persistent(
    mem: Dict[str, jax.Array],
    *,
    prog: STProgram,
    mode: str,
    mesh_shape: Dict[str, int],
    n_iters: int,
    slots: Tuple[str, ...],
    reduce_fn,
    unroll: int,
    coalesce: bool = True,
    sanitize: bool = False,
):
    mem = dict(mem)
    # two copies of each message slot, rotated zero-copy through the
    # carry: iteration i reads `cur` (the copy written at i-2) and its
    # write becomes the next iteration's `alt` — no stacked arrays, no
    # per-iteration dynamic_update copies.  Both copies start as the
    # same initial value (aliased, never materialized twice).
    cur_slots = {n: mem.pop(n) for n in slots}
    alt_slots = dict(cur_slots)
    tokens, comps = fresh_token_banks(prog)
    # None is an empty pytree node: no dead carry when reductions are off
    red = jnp.zeros((n_iters,), jnp.float32) if reduce_fn is not None else None

    def one_iter(i, carry):
        mem, cur_slots, alt_slots, tokens, comps, red = carry
        cur = dict(mem)
        cur.update(cur_slots)
        cur, tokens, comps = _interpret_program(
            cur, prog=prog, mode=mode, mesh_shape=mesh_shape,
            tokens=tokens, comp_tokens=comps, coalesce=coalesce,
            sanitize=sanitize)
        if reduce_fn is not None:  # sees every buffer, slots included
            val = jnp.asarray(reduce_fn(cur), jnp.float32).reshape(())
            red = jax.lax.dynamic_update_index_in_dim(red, val, i, axis=0)
        written = {n: cur.pop(n) for n in slots}
        return cur, alt_slots, written, tokens, comps, red

    mem, _, last_slots, tokens, comps, red = jax.lax.fori_loop(
        0, n_iters, one_iter,
        (mem, cur_slots, alt_slots, tokens, comps, red),
        unroll=unroll)

    # the rotation leaves the last iteration's writes in the alt carry
    mem.update(last_slots)
    if reduce_fn is not None:
        return mem, red
    return mem


def _run_persistent_while(
    mem: Dict[str, jax.Array],
    *,
    prog: STProgram,
    mode: str,
    mesh_shape: Dict[str, int],
    max_iters: int,
    slots: Tuple[str, ...],
    reduce_fn,
    cond_fn,
    coalesce: bool = True,
    sanitize: bool = False,
):
    """Predicate-terminated variant: ``lax.while_loop`` until
    ``cond_fn(reduction)`` goes False (or ``max_iters`` is hit).

    The carry threads the iteration counter explicitly (a while_loop has
    no induction variable) for the reduction-trace index; the slot
    rotation itself is induction-free, so the last realized write sits
    in the ``alt`` carry position however many iterations run.
    """
    mem = dict(mem)
    # zero-copy rotation, as in _run_persistent
    cur_slots = {n: mem.pop(n) for n in slots}
    alt_slots = dict(cur_slots)
    tokens, comps = fresh_token_banks(prog)
    red = jnp.zeros((max_iters,), jnp.float32)

    def cond(carry):
        i, keep_going, *_ = carry
        return jnp.logical_and(keep_going, i < max_iters)

    def body(carry):
        i, _, mem, cur_slots, alt_slots, tokens, comps, red = carry
        cur = dict(mem)
        cur.update(cur_slots)
        cur, tokens, comps = _interpret_program(
            cur, prog=prog, mode=mode, mesh_shape=mesh_shape,
            tokens=tokens, comp_tokens=comps, coalesce=coalesce,
            sanitize=sanitize)
        val = jnp.asarray(reduce_fn(cur), jnp.float32).reshape(())
        red = jax.lax.dynamic_update_index_in_dim(red, val, i, axis=0)
        written = {n: cur.pop(n) for n in slots}
        keep_going = jnp.asarray(cond_fn(val), jnp.bool_).reshape(())
        return i + 1, keep_going, cur, alt_slots, written, tokens, comps, red

    # the first iteration always runs: there is no reduction to test yet
    carry0 = (jnp.zeros((), jnp.int32), jnp.asarray(True),
              mem, cur_slots, alt_slots, tokens, comps, red)
    n_done, _, mem, _, last_slots, tokens, comps, red = jax.lax.while_loop(
        cond, body, carry0)

    # at least one iteration always ran, so the last realized write is
    # in the alt position — no dynamic parity selection needed
    mem.update(last_slots)
    return mem, red, n_done


def _run_schedule_while(
    mem: Dict[str, jax.Array],
    *,
    sched,
    mode: str,
    mesh_shape: Dict[str, int],
    slots: Tuple[str, ...],
    reduce_fns: Dict[str, Callable],
    coalesce: bool = True,
    sanitize: bool = False,
):
    """Multi-queue variant: every sub-program runs to its OWN iteration
    count / predicate inside one ``while_loop``.

    Each iteration interprets the whole interleaved schedule, then a
    per-program ``active`` flag masks the result: an inactive (already
    terminated) program's buffers, slot copies and reduction trace keep
    their frozen values, so its final state is bit-identical to an
    independent run of that program alone.  Slot double-buffering uses
    the same zero-copy ``(cur, alt)`` rotation as the single-program
    loops, masked per program: an active program's pair rotates, a
    frozen program's pair stays put — so every program's last realized
    write ends (and stays) in the ``alt`` position, and no per-program
    parity bookkeeping is needed.
    """
    subs = sched.subs
    max_iters = max(s.n_iters for s in subs)
    name_of_pid = {s.pid: s.name for s in subs}
    pid_of_buf = {b: s.pid for s in subs for b in s.buffers}

    mem = dict(mem)
    cur_slots = {n: mem.pop(n) for n in slots}
    alt_slots = dict(cur_slots)
    tokens, comps = fresh_token_banks(sched)
    reds = {nm: jnp.zeros((max_iters,), jnp.float32) for nm in reduce_fns}
    active0 = {s.name: jnp.asarray(True) for s in subs}
    ndone0 = {s.name: jnp.zeros((), jnp.int32) for s in subs}

    def act_of(active, buf):
        return active[name_of_pid[pid_of_buf[buf]]]

    def cond(carry):
        i, active, *_ = carry
        any_active = functools.reduce(jnp.logical_or, active.values())
        return jnp.logical_and(any_active, i < max_iters)

    def body(carry):
        i, active, ndone, mem, cur_slots, alt_slots, tokens, comps, reds = carry
        cur = dict(mem)
        cur.update(cur_slots)
        new, tokens, comps = _interpret_program(
            cur, prog=sched, mode=mode, mesh_shape=mesh_shape,
            tokens=tokens, comp_tokens=comps, coalesce=coalesce,
            sanitize=sanitize)

        # per-program reductions, realized counts and continue flags
        ndone = dict(ndone)
        reds = dict(reds)
        keep = {}
        for s in subs:
            act = active[s.name]
            val = None
            if s.name in reduce_fns:
                val = jnp.asarray(
                    reduce_fns[s.name](new), jnp.float32).reshape(())
                rec = jax.lax.dynamic_update_index_in_dim(
                    reds[s.name], val, i, axis=0)
                reds[s.name] = jnp.where(act, rec, reds[s.name])
            done = ndone[s.name] + act.astype(jnp.int32)
            ndone[s.name] = done
            k = jnp.logical_and(act, done < s.n_iters)
            if s.until is not None:
                k = jnp.logical_and(
                    k, jnp.asarray(s.until(val), jnp.bool_).reshape(()))
            keep[s.name] = k

        # masked state update: a terminated program's buffers freeze at
        # its own convergence point (the interpreter still ran them this
        # pass, but the results are discarded).  Slot pairs rotate only
        # while their program is active.
        new_cur, new_alt = {}, {}
        for n in slots:
            act = act_of(active, n)
            written = new.pop(n)
            new_cur[n] = jnp.where(act, alt_slots[n], cur_slots[n])
            new_alt[n] = jnp.where(act, written, alt_slots[n])
        out_mem = {
            n: jnp.where(act_of(active, n), new[n], mem[n]) for n in mem
        }
        return (i + 1, keep, ndone, out_mem, new_cur, new_alt,
                tokens, comps, reds)

    # the first iteration always runs for every program
    carry0 = (jnp.zeros((), jnp.int32), active0, ndone0,
              mem, cur_slots, alt_slots, tokens, comps, reds)
    _, _, ndone, mem, _, alt_slots, tokens, comps, reds = jax.lax.while_loop(
        cond, body, carry0)

    # every program's last realized write froze in the alt position
    mem.update(alt_slots)
    return mem, reds, ndone
