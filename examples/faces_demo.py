"""Faces demo: the paper's microbenchmark end-to-end (§V).

26-neighbor halo exchange of a 3-D spectral-element block on a 2×2×2
device grid — pre-post receives, Pallas pack kernels, one batched
trigger, overlap kernel, wait, unpack-add — run both as one fused ST
program and host-orchestrated, validated against the NumPy oracle.

Run:  PYTHONPATH=src python examples/faces_demo.py
"""
import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import numpy as np

from repro.core import (FacesConfig, FusedEngine, HostEngine,
                        build_faces_program, faces_oracle)
from repro.parallel import make_mesh

mesh = make_mesh((2, 2, 2), ("gx", "gy", "gz"))
# pack="pallas" exercises the halo_pack kernels (validated in tests); on
# this CPU container interpret-mode Pallas is slow, so the demo times the
# jnp pack path.
cfg = FacesConfig(grid=(2, 2, 2), points=(16, 16, 16), pack="jnp")
prog = build_faces_program(cfg, mesh)
print(f"Faces program: {len(prog.descriptors)} descriptors, "
      f"{prog.n_channels} channels (26 neighbors), "
      f"{prog.n_batches} trigger batch(es)")

u0 = np.random.RandomState(0).randn(2, 2, 2, 16, 16, 16).astype(np.float32)
ref = faces_oracle(u0, cfg)

N_ITER = 5
st = FusedEngine(prog, mode="stream")
mem = st.init_buffers({"u": u0})
t0 = time.perf_counter(); out = st(dict(mem)); out["u"].block_until_ready()
t_first = time.perf_counter() - t0
t0 = time.perf_counter()
for _ in range(N_ITER):
    out = st(dict(mem))
out["u"].block_until_ready()
t_st = (time.perf_counter() - t0) / N_ITER
np.testing.assert_allclose(np.asarray(out["u"]), ref, rtol=1e-4, atol=1e-4)
print(f"ST fused:  {t_st*1e3:8.2f} ms/iter (compile {t_first:.1f}s)  ✓ matches oracle")

host = HostEngine(prog, sync="every_op")
hmem = host.init_buffers({"u": u0})
host(dict(hmem))  # warm
host.stats.reset()
t0 = time.perf_counter()
for _ in range(N_ITER):
    hout = host(dict(hmem))
t_host = (time.perf_counter() - t0) / N_ITER
np.testing.assert_allclose(np.asarray(hout["u"]), ref, rtol=1e-4, atol=1e-4)
print(f"baseline:  {t_host*1e3:8.2f} ms/iter "
      f"({host.stats.dispatches//N_ITER} dispatches/iter, "
      f"{host.stats.sync_points//N_ITER} syncs/iter)"
      f"  ✓ matches oracle")
print(f"control-path offload speedup on this host: {t_host/t_st:.1f}×")
