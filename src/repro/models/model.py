"""Model facade: init / train-loss / prefill / decode for every arch.

One class serves all 10 assigned architectures; the config decides the
trunk (segments), frontend, caches and heads.  The launcher lowers
``train_step`` / ``prefill_step`` / ``serve_step`` built from these
methods under pjit with shardings resolved from the logical-axes pytree
this module returns alongside the params.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.parallel import act_shard
from . import transformer as tfm
from .frontends import apply_frontend, init_frontend, sinusoidal_positions
from .nn import (
    apply_embedding,
    apply_rmsnorm,
    apply_unembed,
    init_embedding,
    init_rmsnorm,
    init_unembed,
    param,
    unbox,
)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- params ---------------------------------------------------------------

    def init(self, key) -> Tuple[Dict, Dict]:
        """Returns (params, logical_axes) — two aligned pytrees."""
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        boxed: Dict[str, Any] = {
            "embed": init_embedding(ks[0], cfg),
            "decoder": tfm.init_stack(ks[1], cfg, decoder=True),
            "ln_final": init_rmsnorm(ks[2], cfg.d_model, jnp.dtype(cfg.param_dtype)),
            "unembed": init_unembed(ks[3], cfg),
        }
        if cfg.enc_dec:
            boxed["encoder"] = tfm.init_stack(ks[4], cfg, decoder=False)
            boxed["ln_enc"] = init_rmsnorm(ks[4], cfg.d_model,
                                           jnp.dtype(cfg.param_dtype))
        if cfg.frontend != "none":
            boxed["frontend"] = init_frontend(ks[5], cfg)
        if cfg.n_meta_tokens:
            boxed["meta"] = param(ks[6], (cfg.n_meta_tokens, cfg.d_model),
                                  (None, "embed"), jnp.dtype(cfg.param_dtype))
        if cfg.mtp_depth:
            boxed["mtp"] = {
                "proj": param(ks[7], (2 * cfg.d_model, cfg.d_model),
                              ("embed", "embed"), jnp.dtype(cfg.param_dtype)),
                "block": tfm.init_block(ks[7], cfg, "attn_mlp"),
                "ln": init_rmsnorm(ks[7], cfg.d_model, jnp.dtype(cfg.param_dtype)),
            }
        return unbox(boxed)

    def abstract_init(self) -> Tuple[Dict, Dict]:
        """(ShapeDtypeStruct params, logical axes) with zero allocation.

        The axes pytree is static python captured during the eval_shape
        trace (strings can't flow through eval_shape outputs)."""
        store = {}

        def f():
            p, a = self.init(jax.random.PRNGKey(0))
            store["axes"] = a
            return p

        params_sd = jax.eval_shape(f)
        return params_sd, store["axes"]

    # -- encoder (whisper) ------------------------------------------------------

    def _encode(self, params, audio_embeds):
        cfg = self.cfg
        x = apply_frontend(params["frontend"], audio_embeds, cfg)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]
        x, _, _ = tfm.apply_stack(params["encoder"], x, cfg, decoder=False,
                                  causal=False)
        return apply_rmsnorm(params["ln_enc"], x, cfg)

    # -- embedding of the decoder sequence --------------------------------------

    def _embed_tokens(self, params, tokens, *, prefix_embeds=None):
        cfg = self.cfg
        x = apply_embedding(params["embed"], tokens, cfg)
        parts = []
        if cfg.n_meta_tokens:
            meta = jnp.broadcast_to(
                params["meta"].astype(x.dtype)[None],
                (x.shape[0], cfg.n_meta_tokens, cfg.d_model))
            parts.append(meta)
        if prefix_embeds is not None:
            parts.append(prefix_embeds.astype(x.dtype))
        parts.append(x)
        x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else x
        if cfg.pos_embedding == "sinusoidal":
            x = x + sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]
        return x

    def _prefix_len(self) -> int:
        cfg = self.cfg
        n = cfg.n_meta_tokens
        if cfg.frontend == "vision":
            n += cfg.frontend_tokens
        return n

    # -- train forward ------------------------------------------------------------

    def loss(self, params, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        tokens = batch["tokens"]
        targets = batch["targets"]
        B, S = tokens.shape

        enc_out = None
        prefix = None
        if cfg.enc_dec:
            enc_out = self._encode(params, batch["audio_embeds"])
        if cfg.frontend == "vision":
            prefix = apply_frontend(params["frontend"], batch["vision_embeds"], cfg)

        x = self._embed_tokens(params, tokens, prefix_embeds=prefix)
        P = self._prefix_len()
        positions = jnp.arange(x.shape[1])
        x, _, aux = tfm.apply_stack(params["decoder"], x, cfg, decoder=True,
                                    causal=True, positions=positions,
                                    enc_out=enc_out)
        h = apply_rmsnorm(params["ln_final"], x, cfg)
        h_text = act_shard(h[:, P:], "batch", "seq", None)
        logits = apply_unembed(params["embed"], params.get("unembed", {}),
                               h_text, cfg)
        loss = _ce(logits, targets)
        metrics = {"ce": loss}
        if "lb_loss" in aux:
            metrics["lb_loss"] = aux["lb_loss"]
            loss = loss + 0.01 * aux["lb_loss"] / max(cfg.n_layers, 1)
        if cfg.mtp_depth:
            mtp_loss = self._mtp_loss(params, h_text, tokens, targets, positions[P:])
            metrics["mtp_loss"] = mtp_loss
            loss = loss + 0.3 * mtp_loss
        metrics["loss"] = loss
        return loss, metrics

    def _mtp_loss(self, params, h, tokens, targets, positions):
        """DeepSeek MTP depth-1: predict t+2 from [h_t ; emb(target_t)]."""
        cfg = self.cfg
        p = params["mtp"]
        emb_next = apply_embedding(params["embed"], targets, cfg)
        hcat = jnp.concatenate([h, emb_next.astype(h.dtype)], axis=-1)
        hm = jnp.einsum("bsd,de->bse", hcat, p["proj"].astype(h.dtype))
        hm, _, _ = tfm.apply_block(p["block"], hm, cfg, "attn_mlp",
                                   causal=True, positions=positions)
        hm = apply_rmsnorm(p["ln"], hm, cfg)
        logits = apply_unembed(params["embed"], params.get("unembed", {}),
                               hm[:, :-1], cfg)
        # target at depth 1 is token t+2 == targets shifted by one
        return _ce(logits, targets[:, 1:])

    def forward_logits(self, params, batch):
        """Full-sequence logits (no cache) — test/debug path."""
        cfg = self.cfg
        enc_out = (self._encode(params, batch["audio_embeds"])
                   if cfg.enc_dec else None)
        prefix = (apply_frontend(params["frontend"], batch["vision_embeds"], cfg)
                  if cfg.frontend == "vision" else None)
        x = self._embed_tokens(params, batch["tokens"], prefix_embeds=prefix)
        positions = jnp.arange(x.shape[1])
        x, _, _ = tfm.apply_stack(params["decoder"], x, cfg, decoder=True,
                                  causal=True, positions=positions,
                                  enc_out=enc_out)
        h = apply_rmsnorm(params["ln_final"], x, cfg)
        return apply_unembed(params["embed"], params.get("unembed", {}),
                             h[:, self._prefix_len():], cfg)

    # -- serving ----------------------------------------------------------------

    def init_caches(self, batch: int, max_len: int,
                    per_sequence: bool = False) -> Dict[str, Any]:
        """Zeroed decode caches.  With ``per_sequence=True`` the write
        position ``pos`` is a [batch] vector instead of a scalar — every
        cache slot sits at its own depth, which is what lets the
        continuous-batching serve path admit a new request into a freed
        slot while its neighbours are mid-generation."""
        cfg = self.cfg
        segs, pos = tfm.init_caches(cfg, batch, max_len)
        if per_sequence:
            pos = jnp.zeros((batch,), jnp.int32)
        out = {"segments": segs, "pos": pos}
        if cfg.enc_dec:
            out["enc_out"] = jnp.zeros(
                (batch, cfg.frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        return out

    def cache_axes(self, per_sequence: bool = False) -> Dict[str, Any]:
        out = {"segments": tfm.cache_logical_axes(self.cfg),
               "pos": ("batch",) if per_sequence else ()}
        if self.cfg.enc_dec:
            out["enc_out"] = ("batch", None, "act_embed")
        return out

    def select_slots(self, mask, new_caches, old_caches) -> Dict[str, Any]:
        """Per-slot cache merge: slot b takes ``new_caches`` where
        ``mask[b]`` and keeps ``old_caches`` otherwise.

        The serve-path analogue of the composed scheduler's per-program
        masked state update (:func:`repro.core.engine_persistent.
        _run_schedule_while`): each cache leaf's batch axis is looked up
        in :meth:`cache_axes` and the mask broadcast along it, so a
        frozen (still-decoding) slot's K/V, SSM state and position are
        untouched while an admitted slot takes the freshly prefilled
        values — zero-copy for XLA (a select, no gather/scatter)."""
        axes = self.cache_axes(
            per_sequence=getattr(old_caches["pos"], "ndim", 0) == 1)

        def sel(ax, n, o):
            b = ax.index("batch")
            shape = [1] * n.ndim
            shape[b] = mask.shape[0]
            return jnp.where(mask.reshape(shape), n, o)

        return jax.tree.map(
            sel, axes, new_caches, old_caches,
            is_leaf=lambda x: isinstance(x, tuple) and not any(
                hasattr(e, "shape") for e in x))

    def prefill(self, params, batch, caches, *, serve_window: int = 0):
        """Write the prompt into the caches; returns (last_logits, caches)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        enc_out = None
        prefix = None
        if cfg.enc_dec:
            enc_out = self._encode(params, batch["audio_embeds"])
        if cfg.frontend == "vision":
            prefix = apply_frontend(params["frontend"], batch["vision_embeds"], cfg)
        x = self._embed_tokens(params, tokens, prefix_embeds=prefix)
        pos = caches["pos"]
        positions = (jnp.arange(x.shape[1]) + pos if pos.ndim == 0
                     else jnp.arange(x.shape[1])[None] + pos[:, None])
        x, new_segs, _ = tfm.apply_stack(
            params["decoder"], x, cfg, decoder=True, causal=True,
            positions=positions, caches=caches["segments"],
            cache_pos=caches["pos"], serve_window=serve_window, enc_out=enc_out)
        h = apply_rmsnorm(params["ln_final"], x, cfg)
        logits = apply_unembed(params["embed"], params.get("unembed", {}),
                               h[:, -1:], cfg)[:, 0]
        out = {"segments": _merge_caches(caches["segments"], new_segs),
               "pos": caches["pos"] + x.shape[1]}
        if cfg.enc_dec:
            out["enc_out"] = enc_out
        return logits, out

    def decode_step(self, params, caches, token, *, serve_window: int = 0):
        """One-token decode against the cache.  token: [B] int32.

        ``caches["pos"]`` may be a scalar (whole batch at one depth) or
        a [B] vector (per-sequence slot depths — continuous batching)."""
        cfg = self.cfg
        pos = caches["pos"]
        x = apply_embedding(params["embed"], token[:, None], cfg)
        if cfg.pos_embedding == "sinusoidal":
            # sinusoidal embedding at the (traced) cache position(s)
            s = _sinusoid_at(pos, cfg.d_model, x.dtype)
            x = x + (s[None, None] if s.ndim == 1 else s[:, None])
        positions = pos[None] if pos.ndim == 0 else pos[:, None]
        x, new_segs, _ = tfm.apply_stack(
            params["decoder"], x, cfg, decoder=True, causal=True,
            positions=positions, caches=caches["segments"],
            cache_pos=caches["pos"], serve_window=serve_window,
            enc_out=caches.get("enc_out"))
        h = apply_rmsnorm(params["ln_final"], x, cfg)
        logits = apply_unembed(params["embed"], params.get("unembed", {}),
                               h, cfg)[:, 0]
        out = dict(caches)
        out["segments"] = _merge_caches(caches["segments"], new_segs)
        out["pos"] = caches["pos"] + 1
        return logits, out

    # -- dry-run input specs -------------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
            }
            if shape.kind == "train":
                specs["targets"] = jax.ShapeDtypeStruct((B, S), i32)
            if cfg.enc_dec:
                specs["audio_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.dtype(cfg.dtype))
            if cfg.frontend == "vision":
                specs["vision_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.dtype(cfg.dtype))
            return specs
        # decode: one token against a seq_len cache
        return {"token": jax.ShapeDtypeStruct((B,), i32)}


def _merge_caches(old_segs: List, new_segs: List) -> List:
    out = []
    for o, n in zip(old_segs, new_segs):
        if not n:
            out.append(o)
        else:
            merged = dict(o)
            for k, v in n.items():
                merged[k] = v
            out.append(merged)
    return out


def _sinusoid_at(pos, d, dtype):
    """pos scalar → [d]; pos [B] (per-sequence depths) → [B, d]."""
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32)[..., None] / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _ce(logits, targets):
    lg = act_shard(logits.astype(jnp.float32), "batch", "seq", "act_vocab")
    lse = act_shard(jax.nn.logsumexp(lg, axis=-1), "batch", "seq")
    gold = act_shard(
        jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0],
        "batch", "seq")
    return jnp.mean(lse - gold)
