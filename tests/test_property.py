"""Hypothesis property tests on the system's invariants."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.descriptors import (
    GridOffsetPeer,
    OffsetPeer,
    RecvDesc,
    SendDesc,
    perm_for,
)
from repro.core.matching import MatchError, match_batch
from repro.parallel import RULES_DECODE, RULES_TRAIN, logical_spec_sized

SETTINGS = settings(max_examples=60, deadline=None,
                    suppress_health_check=[HealthCheck.too_slow])


# -- matching: every well-posed batch matches completely ----------------------

peer_st = st.one_of(
    st.builds(OffsetPeer,
              axis=st.sampled_from(["x", "y"]),
              delta=st.integers(-3, 3).filter(lambda d: d != 0),
              periodic=st.booleans()),
    st.builds(lambda dx, dy, p: GridOffsetPeer(("x", "y"), (dx, dy), p),
              st.integers(-2, 2), st.integers(-2, 2),
              st.booleans()).filter(lambda g: any(g.deltas)),
)


@SETTINGS
@given(st.lists(st.tuples(peer_st, st.integers(0, 5)), min_size=1, max_size=12))
def test_matching_total_when_recvs_mirror_sends(pairs):
    sends = [SendDesc(f"s{i}", p, tag=t) for i, (p, t) in enumerate(pairs)]
    recvs = [RecvDesc(f"r{i}", p.inverse(), tag=t)
             for i, (p, t) in enumerate(pairs)]
    chans = match_batch(sends, recvs)
    assert len(chans) == len(sends)
    # every send buffer appears exactly once as a channel source
    assert sorted(c.src_buf for c in chans) == sorted(s.buf for s in sends)


@SETTINGS
@given(st.lists(st.tuples(peer_st, st.integers(0, 5)), min_size=1, max_size=8),
       st.integers(0, 7))
def test_matching_incomplete_always_raises(pairs, drop_idx):
    sends = [SendDesc(f"s{i}", p, tag=t) for i, (p, t) in enumerate(pairs)]
    recvs = [RecvDesc(f"r{i}", p.inverse(), tag=t)
             for i, (p, t) in enumerate(pairs)]
    del recvs[drop_idx % len(recvs)]
    with pytest.raises(MatchError):
        match_batch(sends, recvs)


# -- perms: permutations are always injective and in-range ---------------------


@SETTINGS
@given(peer_st, st.integers(1, 5), st.integers(1, 5))
def test_perm_injective_and_in_range(peer, nx, ny):
    shape = {"x": nx, "y": ny}
    if isinstance(peer, OffsetPeer):
        n = shape[peer.axis]
    else:
        n = nx * ny
    _, pairs = perm_for(peer, shape)
    srcs = [s for s, _ in pairs]
    dsts = [d for _, d in pairs]
    assert len(set(srcs)) == len(srcs)
    assert len(set(dsts)) == len(dsts)
    assert all(0 <= s < n and 0 <= d < n for s, d in pairs)


# -- sharding: resolved specs always divide the shape ---------------------------

AXES_POOL = [None, "batch", "seq", "embed", "heads", "kv_heads", "mlp",
             "vocab", "expert", "layers", "cache_seq"]


@SETTINGS
@given(st.lists(st.tuples(st.sampled_from(AXES_POOL),
                          st.integers(1, 4096)),
                min_size=1, max_size=5),
       st.sampled_from(["train", "decode"]))
def test_logical_spec_sized_always_divides(dims, regime):
    import jax
    from repro.parallel import make_mesh

    rules = RULES_TRAIN if regime == "train" else RULES_DECODE
    # a fake 16x16-shaped mesh over 1 device via abstract mesh:
    mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    shape = tuple(d for _, d in dims)
    axes = tuple(a for a, _ in dims)
    spec = logical_spec_sized(shape, axes, rules, mesh)
    sizes = dict(mesh.shape)
    used = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))):
        if entry is None:
            continue
        names = (entry,) if isinstance(entry, str) else entry
        total = int(np.prod([sizes[n] for n in names]))
        assert dim % total == 0, (shape, axes, spec)
        used.extend(names)
    # no mesh axis may shard two different dims
    assert len(used) == len(set(used))
