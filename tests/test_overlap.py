"""core/overlap.py — decomposed collectives vs their jax.lax references.

Fast lane: single-device trivial paths (axis size 1 short-circuits) and
the `triggered` ST wrapper.  Slow lane: per-collective subprocess tests
on an 8-device mesh (finer-grained than the combined check in
tests/test_distributed.py, so a regression names the exact collective).
"""

import numpy as np
import pytest


def _smap1(f, in_specs, out_specs):
    from repro.compat import jit_shard_map
    from repro.parallel import make_mesh

    mesh = make_mesh((1,), ("x",))
    return jit_shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs)


# -- trivial paths (fast, single device) --------------------------------------


def test_single_device_paths_are_identity():
    from functools import partial

    from jax.sharding import PartitionSpec as P

    from repro.core import overlap

    x = np.random.RandomState(0).randn(8, 4).astype(np.float32)
    for fn in (
        partial(overlap.all_gather_ring, axis="x"),
        partial(overlap.all_gather_ring, axis="x", bidirectional=False),
        partial(overlap.reduce_scatter_ring, axis="x"),
        partial(overlap.all_to_all_ppermute, axis="x"),
    ):
        got = _smap1(fn, (P("x"),), P("x"))(x)
        np.testing.assert_allclose(np.asarray(got), x, rtol=1e-6)


def test_all_gather_matmul_single_device():
    from functools import partial

    from jax.sharding import PartitionSpec as P

    from repro.core import overlap

    rng = np.random.RandomState(1)
    x = rng.randn(8, 4).astype(np.float32)
    w = rng.randn(4, 3).astype(np.float32)
    got = _smap1(partial(overlap.all_gather_matmul, axis="x"),
                 (P("x"), P()), P("x"))(x, w)
    np.testing.assert_allclose(np.asarray(got), x @ w, rtol=1e-5, atol=1e-5)


def test_triggered_wrapper_preserves_values():
    import jax.numpy as jnp

    from repro.core import fresh_token, overlap

    token = fresh_token()
    fn = overlap.triggered(lambda v: v * 2.0, token)
    out = fn(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), [0.0, 2.0, 4.0, 6.0])


# -- 8-device references (subprocess, slow lane) ------------------------------

_PRELUDE = """
import numpy as np, jax, jax.numpy as jnp
from functools import partial
from repro.compat import jit_shard_map
from repro.core import overlap
from repro.parallel import make_mesh
from jax.sharding import PartitionSpec as P
mesh = make_mesh((8,), ("x",))
def smap(f, in_specs, out_specs):
    return jit_shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs)
"""


def _check(subproc, code):
    r = subproc(_PRELUDE + code)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


@pytest.mark.slow
@pytest.mark.parametrize("bidirectional", [False, True])
def test_all_gather_ring_matches_lax(subproc, bidirectional):
    _check(subproc, f"""
x = np.random.RandomState(0).randn(32, 16).astype(np.float32)
got = smap(partial(overlap.all_gather_ring, axis="x",
                   bidirectional={bidirectional}), (P("x"),), P())(x)
want = smap(lambda v: jax.lax.all_gather(v, "x", axis=0, tiled=True),
            (P("x"),), P())(x)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
np.testing.assert_allclose(np.asarray(got), x, rtol=1e-6)
""")


@pytest.mark.slow
def test_reduce_scatter_ring_matches_lax(subproc):
    _check(subproc, """
x = np.random.RandomState(1).randn(32, 16).astype(np.float32)
got = smap(partial(overlap.reduce_scatter_ring, axis="x"),
           (P(None, None),), P("x"))(x)
want = smap(lambda v: jax.lax.psum_scatter(v, "x", scatter_dimension=0,
                                           tiled=True),
            (P(None, None),), P("x"))(x)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                           atol=1e-5)
""")


@pytest.mark.slow
def test_all_to_all_ppermute_matches_lax(subproc):
    _check(subproc, """
x = np.random.RandomState(2).randn(64, 4).astype(np.float32)
got = smap(partial(overlap.all_to_all_ppermute, axis="x"),
           (P("x"),), P("x"))(x)
want = smap(lambda v: jax.lax.all_to_all(v, "x", split_axis=0,
                                         concat_axis=0, tiled=True),
            (P("x"),), P("x"))(x)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
""")


@pytest.mark.slow
def test_overlapped_matmuls_match_references(subproc):
    _check(subproc, """
rng = np.random.RandomState(3)
x = rng.randn(32, 16).astype(np.float32)
w = rng.randn(16, 8).astype(np.float32)
got = smap(partial(overlap.all_gather_matmul, axis="x"),
           (P("x"), P()), P())(x, w)
np.testing.assert_allclose(np.asarray(got), x @ w, rtol=1e-4, atol=1e-5)

xk = rng.randn(32, 64).astype(np.float32)
wk = rng.randn(64, 8).astype(np.float32)
got = smap(partial(overlap.matmul_reduce_scatter, axis="x"),
           (P(None, "x"), P("x")), P("x"))(xk, wk)
np.testing.assert_allclose(np.asarray(got), xk @ wk, rtol=1e-4, atol=1e-4)
""")
