"""End-to-end training driver.

Runs real steps on the host mesh (CPU here; the same code path drives a
TPU slice — only the mesh differs).  Used by ``examples/train_tiny.py``
(≈100M params, a few hundred steps) and by integration tests.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \\
      --smoke --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Optional

import jax
import numpy as np

from repro.checkpoint import latest_step, restore_pytree, save_pytree
from repro.configs.base import ModelConfig, ShapeConfig, get_config
from repro.data.synthetic import SyntheticConfig, SyntheticTokens
from repro.launch.steps import build_train_step
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init


def train(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
          steps: int = 100, opt: Optional[AdamWConfig] = None,
          checkpoint_dir: Optional[str] = None,
          checkpoint_every: int = 0,
          log_every: int = 10,
          seed: int = 0):
    opt = opt or AdamWConfig(lr=1e-3)
    bundle = build_train_step(cfg, shape, mesh, opt=opt, total_steps=steps)
    model = bundle.model

    with mesh:
        jitted = jax.jit(bundle.step_fn, in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings,
                         donate_argnums=(0, 1))
        params, _ = model.init(jax.random.PRNGKey(seed))
        params = jax.device_put(params, bundle.in_shardings[0])
        opt_state = adamw_init(params, opt)
        opt_state = jax.device_put(opt_state, bundle.in_shardings[1])

        start = 0
        if checkpoint_dir and (ck := latest_step(checkpoint_dir)) is not None:
            params = restore_pytree(checkpoint_dir, ck, params)
            start = ck

        source = SyntheticTokens(cfg, shape, SyntheticConfig(seed=seed))
        history = []
        t0 = time.time()
        for step in range(start, steps):
            batch = source.device_batch(step, bundle.in_shardings[2])
            params, opt_state, metrics = jitted(params, opt_state, batch)
            if step % log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["wall_s"] = round(time.time() - t0, 2)
                history.append(m)
                print(f"step {step:5d} loss={m['loss']:.4f} "
                      f"ce={m.get('ce', 0):.4f} gnorm={m['grad_norm']:.3f} "
                      f"lr={m['lr']:.2e} t={m['wall_s']}s", flush=True)
            if (checkpoint_dir and checkpoint_every
                    and (step + 1) % checkpoint_every == 0):
                save_pytree(checkpoint_dir, step + 1, params)
        jax.block_until_ready(params)
    return params, opt_state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1x1", help="data x model, e.g. 2x2")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    shape = ShapeConfig("custom_train", args.seq, args.batch, "train")

    dm, tm = (int(x) for x in args.mesh.split("x"))
    n_needed = dm * tm
    if len(jax.devices()) < n_needed:
        raise SystemExit(
            f"need {n_needed} devices; run with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_needed}")
    from repro.parallel import make_mesh
    mesh = make_mesh((dm, tm), ("data", "model"))

    train(cfg, shape, mesh, steps=args.steps,
          opt=AdamWConfig(lr=args.lr),
          checkpoint_dir=args.checkpoint_dir,
          checkpoint_every=args.checkpoint_every)


if __name__ == "__main__":
    main()
