"""Mamba2 SSD chunked-scan Pallas kernel (state-space duality form).

TPU adaptation of the SSD algorithm (Dao & Gu, arXiv:2405.21060): the
sequence is processed in chunks; **within** a chunk the recurrence is
re-expressed as matmuls (MXU work), and **across** chunks only the
(P×N) state is carried:

    a_t   = A_h · dt_t                       (log decay, ≤ 0)
    cum_t = Σ_{u≤t} a_u                      (within chunk)
    Y_intra = ((C Bᵀ) ∘ L ∘ dt) X            L[t,u] = e^{cum_t−cum_u}·[t≥u]
    Y_state = (C ∘ e^{cum}) h_prevᵀ
    h_next  = e^{cum_L} h_prev + Xᵀ (B ∘ (e^{cum_L−cum}·dt))

Tiling: grid = (batch, heads, S/chunk); the chunk dimension is the
innermost, *sequential* grid axis, and the running state lives in VMEM
scratch that persists across grid steps (TPU grids execute serially).
All matmuls are (chunk×N)·(N×chunk), (chunk×chunk)·(chunk×P),
(P×chunk)·(chunk×N) — MXU-aligned when chunk, N, P are multiples of 128
(defaults: chunk 128, N 128, P 64⁺pad by wrapper).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
from repro.compat import tpu_compiler_params
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_body(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
              y_ref, hout_ref, h_scr, *, n_chunks: int, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[0, 0].astype(jnp.float32)  # [P, N]

    x = x_ref[0, :, 0].astype(jnp.float32)    # [chunk, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # [chunk]
    A = a_ref[0].astype(jnp.float32)          # scalar
    Bm = b_ref[0, :, 0].astype(jnp.float32)   # [chunk, N]
    C = c_ref[0, :, 0].astype(jnp.float32)    # [chunk, N]
    h_prev = h_scr[...]                        # [P, N]

    a = A * dt                                 # [chunk] (≤ 0)
    cum = jnp.cumsum(a)                        # [chunk]
    # decay matrix L[t,u] = exp(cum_t - cum_u) for t ≥ u
    diff = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)

    G = jax.lax.dot_general(C, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [chunk, chunk]
    G = G * L * dt[None, :]
    y_intra = jax.lax.dot_general(G, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # [chunk, P]

    c_decay = C * jnp.exp(cum)[:, None]        # [chunk, N]
    y_state = jax.lax.dot_general(c_decay, h_prev, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # [chunk, P]

    y_ref[0, :, 0] = (y_intra + y_state).astype(y_ref.dtype)

    cum_last = cum[-1]
    w = jnp.exp(cum_last - cum) * dt           # [chunk]
    h_inc = jax.lax.dot_general(x, Bm * w[:, None], (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [P, N]
    h_scr[...] = jnp.exp(cum_last) * h_prev + h_inc

    @pl.when(ic == n_chunks - 1)
    def _finish():
        hout_ref[0, 0] = h_scr[...]


def ssd_scan_call(
    x: jax.Array,    # [B, S, H, P]
    dt: jax.Array,   # [B, S, H]
    A: jax.Array,    # [H]
    Bm: jax.Array,   # [B, S, G, N]
    C: jax.Array,    # [B, S, G, N]
    *,
    init_state: Optional[jax.Array] = None,  # [B, H, P, N]
    chunk: int = 128,
    return_state: bool = False,
    interpret: bool = False,
):
    B, S, H, P = x.shape
    _, _, G, N = Bm.shape
    assert H % G == 0
    rep = H // G
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        # dt = 0 on padding → decay 1, input contribution 0 (state-safe)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (S + pad) // chunk
    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), jnp.float32)

    body = functools.partial(_ssd_body, n_chunks=n_chunks, chunk=chunk)
    y, h_last = pl.pallas_call(
        body,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c, _rep=rep: (b, c, h // _rep, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c, _rep=rep: (b, c, h // _rep, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S + pad, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, A, Bm, C, init_state)
    y = y[:, :S] if pad else y
    if return_state:
        return y, h_last
    return y
