"""Mixture-of-Experts layer with sort-based (dropping) token dispatch.

Expert-parallel design: experts shard over the ``model`` mesh axis; the
dispatch gather/scatter across the expert dimension is exactly the
paper's sparse-peer communication pattern (§DESIGN 4) — under pjit the
partitioner lowers it to all-to-all traffic on the expert axis, and the
ST benchmarks exercise the same pattern explicitly through
``overlap.all_to_all_ppermute``.  :func:`build_moe_dispatch_program`
expresses that exchange as a first-class ST program (one start gate of
staged trigger→wait channels, via
:mod:`repro.core.collectives`) so the dispatch composes/tunes/persists
with the rest of a step's schedule.

Routing flavours:
* ``softmax`` (grok-1): softmax over router logits, top-k, renormalized;
* ``sigmoid`` (deepseek-v3): sigmoid scores, top-k on score+bias
  (aux-free load balancing bias, a non-trained buffer), weights
  normalized over the selected experts and scaled by
  ``routed_scaling``.

Dispatch: tokens sort by expert id; each expert processes a fixed
capacity ``C = ceil(T·k/E · capacity_factor)`` (overflow drops — the
standard capacity model); gather → batched expert FFN → weighted
scatter-add.  All shapes static.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
from repro.compat import shard_map
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel import act_shard, current_ctx
from .nn import Boxed, param


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p = {
        "router": param(ks[0], (d, e), ("embed", "act_expert"), dt, scale=0.006),
        "wi": param(ks[1], (e, d, f), ("expert", "embed", "expert_mlp"), dt),
        "wo": param(ks[3], (e, f, d), ("expert", "expert_mlp", "embed"), dt,
                    scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }
    if cfg.act == "silu":
        p["wg"] = param(ks[2], (e, d, f), ("expert", "embed", "expert_mlp"), dt)
    if cfg.router == "sigmoid":
        # aux-free balancing bias — buffer, not a trained weight
        p["router_bias"] = param(ks[4], (e,), ("act_expert",), jnp.dtype("float32"),
                                 init="zeros")
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared_wi"] = param(ks[5], (d, fs), ("embed", "mlp"), dt)
        p["shared_wg"] = param(ks[5], (d, fs), ("embed", "mlp"), dt)
        p["shared_wo"] = param(ks[5], (fs, d), ("mlp", "embed"), dt,
                               scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1)))
    return p


def _route(p, x2d, cfg: ModelConfig):
    """x2d: [T, D] → (topk_idx [T,k], topk_w [T,k], router_probs [T,E])."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    if cfg.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        biased = scores + p["router_bias"][None, :]
        _, idx = jax.lax.top_k(biased, cfg.top_k)
        w = jnp.take_along_axis(scores, idx, axis=-1)
        w = w / (jnp.sum(w, -1, keepdims=True) + 1e-20)
        w = w * cfg.routed_scaling
        probs = scores / (jnp.sum(scores, -1, keepdims=True) + 1e-20)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
        w = w / (jnp.sum(w, -1, keepdims=True) + 1e-20)
    return idx, w, probs


def _expert_ffn(p, xin, cfg: ModelConfig):
    """xin: [E, C, D] → [E, C, D] (batched per-expert FFN)."""
    dt = xin.dtype
    h = jnp.einsum("ecd,edf->ecf", xin, p["wi"].astype(dt))
    if "wg" in p:
        g = jnp.einsum("ecd,edf->ecf", xin, p["wg"].astype(dt))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))


def apply_moe_ep(p, x, cfg: ModelConfig) -> Optional[Tuple[jax.Array, Dict]]:
    """Expert-parallel MoE via shard_map (perf iteration 2, §Perf).

    The auto-partitioned gather dispatch lets tokens reach experts
    across *data* shards, which XLA lowers to whole-activation
    all-gathers per MoE layer (observed: ~6e13 wire bytes/device for
    deepseek-v3 train_4k).  This path instead keeps dispatch LOCAL:

    * activations stay sharded over (pod, data) and replicated over
      ``model`` (they already are, under tensor parallelism);
    * experts shard over ``model``; every (data, model) shard routes its
      own tokens to its own expert block — zero dispatch communication;
    * one ``psum`` over ``model`` combines expert contributions — the
      same collective a dense TP MLP needs anyway.

    Capacity is per data-shard (C_loc = ceil(T_loc·k/E·cf)): statistics
    differ slightly from the global-capacity gather path (drops are
    per-shard), which is the standard expert-parallel trade.

    Returns None when inapplicable (no mesh ctx / indivisible experts);
    caller falls back to the gather path.
    """
    ctx = current_ctx()
    if ctx is None:
        return None
    rules, mesh = ctx
    if "model" not in mesh.axis_names:
        return None
    m = mesh.shape["model"]
    E = cfg.n_experts
    # E ≥ m: E_loc experts per shard.  E < m (grok: 8 experts over a
    # 16-way axis): split each expert's FFN dim over r = m/E ranks
    # ("virtual experts" — elementwise nonlinearity keeps partial-F
    # outputs correct, and the combine psum sums the F-parts).
    if E % m != 0 and m % E != 0:
        return None
    B, S, D = x.shape
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_b = 1
    for a in batch_axes:
        n_b *= mesh.shape[a]
    if B % n_b != 0:
        return None
    T_loc = (B // n_b) * S
    k = cfg.top_k
    E_loc = max(E // m, 1)
    n_rep = max(m // E, 1)
    F = cfg.d_ff_expert
    if F % n_rep != 0:
        return None
    C_loc = max(1, int(math.ceil(T_loc * k / E * cfg.capacity_factor)))

    def _virtualize_in(w):   # (E, D, F) → (E·r, D, F/r)
        if n_rep == 1:
            return w
        return w.reshape(E, D, n_rep, F // n_rep).transpose(0, 2, 1, 3) \
                .reshape(E * n_rep, D, F // n_rep)

    def _virtualize_out(w):  # (E, F, D) → (E·r, F/r, D)
        if n_rep == 1:
            return w
        return w.reshape(E, n_rep, F // n_rep, D).reshape(
            E * n_rep, F // n_rep, D)

    from jax.sharding import PartitionSpec as P

    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    x_spec = P(bspec, None, None)
    in_specs = (
        x_spec,
        P(None, None),                 # router (replicated)
        P("model", None, None),        # wi
        P("model", None, None),        # wg (or dummy)
        P("model", None, None),        # wo
        P(None),                       # router_bias
    )
    has_wg = "wg" in p

    def body(x_l, router, wi, wg_l, wo, rbias):
        Bl, Sl, _ = x_l.shape
        Tl = Bl * Sl
        x2 = x_l.reshape(Tl, D)
        pp = {"router": router, "router_bias": rbias}
        idx, w, probs = _route(pp, x2, cfg)

        # real-expert block of this rank (virtual-expert aware):
        # n_rep=1 → [rank·E_loc, …); n_rep>1 → {rank // n_rep}
        e0 = (jax.lax.axis_index("model") * E_loc) // n_rep
        flat_e = idx.reshape(Tl * k)
        flat_t = jnp.repeat(jnp.arange(Tl), k)
        flat_w = w.reshape(Tl * k)
        local_e = flat_e - e0
        mine = (local_e >= 0) & (local_e < E_loc)
        sort_key = jnp.where(mine, local_e, E_loc)      # strangers last
        order = jnp.argsort(sort_key, stable=True)
        se, st, sw = sort_key[order], flat_t[order], flat_w[order]
        counts = jnp.bincount(sort_key, length=E_loc + 1)[:E_loc]
        starts = jnp.cumsum(counts) - counts
        in_range = se < E_loc
        rank = jnp.arange(Tl * k) - starts[jnp.minimum(se, E_loc - 1)]
        keep = in_range & (rank < C_loc)
        slot = jnp.minimum(se, E_loc - 1) * C_loc + jnp.where(keep, rank, 0)
        slot_scatter = jnp.where(keep, slot, E_loc * C_loc)

        x_pad = jnp.concatenate([x2, jnp.zeros((1, D), x2.dtype)], axis=0)
        dispatch = jnp.full((E_loc * C_loc + 1,), Tl, dtype=jnp.int32).at[
            slot_scatter].set(jnp.where(keep, st, Tl))[:E_loc * C_loc]
        xin = x_pad[dispatch].reshape(E_loc, C_loc, D)

        dt = xin.dtype
        h = jnp.einsum("ecd,edf->ecf", xin, wi.astype(dt))
        if has_wg:
            g = jnp.einsum("ecd,edf->ecf", xin, wg_l.astype(dt))
            h = jax.nn.silu(g) * h
        else:
            h = jax.nn.gelu(h)
        yout = jnp.einsum("ecf,efd->ecd", h, wo.astype(dt))

        y_flat = yout.reshape(E_loc * C_loc, D)[slot]
        contrib = y_flat * (sw * keep).astype(y_flat.dtype)[:, None]
        y2 = jax.ops.segment_sum(contrib, st, num_segments=Tl)
        y2 = jax.lax.psum(y2, "model")                  # combine experts

        # balance stats (identical across model shards pre-psum; average
        # the drop/balance metrics over the data shards)
        frac_tokens = jnp.mean(
            (jax.nn.one_hot(idx, E).sum(1) > 0).astype(jnp.float32), axis=0)
        frac_probs = jnp.mean(probs, axis=0)
        lb = E * jnp.sum(frac_tokens * frac_probs)
        kept_mine = jnp.sum(keep.astype(jnp.float32))
        total_mine = jnp.sum(mine.astype(jnp.float32))
        dropped = 1.0 - kept_mine / jnp.maximum(total_mine, 1.0)
        dropped = jax.lax.pmean(jax.lax.pmean(dropped, "model"),
                                batch_axes) if batch_axes else dropped
        if batch_axes:
            lb = jax.lax.pmean(lb, batch_axes)
            frac_probs = jax.lax.pmean(frac_probs, batch_axes)
        return (y2.reshape(Bl, Sl, D).astype(x_l.dtype), lb, frac_probs,
                dropped)

    out_specs = (x_spec, P(), P(None), P())
    sm = shard_map(body, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    rbias = p.get("router_bias", jnp.zeros((E,), jnp.float32))
    wi_v = _virtualize_in(p["wi"])
    wg_v = _virtualize_in(p["wg"]) if has_wg else wi_v
    wo_v = _virtualize_out(p["wo"])
    y, lb, frac_probs, dropped = sm(x, p["router"], wi_v, wg_v, wo_v, rbias)

    if "shared_wi" in p:
        dt = x.dtype
        h = act_shard(jnp.einsum("bsd,df->bsf", x, p["shared_wi"].astype(dt)),
                      "batch", "seq", "act_mlp")
        g = act_shard(jnp.einsum("bsd,df->bsf", x, p["shared_wg"].astype(dt)),
                      "batch", "seq", "act_mlp")
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h,
                           p["shared_wo"].astype(dt))
    aux = {"lb_loss": lb, "router_probs_mean": frac_probs,
           "dropped_frac": dropped}
    return y, aux


def build_moe_dispatch_program(mesh, axis: str, n_experts: int,
                               capacity: int, d_model: int,
                               dtype=jnp.float32, *, verify: str = "warn",
                               name: str = "st_moe_dispatch"):
    """MoE all-to-all dispatch as a composable ST program.

    The expert-parallel dispatch exchange — every rank's sorted
    capacity buffer ``[E, C, D]`` (flattened to ``E*C`` rows, experts
    contiguous) sent so the block for expert ``e`` lands on the rank
    owning it — is exactly a tiled all-to-all over the expert rows.
    This builder expresses it through
    :func:`repro.core.collectives.build_all_to_all`: one start gate of
    n-1 staged trigger→wait channels, so the dispatch coalesces,
    STLints, prices under ``schedule_cost``, composes with other
    queues (expert FFN kernels ride inside the gate's trigger→wait
    window), and runs persistent.  Bit-identical to
    ``overlap.all_to_all_ppermute`` and ``lax.all_to_all`` (pure
    copies).

    The combine leg is the same exchange in reverse — the tiled a2a is
    an involution, so running the returned program a second time (or
    ``.persistent(2)``) routes expert outputs back to their source
    ranks.

    Returns a :class:`repro.core.collectives.CollectiveMatmul` whose
    ``inputs`` / ``output`` buffers are the flattened dispatch rows.
    """
    from repro.core import collectives

    n = dict(mesh.shape)[axis]
    if n_experts % n:
        raise ValueError(
            f"n_experts ({n_experts}) must divide by the {axis!r} axis "
            f"size ({n}) for expert-parallel dispatch")
    rows = n * n_experts * capacity  # global: every rank holds E*C rows
    return collectives.build_all_to_all(mesh, axis, rows, d_model, dtype,
                                        verify=verify, name=name)


def apply_moe(p, x, cfg: ModelConfig, *, capacity: Optional[int] = None
              ) -> Tuple[jax.Array, Dict]:
    """x: [B, S, D] → (y, aux) with aux = {"lb_loss", "router_probs_mean"}."""
    if cfg.moe_impl == "ep" and capacity is None:
        out = apply_moe_ep(p, x, cfg)
        if out is not None:
            return out
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    x2d = x.reshape(T, D)
    idx, w, probs = _route(p, x2d, cfg)

    if capacity is None:
        capacity = max(1, int(math.ceil(T * k / E * cfg.capacity_factor)))
    C = capacity

    # ---- sort-based dispatch ------------------------------------------------
    flat_e = idx.reshape(T * k)                       # expert of each assignment
    flat_t = jnp.repeat(jnp.arange(T), k)             # token of each assignment
    flat_w = w.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts              # [E]
    rank = jnp.arange(T * k) - starts[se]             # slot within expert
    keep = rank < C
    slot = se * C + jnp.where(keep, rank, 0)          # [T*k] (clamped)

    # gather tokens into expert buffers (padded with a zero row).
    # Dropped assignments scatter into a trash slot (index E*C) so they
    # can never clobber a kept entry's slot.
    x_pad = jnp.concatenate([x2d, jnp.zeros((1, D), x2d.dtype)], axis=0)
    slot_scatter = jnp.where(keep, slot, E * C)
    dispatch = jnp.full((E * C + 1,), T, dtype=jnp.int32).at[
        slot_scatter].set(jnp.where(keep, st, T))[:E * C]
    xin = act_shard(x_pad[dispatch].reshape(E, C, D), "act_expert", None, None)

    yout = act_shard(_expert_ffn(p, xin, cfg), "act_expert", None, None)

    # combine: weighted scatter-add back to tokens
    y_flat = yout.reshape(E * C, D)[slot]             # per-assignment output
    contrib = y_flat * (sw * keep).astype(y_flat.dtype)[:, None]
    y2d = jax.ops.segment_sum(contrib, st, num_segments=T)
    y = y2d.reshape(B, S, D).astype(x.dtype)

    # shared experts (dense path, always on)
    if "shared_wi" in p:
        dt = x.dtype
        h = act_shard(jnp.einsum("bsd,df->bsf", x, p["shared_wi"].astype(dt)),
                      "batch", "seq", "act_mlp")
        g = act_shard(jnp.einsum("bsd,df->bsf", x, p["shared_wg"].astype(dt)),
                      "batch", "seq", "act_mlp")
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h,
                           p["shared_wo"].astype(dt))

    # load-balance loss (Switch-style; deepseek uses the bias instead but
    # we report it for monitoring either way)
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(idx, E).sum(1) > 0).astype(jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(frac_tokens * frac_probs)
    aux = {"lb_loss": lb_loss, "router_probs_mean": frac_probs,
           "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return y, aux
