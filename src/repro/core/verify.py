"""STLint — static verification of triggered-op programs.

Once a DWQ of triggered operations is handed to the NIC nobody is
watching: a wait whose threshold is never reached hangs, a deposit
racing a not-yet-waited slot corrupts silently.  Our programs are
statically known at build time, so the checks the NIC cannot do at
runtime we can do *before* runtime: :func:`verify_program` symbolically
executes the per-program trigger/completion counter banks in stream
order — the exact order :func:`~repro.core.engine_fused
._interpret_program` executes — and emits structured
:class:`Diagnostic` records.

Wired in three places:

* ``STQueue.build(verify="warn")`` (default) and
  ``compose(..., verify="error")`` (default) run :func:`run_verify` on
  every built program;
* ``FusedEngine/PersistentEngine/HostEngine(..., sanitize=True)`` add
  the *runtime* sanitizer: unwritten message slots are poisoned with
  NaN canaries at pass start (a read-before-deposit turns into NaNs
  instead of silently-stale data) and deposit-before-wait ordering is
  asserted inside the interpreter (:class:`SanitizeError` at trace
  time);
* ``python -m repro.analysis`` lints every program the benchmarks
  build and prints a diagnostics table.

Diagnostics catalog
-------------------
ST001  deadlocked wait (error)
    *Meaning*: a ``WaitDesc`` gates a completion whose trigger is not
    emitted before it in stream order — the wait's threshold can never
    be reached.  Checks the program's own batches AND cross-program
    ``links`` (whole-schedule reachability, strictly stronger than the
    interleaver's local cycle test).
    *Example*: reordering a composed schedule so the receiver's gating
    wait precedes the sender's start.
    *Fix*: keep every trigger (start) ahead of the waits that observe
    it; let ``compose`` order linked segments.
ST002  wait before start (error)
    *Meaning*: more waits than starts have been emitted on a program's
    stream — the wait references a batch that was never triggered.
    *Example*: ``enqueue_wait()`` before any ``enqueue_start()``.
    *Fix*: trigger the batch first (also raised early as MatchError at
    enqueue/build time).
ST003  non-monotone thresholds (error)
    *Meaning*: a descriptor's trigger threshold is lower than one
    already enqueued — the DWQ counter contract (thresholds ride a
    monotonically increasing counter) is broken.
    *Example*: hand-mutating descriptors with swapped thresholds.
    *Fix*: let the queue assign thresholds; never renumber by hand.
ST004  untriggered communication (error)
    *Meaning*: a send/recv/collective appears after its program's last
    start gate — no trigger covers it, it can never fire.
    *Example*: ``enqueue_send`` with no following ``enqueue_start``.
    *Fix*: close the batch with ``enqueue_start()``.
ST005  unwaited completions at quiescence (warning; error if persistent)
    *Meaning*: a started batch's completions are never observed by a
    wait of the destination program.  One-shot programs merely leak an
    unobserved completion; persistent reuse of a non-quiescent queue
    drifts its counters across iterations (iteration i+1's thresholds
    race iteration i's in-flight completions — the fixed per-iteration
    counter offset the persistent engine relies on is lost).
    *Example*: a trailing ``enqueue_start`` with no ``enqueue_wait``.
    *Fix*: wait the final batch (completion counters are cumulative:
    one trailing wait covers every earlier batch).
ST006  deposited slot overwritten (warning)
    *Meaning*: a deposit lands in a buffer that still holds a pending
    *unwaited* deposit (replace-mode on either side, overlapping
    regions) — the first message is lost before anything could have
    observed it; a kernel write over a pending deposit is the same
    hazard.
    *Example*: two recvs into one buffer across two batches with no
    wait between them.
    *Fix*: wait the earlier batch, or deposit into distinct buffers /
    disjoint regions (add-mode deposits accumulate and are exempt).
ST007  slot read before wait (error)
    *Meaning*: a kernel (or a later batch's send/collective) reads a
    buffer with a pending unwaited deposit — the stream has not gated
    on the completion, so on real hardware the read races the NIC's
    deposit.  Reads inside the *same* batch as the deposit are exempt
    (the per-channel interpreter defines that order; coalescing
    declines such batches).
    *Example*: moving the unpack kernel ahead of the wait.
    *Fix*: wait the depositing batch before reading the slot.
ST008  coalesced staging-buffer aliasing (error)
    *Meaning*: a batch's :class:`~repro.core.matching.CoalescePlan` is
    internally inconsistent — segments overlap or leave gaps in a
    fused transfer's staging buffer, or a channel's route points at a
    segment of the wrong size/offset — so member payloads would alias.
    *Example*: hand-editing a plan's segment offsets.
    *Fix*: let ``coalesce_batch`` derive plans; never edit them.
ST009  cross-program buffer aliasing (error)
    *Meaning*: a descriptor of program A touches a buffer owned by
    program B without being a resolved cross-program channel — under
    composition no memory is shared, and slot rotation/donation of
    B's buffers would invalidate A's reference.
    *Example*: a hand-built schedule whose kernel reads another
    sub-program's buffer.
    *Fix*: exchange data through ``remote=`` channels, not shared
    buffers.
ST010  persistent accumulator drift (warning)
    *Meaning*: in a persistent (device-resident loop) program, an
    add-mode deposit targets a buffer no kernel ever rewrites — the
    accumulator grows across iterations, which also disqualifies the
    buffer from slot rotation.
    *Example*: ``enqueue_recv(buf, ..., mode="add")`` with no kernel
    resetting ``buf`` each pass.
    *Fix*: rewrite the buffer from fresh state each iteration, or make
    the accumulation intentional and document it.
ST011  dead channels not pruned (warning)
    *Meaning*: a batch that requested coalescing fell back to the
    per-channel path while holding statically-dead channels (empty
    permutation on this mesh) — every rank pays a collective that
    delivers zeros.
    *Example*: a 26-neighbor exchange on a collapsed mesh axis where
    coalescing declined the batch.
    *Fix*: restructure the batch so the coalescer accepts it (the plan
    prunes dead channels), or drop the dead descriptors.
ST012  open cross-program descriptors (error, engine time)
    *Meaning*: a program with unresolved ``remote=`` sends/recvs
    reached an engine — an open channel has no matching side and would
    hang.  Raised by ``STProgram.require_closed()`` (every engine
    calls it); at build time open descriptors are legal (compose
    resolves them) and are therefore not a build diagnostic.
    *Fix*: ``compose()`` the program with its peer(s) before running.
ST013  ring rotation hazard (error)
    *Meaning*: an in-place ring rotation (send and recv on the SAME
    buffer, replace mode — the descriptor spelling of
    ``buf = ppermute(buf, delta)`` used by the collective-matmul
    programs of :mod:`repro.core.collectives`) appears more than once
    for one buffer inside a single start gate.  Every channel of a gate
    reads the same pre-trigger value, so the second rotation does not
    see the first's deposit: the buffer advances one hop, not two, and
    a ring step is silently lost.
    *Example*: enqueueing two +1 rotations of the accumulator between
    one start/wait pair to "skip ahead" two ranks.
    *Fix*: one rotation per gate — give each ring step its own
    start/wait (or rotate by ``delta=2`` in one channel).
ST014  chunk-accumulator clobber (error)
    *Meaning*: a buffer is a ring accumulator — it receives add-mode
    deposits, or kernels that read AND write it (the
    ``acc = acc + piece(...)`` pattern of the ST reduce-scatter) — and
    a kernel REWRITES it without reading it strictly between the first
    and last accumulate events: the partial sum accumulated so far is
    discarded mid-ring.  Seed kernels before the first accumulate are
    the legitimate initialization and are exempt.
    *Example*: re-running the reduce-scatter seed kernel between two
    ring steps.
    *Fix*: seed once before the ring; mid-ring kernels must read the
    accumulator they update.

Happens-before rules (STProve)
------------------------------
Rules ST015-ST018 come from a different engine than the walk above:
:func:`build_happens_before` builds the partial order every legal
interleave policy must respect — per-pid program order, trigger →
deposit-window → gating-wait edges resolved through the counter banks,
cross-program link edges — and flags conflicting declared effects
(:mod:`repro.core.effects`) that the order leaves UNORDERED.  They
catch races the emitted-order walk cannot: a program whose emitted
stream happens to serialize two accesses still fails here if some
other legal merge of the same per-pid streams would not.

ST015  kernel/deposit race across pids (error)
    *Meaning*: a kernel's declared effect on a buffer has no
    happens-before ordering against another program's deposit into the
    same (overlapping) region — under some legal interleaving the
    kernel runs while the NIC owns the slot, even if the emitted order
    is safe.  Same-pid windows stay with ST006/ST007 (stream order
    within one pid is invariant under every policy).
    *Example*: reordering a composed schedule so a consumer kernel
    sits between the producer's start and the consumer's gating wait.
    *Fix*: order the kernel after the wait that observes the deposit.
ST016  WAR on a rotated slot (error)
    *Meaning*: in a persistent program, a read of a double-buffered
    message slot has NO write ordered before it in the pass while a
    cross-stream write races it: under ``(cur, alt)`` slot rotation
    the read may execute first and observe the stale alternate copy
    (iteration i-2's data), under any policy that merges the streams
    differently.
    *Example*: moving a kernel that reads a cross-deposited slot ahead
    of the slot's gating wait in a persistent composition.
    *Fix*: gate every slot read behind the wait observing the pass's
    depositing trigger.
ST017  staging-buffer reuse across overlapping windows (error)
    *Meaning*: two fused transfers *declare* the same staging-buffer
    identity (``CoalescedChannel.staging``) while their trigger→wait
    windows are unordered under happens-before — one pack may
    overwrite payloads the other transfer has not deposited yet.
    Build-time stamps (:func:`repro.core.effects.stamp_staging`) are
    unique per (batch, transfer), so this fires only on hand-built or
    mutated plans.
    *Example*: editing two batches' plans to share one staging name
    with no wait ordering the batches.
    *Fix*: let ``stamp_staging`` assign identities, or wait the first
    batch's completions before triggering the second.
ST018  donated-buffer read after rotation (error)
    *Meaning*: in a persistent program, a read of a rotated/donated
    slot is ordered after one of the pass's writes but races ANOTHER
    write of the same slot — after slot rotation/donation the read may
    observe either generation's copy depending on the interleaving.
    *Example*: two cross-program deposits into one slot with the
    consumer kernel gated on only the first.
    *Fix*: give each deposit generation its own slot, or gate the read
    on the wait observing the last write.
ST019  implicit kernel effects (warning)
    *Meaning*: ``enqueue_compute`` was called without ``reads=`` — the
    conservative reads-everything fallback is in force, which
    over-serializes the happens-before graph (every pending deposit
    looks like a race with this kernel) and hides the kernel's true
    footprint from the equivalence certifier.
    *Example*: ``queue.enqueue_compute(fn)`` with no effect keywords.
    *Fix*: declare ``reads=``/``writes=`` explicitly (in-repo builders
    are lint-enforced by ``scripts/lint_repo.py``).
"""

from __future__ import annotations

import dataclasses
import warnings
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .descriptors import (
    CollDesc,
    KernelDesc,
    RecvDesc,
    SendDesc,
    StartDesc,
    WaitDesc,
    perm_for,
)
from .effects import cross_gate_map

RULES: Dict[str, Tuple[str, str]] = {
    # rule id -> (default severity, one-line title)
    "ST001": ("error", "deadlocked wait: threshold unreachable from "
                       "triggers emitted before it"),
    "ST002": ("error", "wait before any matching start"),
    "ST003": ("error", "non-monotone trigger thresholds"),
    "ST004": ("error", "communication op not covered by a start gate"),
    "ST005": ("warning", "unwaited completions at quiescence"),
    "ST006": ("warning", "pending unwaited deposit overwritten"),
    "ST007": ("error", "slot read before its deposit is waited"),
    "ST008": ("error", "coalesced staging-buffer aliasing"),
    "ST009": ("error", "cross-program buffer aliasing"),
    "ST010": ("warning", "persistent accumulator drift"),
    "ST011": ("warning", "dead channels not pruned"),
    "ST012": ("error", "open cross-program descriptors at engine time"),
    "ST013": ("error", "ring rotation hazard: one buffer rotated twice "
                       "in a single start gate"),
    "ST014": ("error", "chunk-accumulator clobber: accumulator rewritten "
                       "without read mid-ring"),
    "ST015": ("error", "kernel/deposit race across pids: unordered under "
                       "happens-before"),
    "ST016": ("error", "WAR on a rotated slot: read may precede the "
                       "pass's first write under some interleaving"),
    "ST017": ("error", "staging-buffer reuse across overlapping "
                       "trigger-to-wait windows"),
    "ST018": ("error", "donated-buffer read after rotation races a "
                       "same-pass write"),
    "ST019": ("warning", "kernel enqueued with implicit (undeclared) "
                         "effects"),
}


class STLintWarning(UserWarning):
    """A warning-severity STLint diagnostic surfaced via ``warnings``."""


class VerifyError(RuntimeError):
    """Error-severity diagnostics under ``verify='error'`` policy."""

    def __init__(self, diagnostics):
        self.diagnostics = tuple(diagnostics)
        lines = "\n".join(f"  {d}" for d in self.diagnostics)
        super().__init__(
            f"STLint found {len(self.diagnostics)} error(s):\n{lines}")


class SanitizeError(RuntimeError):
    """Runtime-sanitizer ordering violation (``sanitize=True``)."""


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One STLint finding.

    ``index`` is the offending descriptor's position in
    ``program.descriptors`` (None for program-level findings such as a
    plan inconsistency); ``site`` is the enqueue-site provenance
    (``file:line``) captured on the descriptor, when available.
    """

    rule: str
    severity: str  # "error" | "warning"
    pid: int
    message: str
    index: Optional[int] = None
    site: Optional[str] = None
    program: str = ""

    def __str__(self) -> str:
        where = f" [enqueued at {self.site}]" if self.site else ""
        at = f" desc#{self.index}" if self.index is not None else ""
        return (f"[{self.rule}] {self.severity} pid={self.pid}{at}: "
                f"{self.message}{where}")


def run_verify(prog, policy: str = "warn") -> List[Diagnostic]:
    """Run the static pass under a policy: ``warn`` | ``error`` | ``off``.

    ``warn`` reports every diagnostic as an :class:`STLintWarning`;
    ``error`` raises :class:`VerifyError` if any error-severity
    diagnostic is found (warning-severity ones still warn); ``off``
    skips the pass entirely.  Returns the diagnostics found.
    """
    if policy == "off":
        return []
    if policy not in ("warn", "error"):
        raise ValueError(
            f"verify must be 'warn', 'error' or 'off', got {policy!r}")
    diags = verify_program(prog)
    if policy == "error":
        errors = [d for d in diags if d.severity == "error"]
        if errors:
            raise VerifyError(errors)
    for d in diags:
        warnings.warn(str(d), STLintWarning, stacklevel=3)
    return diags


def format_diagnostics(diags: List[Diagnostic]) -> str:
    """Plain-text table of diagnostics (the ``repro.analysis`` CLI)."""
    if not diags:
        return "  (clean: 0 diagnostics)"
    rows = [("rule", "severity", "pid", "site", "message")]
    for d in diags:
        rows.append((d.rule, d.severity, str(d.pid), d.site or "-",
                     d.message))
    widths = [max(len(r[i]) for r in rows) for i in range(4)]
    out = []
    for r in rows:
        head = "  ".join(c.ljust(w) for c, w in zip(r[:4], widths))
        out.append(f"  {head}  {r[4]}")
    return "\n".join(out)


# --------------------------------------------------------------------------
# The symbolic counter-bank walk
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _Pending:
    """One deposit whose completion has not been waited yet."""

    mode: str                       # replace | add
    gate_pid: int                   # whose wait observes it
    gate_batch: int                 # ...at-or-after this batch index
    region: Optional[Tuple]         # recv region (None = whole buffer)
    site: Optional[str]             # provenance of the depositing side
    index: Optional[int]            # stream position of the trigger


def _regions_overlap(a, b) -> bool:
    """Whether two recv regions may overlap (None = whole buffer)."""
    if a is None or b is None or a == b:
        return True
    try:
        for sa, sb in zip(tuple(a), tuple(b)):
            if not (isinstance(sa, slice) and isinstance(sb, slice)):
                return True  # fancy indexing: assume overlap
            a0, a1 = sa.start or 0, sa.stop
            b0, b1 = sb.start or 0, sb.stop
            if a1 is not None and b1 is not None and (a1 <= b0 or b1 <= a0):
                return False  # provably disjoint along this dim
    except TypeError:
        return True
    return True


# the cross-gate resolution is shared with the effect-trace layer
# (repro.core.effects) so the happens-before graph, the symbolic walk,
# the runtime sanitizer and the equivalence certifier all agree on
# which wait observes which cross-program deposit
_cross_gate_map = cross_gate_map


def _buffer_owner(prog) -> Dict[str, int]:
    return {buf: pid
            for pid, bufs in prog.buffers_by_pid().items() for buf in bufs}


def verify_program(prog) -> List[Diagnostic]:
    """Symbolically execute ``prog`` in stream order; return diagnostics.

    Mirrors the fused interpreter: per-pid trigger/completion counter
    banks advance at starts and waits while a pending-deposit table
    tracks every slot the NIC would still own.  See the module
    docstring for the rule catalog.
    """
    diags: List[Diagnostic] = []
    seen_keys = set()

    def diag(rule, pid, message, index=None, site=None, severity=None):
        key = (rule, pid, index, message)
        if key in seen_keys:
            return
        seen_keys.add(key)
        diags.append(Diagnostic(
            rule=rule, severity=severity or RULES[rule][0], pid=pid,
            message=message, index=index, site=site, program=prog.name))

    mesh_shape = dict(prog.mesh.shape)
    owner = _buffer_owner(prog)
    batches = {b.index: b for b in prog.batches}
    links = tuple(getattr(prog, "links", ()) or ())
    subs = getattr(prog, "subs", ())
    pid_of_name = {s.name: s.pid for s in subs}
    cross_gates = _cross_gate_map(prog)
    gate_cursor: Dict[Tuple[int, str], int] = defaultdict(int)

    def own_completions(b) -> bool:
        """Does batch ``b`` produce completions on its OWN counter bank?"""
        return bool(b.colls) or any(
            ch.dst_pid is None or ch.dst_pid == b.pid for ch in b.channels)

    # last start position per pid (ST004: comm descs after it are dead)
    last_start_pos: Dict[int, int] = {}
    for i, d in enumerate(prog.descriptors):
        if isinstance(d, StartDesc):
            last_start_pos[d.pid] = i

    starts_count: Dict[int, int] = defaultdict(int)
    waits_count: Dict[int, int] = defaultdict(int)
    last_thr: Dict[int, int] = defaultdict(int)
    started: set = set()            # global batch indices already triggered
    waited_upto: Dict[int, int] = defaultdict(lambda: -1)
    pending: Dict[str, List[_Pending]] = defaultdict(list)

    def check_read(buf, pid, index, site, what):
        for p in pending.get(buf, ()):
            diag("ST007", pid,
                 f"{what} reads {buf!r} while it holds a pending unwaited "
                 f"deposit (gated by pid {p.gate_pid}'s wait on batch "
                 f"{p.gate_batch})", index=index, site=site)

    def register_deposit(buf, mode, region, gate_pid, gate_batch, pid,
                         index, site):
        for p in pending.get(buf, ()):
            if (("replace" in (p.mode, mode))
                    and _regions_overlap(p.region, region)):
                diag("ST006", pid,
                     f"deposit into {buf!r} overwrites a pending unwaited "
                     f"deposit (message lost before pid {p.gate_pid} waits "
                     f"batch {p.gate_batch})", index=index, site=site)
        pending[buf].append(_Pending(mode=mode, gate_pid=gate_pid,
                                     gate_batch=gate_batch, region=region,
                                     site=site, index=index))

    for i, d in enumerate(prog.descriptors):
        pid = d.pid
        if isinstance(d, (SendDesc, RecvDesc, CollDesc)):
            if d.threshold >= 0 and d.threshold < last_thr[pid]:
                diag("ST003", pid,
                     f"threshold {d.threshold} below the program's already-"
                     f"enqueued maximum {last_thr[pid]} (DWQ counters are "
                     f"monotone)", index=i, site=d.site)
            last_thr[pid] = max(last_thr[pid], d.threshold)
            if i > last_start_pos.get(pid, -1):
                diag("ST004", pid,
                     f"{type(d).__name__} after the program's last start "
                     f"gate: no trigger covers it, it can never fire",
                     index=i, site=d.site)
            bufs = (d.buf, d.out) if isinstance(d, CollDesc) else (d.buf,)
            for buf in bufs:
                if owner.get(buf, pid) != pid:
                    diag("ST009", pid,
                         f"{type(d).__name__} touches {buf!r}, owned by pid "
                         f"{owner[buf]} (no shared memory under "
                         f"composition)", index=i, site=d.site)

        elif isinstance(d, KernelDesc):
            if getattr(d, "implicit_effects", False):
                diag("ST019", pid,
                     f"kernel {d.name!r} was enqueued without declared "
                     f"effects (enqueue_compute with no reads=): the "
                     f"conservative reads-everything fallback is in force, "
                     f"which over-serializes the happens-before analysis — "
                     f"declare reads=/writes= explicitly",
                     index=i, site=d.site)
            for r in d.reads:
                check_read(r, pid, i, d.site, f"kernel {d.name!r}")
            for w in list(d.reads) + list(d.writes):
                if owner.get(w, pid) != pid:
                    diag("ST009", pid,
                         f"kernel {d.name!r} touches {w!r}, owned by pid "
                         f"{owner[w]} (no shared memory under composition)",
                         index=i, site=d.site)
            for w in d.writes:
                for p in pending.get(w, ()):
                    diag("ST006", pid,
                         f"kernel {d.name!r} writes {w!r} over a pending "
                         f"unwaited deposit (message lost before pid "
                         f"{p.gate_pid} waits batch {p.gate_batch})",
                         index=i, site=d.site)

        elif isinstance(d, StartDesc):
            starts_count[pid] += 1
            batch = batches.get(d.batch)
            started.add(d.batch)
            if batch is None:
                continue
            # ST013: every channel of a gate reads the same pre-trigger
            # value, so a second in-place rotation of one buffer in the
            # same gate overwrites (not chains) the first — a ring hop
            # is silently lost
            rotations: Dict[str, int] = defaultdict(int)
            for ch in batch.channels:
                if ch.src_buf == ch.dst_buf and ch.mode == "replace":
                    rotations[ch.src_buf] += 1
            for rbuf, cnt in rotations.items():
                if cnt > 1:
                    diag("ST013", pid,
                         f"batch {d.batch} rotates {rbuf!r} in place {cnt} "
                         f"times under one start gate: rotations read the "
                         f"pre-trigger value, so only one hop survives — "
                         f"give each ring step its own start/wait",
                         index=i, site=d.site)
            # reads (packs) happen before this batch's own deposits land
            for ch in batch.channels:
                check_read(ch.src_buf, pid, i,
                           getattr(ch, "send_site", None) or d.site,
                           f"batch {d.batch}'s send")
                if owner.get(ch.src_buf, pid) != pid:
                    diag("ST009", pid,
                         f"channel sends {ch.src_buf!r}, owned by pid "
                         f"{owner[ch.src_buf]}", index=i, site=d.site)
                dpid = pid if ch.dst_pid is None else ch.dst_pid
                if owner.get(ch.dst_buf, dpid) != dpid:
                    diag("ST009", pid,
                         f"channel deposits into {ch.dst_buf!r}, owned by "
                         f"pid {owner[ch.dst_buf]} but completed on pid "
                         f"{dpid}'s bank", index=i, site=d.site)
            for coll in batch.colls:
                check_read(coll.buf, pid, i, coll.site,
                           f"batch {d.batch}'s collective")
            for ch in batch.channels:
                dpid = pid if ch.dst_pid is None else ch.dst_pid
                if dpid == pid:
                    gate = (pid, d.batch)
                else:
                    key = (d.batch, ch.dst_buf)
                    opts = cross_gates.get(key, [])
                    cur = gate_cursor[key]
                    gate = (opts[min(cur, len(opts) - 1)] if opts
                            else (dpid, d.batch))
                    gate_cursor[key] = cur + 1
                register_deposit(
                    ch.dst_buf, ch.mode, ch.recv_region, gate[0], gate[1],
                    pid, i, getattr(ch, "recv_site", None) or d.site)
            for coll in batch.colls:
                register_deposit(coll.out, "replace", None, pid, d.batch,
                                 pid, i, coll.site)

        elif isinstance(d, WaitDesc):
            waits_count[pid] += 1
            if waits_count[pid] > starts_count[pid]:
                diag("ST002", pid,
                     "wait before any matching start on this program's "
                     "stream", index=i, site=d.site)
                continue
            # ST001: every completion this wait gates must have its
            # trigger already emitted in stream order
            for b in prog.batches:
                if (b.pid == pid and b.index <= d.batch
                        and own_completions(b) and b.index not in started):
                    diag("ST001", pid,
                         f"wait on batch {d.batch} gates batch {b.index}'s "
                         f"completions, but batch {b.index}'s start is not "
                         f"emitted before it in stream order (threshold "
                         f"never reached: deadlock)", index=i, site=d.site)
            for l in links:
                if (pid_of_name.get(l.dst, -1) == pid
                        and l.dst_batch <= d.batch
                        and l.src_batch not in started):
                    diag("ST001", pid,
                         f"wait on batch {d.batch} gates the cross-program "
                         f"deposit from {l.src!r} (tag {l.tag}, trigger "
                         f"batch {l.src_batch}), whose start is not emitted "
                         f"before it in stream order (threshold never "
                         f"reached: deadlock)", index=i, site=d.site)
            waited_upto[pid] = max(waited_upto[pid], d.batch)
            for buf in list(pending):
                pending[buf] = [p for p in pending[buf]
                                if not (p.gate_pid == pid
                                        and p.gate_batch <= d.batch)]
                if not pending[buf]:
                    del pending[buf]

    # -- quiescence (ST005) -------------------------------------------------
    persistent = bool(getattr(prog, "is_persistent", False))
    sev5 = "error" if persistent else None
    why5 = ("persistent reuse of a non-quiescent queue: counters would "
            "not agree across iterations" if persistent
            else "its completion is never observed")
    for b in prog.batches:
        if b.index not in started:
            continue
        if own_completions(b) and waited_upto[b.pid] < b.index:
            diag("ST005", b.pid,
                 f"batch {b.index} is started but never waited — {why5}",
                 severity=sev5)
    for l in links:
        dpid = pid_of_name.get(l.dst, -1)
        if l.src_batch in started and waited_upto[dpid] < l.dst_batch:
            diag("ST005", dpid,
                 f"cross-program deposit from {l.src!r} into batch "
                 f"{l.dst_batch} is never waited by {l.dst!r} — {why5}",
                 severity=sev5)

    # -- persistent accumulator drift (ST010) --------------------------------
    if persistent:
        kernel_written = {w for d in prog.descriptors
                          if isinstance(d, KernelDesc) for w in d.writes}
        for b in prog.batches:
            for ch in b.channels:
                if ch.mode == "add" and ch.dst_buf not in kernel_written:
                    diag("ST010", b.pid,
                         f"add-mode deposit into {ch.dst_buf!r} with no "
                         f"kernel rewriting it: the accumulator grows "
                         f"across persistent iterations",
                         site=getattr(ch, "recv_site", None))

    # -- chunk-accumulator clobber (ST014) -----------------------------------
    # accumulate events per buffer, in descriptor order: add-mode
    # deposits (the start gate's position) and read+write kernels (the
    # ring accumulate pattern).  A kernel that REWRITES the buffer
    # without reading it strictly inside that span discards the partial
    # sum; the seed kernel before the first accumulate is exempt.
    acc_pos: Dict[Tuple[int, str], List[int]] = defaultdict(list)
    for i, d in enumerate(prog.descriptors):
        if isinstance(d, StartDesc):
            batch = batches.get(d.batch)
            if batch is None:
                continue
            for ch in batch.channels:
                if ch.mode == "add":
                    dpid = d.pid if ch.dst_pid is None else ch.dst_pid
                    acc_pos[(dpid, ch.dst_buf)].append(i)
        elif isinstance(d, KernelDesc):
            for w in d.writes:
                if w in d.reads:
                    acc_pos[(d.pid, w)].append(i)
    for (apid, buf), positions in acc_pos.items():
        if len(positions) < 2:
            continue
        lo, hi = positions[0], positions[-1]
        for i, d in enumerate(prog.descriptors):
            if (lo < i < hi and isinstance(d, KernelDesc) and d.pid == apid
                    and buf in d.writes and buf not in d.reads):
                diag("ST014", apid,
                     f"kernel {d.name!r} rewrites accumulator {buf!r} "
                     f"without reading it, between its accumulate steps "
                     f"(descriptor positions {lo}..{hi}): the partial sum "
                     f"is discarded mid-ring", index=i, site=d.site)

    # -- structural: dead channels (ST011) and plan consistency (ST008) -----
    for b in prog.batches:
        if b.coalesce and b.plan is None:
            for ch in b.channels:
                if not perm_for(ch.peer, mesh_shape)[1]:
                    diag("ST011", b.pid,
                         f"batch {b.index} declined coalescing while "
                         f"holding statically-dead channel "
                         f"{ch.src_buf!r}->{ch.dst_buf!r} (empty "
                         f"permutation: every rank pays a collective that "
                         f"delivers zeros)",
                         site=getattr(ch, "send_site", None))
        if b.plan is not None:
            _check_plan(b, diag)

    # -- happens-before race rules (ST015-ST018) ----------------------------
    _hb_rules(prog, diag)

    return diags


def _check_plan(b, diag) -> None:
    """ST008: a CoalescePlan's segments must tile each staging buffer
    exactly and every route must land on a segment of the right size."""
    plan = b.plan
    for ti, t in enumerate(plan.transfers):
        run = 0
        for seg in sorted(t.segments, key=lambda s: s.offset):
            if seg.offset != run:
                diag("ST008", b.pid,
                     f"batch {b.index} transfer {ti}: segment for channel "
                     f"{seg.channel} at offset {seg.offset} expected "
                     f"{run} (staging-buffer "
                     f"{'overlap' if seg.offset < run else 'gap'})")
                break
            run += seg.size
    for ci, route in enumerate(plan.routes):
        if not route:
            continue  # statically dead: deposits zeros, rides no transfer
        size = int(np.prod(plan.shapes[ci], dtype=np.int64))
        for hop, (ti, off) in enumerate(route):
            if not (0 <= ti < len(plan.transfers)):
                diag("ST008", b.pid,
                     f"batch {b.index} channel {ci} hop {hop} routes "
                     f"through nonexistent transfer {ti}")
                continue
            seg = next((s for s in plan.transfers[ti].segments
                        if s.channel == ci and s.hop == hop), None)
            if seg is None or seg.offset != off or seg.size != size:
                diag("ST008", b.pid,
                     f"batch {b.index} channel {ci} hop {hop}: route "
                     f"({ti}, {off}) does not match its segment "
                     f"(payload would alias a neighbor's slab)")


# --------------------------------------------------------------------------
# STProve: the happens-before analysis (rules ST015-ST018)
# --------------------------------------------------------------------------
#
# The symbolic walk above checks the *emitted* stream order — one
# particular merge of the per-program streams.  The happens-before
# graph checks every merge at once: its only ordering edges are the
# ones NO legal interleave policy may break —
#
#   * per-pid program order (each queue is FIFO by contract);
#   * trigger -> deposit -> completion -> gating-wait: a deposit is
#     modeled as a *window* node reachable from its StartDesc and
#     reaching the wait that observes its completion (resolved through
#     the same cross-gate map as the walk/sanitizer), nothing else —
#     between those two points the NIC owns the slot;
#   * cross-program links, which are exactly the window edges whose
#     gating wait lives on another pid's stream.
#
# Pack reads (send sources, collective inputs) attach to the StartDesc
# node itself: the engines pack at trigger, in stream order, under
# every policy.  Two conflicting effects with no happens-before path
# either way can race under SOME legal interleaving even if the
# emitted order happens to serialize them — that is what ST015-ST018
# report, and what "race-free under all interleavings" certifies.


@dataclasses.dataclass(frozen=True)
class _HBEffect:
    """One effect placed on a happens-before node."""

    node: int
    buf: str
    kind: str       # read | write | accum
    source: str     # kernel | pack | deposit
    pid: int        # triggering stream's pid
    region: Optional[Tuple]   # raw region (slices), None = whole buffer
    index: Optional[int]      # descriptor index for diagnostics
    site: Optional[str]


@dataclasses.dataclass(frozen=True)
class _HBTransfer:
    """One fused transfer's staging window (for ST017)."""

    staging: Optional[str]
    pid: int
    batch: int
    ti: int
    start_node: int
    gate_nodes: Tuple[Optional[int], ...]  # per member channel
    site: Optional[str]


class HappensBefore:
    """Reachability over the happens-before graph of one program.

    ``effects`` carries every declared memory access placed on a node;
    ``transfers`` the staging windows.  ``reaches(a, b)`` is transitive
    reachability (reflexive); ``ordered`` is reachability either way —
    two conflicting effects that are NOT ordered race under some legal
    interleaving.
    """

    def __init__(self, n_nodes: int, succ: Dict[int, List[int]],
                 effects: List[_HBEffect],
                 transfers: List[_HBTransfer]):
        self.n_nodes = n_nodes
        self.effects = effects
        self.transfers = transfers
        # bitmask fixpoint: reach[i] has bit j set iff i ->* j.  The
        # graph is a DAG whose edges mostly point forward in node id
        # (chains, start->window) with only window->gate-wait pointing
        # back, so a reverse-id sweep converges in a couple of rounds;
        # masks only grow, so the loop terminates regardless.
        reach = [1 << i for i in range(n_nodes)]
        changed = True
        while changed:
            changed = False
            for i in reversed(range(n_nodes)):
                r = reach[i]
                for j in succ.get(i, ()):
                    r |= reach[j]
                if r != reach[i]:
                    reach[i] = r
                    changed = True
        self._reach = reach

    def reaches(self, a: int, b: int) -> bool:
        return bool((self._reach[a] >> b) & 1)

    def ordered(self, a: int, b: int) -> bool:
        return self.reaches(a, b) or self.reaches(b, a)


def build_happens_before(prog) -> HappensBefore:
    """Build the happens-before graph + effect placement for ``prog``.

    Nodes are descriptor indices plus one virtual *window* node per
    (start, deposit) — see the section comment above for the edge set.
    """
    descs = prog.descriptors
    batches = {b.index: b for b in prog.batches}
    succ: Dict[int, List[int]] = defaultdict(list)

    last_by_pid: Dict[int, int] = {}
    for i, d in enumerate(descs):
        prev = last_by_pid.get(d.pid)
        if prev is not None:
            succ[prev].append(i)
        last_by_pid[d.pid] = i

    waits_by_pid: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
    for i, d in enumerate(descs):
        if isinstance(d, WaitDesc):
            waits_by_pid[d.pid].append((d.batch, i))

    def gate_wait_node(gpid: int, gbatch: int) -> Optional[int]:
        # completion counters are cumulative: the FIRST wait of the
        # gating pid at-or-after the gating batch observes the deposit
        for wb, wi in waits_by_pid.get(gpid, ()):
            if wb >= gbatch:
                return wi
        return None

    gates = cross_gate_map(prog)
    cursor: Dict[Tuple[int, str], int] = defaultdict(int)
    next_node = len(descs)
    effects: List[_HBEffect] = []
    transfers: List[_HBTransfer] = []

    for i, d in enumerate(descs):
        if isinstance(d, KernelDesc):
            for r in d.reads:
                effects.append(_HBEffect(i, r, "read", "kernel", d.pid,
                                         None, i, d.site))
            for w in d.writes:
                effects.append(_HBEffect(i, w, "write", "kernel", d.pid,
                                         None, i, d.site))
        elif isinstance(d, StartDesc):
            batch = batches.get(d.batch)
            if batch is None:
                continue
            # pack reads execute AT the trigger, in stream order
            for ch in batch.channels:
                effects.append(_HBEffect(
                    i, ch.src_buf, "read", "pack", d.pid, ch.send_region,
                    i, getattr(ch, "send_site", None) or d.site))
            for coll in batch.colls:
                effects.append(_HBEffect(i, coll.buf, "read", "pack",
                                         d.pid, None, i, coll.site))
            # deposits live on window nodes: start -> window -> gating wait
            ch_gate: Dict[int, Optional[int]] = {}
            for ci, ch in enumerate(batch.channels):
                dpid = d.pid if ch.dst_pid is None else ch.dst_pid
                if dpid == d.pid:
                    gate = (d.pid, d.batch)
                else:
                    key = (d.batch, ch.dst_buf)
                    opts = gates.get(key, [])
                    cur = cursor[key]
                    gate = (opts[min(cur, len(opts) - 1)] if opts
                            else (dpid, d.batch))
                    cursor[key] = cur + 1
                w = next_node
                next_node += 1
                succ[i].append(w)
                gw = gate_wait_node(*gate)
                if gw is not None:
                    succ[w].append(gw)
                ch_gate[ci] = gw
                effects.append(_HBEffect(
                    w, ch.dst_buf,
                    "accum" if ch.mode == "add" else "write", "deposit",
                    d.pid, ch.recv_region, i,
                    getattr(ch, "recv_site", None) or d.site))
            for coll in batch.colls:
                w = next_node
                next_node += 1
                succ[i].append(w)
                gw = gate_wait_node(d.pid, d.batch)
                if gw is not None:
                    succ[w].append(gw)
                effects.append(_HBEffect(w, coll.out, "write", "deposit",
                                         d.pid, None, i, coll.site))
            if batch.plan is not None:
                for ti, t in enumerate(batch.plan.transfers):
                    transfers.append(_HBTransfer(
                        staging=getattr(t, "staging", None), pid=d.pid,
                        batch=d.batch, ti=ti, start_node=i,
                        gate_nodes=tuple(ch_gate.get(s.channel)
                                         for s in t.segments),
                        site=d.site))

    return HappensBefore(next_node, succ, effects, transfers)


def _hb_rules(prog, diag) -> None:
    """Run the happens-before race rules, reporting through ``diag``."""
    hb = build_happens_before(prog)
    descs = prog.descriptors
    by_buf: Dict[str, List[_HBEffect]] = defaultdict(list)
    for e in hb.effects:
        by_buf[e.buf].append(e)

    def kname(e: _HBEffect) -> str:
        d = descs[e.index] if e.index is not None else None
        return getattr(d, "name", "?") if isinstance(d, KernelDesc) else "?"

    # -- ST015: kernel effect vs another pid's deposit, unordered ----------
    for buf, effs in by_buf.items():
        kernels = [e for e in effs if e.source == "kernel"]
        deposits = [e for e in effs if e.source == "deposit"]
        for ek in kernels:
            for ed in deposits:
                if ed.pid == ek.pid:
                    continue  # same-pid windows: ST006/ST007's walk owns it
                if not _regions_overlap(ek.region, ed.region):
                    continue
                if hb.ordered(ek.node, ed.node):
                    continue
                diag("ST015", ek.pid,
                     f"kernel {kname(ek)!r} {ek.kind}s {buf!r} with no "
                     f"happens-before ordering against pid {ed.pid}'s "
                     f"deposit into it: some legal interleaving runs the "
                     f"kernel while the NIC owns the slot",
                     index=ek.index, site=ek.site)

    # -- ST016 / ST018: rotated-slot hazards (persistent programs) ---------
    if getattr(prog, "is_persistent", False):
        from .engine_persistent import slot_buffers  # lazy: imports us back
        slots = set(slot_buffers(prog))
        for buf in slots:
            effs = by_buf.get(buf, [])
            writes = [e for e in effs if e.kind in ("write", "accum")]
            for r in (e for e in effs if e.kind == "read"):
                racing = [w for w in writes
                          if w.pid != r.pid and w.node != r.node
                          and _regions_overlap(w.region, r.region)
                          and not hb.ordered(w.node, r.node)]
                if not racing:
                    continue
                preceded = any(w.node != r.node
                               and hb.reaches(w.node, r.node)
                               for w in writes)
                w0 = racing[0]
                if not preceded:
                    diag("ST016", r.pid,
                         f"read of rotated slot {buf!r} has no write "
                         f"ordered before it this pass and races pid "
                         f"{w0.pid}'s write: under (cur, alt) slot "
                         f"rotation the read may observe the stale "
                         f"alternate copy", index=r.index, site=r.site)
                else:
                    diag("ST018", r.pid,
                         f"read of rotated slot {buf!r} is ordered after "
                         f"one write but races pid {w0.pid}'s later "
                         f"write of the same pass: after rotation/"
                         f"donation the read may observe either "
                         f"generation's copy", index=r.index, site=r.site)

    # -- ST017: declared staging identity shared across unordered windows --
    groups: Dict[str, List[_HBTransfer]] = defaultdict(list)
    for t in hb.transfers:
        if t.staging is not None:
            groups[t.staging].append(t)

    def retired_before(a: _HBTransfer, b: _HBTransfer) -> bool:
        """Every deposit of ``a`` is gated by a wait that happens-before
        ``b``'s trigger (so ``a``'s staging window is provably closed)."""
        return bool(a.gate_nodes) and all(
            g is not None and hb.reaches(g, b.start_node)
            for g in a.gate_nodes)

    for staging, ts in groups.items():
        for x in range(len(ts)):
            for y in range(x + 1, len(ts)):
                t1, t2 = ts[x], ts[y]
                if retired_before(t1, t2) or retired_before(t2, t1):
                    continue
                diag("ST017", t2.pid,
                     f"staging buffer {staging!r} is shared by transfers "
                     f"of batches {t1.batch} and {t2.batch} whose "
                     f"trigger-to-wait windows are unordered under "
                     f"happens-before: one pack may overwrite payloads "
                     f"the other transfer has not deposited yet",
                     index=t2.start_node, site=t2.site)


def hb_race_diagnostics(prog) -> List[Diagnostic]:
    """Just the happens-before race rules (ST015-ST018) over ``prog``.

    The equivalence certifier (:func:`repro.core.effects
    .certify_equivalence`) and the ``repro.analysis`` certificate
    summary call this directly — a certified-equivalent candidate must
    also be race-free under every interleaving.
    """
    diags: List[Diagnostic] = []
    seen = set()

    def diag(rule, pid, message, index=None, site=None, severity=None):
        key = (rule, pid, index, message)
        if key in seen:
            return
        seen.add(key)
        diags.append(Diagnostic(
            rule=rule, severity=severity or RULES[rule][0], pid=pid,
            message=message, index=index, site=site, program=prog.name))

    _hb_rules(prog, diag)
    return diags


# --------------------------------------------------------------------------
# Runtime sanitizer support (engines, sanitize=True)
# --------------------------------------------------------------------------


def canary_buffers(prog) -> Tuple[str, ...]:
    """Buffers safe to poison with NaN at pass start.

    A buffer qualifies when it is float-dtype, every deposit into it is
    a whole-buffer replace (add-mode reads the accumulator; a region
    deposit leaves lanes the canary would corrupt), and its first
    access in execution order is such a deposit — so in a race-free
    program the canary is fully overwritten (receiver lanes) or
    restored from the saved original (non-receiver lanes) before
    anything reads it.
    """
    deposit_kinds: Dict[str, set] = defaultdict(set)
    for b in prog.batches:
        for ch in b.channels:
            deposit_kinds[ch.dst_buf].add(
                (ch.mode, ch.recv_region is None))
        for coll in b.colls:
            deposit_kinds[coll.out].add(("replace", True))

    first: Dict[str, str] = {}

    def see(buf, kind):
        first.setdefault(buf, kind)

    batches = {b.index: b for b in prog.batches}
    for d in prog.descriptors:
        if isinstance(d, KernelDesc):
            for r in d.reads:
                see(r, "read")
            for w in d.writes:
                see(w, "kwrite")
        elif isinstance(d, StartDesc):
            b = batches.get(d.batch)
            if b is None:
                continue
            for ch in b.channels:
                see(ch.src_buf, "read")
            for coll in b.colls:
                see(coll.buf, "read")
            for ch in b.channels:
                see(ch.dst_buf,
                    "deposit" if ch.mode == "replace" else "read")
            for coll in b.colls:
                see(coll.out, "deposit")

    out = []
    for buf, kinds in deposit_kinds.items():
        if kinds != {("replace", True)}:
            continue
        if first.get(buf) != "deposit":
            continue
        spec = prog.buffers.get(buf)
        if spec is None or not np.issubdtype(np.dtype(spec.dtype),
                                             np.floating):
            continue
        out.append(buf)
    return tuple(sorted(out))


class DepositTracker:
    """Deposit-before-wait assertion state for the sanitizer.

    The interpreter (``sanitize=True``) feeds it every descriptor as it
    traces; a read of (or overlapping deposit into) a slot whose
    completion has not been waited raises :class:`SanitizeError` —
    at trace time, before any device work runs.  :func:`check_deposit_order`
    runs the same walk statically for the host engine.
    """

    def __init__(self, prog):
        self._batches = {b.index: b for b in prog.batches}
        self._gates = _cross_gate_map(prog)
        self._cursor: Dict[Tuple[int, str], int] = defaultdict(int)
        self._pending: Dict[str, List[_Pending]] = defaultdict(list)
        self._name = prog.name

    def _fail(self, msg: str):
        raise SanitizeError(f"[sanitize] {self._name}: {msg}")

    def _check_read(self, buf, what, site):
        for p in self._pending.get(buf, ()):
            self._fail(
                f"{what} reads {buf!r} while it holds a pending unwaited "
                f"deposit (gated by pid {p.gate_pid}'s wait on batch "
                f"{p.gate_batch})"
                + (f" [enqueued at {site}]" if site else ""))

    def kernel(self, d: KernelDesc):
        for r in d.reads:
            self._check_read(r, f"kernel {d.name!r}", d.site)
        for w in d.writes:
            for p in self._pending.get(w, ()):
                self._fail(
                    f"kernel {d.name!r} writes {w!r} over a pending "
                    f"unwaited deposit (gated by pid {p.gate_pid}'s wait "
                    f"on batch {p.gate_batch})")

    def start(self, d: StartDesc):
        batch = self._batches.get(d.batch)
        if batch is None:
            return
        for ch in batch.channels:
            self._check_read(ch.src_buf, f"batch {d.batch}'s send",
                             getattr(ch, "send_site", None))
        for coll in batch.colls:
            self._check_read(coll.buf, f"batch {d.batch}'s collective",
                             coll.site)
        for ch in batch.channels:
            dpid = d.pid if ch.dst_pid is None else ch.dst_pid
            if dpid == d.pid:
                gate = (d.pid, d.batch)
            else:
                key = (d.batch, ch.dst_buf)
                opts = self._gates.get(key, [])
                cur = self._cursor[key]
                gate = (opts[min(cur, len(opts) - 1)] if opts
                        else (dpid, d.batch))
                self._cursor[key] = cur + 1
            for p in self._pending.get(ch.dst_buf, ()):
                if (("replace" in (p.mode, ch.mode))
                        and _regions_overlap(p.region, ch.recv_region)):
                    self._fail(
                        f"deposit into {ch.dst_buf!r} overwrites a pending "
                        f"unwaited deposit (message lost before pid "
                        f"{p.gate_pid} waits batch {p.gate_batch})")
            self._pending[ch.dst_buf].append(_Pending(
                mode=ch.mode, gate_pid=gate[0], gate_batch=gate[1],
                region=ch.recv_region,
                site=getattr(ch, "recv_site", None), index=None))
        for coll in batch.colls:
            self._pending[coll.out].append(_Pending(
                mode="replace", gate_pid=d.pid, gate_batch=d.batch,
                region=None, site=coll.site, index=None))

    def wait(self, d: WaitDesc):
        for buf in list(self._pending):
            self._pending[buf] = [
                p for p in self._pending[buf]
                if not (p.gate_pid == d.pid and p.gate_batch <= d.batch)]
            if not self._pending[buf]:
                del self._pending[buf]


def check_deposit_order(prog) -> None:
    """Statically run the sanitizer's deposit-before-wait assertion over
    the whole descriptor stream (host engine's ``sanitize=True``:
    it never enters the fused interpreter)."""
    tracker = DepositTracker(prog)
    for d in prog.descriptors:
        if isinstance(d, KernelDesc):
            tracker.kernel(d)
        elif isinstance(d, StartDesc):
            tracker.start(d)
        elif isinstance(d, WaitDesc):
            tracker.wait(d)
