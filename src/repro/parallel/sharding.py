"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Model code names array dimensions with *logical* axes ("batch", "embed",
"heads", "expert", ...).  A rule table maps logical axes to mesh axes
("pod", "data", "model") per execution regime.  The launcher resolves
params/inputs/outputs to ``NamedSharding`` through these tables; model
internals use :func:`shard_constraint` for activation hints.

Regimes
-------
``RULES_TRAIN``       — batch over (pod×)data, tensor/expert over model,
                        parameters FSDP-sharded over (pod×)data on their
                        largest non-model dim (ZeRO-3 style).
``RULES_DECODE``      — decode batch over (pod×)data, KV heads over model.
``RULES_LONG_DECODE`` — batch=1: the KV/state *sequence* shards over
                        (pod×)data instead of batch.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices=None) -> Mesh:
    """jax.make_mesh with explicit Auto axis types (silences the 0.9
    behaviour-change warning; we use shard_map/pjit auto mode)."""
    import numpy as np

    from repro.compat import auto_axis_types

    if devices is None:
        return jax.make_mesh(
            tuple(shape), tuple(axes), **auto_axis_types(len(axes)),
        )
    dev = np.asarray(devices).reshape(tuple(shape))
    return Mesh(dev, tuple(axes), **auto_axis_types(len(axes)))


@dataclasses.dataclass(frozen=True)
class LogicalRules:
    """Ordered logical→mesh mapping.  First match wins per logical axis;
    a mesh axis may appear at most once in one PartitionSpec, so
    `logical_spec` drops later duplicate mesh axes."""

    rules: Tuple[Tuple[str, MeshAxes], ...]
    name: str = "rules"

    def mesh_axes(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        for k, v in self.rules:
            if k == logical:
                return v
        return None

    def replace(self, **updates: MeshAxes) -> "LogicalRules":
        new = [(k, updates.pop(k)) if k in updates else (k, v)
               for k, v in self.rules]
        for k, v in updates.items():
            new.append((k, v))
        return LogicalRules(tuple(new), name=self.name + "*")


def logical_spec(axes: Sequence[Optional[str]], rules: LogicalRules,
                 mesh: Optional[Mesh] = None) -> P:
    """Resolve a tuple of logical axis names to a PartitionSpec.

    Mesh axes already used by an earlier dim are dropped (a mesh axis can
    shard only one dim); mesh axes not present in `mesh` are dropped too
    (lets the same rules serve single-pod and multi-pod meshes).
    """
    used = set()
    out = []
    avail = set(mesh.axis_names) if mesh is not None else None
    for ax in axes:
        m = rules.mesh_axes(ax)
        if m is None:
            out.append(None)
            continue
        cand = (m,) if isinstance(m, str) else tuple(m)
        keep = tuple(a for a in cand
                     if a not in used and (avail is None or a in avail))
        used.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(keep)
    return P(*out)


def logical_sharding(axes: Sequence[Optional[str]], rules: LogicalRules,
                     mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, logical_spec(axes, rules, mesh))


def logical_spec_sized(shape: Sequence[int], axes: Sequence[Optional[str]],
                       rules: LogicalRules, mesh: Mesh) -> P:
    """Like `logical_spec` but drops mesh axes a dimension cannot divide.

    Example: a 50280-vocab can't shard 16 ways → the vocab dim falls back
    to replicated; whisper's 20 heads can't shard over model=16 → heads
    replicated (the arch then runs FSDP+DP only — recorded in DESIGN.md).
    For tuple assignments like ("pod","data") the prefix subsets are
    tried before giving up.
    """
    assert len(shape) == len(axes), (shape, axes)
    used = set()
    avail = dict(mesh.shape)
    out = []
    for dim, ax in zip(shape, axes):
        m = rules.mesh_axes(ax)
        if m is None:
            out.append(None)
            continue
        cand = (m,) if isinstance(m, str) else tuple(m)
        cand = tuple(a for a in cand if a in avail and a not in used)
        # try the longest divisible prefix
        chosen: Tuple[str, ...] = ()
        for k in range(len(cand), 0, -1):
            size = int(np.prod([avail[a] for a in cand[:k]]))
            if dim % size == 0:
                chosen = cand[:k]
                break
        used.update(chosen)
        if not chosen:
            out.append(None)
        elif len(chosen) == 1:
            out.append(chosen[0])
        else:
            out.append(chosen)
    return P(*out)


def shard_constraint(x: jax.Array, axes: Sequence[Optional[str]],
                     rules: Optional[LogicalRules],
                     mesh: Optional[Mesh] = None) -> jax.Array:
    """Activation sharding hint; no-op without rules/mesh context."""
    if rules is None:
        return x
    try:
        spec = logical_spec(axes, rules, mesh)
        if mesh is not None:
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # no mesh context (e.g. single-device smoke)


# --------------------------------------------------------------------------
# ambient activation-sharding context (perf iteration #1, EXPERIMENTS §Perf)
#
# Without explicit activation constraints the SPMD partitioner replicated
# attention heads over the `model` axis (observed: per-device QK^T dots
# with the FULL head count — a ~16× compute inflation).  Model code calls
# `act_shard(x, *logical_axes)`; the launcher activates the context per
# step so smoke tests (no mesh) stay unaffected.
# --------------------------------------------------------------------------

import contextlib
import threading

_ctx = threading.local()


@contextlib.contextmanager
def sharding_ctx(rules: LogicalRules, mesh: Mesh):
    prev = getattr(_ctx, "val", None)
    _ctx.val = (rules, mesh)
    try:
        yield
    finally:
        _ctx.val = prev


def current_ctx():
    """(rules, mesh) of the ambient sharding context, or None."""
    return getattr(_ctx, "val", None)


def act_shard(x, *axes: Optional[str]):
    """Constrain an activation to its logical axes (ambient ctx; no-op
    outside a `sharding_ctx`).  Indivisible dims fall back gracefully."""
    ctx = getattr(_ctx, "val", None)
    if ctx is None:
        return x
    rules, mesh = ctx
    spec = logical_spec_sized(x.shape, axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------
# Rule tables
# --------------------------------------------------------------------------

_FSDP = ("pod", "data")  # parameter / optimizer-state sharding axes

RULES_TRAIN = LogicalRules(
    name="train",
    rules=(
        # activations
        ("batch", _FSDP),
        ("seq", None),
        ("act_embed", None),
        ("act_heads", "model"),
        ("act_kv_heads", "model"),
        # batch_attn disabled in TRAIN: the per-layer resharding traffic
        # exceeds the compute win when training is collective-bound
        # (EXPERIMENTS §Perf iteration 6); decode/prefill keep it.
        ("batch_attn", None),
        ("act_mlp", "model"),
        ("act_expert", "model"),
        ("act_vocab", "model"),
        # parameters: tensor-parallel over model; FSDP over (pod, data)
        ("embed", _FSDP),          # d_model dim of params
        ("vocab", "model"),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("head_dim", None),
        ("mlp", "model"),
        ("expert", "model"),       # expert-parallel
        ("expert_mlp", ("model", "data")),  # TP if expert dim could not take model (grok E=8), else FSDP
        ("layers", None),
        ("kv_lora", None),
        ("q_lora", None),
        ("state", None),
        ("conv", None),
        ("frontend", None),
    ),
)

RULES_DECODE = LogicalRules(
    name="decode",
    rules=(
        ("batch", _FSDP),
        ("seq", None),
        ("cache_seq", None),
        ("act_embed", None),
        ("act_heads", "model"),
        ("act_kv_heads", "model"),
        ("batch_attn", ("pod", "data", "model")),
        ("act_mlp", "model"),
        ("act_expert", "model"),
        ("act_vocab", "model"),
        ("embed", None),           # params replicated over data for serving,
        ("vocab", "model"),        # sharded over model only (weights are
        ("heads", "model"),        # read-only; FSDP gather every step would
        ("kv_heads", "model"),     # dominate decode)
        ("head_dim", None),
        ("mlp", "model"),
        ("expert", "model"),
        ("expert_mlp", None),
        ("layers", None),
        ("kv_lora", None),
        ("q_lora", None),
        ("state", None),
        ("conv", None),
        ("frontend", None),
    ),
)

# batch=1 long-context: shard the cache sequence dim over (pod, data)
RULES_LONG_DECODE = RULES_DECODE.replace(
    batch=None, cache_seq=_FSDP,
)
RULES_LONG_DECODE = dataclasses.replace(RULES_LONG_DECODE, name="long_decode")
