"""Decomposed-collective + ST collective-matmul benchmarks.

Two sections:

**Decomposed overlap (8-device ring, fixed shapes).**  Contrasts stock
``all_gather``-then-matmul vs the per-chunk ``all_gather_matmul``
interleave, matmul-then-``psum_scatter`` vs ``matmul_reduce_scatter``,
and uni- vs bidirectional ring gathers — dispatch/fusion effects on
CPU, with the HLO collective-op count + wire bytes the TPU roofline
cares about in the derived column.

**Transformer block as ST schedule (the PR-9 headline).**  The same
collectives expressed as first-class ST descriptors
(:mod:`repro.core.collectives`): single-dispatch ``st_ag_matmul`` /
``st_matmul_rs`` / ``st_a2a`` rows assert bit-identity against the
decomposed references, and the gate rows run an N-layer Megatron-MLP
chain two ways —

``tp_stock_chain``     N jitted stock ``shard_map`` calls
                       (``psum_scatter(relu(all_gather(x)@w1)@w2)``),
                       one host dispatch per layer;
``tp_st_persistent``   the SAME chain as ONE
                       :class:`~repro.core.engine_persistent
                       .PersistentEngine` dispatch (``chain=True``
                       feedback kernel + ``program.persistent(N)``),
                       knobs picked by :func:`repro.launch.tune.tune`.

Emits ``BENCH_overlap.json`` (via ``benchmarks/run.py overlap``) with a
``_meta`` workload stamp; ``--check-against BENCH_overlap.json`` gates
CI:

* unconditional same-run invariants: the tuned ST chain **beats the
  stock shard_map chain** (measured back-to-back, machine speed cancels
  out), the tuner never publishes a slower number than untuned, and the
  ST rows really run in one dispatch;
* stored-median comparison (speed-factor-normalized) only when
  ``_meta`` matches, with tolerance widened by ``BENCH_NOISE_FACTOR``
  (``--noise-factor`` in run.py) for noisy 1-core runners.

Env knobs: OVERLAP_DEVICES, OVERLAP_M, OVERLAP_K, OVERLAP_F,
OVERLAP_LAYERS, OVERLAP_REPEATS.  The defaults (4-device ring,
m=512/k=128/f=128, 16 layers) are the collective-bound regime where the
ring cost is small enough per layer that dispatch amortization wins.
"""

from __future__ import annotations

import json
import os
import sys
import time
from functools import partial
from typing import Dict, List

import numpy as np

RESULTS: List[Dict] = []
# tuner-chosen knobs per published row — stamped into _meta by collect()
TUNED_KNOBS: Dict[str, Dict] = {}

CHECK_TOLERANCE = 1.20


def _noise_factor() -> float:
    """Explicit gate-tolerance widening for noisy 1-core CI runners
    (``--noise-factor`` in run.py sets BENCH_NOISE_FACTOR).  Never
    narrows below 1.0: the recorded medians stay the pin."""
    return max(1.0, float(os.environ.get("BENCH_NOISE_FACTOR", "1")))


def _cfg_env(name, default, cast=int):
    return cast(os.environ.get(name, default))


def _workload() -> Dict:
    return {
        "devices": _cfg_env("OVERLAP_DEVICES", 4),
        "m": _cfg_env("OVERLAP_M", 512),
        "k": _cfg_env("OVERLAP_K", 128),
        "f": _cfg_env("OVERLAP_F", 128),
        "layers": _cfg_env("OVERLAP_LAYERS", 16),
        "repeats": _cfg_env("OVERLAP_REPEATS", 5),
    }


def _time(fn, *args, repeats=20):
    import jax
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6


def _run_decomposed():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import jit_shard_map
    from repro.core import overlap
    from repro.launch.hlo_analysis import analyze_collectives
    from repro.parallel import make_mesh

    mesh = make_mesh((8,), ("x",))
    n = 8
    print("Decomposed/overlapped collectives (8-device ring)")

    def smap(f, in_specs, out_specs):
        return jit_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)

    rng = np.random.RandomState(0)
    x = rng.randn(1024, 512).astype(np.float32)   # gathered over rows
    w = rng.randn(512, 256).astype(np.float32)

    cases = {
        "ag_then_matmul": smap(
            lambda a, b: jax.lax.all_gather(a, "x", axis=0, tiled=True) @ b,
            (P("x"), P()), P()),
        "ag_matmul_overlap": smap(
            partial(overlap.all_gather_matmul, axis="x"),
            (P("x"), P()), P()),
    }
    xk = rng.randn(1024, 512).astype(np.float32)
    wk = rng.randn(512, 256).astype(np.float32)
    cases["matmul_then_rs"] = smap(
        lambda a, b: jax.lax.psum_scatter(a @ b, "x", scatter_dimension=0,
                                          tiled=True),
        (P(None, "x"), P("x")), P("x"))
    cases["matmul_rs_overlap"] = smap(
        partial(overlap.matmul_reduce_scatter, axis="x"),
        (P(None, "x"), P("x")), P("x"))
    cases["ag_ring_uni"] = smap(
        partial(overlap.all_gather_ring, axis="x", bidirectional=False),
        (P("x"),), P())
    cases["ag_ring_bidi"] = smap(
        partial(overlap.all_gather_ring, axis="x", bidirectional=True),
        (P("x"),), P())

    for name, fn in cases.items():
        args = (x, w) if "matmul" in name and "rs" not in name else (
            (xk, wk) if "rs" in name else (x,))
        us = _time(fn, *args)
        lowered = fn.lower(*args)
        colls = analyze_collectives(lowered.compile().as_text(), n)
        derived = (f"coll_ops={sum(colls.count_by_kind.values())};"
                   f"wire_bytes={colls.total_bytes:.3e}")
        RESULTS.append({"bench": "overlap", "variant": name,
                        "us_per_call": us, "derived": derived})
        print(f"  {name:20s} {us:10.1f} us  {derived}")

    # serial-step count: bidi ring halves the chain depth
    RESULTS.append({
        "bench": "overlap", "variant": "ring_steps",
        "us_per_call": 0.0,
        "derived": f"uni_steps={n-1};bidi_steps={(n-1+1)//2}"})


def _run_st(w: Dict):
    """ST collective-matmul rows: bit-identity asserts + the tuned
    persistent transformer-block chain vs the stock shard_map chain."""
    import jax
    from repro.core import collectives
    from repro.core.engine_fused import FusedEngine
    from repro.core.engine_persistent import PersistentEngine
    from repro.launch.tune import Knobs, measure, tune
    from repro.parallel import make_mesh

    n = w["devices"]
    m, k, f, layers = w["m"], w["k"], w["f"], w["layers"]
    repeats = w["repeats"]
    mesh = make_mesh((n,), ("x",))
    rng = np.random.RandomState(0)
    print(f"\nST collective matmul ({n}-device ring, m={m} k={k} f={f}, "
          f"{layers}-layer chain)")

    def row(variant, median_ms, dispatches, derived):
        RESULTS.append({"bench": "overlap", "variant": variant,
                        "us_per_call": median_ms * 1e3,
                        "median_ms": median_ms, "dispatches": dispatches,
                        "derived": derived})
        print(f"  {variant:22s} {median_ms:9.2f} ms  "
              f"dispatches={dispatches:3d}  {derived}")

    # --- single-dispatch ST collectives: bit-identical, priced, timed
    builders = {
        "st_ag_matmul": (
            lambda: collectives.build_all_gather_matmul(mesh, "x", m, k, f),
            lambda: {"x": rng.randn(m, k).astype(np.float32),
                     "w": rng.randn(k, f).astype(np.float32)}),
        "st_matmul_rs": (
            lambda: collectives.build_matmul_reduce_scatter(
                mesh, "x", m, k, f),
            lambda: {"x": rng.randn(m, k).astype(np.float32),
                     "w": rng.randn(k, f).astype(np.float32)}),
        "st_a2a": (
            lambda: collectives.build_all_to_all(mesh, "x", m, k),
            lambda: {"x": rng.randn(m, k).astype(np.float32)}),
    }
    for variant, (build, make_in) in builders.items():
        cm = build()
        eng = FusedEngine(cm.program, mode="dataflow")
        inputs = make_in()
        mem = eng.init_buffers(inputs)
        out = np.asarray(eng(mem)[cm.output])
        ref = np.asarray(cm.reference(*(inputs[b] for b in cm.inputs)))
        bitwise = bool((out == ref).all())
        assert bitwise, f"{variant}: ST output != decomposed reference"
        st = measure(eng, lambda: eng.init_buffers(make_in()), 1, repeats)
        ref_t = measure(lambda a: cm.reference(*a),
                        lambda: tuple(inputs[b] for b in cm.inputs),
                        1, repeats)
        row(variant, st["med_s"] * 1e3, 1,
            f"bitwise_vs_decomposed={bitwise};"
            f"reference_ms={ref_t['med_s'] * 1e3:.2f}")

    # --- the gate: N-layer TP-MLP chain, stock vs persistent ST
    tp = collectives.build_tp_block(mesh, "x", m, k, f, chain=True)
    x0 = rng.randn(m, k).astype(np.float32)
    w1 = rng.randn(k, f).astype(np.float32)
    w2 = rng.randn(f, k).astype(np.float32)
    stock = tp.reference_stock

    def stock_chain(a):
        for _ in range(layers):
            a = stock(a, w1, w2)
        return a

    st_stock = measure(stock_chain, lambda: x0, 1, repeats)
    row("tp_stock_chain", st_stock["med_s"] * 1e3, layers,
        f"layers={layers};lowering=shard_map")

    pprog = tp.program.persistent(layers)

    def fresh():
        # donate=True consumes the carry: re-materialize per repeat
        return PersistentEngine(pprog, donate=True).init_buffers(
            {"x": x0, "w1": w1, "w2": w2})

    def build(knobs: Knobs):
        eng = PersistentEngine(pprog, donate=True, **knobs.engine_kwargs())
        return eng, lambda: eng.init_buffers({"x": x0, "w1": w1, "w2": w2})

    # bit-identity of the whole chain: persistent(N) == N decomposed
    # block applications (the feedback kernel feeds out back into x)
    eng0, fresh0 = build(Knobs())
    chained = np.asarray(eng0(fresh0())["out"])
    ref = x0
    for _ in range(layers):
        ref = tp.reference(ref, w1, w2)
    assert (chained == np.asarray(ref)).all(), \
        "persistent ST chain != decomposed reference chain"
    st_untuned = measure(eng0, fresh0, 1, repeats)
    row("tp_st_persistent_untuned", st_untuned["med_s"] * 1e3, 1,
        f"layers={layers};knobs=default")

    res = tune(build,
               {"mode": ["stream", "dataflow"],
                "coalesce": [True, False],
                "double_buffer": [None, False]},
               inner=1, repeats=repeats, measure_top=3)
    # the default point (= the untuned row, already measured with the
    # same loop) is part of the space: publish whichever measured
    # faster, with the knobs that produced the published number
    best_ms, best_knobs = res.best.measured_ms, res.best.knobs
    if st_untuned["med_s"] * 1e3 < best_ms:
        best_ms, best_knobs = st_untuned["med_s"] * 1e3, Knobs()
    TUNED_KNOBS["overlap/tp_st_persistent"] = best_knobs.asdict()
    row("tp_st_persistent", best_ms, 1,
        f"layers={layers};knobs={best_knobs.label()};"
        f"speedup_vs_stock={st_stock['med_s'] * 1e3 / best_ms:.2f}x")


def run_all():
    _run_decomposed()
    _run_st(_workload())
    return RESULTS


def collect(results: List[Dict]) -> Dict:
    """BENCH_overlap.json payload from run_all() rows (rows without a
    median — the legacy us_per_call section — are not tracked)."""
    out = {
        f"{r['bench']}/{r['variant']}": {
            "median_ms": round(r["median_ms"], 4),
            "dispatches": r["dispatches"],
        }
        for r in results
        if r["bench"] == "overlap" and "median_ms" in r
    }
    if out:
        w = _workload()
        out["_meta"] = {k: w[k] for k in
                        ("devices", "m", "k", "f", "layers", "repeats")}
        if TUNED_KNOBS:
            out["_meta"]["tuned_knobs"] = TUNED_KNOBS
    return out


def check_against(fresh: Dict, path: str) -> int:
    """Overlap perf gate (cf. the Faces gate in benchmarks/run.py).

    Same-run invariants are unconditional — the variants are measured
    back-to-back in one process, so machine speed cancels out:

    * the tuned persistent ST chain beats the stock shard_map chain
      (the PR-9 acceptance criterion: model parallelism through the ST
      scheduler must win on a collective-bound shape);
    * the auto-tuner never publishes a slower number than untuned;
    * the ST rows really run in ONE dispatch.

    Stored medians are only compared when the ``_meta`` workload stamp
    (minus the advisory ``tuned_knobs``) matches, normalized by the
    run-wide speed factor, with tolerance widened by BENCH_NOISE_FACTOR
    for noisy runners.  Knob drift is a warning, never a failure.
    """
    with open(path) as f:
        stored = json.load(f)

    failures = []
    st = fresh.get("overlap/tp_st_persistent")
    stock = fresh.get("overlap/tp_stock_chain")
    untuned = fresh.get("overlap/tp_st_persistent_untuned")
    if st and stock and st["median_ms"] >= stock["median_ms"]:
        failures.append(
            f"overlap/tp_st_persistent ({st['median_ms']:.2f}ms) does not "
            f"beat overlap/tp_stock_chain ({stock['median_ms']:.2f}ms): "
            f"the tuned ST transformer-block chain must beat the stock "
            f"shard_map lowering")
    if st and untuned and st["median_ms"] > untuned["median_ms"] * 1.05:
        failures.append(
            f"overlap/tp_st_persistent ({st['median_ms']:.2f}ms) is slower "
            f"than untuned ({untuned['median_ms']:.2f}ms): the auto-tuner "
            f"must never publish a slower number")
    for key in ("overlap/st_ag_matmul", "overlap/st_matmul_rs",
                "overlap/st_a2a", "overlap/tp_st_persistent"):
        r = fresh.get(key)
        if r and r.get("dispatches") != 1:
            failures.append(
                f"{key} used {r.get('dispatches')} dispatches: ST "
                f"collective-matmul rows must run in one dispatch")

    stored_meta = stored.get("_meta", {})
    fresh_meta = fresh.get("_meta", {})
    stored_knobs = stored_meta.get("tuned_knobs", {})
    fresh_knobs = fresh_meta.get("tuned_knobs", {})
    stored_settings = {kk: v for kk, v in stored_meta.items()
                       if kk != "tuned_knobs"}
    fresh_settings = {kk: v for kk, v in fresh_meta.items()
                      if kk != "tuned_knobs"}
    if not stored_settings:
        print("note: recorded file has no _meta stamp — median checks "
              "skipped (invariants only)")
        compare = False
    elif stored_settings != fresh_settings:
        print(f"note: workload differs from recorded ({fresh_settings} vs "
              f"{stored_settings}) — median checks skipped, invariants "
              f"enforced")
        compare = False
    else:
        compare = True
    if compare and stored_knobs:
        for rr in sorted(set(stored_knobs) | set(fresh_knobs)):
            if stored_knobs.get(rr) != fresh_knobs.get(rr):
                print(f"WARNING knob-drift {rr}: recorded "
                      f"{stored_knobs.get(rr)} vs re-tuned "
                      f"{fresh_knobs.get(rr)} — a re-tune now picks "
                      f"differently; re-record {path} to pin the new choice")

    if compare:
        tol = CHECK_TOLERANCE * _noise_factor()
        keys = [kk for kk in fresh if not kk.startswith("_")
                and isinstance(stored.get(kk), dict)
                and stored[kk].get("median_ms")]
        ratios = sorted(fresh[kk]["median_ms"] / stored[kk]["median_ms"]
                        for kk in keys)
        speed = ratios[len(ratios) // 2] if ratios else 1.0
        for kk in keys:
            bound = stored[kk]["median_ms"] * speed * tol
            if fresh[kk]["median_ms"] > bound:
                failures.append(
                    f"{kk}: median {fresh[kk]['median_ms']:.2f}ms > bound "
                    f"{bound:.2f}ms (recorded "
                    f"{stored[kk]['median_ms']:.2f}ms x speed {speed:.2f} "
                    f"x tolerance {tol:.2f})")

    if failures:
        # stderr + flush, mirroring the Faces/serve gates: the non-zero
        # exit must name every failing row in the CI log
        print(f"\nOVERLAP PERF GATE FAILED ({len(failures)} failing "
              f"row(s)):", file=sys.stderr, flush=True)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr, flush=True)
        names = ", ".join(msg.split(":", 1)[0] for msg in failures)
        print(f"OVERLAP PERF GATE FAILED rows: {names}", file=sys.stderr,
              flush=True)
        return 1
    print("\noverlap perf gate OK: tuned ST chain beats stock shard_map "
          "chain; tuned <= untuned; ST rows are 1-dispatch"
          + ("; medians within tolerance" if compare else ""))
    return 0
