"""Analytic cost models: ST schedules + calibrated model-arch roofline.

Two halves share this module:

**ST schedule costing** (:func:`schedule_cost`, top half) — an analytic
price for a built :class:`~repro.core.queue.STProgram` /
:class:`~repro.core.schedule.STSchedule` under a chosen execution
configuration (engine / mode / coalesce / …).  It walks the descriptor
stream in symbolic stream order — the same per-pid start/wait execution
the STLint verifier (:mod:`repro.core.verify`) performs, but
accumulating microseconds instead of diagnostics:

* **bytes moved × hops** per fired collective — coalesced batches price
  their :class:`~repro.core.matching.CoalescePlan` transfers (one
  single-axis hop each, full-identity transfers elided exactly like the
  fused engine elides them); per-channel batches price one multi-axis
  ppermute per channel, scaled by its hop count;
* **collectives per start gate** — a fixed launch cost per fired
  collective (why coalesced < uncoalesced);
* **staging/slot pressure** — pack/deposit copy bytes through the
  contiguous staging buffers, plus the message-slot footprint the
  persistent engine double-buffers;
* **trigger→wait overlap** — compute priced *between* a trigger and its
  gating wait credits against that window's in-flight communication;
  what the credit cannot hide is charged as exposed wait time
  (per-segment critical path);
* **stream switches** — consecutive descriptors from different
  sub-programs cost a scheduling switch, which is what makes the
  interleave policy (:class:`~repro.core.schedule.InterleavePolicy`) a
  priceable knob;
* **host dispatches** — per-dispatch round-trips under the chosen
  engine (why persistent < fused < host).

The constants (:class:`CostParams`) are calibrated against the CPU
host-device grid the benchmarks run on; only *orderings* are trusted
(predict-then-measure: the model prunes the tuner's candidate space in
:mod:`repro.launch.tune`, medians decide — and
``benchmarks/roofline.py`` prints predicted-vs-measured rows for the
program registry).  Costs depend only on program *structure*, never on
buffer or program names (rename-invariant, property-tested).

**Model-arch costing** (:func:`run_one`, bottom half) — calibrated
roofline costing for the scanned-layers training programs (companion to
dryrun.py).  ``cost_analysis()`` on a scanned-layers program counts the
loop body ONCE, undercounting FLOPs/bytes/collectives by ~n_layers;
this half compiles small **unrolled** variants and extrapolates
(``calibrated`` mode: per-layer cost from an L₂/L₄ pair).  Artifacts
land in ``artifacts/costing/*.json``; benchmarks/roofline.py prefers
them over the scanned dry-run numbers.  Running this half standalone
(``python -m repro.launch.costing``) forces the 512-device dry-run
grid; merely importing the module no longer touches ``XLA_FLAGS`` (the
ST half must be importable from tests and benches that set their own
device count).
"""

import dataclasses
import os
from typing import Any, Dict, List, Optional, Tuple

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "costing")


# =========================================================================
# ST schedule cost model
# =========================================================================


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Calibrated unit costs (µs) for the CPU host-device grid.

    Absolute values are rough; the model is used for *ordering* and
    pruning (measurements decide winners).  Calibration anchors, from
    BENCH_faces.json on the recorded grid: per-op host dispatch ≈
    0.6 ms; one fused collective ≈ 20 µs of launch overhead beyond its
    bytes; a lowered kernel/pack op ≈ 3 µs; a sub-program switch in the
    fused stream ≈ 7 µs.
    """

    dispatch_us: float = 1000.0    # host round-trip per dispatch
    collective_us: float = 20.0    # fixed launch cost per fired collective
    kernel_us: float = 3.0         # fixed cost per lowered kernel op
    byte_us: float = 1e-4          # per byte through a collective, per hop
    compute_byte_us: float = 2e-5  # per byte a kernel touches
    stage_byte_us: float = 3e-5    # per byte packed/deposited (staging copy)
    slot_byte_us: float = 1e-5     # per slot-resident byte, per iteration
    switch_us: float = 7.0         # per adjacent-descriptor pid switch
    overlap_eff: float = 0.6       # fraction of in-window compute hiding comm


DEFAULT_PARAMS = CostParams()

_ENGINE_ORDER = ("host", "fused", "persistent")


@dataclasses.dataclass
class ScheduleCost:
    """Itemized analytic cost of one execution configuration.

    All time components are µs for the whole ``n_iters`` run;
    ``total_us`` is their sum.  Counts are per iteration.
    """

    engine: str
    mode: str
    coalesce: bool
    n_iters: int
    dispatch_us: float = 0.0
    collective_us: float = 0.0
    bytes_us: float = 0.0
    kernel_us: float = 0.0
    staging_us: float = 0.0
    slot_us: float = 0.0
    exposed_us: float = 0.0
    switch_us: float = 0.0
    n_dispatches: int = 0
    n_collectives: int = 0      # fired per iteration (post-elision)
    n_elided: int = 0           # full-identity transfers skipped
    n_kernels: int = 0
    bytes_moved: int = 0        # through collectives, per iteration
    staged_bytes: int = 0       # packed+deposited, per iteration
    slot_bytes: int = 0         # message-slot footprint (double-buffered)

    @property
    def total_us(self) -> float:
        return (self.dispatch_us + self.collective_us + self.bytes_us
                + self.kernel_us + self.staging_us + self.slot_us
                + self.exposed_us + self.switch_us)

    def row(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["total_us"] = self.total_us
        return d


def _buf_bytes(spec, mesh_shape) -> int:
    import numpy as np
    from repro.core.matching import _local_shape
    shape = _local_shape(spec, mesh_shape)
    return int(np.prod(shape, dtype=np.int64)) * np.dtype(spec.dtype).itemsize


def _send_bytes(ch, buffers, mesh_shape) -> int:
    import numpy as np
    from repro.core.matching import _NoCoalesce, _send_shape
    try:
        shape = _send_shape(ch, buffers, mesh_shape)
    except _NoCoalesce:
        from repro.core.matching import _local_shape
        shape = _local_shape(buffers[ch.src_buf], mesh_shape)
    itemsize = np.dtype(buffers[ch.src_buf].dtype).itemsize
    return int(np.prod(shape, dtype=np.int64)) * itemsize


def _identity_perm(perm, axes, mesh_shape) -> bool:
    n = 1
    for a in axes:
        n *= mesh_shape[a]
    return len(perm) == n and all(s == d for s, d in perm)


def _axes_of(axis) -> Tuple[str, ...]:
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _price_batch(batch, buffers, mesh_shape, axis_order, coalesce,
                 params: CostParams):
    """Price one start gate: (comm_us, cost-component deltas).

    Mirrors the fused engine's lowering choice: a plan-carrying batch
    fires its fused transfers (identity transfers elided), otherwise one
    ppermute per channel (identity channels elided), plus whole-buffer
    collectives either way.
    """
    import numpy as np
    from repro.core.descriptors import hop_decomposition
    comm_us = coll_us = byte_us = stage_us = 0.0
    n_coll = n_elided = 0
    bytes_moved = staged = 0
    if coalesce and batch.plan is not None:
        plan = batch.plan
        for t in plan.transfers:
            itemsize = np.dtype(t.dtype).itemsize
            nbytes = sum(s.size for s in t.segments) * itemsize
            stage_us += nbytes * params.stage_byte_us  # pack copy
            staged += nbytes
            if _identity_perm(t.perm, _axes_of(t.axis), mesh_shape):
                n_elided += 1
                continue
            n_coll += 1
            coll_us += params.collective_us
            byte_us += nbytes * params.byte_us
            bytes_moved += nbytes
        for ci, ch in enumerate(plan.channels):
            itemsize = np.dtype(buffers[ch.dst_buf].dtype).itemsize
            nbytes = int(np.prod(plan.shapes[ci], dtype=np.int64)) * itemsize
            stage_us += nbytes * params.stage_byte_us  # deposit copy
            staged += nbytes
    else:
        for ch in batch.channels:
            nbytes = _send_bytes(ch, buffers, mesh_shape)
            axes = _axes_of(ch.axis)
            if _identity_perm(ch.perm(mesh_shape), axes, mesh_shape):
                n_elided += 1
                stage_us += nbytes * params.stage_byte_us
                staged += nbytes
                continue
            hops = hop_decomposition(ch.peer, axis_order)
            n_hops = len(hops) if hops else max(1, len(axes))
            n_coll += 1
            coll_us += params.collective_us
            byte_us += nbytes * params.byte_us * n_hops
            bytes_moved += nbytes
    for coll in batch.colls:
        nbytes = _buf_bytes(buffers[coll.buf], mesh_shape)
        n_coll += 1
        coll_us += params.collective_us
        byte_us += nbytes * params.byte_us
        bytes_moved += nbytes
    comm_us = coll_us + byte_us
    return comm_us, coll_us, byte_us, stage_us, n_coll, n_elided, \
        bytes_moved, staged


def schedule_cost(
    prog,
    *,
    engine: str = "persistent",
    mode: str = "dataflow",
    coalesce: bool = True,
    double_buffer: Optional[bool] = None,
    n_iters: Optional[int] = None,
    params: CostParams = DEFAULT_PARAMS,
) -> ScheduleCost:
    """Analytically price one execution configuration of ``prog``.

    The walk is the verifier's symbolic stream-order execution: every
    descriptor is visited once, per-pid in-flight communication is
    registered at each ``StartDesc`` and settled at the gating
    ``WaitDesc``, and compute priced between the two credits against
    the window (``overlap_eff``); the remainder is exposed wait time.
    ``engine`` picks the dispatch model (``"host"`` per-op, ``"fused"``
    one dispatch per iteration, ``"persistent"`` one dispatch total);
    host-engine runs are synchronous per op, so they earn no overlap
    credit.  Returns an itemized :class:`ScheduleCost`.
    """
    from repro.core.descriptors import KernelDesc, StartDesc, WaitDesc
    if engine not in _ENGINE_ORDER:
        raise ValueError(f"engine must be one of {_ENGINE_ORDER}, "
                         f"got {engine!r}")
    if mode not in ("stream", "dataflow"):
        raise ValueError(f"mode must be 'stream' or 'dataflow', got {mode!r}")
    mesh_shape = dict(prog.mesh.shape)
    axis_order = list(mesh_shape)
    buffers = prog.buffers
    iters = int(n_iters if n_iters is not None
                else max(1, getattr(prog, "n_iters", 1) or 1))
    if double_buffer is None:
        double_buffer = (mode == "dataflow")

    cost = ScheduleCost(engine=engine, mode=mode, coalesce=coalesce,
                        n_iters=iters)
    batches_by_index = {b.index: b for b in prog.batches}
    overlap_eff = 0.0 if engine == "host" else params.overlap_eff

    in_flight: Dict[int, float] = {}
    credit: Dict[int, float] = {}
    pending_recv: Dict[int, set] = {}
    last_pid = None
    n_switches = 0
    per_iter_kernel_us = per_iter_coll_us = per_iter_byte_us = 0.0
    per_iter_stage_us = per_iter_exposed_us = 0.0

    for d in prog.descriptors:
        pid = d.pid
        if last_pid is not None and pid != last_pid:
            n_switches += 1
        last_pid = pid
        if isinstance(d, KernelDesc):
            nbytes = sum(_buf_bytes(buffers[b], mesh_shape)
                         for b in tuple(d.reads) + tuple(d.writes))
            k_us = params.kernel_us + nbytes * params.compute_byte_us
            per_iter_kernel_us += k_us
            cost.n_kernels += 1
            for q, fl in in_flight.items():
                if fl <= 0.0:
                    continue
                if q != pid:
                    credit[q] = credit.get(q, 0.0) + k_us
                elif mode == "dataflow" and not (
                        set(d.reads) & pending_recv.get(pid, set())):
                    # XLA may run a kernel that doesn't consume the
                    # in-flight deposits concurrently with them
                    credit[q] = credit.get(q, 0.0) + k_us
        elif isinstance(d, StartDesc):
            batch = batches_by_index[d.batch]
            comm, coll_us, byte_us, stage_us, n_coll, n_elided, moved, \
                staged = _price_batch(batch, buffers, mesh_shape, axis_order,
                                      coalesce, params)
            per_iter_coll_us += coll_us
            per_iter_byte_us += byte_us
            per_iter_stage_us += stage_us
            cost.n_collectives += n_coll
            cost.n_elided += n_elided
            cost.bytes_moved += moved
            cost.staged_bytes += staged
            in_flight[pid] = in_flight.get(pid, 0.0) + comm
            credit.setdefault(pid, 0.0)
            recvs = {c.dst_buf for c in batch.channels} | \
                    {c.out for c in batch.colls} | set(batch.cross_recv_bufs)
            pending_recv.setdefault(pid, set()).update(recvs)
        elif isinstance(d, WaitDesc):
            fl = in_flight.pop(pid, 0.0)
            cr = credit.pop(pid, 0.0)
            per_iter_exposed_us += max(0.0, fl - overlap_eff * cr)
            pending_recv.pop(pid, None)

    # communication never waited inside the pass is exposed at pass end
    for pid, fl in in_flight.items():
        per_iter_exposed_us += max(
            0.0, fl - overlap_eff * credit.get(pid, 0.0))

    if engine == "host":
        n_disp = prog.dispatch_count_host() * iters
    elif engine == "fused":
        n_disp = iters
    else:
        n_disp = 1
    cost.n_dispatches = n_disp
    cost.dispatch_us = n_disp * params.dispatch_us
    cost.kernel_us = per_iter_kernel_us * iters
    cost.collective_us = per_iter_coll_us * iters
    cost.bytes_us = per_iter_byte_us * iters
    cost.staging_us = per_iter_stage_us * iters
    cost.exposed_us = per_iter_exposed_us * iters
    cost.switch_us = n_switches * params.switch_us * iters

    if engine == "persistent":
        from repro.core.engine_persistent import slot_buffers
        slots = slot_buffers(prog)
        slot_bytes = sum(_buf_bytes(buffers[s], mesh_shape) for s in slots)
        if double_buffer:
            slot_bytes *= 2
        cost.slot_bytes = slot_bytes
        cost.slot_us = slot_bytes * params.slot_byte_us * iters
    return cost


def predict_ranking(progs, **kw) -> List[Tuple[str, float]]:
    """``[(name, total_us)]`` sorted cheapest-first for built programs.

    ``progs`` is an iterable of ``(name, program)`` pairs; ``kw``
    forwards to :func:`schedule_cost` (same configuration for every
    program, so the ranking isolates program structure).
    """
    out = [(name, schedule_cost(p, **kw).total_us) for name, p in progs]
    return sorted(out, key=lambda t: t[1])


# =========================================================================
# Model-architecture calibrated costing (dry-run companion)
# =========================================================================


def _pattern_unit(cfg) -> int:
    """Smallest depth that preserves the layer pattern (gemma 5:1 etc.).

    Sparse-global patterns with a long period (hymba: global every 16)
    are calibrated on local-only layers — the 2-of-32 global layers are
    approximated as local ones (documented in EXPERIMENTS.md)."""
    if cfg.global_every and cfg.global_every <= 8:
        return cfg.global_every
    return 1


def _with_depth(cfg, L: int):
    updates = dict(n_layers=L, scan_layers=False)
    if cfg.enc_dec:
        updates["n_enc_layers"] = L
    if cfg.first_k_dense:
        # calibrate the homogeneous MoE layer; the 3 dense layers are
        # approximated as MoE layers (overestimates <5% of depth)
        updates["first_k_dense"] = 0
    if cfg.mtp_depth:
        updates["mtp_depth"] = cfg.mtp_depth  # stays outside the depth scaling
    return dataclasses.replace(cfg, **updates)


def _compile_costs(cfg, shape, mesh):
    from repro.launch.hlo_analysis import analyze_collectives, analyze_dots
    from repro.launch.steps import build_bundle
    bundle = build_bundle(cfg, shape, mesh)
    lowered = bundle.lower()
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    colls = analyze_collectives(hlo, mesh.devices.size)
    dots = analyze_dots(hlo)
    mem = {}
    try:
        m = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes"):
            mem[attr] = int(getattr(m, attr))
    except Exception:
        pass
    return {
        "flops": float(cost.get("flops", 0.0)),
        "dot_flops": dots.total_flops,
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": colls.total_bytes,
        "coll_by_kind": colls.bytes_by_kind,
        "memory": mem,
        "top_dots": dots.largest[:8],
    }


def _lin(c2, c4, L2, L4, L, key):
    per_layer = (c4[key] - c2[key]) / (L4 - L2)
    return c2[key] + per_layer * (L - L2), per_layer


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            save: bool = True) -> dict:
    import time
    import traceback

    from repro.configs.base import SHAPES, get_config
    from repro.launch.dryrun import SKIPS
    from repro.launch.mesh import make_production_mesh
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if (arch, shape_name) in SKIPS:
        rec.update(status="skipped", reason=SKIPS[(arch, shape_name)])
        _save(rec, save)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        unit = _pattern_unit(cfg)
        L = cfg.n_layers
        eff_L = L + (cfg.n_enc_layers if cfg.enc_dec else 0)
        full_unroll = (eff_L <= 28 and cfg.d_model <= 4096) or eff_L <= 8

        if full_unroll:
            costs = _compile_costs(dataclasses.replace(
                cfg, scan_layers=False), shape, mesh)
            rec.update(status="ok", mode="unrolled",
                       flops=costs["flops"], dot_flops=costs["dot_flops"],
                       bytes=costs["bytes"],
                       coll_bytes=costs["coll_bytes"],
                       coll_by_kind=costs["coll_by_kind"],
                       memory=costs["memory"], top_dots=costs["top_dots"])
        else:
            L2, L4 = 2 * unit, 4 * unit
            c2 = _compile_costs(_with_depth(cfg, L2), shape, mesh)
            c4 = _compile_costs(_with_depth(cfg, L4), shape, mesh)
            out = {}
            for key in ("flops", "dot_flops", "bytes", "coll_bytes"):
                total, per_layer = _lin(c2, c4, L2, L4, L, key)
                out[key] = total
                out[f"{key}_per_layer"] = per_layer
            kinds = {}
            for k in set(c2["coll_by_kind"]) | set(c4["coll_by_kind"]):
                a, b = c2["coll_by_kind"].get(k, 0.0), c4["coll_by_kind"].get(k, 0.0)
                kinds[k] = a + (b - a) / (L4 - L2) * (L - L2)
            rec.update(status="ok", mode=f"calibrated(L{L2},L{L4})",
                       flops=out["flops"], dot_flops=out["dot_flops"],
                       bytes=out["bytes"],
                       coll_bytes=out["coll_bytes"], coll_by_kind=kinds,
                       per_layer={k: out[f"{k}_per_layer"]
                                  for k in ("flops", "dot_flops", "bytes",
                                            "coll_bytes")},
                       memory=c4["memory"], top_dots=c4["top_dots"])
        rec["n_devices"] = int(mesh.devices.size)
        rec["wall_s"] = round(time.time() - t0, 1)
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-3000:])
    _save(rec, save)
    return rec


def _save(rec, save):
    import json
    if not save:
        return
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(
            ARTIFACTS, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"),
            "w") as f:
        json.dump(rec, f, indent=1)


def main():
    # the dry-run meshes need the 512-device grid; set it before any
    # jax backend initializes (standalone entry point only — importing
    # this module must NOT touch XLA_FLAGS)
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    import argparse
    import json

    from repro.configs.base import ARCH_IDS, SHAPES
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    results = []
    for arch in archs:
        for shape in shapes:
            mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
            path = os.path.join(ARTIFACTS, f"{arch}__{shape}__{mesh_name}.json")
            if args.skip_existing and os.path.exists(path):
                rec = json.load(open(path))
                if rec.get("status") in ("ok", "skipped"):
                    print(f"[cached ] {arch} {shape} {rec['status']}", flush=True)
                    results.append(rec)
                    continue
            rec = run_one(arch, shape, args.multi_pod)
            extra = ""
            if rec["status"] == "ok":
                extra = (f"mode={rec['mode']} flops={rec['flops']:.3e} "
                         f"coll={rec['coll_bytes']:.3e}B t={rec['wall_s']}s")
            elif rec["status"] == "error":
                extra = rec["error"][:140]
            print(f"[{rec['status']:7s}] {arch} {shape} {extra}", flush=True)
            results.append(rec)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"COSTING SUMMARY: {len(results)-n_err} ok/skip, {n_err} errors")


if __name__ == "__main__":
    main()
