"""JAX version-compatibility shims.

The codebase targets the current jax API surface (``jax.shard_map``,
``jax.sharding.AxisType``, pallas-TPU ``CompilerParams``); older
installs (0.4.x) spell these differently or lack them.  Importing the
aliases from here keeps every call site on the modern spelling while
remaining runnable on the baked-in toolchain:

* :func:`shard_map`  — ``jax.shard_map`` when present, else
  ``jax.experimental.shard_map.shard_map``; the modern ``check_vma``
  kwarg maps onto legacy ``check_rep``.
* :func:`auto_axis_types` — the ``axis_types=(AxisType.Auto, ...)``
  kwarg dict for ``Mesh``/``jax.make_mesh``, empty where unsupported
  (pre-AxisType jax is implicitly all-auto).
* :func:`tpu_compiler_params` — pallas-TPU ``CompilerParams`` /
  ``TPUCompilerParams`` constructor.
"""

from __future__ import annotations

from typing import Optional

import jax

_NEW_SHARD_MAP = getattr(jax, "shard_map", None)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None):
    """``jax.shard_map`` across jax versions (``check_vma``⇄``check_rep``)."""
    if _NEW_SHARD_MAP is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return _NEW_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {} if check_vma is None else {"check_rep": bool(check_vma)}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def jit_shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.jit(shard_map(...))`` — the wrapper benches/tests hand-roll;
    centralized so the next jax-compat change lands in one place."""
    return jax.jit(shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma))


def axis_size(axis) -> int:
    """``jax.lax.axis_size`` (newer jax) or the classic ``psum(1, axis)``
    idiom, which stays a static int for constant operands."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis)
    return jax.lax.psum(1, axis)


def auto_axis_types(n_axes: int) -> dict:
    """``axis_types`` kwarg marking all ``n_axes`` mesh axes Auto, or an
    empty dict on jax versions without ``jax.sharding.AxisType``."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def tpu_compiler_params(**kwargs):
    """Pallas-TPU compiler params (``CompilerParams``, formerly
    ``TPUCompilerParams``)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
