"""Core NN building blocks (pure JAX, functional params).

Params are nested dicts of ``Boxed(value, axes)`` leaves during init;
``unbox`` splits them into a value pytree and a logical-axes pytree that
the launcher maps to mesh shardings via :mod:`repro.parallel`.

Covers every attention flavour in the assigned pool:
GQA (+QKV bias), MLA (compressed KV, absorbed decode), sliding window,
per-layer rope theta (traced), qk-norm, logit softcap, cross-attention.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel import act_shard, current_ctx

# --------------------------------------------------------------------------
# boxed params
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Boxed:
    value: Any
    axes: Tuple[Optional[str], ...]


def _is_boxed(x):
    return isinstance(x, Boxed)


def param(key, shape, axes, dtype, scale: Optional[float] = None,
          init: str = "normal") -> Boxed:
    if init == "normal":
        scale = 0.02 if scale is None else scale
        v = jax.random.normal(key, shape, dtype) * scale
    elif init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    else:
        raise ValueError(init)
    assert len(shape) == len(axes), (shape, axes)
    return Boxed(v, tuple(axes))


def unbox(tree):
    values = jax.tree.map(lambda b: b.value, tree, is_leaf=_is_boxed)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=_is_boxed)
    return values, axes


def stack_boxed(trees):
    """Stack per-layer boxed param trees along a new leading 'layers' dim."""
    def _stack(*leaves):
        vals = jnp.stack([l.value for l in leaves])
        return Boxed(vals, ("layers",) + leaves[0].axes)
    return jax.tree.map(_stack, *trees, is_leaf=_is_boxed)


# --------------------------------------------------------------------------
# norms / embeddings / mlp
# --------------------------------------------------------------------------


def init_rmsnorm(key, d, dtype):
    return {"scale": param(key, (d,), ("embed",), dtype, init="zeros")}
    # stored as zeros; applied as (scale + 1 + cfg.norm_offset-1)… see apply.


def apply_rmsnorm(p, x, cfg: ModelConfig):
    # stored scale is centered at 0 → effective weight = scale + 1
    # (matches gemma's (w+1) with norm_offset folded in; for offset=0
    # models the stored-at-zero parameterization is equivalent at init).
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
    w = p["scale"].astype(jnp.float32) + 1.0
    return (y * w).astype(x.dtype)


def init_embedding(key, cfg: ModelConfig):
    return {"table": param(key, (cfg.vocab, cfg.d_model), ("vocab", "embed"),
                           jnp.dtype(cfg.param_dtype))}


def apply_embedding(p, ids, cfg: ModelConfig):
    x = jnp.take(p["table"].astype(jnp.dtype(cfg.dtype)), ids, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def apply_unembed(p_embed, p_head, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = p_embed["table"].astype(x.dtype)
        return jnp.einsum("...d,vd->...v", x, w)
    return jnp.einsum("...d,dv->...v", x, p_head["w"].astype(x.dtype))


def init_unembed(key, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return {}
    return {"w": param(key, (cfg.d_model, cfg.vocab), ("embed", "vocab"),
                       jnp.dtype(cfg.param_dtype))}


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":  # gated (swiglu)
        return {
            "wi": param(ks[0], (d, f), ("embed", "mlp"), dt),
            "wg": param(ks[1], (d, f), ("embed", "mlp"), dt),
            "wo": param(ks[2], (f, d), ("mlp", "embed"), dt,
                        scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
        }
    return {
        "wi": param(ks[0], (d, f), ("embed", "mlp"), dt),
        "wo": param(ks[2], (f, d), ("mlp", "embed"), dt,
                    scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def apply_mlp(p, x, cfg: ModelConfig):
    dt = x.dtype
    h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dt))
    if "wg" in p:
        g = jnp.einsum("...d,df->...f", x, p["wg"].astype(dt))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = act_shard(h, "batch", "seq", "act_mlp")
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(dt))


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def apply_rope(x: jax.Array, positions: jax.Array, theta, rotary_dim: int):
    """x: [..., S, H, D] (positions [..., S] broadcastable); NeoX halves."""
    if rotary_dim <= 0:
        return x
    half = rotary_dim // 2
    freq_exponents = jnp.arange(half, dtype=jnp.float32) / half
    inv_freq = jnp.power(jnp.asarray(theta, jnp.float32), -freq_exponents)
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # [..., S, half]
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:rotary_dim].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rot = jnp.concatenate([r1, r2], axis=-1).astype(x.dtype)
    if rotary_dim == x.shape[-1]:
        return rot
    return jnp.concatenate([rot, x[..., rotary_dim:]], axis=-1)


# --------------------------------------------------------------------------
# attention (GQA family)
# --------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    if cfg.use_mla and not cross:
        return _init_mla(key, cfg)
    d = cfg.d_model
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim()
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p = {
        "wq": param(ks[0], (d, hq, hd), ("embed", "heads", "head_dim"), dt),
        "wk": param(ks[1], (d, hkv, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wv": param(ks[2], (d, hkv, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wo": param(ks[3], (hq, hd, d), ("heads", "head_dim", "embed"), dt,
                    scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }
    if cfg.qkv_bias:
        p["bq"] = param(ks[4], (hq, hd), ("heads", "head_dim"), dt, init="zeros")
        p["bk"] = param(ks[5], (hkv, hd), ("kv_heads", "head_dim"), dt, init="zeros")
        p["bv"] = param(ks[6], (hkv, hd), ("kv_heads", "head_dim"), dt, init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = param(ks[7], (hd,), ("head_dim",), dt, init="zeros")
        p["k_norm"] = param(ks[7], (hd,), ("head_dim",), dt, init="zeros")
    return p


def _heads_shardable(n_heads: int) -> bool:
    """False when the head count can't divide the model axis (whisper's
    20 heads on a 16-way axis) — attention activations then shard batch
    over data×model instead (perf iteration 4, EXPERIMENTS §Perf)."""
    ctx = current_ctx()
    if not ctx:
        return True
    _, mesh = ctx
    m = dict(mesh.shape).get("model", 1)
    return n_heads % m == 0


def _batch_attn_enabled() -> bool:
    ctx = current_ctx()
    if not ctx:
        return False
    rules, _ = ctx
    return rules.mesh_axes("batch_attn") is not None


def _attn_axes(n_heads, with_head_dim=True):
    if _heads_shardable(n_heads):
        axes = ("batch", "seq", "act_heads")
    elif _batch_attn_enabled():
        axes = ("batch_attn", "seq", None)
    else:
        axes = ("batch", "seq", None)   # heads replicated, batch over data
    return axes + ((None,) if with_head_dim else ())


def _headwise_rms(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) *
            (scale.astype(jnp.float32) + 1.0)).astype(x.dtype)


def _attn_mask(*, T, causal, window, q_pos, k_valid):
    """Validity × causal × window mask, batch-aware.

    ``q_pos`` may be [S] (positions shared across the batch) or [B,S]
    (per-sequence positions — the continuous-batching serve path, where
    every cache slot sits at its own depth); ``k_valid`` likewise scalar
    or [B].  Returns [b?,S,T] with b? ∈ {1,B} so it broadcasts against
    [B,H,S,T] logits either way — the shared-position path lowers to
    exactly the pre-batched mask values.
    """
    qp = q_pos if q_pos.ndim == 2 else q_pos[None]            # [b?,S]
    kv = jnp.asarray(k_valid)
    kv = kv if kv.ndim == 1 else kv[None]                     # [b?]
    kpos = jnp.arange(T)
    mask = kpos[None, None, :] < kv[:, None, None]            # [b?,1,T]
    if causal:
        mask = mask & (kpos[None, None, :] <= qp[:, :, None])
    mask = mask & jnp.where(
        window > 0, kpos[None, None, :] > (qp[:, :, None] - window), True)
    return mask


def _sdpa(q, k, v, *, scale, causal, window, softcap, q_pos, k_valid):
    """q: [B,S,H,D]; k/v: [B,T,Hkv,D]; window/theta may be traced.

    ``window``: 0 → full attention.  ``q_pos``: [S] or [B,S] global
    positions.  ``k_valid``: number of valid cache entries (traced ok,
    scalar or per-sequence [B]).
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    qf = q.astype(jnp.float32) * scale
    # repeat kv heads (XLA fuses the broadcast; no HBM materialization)
    kr = act_shard(jnp.repeat(k, group, axis=2).astype(jnp.float32),
                   *_attn_axes(Hq))
    vr = act_shard(jnp.repeat(v, group, axis=2).astype(jnp.float32),
                   *_attn_axes(Hq))
    if _heads_shardable(Hq):
        logits = act_shard(jnp.einsum("bshd,bthd->bhst", qf, kr),
                           "batch", "act_heads", None, None)
    elif _batch_attn_enabled():
        logits = act_shard(jnp.einsum("bshd,bthd->bhst", qf, kr),
                           "batch_attn", None, None, None)
    else:
        logits = act_shard(jnp.einsum("bshd,bthd->bhst", qf, kr),
                           "batch", None, None, None)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    mask = _attn_mask(T=T, causal=causal, window=window,
                      q_pos=q_pos, k_valid=k_valid)
    logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, vr)
    return act_shard(out.astype(q.dtype), *_attn_axes(Hq))


def _cache_write(cache_buf, val, pos):
    """Write ``val`` [B,S,...] into ``cache_buf`` [B,T,...] at sequence
    offset ``pos`` — scalar (one depth for the whole batch) or [B]
    (per-sequence slot depths; each row updates at its own offset)."""
    if getattr(pos, "ndim", 0) == 1:
        return jax.vmap(
            lambda c, v, p: jax.lax.dynamic_update_slice_in_dim(
                c, v, p, axis=0))(cache_buf, val, pos)
    return jax.lax.dynamic_update_slice_in_dim(cache_buf, val, pos, axis=1)


def apply_attention(
    p, x, cfg: ModelConfig, *,
    causal: bool = True,
    window=0,                 # static int or traced scalar; 0 → full
    rope_theta=None,          # static float or traced scalar
    positions=None,           # [S] or [B,S] global positions of x tokens
    cache: Optional[Dict] = None,   # {"k","v","pos"} decode cache (updated)
    kv_x: Optional[jax.Array] = None,  # cross-attention source
):
    """Returns (y, new_cache_entry_or_None)."""
    if cfg.use_mla and kv_x is None:
        return _apply_mla(p, x, cfg, window=window, rope_theta=rope_theta,
                          positions=positions, cache=cache, causal=causal)
    B, S, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim()
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt))
    src = kv_x if kv_x is not None else x
    k = jnp.einsum("bsd,dhe->bshe", src, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhe->bshe", src, p["wv"].astype(dt))
    if _heads_shardable(hq):
        q = act_shard(q, "batch", "seq", "act_heads", None)
        k = act_shard(k, "batch", "seq", "act_kv_heads", None)
        v = act_shard(v, "batch", "seq", "act_kv_heads", None)
    else:
        q = act_shard(q, *_attn_axes(hq))
        k = act_shard(k, *_attn_axes(hq))
        v = act_shard(v, *_attn_axes(hq))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if "q_norm" in p:
        q = _headwise_rms(q, p["q_norm"], cfg.norm_eps)
        k = _headwise_rms(k, p["k_norm"], cfg.norm_eps)

    if positions is None:
        positions = jnp.arange(S)
    theta = cfg.rope_theta if rope_theta is None else rope_theta
    rotary_dim = int(hd * cfg.rotary_frac) if (cfg.pos_embedding == "rope") else 0
    if rotary_dim and kv_x is None:
        q = apply_rope(q, positions, theta, rotary_dim)
        k = apply_rope(k, jnp.arange(k.shape[1]) if cache is None else positions,
                       theta, rotary_dim)

    new_cache = None
    if cache is not None and kv_x is None:
        # write this step's K/V at cache position(s) — pos may be a
        # scalar (whole batch at one depth) or [B] (per-sequence slot
        # positions, the continuous-batching serve path)
        pos = cache["pos"]
        ck = _cache_write(cache["k"], k.astype(cache["k"].dtype), pos)
        cv = _cache_write(cache["v"], v.astype(cache["v"].dtype), pos)
        k, v = ck, cv
        k_valid = pos + S
        new_cache = {"k": ck, "v": cv}
    else:
        k_valid = k.shape[1]

    scale = (hd ** -0.5) if not cfg.attn_output_multiplier else cfg.attn_output_multiplier
    out = _sdpa(q, k.astype(dt), v.astype(dt), scale=scale, causal=causal and kv_x is None,
                window=window if kv_x is None else 0,
                softcap=cfg.attn_softcap, q_pos=positions, k_valid=k_valid)
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(dt))
    return y, new_cache


# --------------------------------------------------------------------------
# MLA (deepseek-v3)
# --------------------------------------------------------------------------


def _init_mla(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.n_heads
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    return {
        "wq_a": param(ks[0], (d, ql), ("embed", "q_lora"), dt),
        "q_norm": param(ks[1], (ql,), ("q_lora",), dt, init="zeros"),
        "wq_b": param(ks[2], (ql, h, dn + dr), ("q_lora", "heads", "head_dim"), dt),
        "wkv_a": param(ks[3], (d, kl + dr), ("embed", "kv_lora"), dt),
        "kv_norm": param(ks[4], (kl,), ("kv_lora",), dt, init="zeros"),
        "wkv_b": param(ks[5], (kl, h, dn + dv), ("kv_lora", "heads", "head_dim"), dt),
        "wo": param(ks[6], (h, dv, d), ("heads", "head_dim", "embed"), dt,
                    scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def _apply_mla(p, x, cfg: ModelConfig, *, window, rope_theta, positions,
               cache, causal=True):
    B, S, _ = x.shape
    h = cfg.n_heads
    kl = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = x.dtype
    theta = cfg.rope_theta if rope_theta is None else rope_theta
    if positions is None:
        positions = jnp.arange(S)

    # queries through the low-rank path
    q_c = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(dt))
    q_c = _vecnorm(q_c, p["q_norm"], cfg.norm_eps)
    q = act_shard(jnp.einsum("bsr,rhe->bshe", q_c, p["wq_b"].astype(dt)),
                  "batch", "seq", "act_heads", None)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, theta, dr)

    # compressed KV
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(dt))
    c_kv, k_rope_in = ckv[..., :kl], ckv[..., kl:]
    c_kv = _vecnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope_in[:, :, None, :], positions, theta, dr)[:, :, 0]

    scale = (dn + dr) ** -0.5
    if cache is not None:
        pos = cache["pos"]
        cc = _cache_write(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), pos)
        cr = _cache_write(cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
                          pos)
        k_valid = pos + S
        # absorbed decode path: score in compressed space
        wkv_b_k = p["wkv_b"].astype(dt)[..., :dn]      # [kl, h, dn]
        q_eff = act_shard(jnp.einsum("bshe,rhe->bshr", q_nope, wkv_b_k),
                          "batch", "seq", "act_heads", None)  # [B,S,h,kl]
        T = cc.shape[1]
        logits = (jnp.einsum("bshr,btr->bhst", q_eff.astype(jnp.float32),
                             cc.astype(jnp.float32))
                  + jnp.einsum("bshe,bte->bhst", q_rope.astype(jnp.float32),
                               cr.astype(jnp.float32))) * scale
        mask = _attn_mask(T=T, causal=causal, window=window,
                          q_pos=positions, k_valid=k_valid)
        logits = jnp.where(mask[:, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", probs, cc.astype(jnp.float32)).astype(dt)
        wkv_b_v = p["wkv_b"].astype(dt)[..., dn:]      # [kl, h, dv]
        out = jnp.einsum("bshr,rhe->bshe", ctx, wkv_b_v)
        y = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(dt))
        return y, {"c_kv": cc, "k_rope": cr}

    # train / prefill: expand K and V per head
    kv = act_shard(jnp.einsum("bsr,rhe->bshe", c_kv, p["wkv_b"].astype(dt)),
                   "batch", "seq", "act_heads", None)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (*k_nope.shape[:3], dr))],
        axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = _sdpa(qq, k, v, scale=scale, causal=causal, window=window,
                softcap=0.0, q_pos=positions, k_valid=k.shape[1])
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(dt))
    return y, None


def _vecnorm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) *
            (scale.astype(jnp.float32) + 1.0)).astype(x.dtype)
