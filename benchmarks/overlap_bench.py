"""Decomposed-collective benchmarks (beyond-paper §Perf lever).

Contrasts, on an 8-device host ring:
* ``all_gather`` then matmul (two phases, no overlap possible) vs
  ``all_gather_matmul`` (per-chunk interleave);
* ``matmul`` then ``reduce_scatter`` vs ``matmul_reduce_scatter``;
* unidirectional vs bidirectional ring all-gather.

Wall-clock on CPU measures dispatch/fusion effects only; the derived
column also reports the HLO collective op count + wire bytes from the
lowered program (the quantity the TPU roofline cares about).
"""

from __future__ import annotations

import time
from functools import partial
from typing import Dict, List

import numpy as np

RESULTS: List[Dict] = []


def _time(fn, *args, repeats=20):
    import jax
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6


def run_all():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import jit_shard_map
    from repro.core import overlap
    from repro.launch.hlo_analysis import analyze_collectives
    from repro.parallel import make_mesh

    mesh = make_mesh((8,), ("x",))
    n = 8
    print("Decomposed/overlapped collectives (8-device ring)")

    def smap(f, in_specs, out_specs):
        return jit_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)

    rng = np.random.RandomState(0)
    x = rng.randn(1024, 512).astype(np.float32)   # gathered over rows
    w = rng.randn(512, 256).astype(np.float32)

    cases = {
        "ag_then_matmul": smap(
            lambda a, b: jax.lax.all_gather(a, "x", axis=0, tiled=True) @ b,
            (P("x"), P()), P()),
        "ag_matmul_overlap": smap(
            partial(overlap.all_gather_matmul, axis="x"),
            (P("x"), P()), P()),
    }
    xk = rng.randn(1024, 512).astype(np.float32)
    wk = rng.randn(512, 256).astype(np.float32)
    cases["matmul_then_rs"] = smap(
        lambda a, b: jax.lax.psum_scatter(a @ b, "x", scatter_dimension=0,
                                          tiled=True),
        (P(None, "x"), P("x")), P("x"))
    cases["matmul_rs_overlap"] = smap(
        partial(overlap.matmul_reduce_scatter, axis="x"),
        (P(None, "x"), P("x")), P("x"))
    cases["ag_ring_uni"] = smap(
        partial(overlap.all_gather_ring, axis="x", bidirectional=False),
        (P("x"),), P())
    cases["ag_ring_bidi"] = smap(
        partial(overlap.all_gather_ring, axis="x", bidirectional=True),
        (P("x"),), P())

    for name, fn in cases.items():
        args = (x, w) if "matmul" in name and "rs" not in name else (
            (xk, wk) if "rs" in name else (x,))
        us = _time(fn, *args)
        lowered = fn.lower(*args)
        colls = analyze_collectives(lowered.compile().as_text(), n)
        derived = (f"coll_ops={sum(colls.count_by_kind.values())};"
                   f"wire_bytes={colls.total_bytes:.3e}")
        RESULTS.append({"bench": "overlap", "variant": name,
                        "us_per_call": us, "derived": derived})
        print(f"  {name:20s} {us:10.1f} us  {derived}")

    # serial-step count: bidi ring halves the chain depth
    RESULTS.append({
        "bench": "overlap", "variant": "ring_steps",
        "us_per_call": 0.0,
        "derived": f"uni_steps={n-1};bidi_steps={(n-1+1)//2}"})
    return RESULTS
