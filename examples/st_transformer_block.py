"""ST-integrated transformer block: model collectives through STQueue.

The paper's interface batches *communication descriptors* and triggers
them from the device stream.  This example applies the same programming
model to a Megatron-style sequence-parallel MLP block — the per-layer
collectives become deferred ST descriptors between compute kernels:

    enqueue_collective(all_gather x)       # sequence-parallel gather
    enqueue_start(); enqueue_wait()        # trigger + stream gate
    enqueue_kernel(h = silu(x @ w1_loc))   # column-parallel
    enqueue_kernel(y~ = h @ w2_loc)        # row-parallel (partial sums)
    enqueue_collective(reduce_scatter y~)  # TP combine + re-scatter
    enqueue_start(); enqueue_wait()

Both engines execute the same program; results match a plain jnp
reference of the unsharded block.

Run:  PYTHONPATH=src python examples/st_transformer_block.py
"""
import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FusedEngine, HostEngine, create_queue
from repro.parallel import make_mesh

N = 8                            # tp ranks
T, D, FL = 16, 128, 64           # tokens/shard, model dim, ff dim/shard
mesh = make_mesh((N,), ("tp",))

q = create_queue(mesh, "st_mlp")
q.buffer("x", (N * T, D), np.float32, pspec=("tp",))          # seq-parallel
q.buffer("x_full", (N * N * T, D), np.float32, pspec=("tp",))  # gathered/shard
q.buffer("w1", (N, D, FL), np.float32, pspec=("tp",))          # column-par
q.buffer("w2", (N, FL, D), np.float32, pspec=("tp",))          # row-par
q.buffer("h", (N * N * T, FL), np.float32, pspec=("tp",))
q.buffer("y_part", (N * N * T, D), np.float32, pspec=("tp",))
q.buffer("y", (N * T, D), np.float32, pspec=("tp",))

# batch 1: deferred sequence-parallel all-gather of the activations
q.enqueue_collective("all_gather", "x", "x_full", "tp", dim=0)
q.enqueue_start()
q.enqueue_wait()

# compute kernels (local views: x_full [N*T,D], w1 [1,D,FL], w2 [1,FL,D])
q.enqueue_kernel(lambda xf, w1: jax.nn.silu(xf @ w1[0]),
                 reads=["x_full", "w1"], writes=["h"], name="mlp_in")
q.enqueue_kernel(lambda h, w2: h @ w2[0],
                 reads=["h", "w2"], writes=["y_part"], name="mlp_out")

# batch 2: deferred TP reduce-scatter (combine partial sums, re-scatter seq)
q.enqueue_collective("reduce_scatter", "y_part", "y", "tp", dim=0)
q.enqueue_start()
q.enqueue_wait()

prog = q.build()
print(f"ST MLP block: {len(prog.descriptors)} descriptors, "
      f"{prog.n_batches} trigger batches, host dispatches "
      f"{prog.dispatch_count_host()} vs fused {prog.dispatch_count_fused()}")

rng = np.random.RandomState(0)
x0 = rng.randn(N * T, D).astype(np.float32) * 0.5
w1 = rng.randn(N, D, FL).astype(np.float32) * 0.05
w2 = rng.randn(N, FL, D).astype(np.float32) * 0.05

fused = FusedEngine(prog, mode="dataflow")
out_f = fused(fused.init_buffers({"x": x0, "w1": w1, "w2": w2}))
host = HostEngine(prog, sync="every_op")
out_h = host(host.init_buffers({"x": x0, "w1": w1, "w2": w2}))

# unsharded reference: w1 concat over FL columns, w2 concat over FL rows
w1_full = np.concatenate(list(w1), axis=1)          # (D, N*FL)
w2_full = np.concatenate(list(w2), axis=0)          # (N*FL, D)
y_ref = np.asarray(jax.nn.silu(jnp.asarray(x0) @ w1_full)) @ w2_full

np.testing.assert_allclose(np.asarray(out_f["y"]), y_ref, rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(np.asarray(out_h["y"]), y_ref, rtol=2e-4, atol=2e-4)
print("fused == host == unsharded reference ✓")
print(f"host control path: {host.stats.dispatches} dispatches / "
      f"{host.stats.sync_points} syncs; ST: 1 / 1")
