"""Host-orchestrated engine — the paper's *baseline* control path (Fig. 1)
and its progress-thread emulation cost model.

Executes the same :class:`~repro.core.queue.STProgram` as the fused
engine, but the way a conventional GPU-aware MPI application does:

* every compute kernel is its **own** device dispatch;
* the host **synchronizes** with the device at kernel boundaries
  (``block_until_ready`` — the "expensive synchronization points" of
  paper Fig. 1);
* each communication batch is dispatched as separate per-channel device
  programs, again host-driven — the analogue of the CPU progress thread
  walking descriptors and posting them one at a time (paper §IV-B).

The engine counts dispatches and host sync points so benchmarks can
report the control-path cost next to wall time.  Results are bit-wise
comparable with the fused engine (tests assert allclose), so the A/B is
purely a control-path experiment — exactly the paper's methodology.

Sync policies
-------------
``every_op``  — block after *every* dispatch (paper Fig. 1 behaviour).
``batch``     — block once per communication batch (an optimistic host
                baseline: a perfectly pipelining CPU progress thread).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from .descriptors import CollDesc, KernelDesc, StartDesc, WaitDesc
from .engine_fused import _axes_tuple, _ensure_vma, _linear_rank
from .matching import Channel
from .queue import STProgram


@dataclasses.dataclass
class HostStats:
    dispatches: int = 0
    sync_points: int = 0

    def reset(self):
        self.dispatches = 0
        self.sync_points = 0


class HostEngine:
    """Per-descriptor, host-driven execution of an STProgram."""

    def __init__(self, program: STProgram, sync: str = "every_op",
                 sanitize: bool = False):
        if sync not in ("every_op", "batch"):
            raise ValueError("sync must be 'every_op' or 'batch'")
        program.require_closed()
        if sanitize:
            # the host engine syncs at descriptor boundaries, so there is
            # no canary to plant — the sanitizer reduces to the static
            # deposit-before-wait assertion over the descriptor stream
            from .verify import check_deposit_order
            check_deposit_order(program)
        self.program = program
        self.sync = sync
        self.mesh = program.mesh
        self._mesh_shape = dict(self.mesh.shape)
        self.stats = HostStats()
        self._kernel_cache: Dict[int, Any] = {}
        self._channel_cache: Dict[int, Any] = {}
        self._coll_cache: Dict[int, Any] = {}

    # -- buffers (same layout as the fused engine) ----------------------------

    def shardings(self) -> Dict[str, NamedSharding]:
        return {
            name: NamedSharding(self.mesh, P(*spec.pspec))
            for name, spec in self.program.buffers.items()
        }

    def init_buffers(self, init: Optional[Dict[str, Any]] = None) -> Dict[str, jax.Array]:
        init = init or {}
        out = {}
        for name, spec in self.program.buffers.items():
            sh = NamedSharding(self.mesh, P(*spec.pspec))
            if name in init:
                out[name] = jax.device_put(jnp.asarray(init[name], spec.dtype), sh)
            else:
                out[name] = jax.device_put(jnp.zeros(spec.shape, spec.dtype), sh)
        return out

    # -- execution ------------------------------------------------------------

    def __call__(self, mem: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        prog = self.program
        mem = dict(mem)
        batches = {b.index: b for b in prog.batches}

        for i, d in enumerate(prog.descriptors):
            if isinstance(d, KernelDesc):
                fn = self._kernel_fn(i, d)
                outs = fn(*[mem[r] for r in d.reads])
                if not isinstance(outs, (tuple, list)):
                    outs = (outs,)
                mem.update(zip(d.writes, outs))
                self.stats.dispatches += 1
                self._sync(outs, always=False)

            elif isinstance(d, StartDesc):
                # The "progress thread" observes the trigger and posts each
                # descriptor of the batch as its own device program.
                batch = batches[d.batch]
                results = []
                for j, ch in enumerate(batch.channels):
                    fn = self._channel_fn((i, j), ch)
                    mem[ch.dst_buf], r = fn(mem[ch.src_buf], mem[ch.dst_buf])
                    results.append(r)
                    self.stats.dispatches += 1
                    self._sync([r], always=False)
                for j, coll in enumerate(batch.colls):
                    fn = self._coll_fn((i, j), coll)
                    mem[coll.out] = fn(mem[coll.buf])
                    results.append(mem[coll.out])
                    self.stats.dispatches += 1
                    self._sync([mem[coll.out]], always=False)
                if self.sync == "batch" and results:
                    jax.block_until_ready(results)
                    self.stats.sync_points += 1

            elif isinstance(d, WaitDesc):
                # Host-level MPI_Waitall: a true host block.
                jax.block_until_ready(list(mem.values()))
                self.stats.sync_points += 1

        return mem

    # -- per-descriptor compiled programs --------------------------------------

    def _sync(self, vals, always: bool):
        if always or self.sync == "every_op":
            jax.block_until_ready(list(vals))
            self.stats.sync_points += 1

    def _kernel_fn(self, key: int, d: KernelDesc):
        if key not in self._kernel_cache:
            prog = self.program
            in_specs = tuple(P(*prog.buffers[r].pspec) for r in d.reads)
            out_specs = tuple(P(*prog.buffers[w].pspec) for w in d.writes)

            def body(*args):
                outs = d.fn(*args)
                if not isinstance(outs, (tuple, list)):
                    outs = (outs,)
                fixed = []
                for w, o in zip(d.writes, outs):
                    axes = tuple(a for a in jax.tree.leaves(list(prog.buffers[w].pspec)) if a)
                    fixed.append(_ensure_vma(o.astype(prog.buffers[w].dtype), axes))
                return tuple(fixed)

            self._kernel_cache[key] = jax.jit(
                shard_map(body, mesh=self.mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
            )
        return self._kernel_cache[key]

    def _channel_fn(self, key, ch: Channel):
        if key not in self._channel_cache:
            prog = self.program
            mesh_shape = self._mesh_shape
            axes = _axes_tuple(ch.axis)
            src_spec = P(*prog.buffers[ch.src_buf].pspec)
            dst_spec = P(*prog.buffers[ch.dst_buf].pspec)
            perm = ch.perm(mesh_shape)

            def body(src, dst):
                s = src[ch.send_region] if ch.send_region is not None else src
                received = jax.lax.ppermute(
                    s, axes if len(axes) > 1 else axes[0], perm
                )
                region = ch.recv_region if ch.recv_region is not None else tuple(
                    slice(None) for _ in dst.shape
                )
                if ch.mode == "add":
                    dst = dst.at[region].add(received.astype(dst.dtype))
                else:
                    dsts = np.array(sorted({t for _, t in perm}), dtype=np.int32)
                    me = _linear_rank(axes, mesh_shape)
                    is_recv = jnp.isin(me, jnp.asarray(dsts))
                    dst = dst.at[region].set(
                        jnp.where(is_recv, received.astype(dst.dtype), dst[region])
                    )
                return dst, received

            self._channel_cache[key] = jax.jit(
                shard_map(body, mesh=self.mesh,
                          in_specs=(src_spec, dst_spec),
                          out_specs=(dst_spec, src_spec), check_vma=False)
            )
        return self._channel_cache[key]

    def _coll_fn(self, key, coll: CollDesc):
        if key not in self._coll_cache:
            prog = self.program
            axes = _axes_tuple(coll.axis)
            axis = axes if len(axes) > 1 else axes[0]
            in_spec = P(*prog.buffers[coll.buf].pspec)
            out_spec = P(*prog.buffers[coll.out].pspec)
            kw = dict(coll.kwargs)

            def body(x):
                if coll.op == "all_gather":
                    out = jax.lax.all_gather(x, axis, axis=kw.get("dim", 0),
                                             tiled=kw.get("tiled", True))
                elif coll.op == "reduce_scatter":
                    out = jax.lax.psum_scatter(x, axis,
                                               scatter_dimension=kw.get("dim", 0),
                                               tiled=kw.get("tiled", True))
                elif coll.op == "all_reduce":
                    out = jax.lax.psum(x, axis)
                elif coll.op == "all_to_all":
                    out = jax.lax.all_to_all(x, axis, split_axis=kw.get("split_axis", 0),
                                             concat_axis=kw.get("concat_axis", 0),
                                             tiled=kw.get("tiled", True))
                elif coll.op == "ppermute":
                    out = jax.lax.ppermute(x, axis, kw["perm"])
                else:  # pragma: no cover
                    raise ValueError(coll.op)
                out_axes = tuple(a for a in jax.tree.leaves(list(prog.buffers[coll.out].pspec)) if a)
                return _ensure_vma(out.astype(prog.buffers[coll.out].dtype), out_axes)

            self._coll_cache[key] = jax.jit(
                shard_map(body, mesh=self.mesh, in_specs=(in_spec,),
                          out_specs=out_spec, check_vma=False)
            )
        return self._coll_cache[key]
