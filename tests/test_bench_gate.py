"""benchmarks/run.py ``check_against`` — the Faces perf-regression gate.

Pure-logic unit tests (no JAX, no timing): the median comparison must
run ONLY when the recorded file carries a ``_meta`` loop-settings stamp
that matches the fresh run's — a stamp-less (stale) file must fall back
to invariants-only instead of comparing medians at unknown settings.
"""

import importlib
import json
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

META = {"faces_inner": 10, "faces_max_iters": 64}
OTHER_META = {"faces_inner": 6, "faces_max_iters": 16}


@pytest.fixture()
def check_against(monkeypatch):
    # benchmarks.run sets a default XLA_FLAGS at import for its own
    # __main__ use; pin the var (and restore after) so importing the
    # module can never leak an 8-device grid into this test process
    monkeypatch.setenv("XLA_FLAGS", os.environ.get("XLA_FLAGS", ""))
    monkeypatch.syspath_prepend(REPO)
    mod = importlib.import_module("benchmarks.run")
    return mod.check_against


VARIANTS = ("faces_fig8/baseline", "faces_fig8/st_offload",
            "faces_fig11/baseline", "faces_fig11/st_offload")


def _faces(median_ms, meta=META):
    """A run where every tracked variant is steady except fig8/baseline,
    whose median is ``median_ms`` (the speed-normalization uses the
    run-wide MEDIAN ratio, so a lone drifting variant cannot hide)."""
    out = {k: {"median_ms": 50.0, "dispatches": 1} for k in VARIANTS}
    out["faces_fig8/baseline"] = {"median_ms": median_ms, "dispatches": 79}
    if meta is not None:
        out["_meta"] = dict(meta)
    return out


def _write(tmp_path, data):
    path = tmp_path / "BENCH_faces.json"
    path.write_text(json.dumps(data))
    return str(path)


def test_matching_meta_compares_medians(tmp_path, check_against, capsys):
    path = _write(tmp_path, _faces(100.0))
    # >20% regression at MATCHING settings must fail the gate
    assert check_against(_faces(200.0), path) == 1
    err = capsys.readouterr().err
    # the non-zero exit must NAME the failing row (stderr, so it is
    # visible in CI logs even when stdout is buffered away)
    assert "PERF GATE FAILED" in err
    assert "faces_fig8/baseline" in err and "> bound" in err
    # and an unchanged run passes with the medians actually checked
    assert check_against(_faces(100.0), path) == 0
    assert f"{len(VARIANTS)} tracked medians" in capsys.readouterr().out


def test_mismatched_meta_skips_medians(tmp_path, check_against, capsys):
    path = _write(tmp_path, _faces(100.0))
    # same 2x "regression", but at different loop settings: skipped
    assert check_against(_faces(200.0, meta=OTHER_META), path) == 0
    out = capsys.readouterr().out
    assert "settings differ" in out and "median checks skipped" in out


def test_absent_stored_meta_skips_medians(tmp_path, check_against, capsys):
    """A recorded file WITHOUT a _meta stamp must not be median-compared
    at arbitrary settings — a stale file used to fail (or wrongly pass)
    CI this way."""
    path = _write(tmp_path, _faces(100.0, meta=None))
    assert check_against(_faces(200.0), path) == 0
    out = capsys.readouterr().out
    assert "no _meta settings stamp" in out
    assert "median checks skipped" in out
    # invariants still enforced even without the stamp
    stale = _faces(100.0, meta=None)
    stale["faces_figP/fused_per_iter"] = {"median_ms": 1.0, "dispatches": 10}
    path = _write(tmp_path, stale)
    fresh = _faces(100.0)
    fresh["faces_figP/persistent"] = {"median_ms": 9.0, "dispatches": 1}
    fresh["faces_figP/fused_per_iter"] = {"median_ms": 3.0, "dispatches": 10}
    assert check_against(fresh, path) == 1
    assert "1-dispatch path" in capsys.readouterr().err
