"""Fused ST engine — the TPU-native stream-triggered execution path.

Executes an :class:`~repro.core.queue.STProgram` as **one** XLA
computation: every enqueued kernel, trigger, channel and wait lowers
into a single ``jax.jit(shard_map(...))`` program.  The host dispatches
once per program (vs once per descriptor in
:mod:`~repro.core.engine_host`), which is the paper's control-path
offload: after enqueue, the device sequencer drives kernels and
communication with no host round-trips.

Lowering of each descriptor kind
--------------------------------
* ``KernelDesc``      — apply ``fn`` to local buffer views.
* ``StartDesc``       — *writeValue*: bump the trigger token, after tying
                        it to everything the stream has produced so far
                        (stream order: a writeValue executes only after
                        all earlier stream commands complete).
* matched channels    — ``jax.lax.ppermute`` whose operand is *tied* to
                        the trigger token (the DWQ descriptor fires when
                        the counter hits its threshold).
* ``CollDesc``        — a whole deferred collective (beyond-paper).
* ``WaitDesc``        — *waitValue*: derive the completion counter from
                        the channel results and *gate* the stream on it.

Modes
-----
``stream``  (paper-faithful) — literal GPU-stream FIFO: the trigger
    depends on **all** prior stream commands and the wait gates **all**
    buffers, exactly like a stream-wide waitValue.
``dataflow`` (beyond-paper) — the trigger depends only on the buffers
    the batch actually sends, and the wait gates only the buffers the
    batch received into.  XLA may overlap independent kernels with
    communication — the scheduling freedom the paper's NIC offload was
    reaching for, recovered at compile time.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from . import counters
from .descriptors import (
    CollDesc,
    GridOffsetPeer,
    KernelDesc,
    OffsetPeer,
    PairListPeer,
    StartDesc,
    WaitDesc,
    perm_for,
)
from .matching import Channel
from .queue import STProgram


def _axes_tuple(axis) -> Tuple[str, ...]:
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _ensure_vma(x, axis_names: Tuple[str, ...]):
    """Make `x` explicitly varying over `axis_names` (new-style shard_map
    tracks a "varying manual axes" set; constants need `pvary`)."""
    try:
        cur = jax.typeof(x).vma  # type: ignore[attr-defined]
    except Exception:
        return x
    missing = tuple(a for a in axis_names if a not in cur)
    if missing:
        x = jax.lax.pvary(x, missing)
    return x


def _linear_rank(axes: Tuple[str, ...], mesh_shape: Dict[str, int]):
    """Flattened rank index over an ordered tuple of mesh axes."""
    idx = jnp.zeros((), dtype=jnp.int32)
    for a in axes:
        idx = idx * mesh_shape[a] + jax.lax.axis_index(a)
    return idx


def _is_full_identity(perm, axes: Tuple[str, ...],
                      mesh_shape: Dict[str, int]) -> bool:
    """True iff ``perm`` maps EVERY rank along ``axes`` to itself.

    Such a ppermute returns its operand bit-for-bit on every rank, so
    the collective can be elided — the payload is already in place.
    (A *partial* identity does not qualify: unmatched ranks would have
    received zeros, so the ppermute still changes data.)  Identity
    channels are how a part's own ghost planes ride the trigger/wait
    machinery (``GridOffsetPeer(axes, (0,..,0))``); eliding the
    collective keeps their counter semantics while costing only the
    pack/deposit copies.
    """
    n = 1
    for a in axes:
        n *= mesh_shape[a]
    return len(perm) == n and all(s == d for s, d in perm)


class FusedEngine:
    """Compile & run an STProgram as one fused XLA program."""

    def __init__(
        self,
        program: STProgram,
        mode: str = "stream",
        donate: bool = False,
        coalesce: bool = True,
        sanitize: bool = False,
    ):
        if mode not in ("stream", "dataflow"):
            raise ValueError("mode must be 'stream' or 'dataflow'")
        program.require_closed()
        self.program = program
        self.mode = mode
        self.donate = donate
        # Execute the batches' recorded coalescing plans (fused by-axis
        # transfers) when present; False forces the per-channel lowering
        # even on a plan-carrying program (A/B benchmarks, parity tests).
        self.coalesce = coalesce
        # Runtime sanitizer (see repro.core.verify): NaN-canary poisoning
        # of unwritten message slots + deposit-before-wait assertions
        # inside the interpreter (SanitizeError at trace time).
        self.sanitize = sanitize
        self.mesh = program.mesh
        self._mesh_shape = dict(self.mesh.shape)
        self._jitted = None
        # HostStats-shaped dispatch accounting (one dispatch per call,
        # zero host sync points) so benchmarks measure rather than infer
        from .engine_host import HostStats
        self.stats = HostStats()

    # -- public API -----------------------------------------------------------

    def shardings(self) -> Dict[str, NamedSharding]:
        return {
            name: NamedSharding(self.mesh, P(*spec.pspec))
            for name, spec in self.program.buffers.items()
        }

    def init_buffers(self, init: Optional[Dict[str, Any]] = None) -> Dict[str, jax.Array]:
        """Device-place (and shard) the program's buffers."""
        init = init or {}
        out = {}
        for name, spec in self.program.buffers.items():
            sh = NamedSharding(self.mesh, P(*spec.pspec))
            if name in init:
                out[name] = jax.device_put(jnp.asarray(init[name], spec.dtype), sh)
            else:
                out[name] = jax.device_put(
                    jnp.zeros(spec.shape, spec.dtype), sh
                )
        return out

    def compile(self):
        if self._jitted is None:
            self._jitted = self._build_jit()
        return self._jitted

    def __call__(self, mem: Dict[str, jax.Array]):
        out = self.compile()(mem)
        self.stats.dispatches += 1
        return out

    def lower(self, mem_specs: Optional[Dict[str, jax.ShapeDtypeStruct]] = None):
        """Lower (ShapeDtypeStruct stand-ins — used by dry-run/benchmarks)."""
        if mem_specs is None:
            shardings = self.shardings()
            mem_specs = {
                n: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=shardings[n])
                for n, s in self.program.buffers.items()
            }
        return self.compile().lower(mem_specs)

    # -- lowering ---------------------------------------------------------------

    def _build_jit(self):
        prog = self.program
        specs = {n: P(*s.pspec) for n, s in prog.buffers.items()}

        body = functools.partial(_run_program, prog=prog, mode=self.mode,
                                 mesh_shape=self._mesh_shape,
                                 coalesce=self.coalesce,
                                 sanitize=self.sanitize)
        # check_vma=False: Pallas calls inside the program can't declare
        # varying-mesh-axes on their out_shapes; ordering is enforced by
        # the token ties, not by vma tracking.
        sharded = shard_map(
            body, mesh=self.mesh, in_specs=(specs,), out_specs=specs,
            check_vma=False,
        )
        donate = (0,) if self.donate else ()
        return jax.jit(sharded, donate_argnums=donate)


# -- program interpreter (runs inside shard_map, traced once) ----------------


def _run_program(mem: Dict[str, jax.Array], *, prog: STProgram, mode: str,
                 mesh_shape: Dict[str, int],
                 coalesce: bool = True,
                 sanitize: bool = False) -> Dict[str, jax.Array]:
    mem, _, _ = _interpret_program(mem, prog=prog, mode=mode,
                                   mesh_shape=mesh_shape, coalesce=coalesce,
                                   sanitize=sanitize)
    return mem


def fresh_token_banks(prog: STProgram):
    """One (trigger, completion) counter pair per program id — a single
    entry for a plain program, one per sub-program for a composed
    :class:`~repro.core.schedule.STSchedule` (each MPIX_Queue keeps its
    own counters; composition must not merge them)."""
    pids = tuple(prog.buffers_by_pid())
    return ({pid: counters.fresh_token() for pid in pids},
            {pid: counters.fresh_token() for pid in pids})


def _interpret_program(
    mem: Dict[str, jax.Array],
    *,
    prog: STProgram,
    mode: str,
    mesh_shape: Dict[str, int],
    tokens: Optional[Dict[int, jax.Array]] = None,
    comp_tokens: Optional[Dict[int, jax.Array]] = None,
    coalesce: bool = True,
    sanitize: bool = False,
) -> Tuple[Dict[str, jax.Array], Dict[int, jax.Array], Dict[int, jax.Array]]:
    """Interpret one pass over ``prog``'s descriptors.

    Shared by :class:`FusedEngine` (one pass per host dispatch) and
    :class:`~repro.core.engine_persistent.PersistentEngine` (N passes
    inside a device-resident loop).  ``tokens``/``comp_tokens`` are the
    trigger and completion counter *banks*, keyed by program id: a plain
    program uses the single pid-0 pair; a composed schedule gets one
    pair per sub-program, so each queue's FIFO/gating is scoped to its
    own buffers and queues never serialize each other.  Passing the
    banks returned by a previous pass preserves MPIX_Queue-reuse
    semantics — the counters keep advancing across iterations instead
    of restarting at zero.

    With ``coalesce`` (default) a batch that carries a build-time
    :class:`~repro.core.matching.CoalescePlan` fires its fused by-axis
    transfers instead of one ppermute per channel; deposits replay in
    the original channel order so results are bit-identical either way.

    ``sanitize`` turns on the runtime sanitizer (see
    :mod:`repro.core.verify`): message-slot buffers are poisoned with
    NaN canaries at pass start — a read before the slot's deposit lands
    surfaces as NaNs instead of silently-stale data — and a
    :class:`~repro.core.verify.DepositTracker` asserts deposit-before-
    wait ordering as the interpreter traces, raising
    :class:`~repro.core.verify.SanitizeError` before any device work
    runs.  Race-free programs stay bit-identical: the canary's original
    value is saved and non-receiving ranks of the slot's first replace
    deposit restore it (later deposits see post-deposit contents, so
    only the first needs the fallback).
    """
    mem = dict(mem)
    if sanitize:
        from .verify import DepositTracker, canary_buffers
        tracker: Optional[DepositTracker] = DepositTracker(prog)
        canary_saved: Optional[Dict[str, jax.Array]] = {}
        for cb in canary_buffers(prog):
            if cb in mem:
                canary_saved[cb] = mem[cb]
                mem[cb] = jnp.full_like(mem[cb], jnp.nan)
    else:
        tracker = None
        canary_saved = None
    pid_bufs = prog.buffers_by_pid()
    if tokens is None or comp_tokens is None:
        fresh_trigs, fresh_comps = fresh_token_banks(prog)
        tokens = fresh_trigs if tokens is None else tokens
        comp_tokens = fresh_comps if comp_tokens is None else comp_tokens
    tokens = dict(tokens)
    comp_tokens = dict(comp_tokens)
    batches_by_index = {b.index: b for b in prog.batches}
    # buffers each batch received into (for dataflow-mode waits): a
    # cross-program channel's deposit is gated by the RECEIVING batch's
    # wait (cross_recv_bufs), not by the triggering batch's own wait
    recv_bufs_by_batch: Dict[int, List[str]] = {
        b.index: [c.dst_buf for c in b.channels
                  if c.dst_pid is None or c.dst_pid == b.pid]
        + [c.out for c in b.colls] + list(b.cross_recv_bufs)
        for b in prog.batches
    }
    send_bufs_by_batch: Dict[int, List[str]] = {
        b.index: [c.src_buf for c in b.channels] + [c.buf for c in b.colls]
        for b in prog.batches
    }

    for d in prog.descriptors:
        pid = d.pid
        if isinstance(d, KernelDesc):
            if tracker is not None:
                tracker.kernel(d)
            args = [mem[r] for r in d.reads]
            if mode == "stream":
                # strict FIFO: kernel ordered after everything before it
                # on its OWN program's stream (queues stay independent)
                tokens[pid], args = counters.tie(tokens[pid], *args)
            outs = d.fn(*args)
            if not isinstance(outs, (tuple, list)):
                outs = (outs,)
            if len(outs) != len(d.writes):
                raise ValueError(
                    f"kernel {d.name!r} returned {len(outs)} values for "
                    f"{len(d.writes)} write buffers"
                )
            for w, o in zip(d.writes, outs):
                spec = prog.buffers[w].pspec
                axes = tuple(a for a in jax.tree.leaves(list(spec)) if a)
                mem[w] = _ensure_vma(o.astype(prog.buffers[w].dtype), axes)
                if canary_saved:
                    canary_saved.pop(w, None)  # whole-buffer rewrite
            if mode == "stream":
                tokens[pid] = counters.completion_from(
                    tokens[pid], *[mem[w] for w in d.writes])

        elif isinstance(d, StartDesc):
            if tracker is not None:
                tracker.start(d)
            batch = batches_by_index[d.batch]
            use_plan = coalesce and batch.plan is not None
            # writeValue: bump after all earlier commands of THIS
            # program's stream.
            if mode == "stream":
                deps = [mem[b] for b in pid_bufs[pid]]
                tokens[pid], _ = counters.tie(tokens[pid], *deps)
            elif not use_plan:
                deps = [mem[b] for b in send_bufs_by_batch[d.batch]]
                tokens[pid], _ = counters.tie(tokens[pid], *deps)
            # else (dataflow + coalesced): the trigger ties only to the
            # packed staging buffers, inside _run_coalesced_batch — the
            # pack already depends on every source slab, so tying the
            # whole live set would just re-materialize untouched buffers
            tokens[pid] = counters.bump(tokens[pid])
            # fire every descriptor in the batch (threshold reached).
            # Completion is banked per DESTINATION program: a
            # cross-program channel bumps the receiver's completion
            # counter, so the receiver's wait gate observes this
            # sender's completion (trigger stays on the sender's bank).
            results_by_pid: Dict[int, List[Any]] = {}
            if use_plan:
                plan = batch.plan
                mem, received = _run_coalesced_batch(mem, plan, tokens[pid],
                                                     mesh_shape,
                                                     fallbacks=canary_saved)
                # a fused transfer feeds the completion counter of every
                # program it carries a final segment for (the deposited
                # slabs are slices of the payload, so gating on the
                # payload gates the deposits — and an all-domestic batch
                # keeps the exact PR-4 graph: one barrier, all payloads)
                pid_transfers: Dict[int, List[int]] = {}
                for ci, ch in enumerate(plan.channels):
                    if not plan.routes[ci]:
                        continue  # statically dead: deposits zeros only
                    dpid = pid if ch.dst_pid is None else ch.dst_pid
                    ti = plan.routes[ci][-1][0]
                    pid_transfers.setdefault(dpid, []).append(ti)
                for dpid, tis in pid_transfers.items():
                    results_by_pid[dpid] = [received[ti]
                                            for ti in sorted(set(tis))]
            else:
                for ch in batch.channels:
                    mem, r = _run_channel(mem, ch, tokens[pid], mesh_shape,
                                          fallbacks=canary_saved)
                    dpid = pid if ch.dst_pid is None else ch.dst_pid
                    results_by_pid.setdefault(dpid, []).append(r)
            for coll in batch.colls:
                mem, r = _run_collective(mem, coll, tokens[pid], prog)
                if canary_saved:
                    canary_saved.pop(coll.out, None)  # wholly overwritten
                results_by_pid.setdefault(pid, []).append(r)
            for dpid, rs in results_by_pid.items():
                comp_tokens[dpid] = counters.completion_from(
                    comp_tokens[dpid], *rs)

        elif isinstance(d, WaitDesc):
            if tracker is not None:
                tracker.wait(d)
            # waitValue: gate this program's stream on its completion
            # counter (another program's descriptors flow right past).
            if mode == "stream":
                names = list(pid_bufs[pid])
                comp_tokens[pid], vals = counters.gate(
                    comp_tokens[pid], *[mem[n] for n in names])
                mem.update(zip(names, vals))
                tokens[pid] = (counters.bump(tokens[pid], 0)
                               + 0 * comp_tokens[pid])  # stream advances
            else:
                names = recv_bufs_by_batch.get(d.batch, [])
                if names:
                    comp_tokens[pid], vals = counters.gate(
                        comp_tokens[pid], *[mem[n] for n in names])
                    mem.update(zip(names, vals))
        # Send/Recv/Coll descs themselves are no-ops here: they were
        # matched into their batch at build time (deferred execution).

    return mem, tokens, comp_tokens


def _deposit_channel(mem, ch: Channel, received, mesh_shape,
                     fallbacks: Optional[Dict[str, jax.Array]] = None):
    """Deposit one channel's received slab into its destination buffer.

    Shared by the per-channel and coalesced lowerings (same ops, same
    order → bit-identical results).  The receiver mask always derives
    from the channel's *original* peer permutation, independent of how
    the payload travelled.

    ``fallbacks`` is the sanitizer's saved-original map: when the
    destination buffer was NaN-poisoned at pass start, the first
    replace deposit takes its non-receiver lanes from the saved
    original instead of the poisoned current value (consumed on use, so
    later deposits see real post-deposit contents).
    """
    axes = _axes_tuple(ch.axis)
    perm = ch.perm(mesh_shape)
    dst = mem[ch.dst_buf]
    region = ch.recv_region if ch.recv_region is not None else tuple(
        slice(None) for _ in dst.shape
    )
    if ch.mode == "add":
        # unmatched receivers got zeros from ppermute — neutral for add
        dst = dst.at[region].add(received.astype(dst.dtype))
    else:
        # only ranks that actually have a matching sender take the value
        dsts = np.array(sorted({d for _, d in perm}), dtype=np.int32)
        me = _linear_rank(axes, mesh_shape)
        is_receiver = jnp.isin(me, jnp.asarray(dsts))
        orig = fallbacks.pop(ch.dst_buf, None) if fallbacks else None
        cur = dst[region] if orig is None else orig[region]
        dst = dst.at[region].set(
            jnp.where(is_receiver, received.astype(dst.dtype), cur)
        )
    mem[ch.dst_buf] = dst
    return mem


def _run_channel(mem, ch: Channel, token, mesh_shape, fallbacks=None):
    """One matched (send, recv) pair → one ppermute, tied to the trigger."""
    axes = _axes_tuple(ch.axis)
    src = mem[ch.src_buf]
    if ch.send_region is not None:
        src = src[ch.send_region]
    # DWQ deferred execution: operand depends on the trigger counter.
    _, (src,) = counters.tie(token, src)
    perm = ch.perm(mesh_shape)
    if _is_full_identity(perm, axes, mesh_shape):
        received = src  # every rank keeps its payload: collective elided
    else:
        received = jax.lax.ppermute(
            src, axes if len(axes) > 1 else axes[0], perm)
    mem = _deposit_channel(mem, ch, received, mesh_shape, fallbacks=fallbacks)
    return mem, received


def _run_coalesced_batch(mem, plan, token, mesh_shape, fallbacks=None):
    """Fire one batch's coalescing plan: fused by-axis transfers.

    Stage by stage, each :class:`~repro.core.matching.CoalescedChannel`
    packs its member slabs (first hop) and relayed payloads (later
    hops) into ONE contiguous staging buffer at static offsets — the
    paper's contiguous MPI buffer — ties it to the trigger counter, and
    moves it with ONE single-axis ``ppermute``.  Because relays copy
    payloads verbatim and an axis-ordered route exists iff the direct
    source rank exists, each channel's final segment is bit-identical
    to its direct multi-axis ppermute; deposits then replay in original
    channel order.

    Returns ``(mem, received)`` with one payload per fused transfer;
    the caller banks each destination program's completion on the
    transfers that carry its final segments (see the StartDesc
    handling in :func:`_interpret_program`).
    """
    received = []
    for t in plan.transfers:
        parts = []
        for seg in t.segments:
            if seg.hop == 0:
                ch = plan.channels[seg.channel]
                src = mem[ch.src_buf]
                if ch.send_region is not None:
                    src = src[ch.send_region]
                parts.append(src.reshape(-1))
            else:  # relay: verbatim copy out of the previous hop's buffer
                pt, po = plan.routes[seg.channel][seg.hop - 1]
                parts.append(
                    jax.lax.slice_in_dim(received[pt], po, po + seg.size))
        staged = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        # DWQ deferred execution: ONE tie for the whole fused transfer.
        _, (staged,) = counters.tie(token, staged)
        if _is_full_identity(t.perm, _axes_tuple(t.axis), mesh_shape):
            received.append(staged)  # full identity: collective elided
        else:
            received.append(jax.lax.ppermute(staged, t.axis, t.perm))

    for ci, ch in enumerate(plan.channels):
        route = plan.routes[ci]
        if not route:
            # statically dead channel: its ppermute would deliver zeros
            # on every rank — deposit them without packing or moving
            seg = jnp.zeros(plan.shapes[ci], mem[ch.src_buf].dtype)
            mem = _deposit_channel(mem, ch, seg, mesh_shape,
                                   fallbacks=fallbacks)
            continue
        ti, off = route[-1]
        size = int(np.prod(plan.shapes[ci], dtype=np.int64))
        seg = jax.lax.slice_in_dim(received[ti], off, off + size)
        mem = _deposit_channel(mem, ch, seg.reshape(plan.shapes[ci]),
                               mesh_shape, fallbacks=fallbacks)
    return mem, received


def _run_collective(mem, coll: CollDesc, token, prog: STProgram):
    axes = _axes_tuple(coll.axis)
    axis = axes if len(axes) > 1 else axes[0]
    x = mem[coll.buf]
    _, (x,) = counters.tie(token, x)
    kw = dict(coll.kwargs)
    if coll.op == "all_gather":
        out = jax.lax.all_gather(x, axis, axis=kw.get("dim", 0), tiled=kw.get("tiled", True))
    elif coll.op == "reduce_scatter":
        out = jax.lax.psum_scatter(x, axis, scatter_dimension=kw.get("dim", 0), tiled=kw.get("tiled", True))
    elif coll.op == "all_reduce":
        out = jax.lax.psum(x, axis)
    elif coll.op == "all_to_all":
        out = jax.lax.all_to_all(x, axis, split_axis=kw.get("split_axis", 0),
                                 concat_axis=kw.get("concat_axis", 0), tiled=kw.get("tiled", True))
    elif coll.op == "ppermute":
        out = jax.lax.ppermute(x, axis, kw["perm"])
    else:  # pragma: no cover — validated at enqueue
        raise ValueError(coll.op)
    spec = prog.buffers[coll.out].pspec
    out_axes = tuple(a for a in jax.tree.leaves(list(spec)) if a)
    mem[coll.out] = _ensure_vma(out.astype(prog.buffers[coll.out].dtype), out_axes)
    return mem, out
