import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch × shape × mesh) lowers+compiles.

The FIRST two lines above must run before any jax import (jax locks the
device count at first init); 512 placeholder host devices back both the
single-pod 16×16 mesh and the 2×16×16 multi-pod mesh.

For each combination this script:
  1. builds the step bundle (ShapeDtypeStruct inputs — zero allocation);
  2. ``.lower()`` + ``.compile()`` under the production mesh;
  3. records ``memory_analysis()`` / ``cost_analysis()`` / collective
     bytes parsed from the optimized HLO into
     ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` —
     the roofline table (§Roofline, benchmarks/roofline.py) reads these.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
"""

import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch.hlo_analysis import analyze_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_bundle

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")

# (arch, shape) pairs skipped with a reason (DESIGN.md §5)
SKIPS = {
    ("whisper-large-v3", "long_500k"):
        "enc-dec with a 448-token decoder spec; 500k decode is architecture-"
        "inapplicable",
    ("qwen1.5-110b", "long_500k"):
        "pure full attention, no windowed variant in the source model",
    ("internvl2-76b", "long_500k"):
        "pure full attention, no windowed variant in the source model",
    ("grok-1-314b", "long_500k"):
        "pure full attention, no windowed variant in the source model",
}


def run_one(arch: str, shape_name: str, multi_pod: bool,
            save: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "pending"}

    if (arch, shape_name) in SKIPS:
        rec.update(status="skipped", reason=SKIPS[(arch, shape_name)])
        _save(rec, save)
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.devices.size
        bundle = build_bundle(cfg, shape, mesh)
        lowered = bundle.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_rec = {}
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            try:
                mem_rec[attr] = int(getattr(mem, attr))
            except Exception:
                pass
        cost = compiled.cost_analysis() or {}
        cost_rec = {k: float(v) for k, v in cost.items()
                    if isinstance(v, (int, float)) and (
                        "flops" in k or "bytes" in k or "utilization" in k.lower())}
        hlo = compiled.as_text()
        colls = analyze_collectives(hlo, n_dev)

        rec.update(
            status="ok",
            n_devices=int(n_dev),
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=mem_rec,
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            cost=cost_rec,
            collectives=colls.as_dict(),
            hlo_bytes=len(hlo),
        )
    except Exception as e:  # a failure here is a bug in the system
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _save(rec, save)
    return rec


def _save(rec: dict, save: bool):
    if not save:
        return
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(
        ARTIFACTS, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.multi_pod or not args.single_pod:
        meshes.append(True)
    if args.single_pod or not args.multi_pod:
        meshes.append(False)
    meshes = sorted(set(meshes))  # False (single) first

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = os.path.join(
                    ARTIFACTS, f"{arch}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    rec = json.load(open(path))
                    if rec.get("status") in ("ok", "skipped"):
                        print(f"[cached ] {arch} {shape} {mesh_name} "
                              f"{rec['status']}")
                        results.append(rec)
                        continue
                rec = run_one(arch, shape, mp)
                msg = rec["status"]
                if rec["status"] == "ok":
                    msg += (f" flops={rec['flops']:.3e} "
                            f"coll={rec['collectives']['total_bytes']:.3e}B "
                            f"compile={rec['compile_s']}s")
                elif rec["status"] == "error":
                    msg += f" {rec['error'][:160]}"
                print(f"[{rec['status']:7s}] {arch} {shape} "
                      f"{'pod2x16x16' if mp else 'pod16x16'} {msg}", flush=True)
                results.append(rec)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\nDRY-RUN SUMMARY: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(results)}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
