"""Quick multi-device smoke of the ST core (run with 8 host devices)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    FacesConfig, FusedEngine, HostEngine, build_faces_program, faces_oracle,
)

mesh = jax.make_mesh((2, 2, 2), ("gx", "gy", "gz"))
cfg = FacesConfig(grid=(2, 2, 2), points=(5, 4, 3))
prog = build_faces_program(cfg, mesh)
print("batches:", prog.n_batches, "channels:", prog.n_channels,
      "host dispatches:", prog.dispatch_count_host())

rng = np.random.RandomState(0)
u0 = rng.randn(2, 2, 2, 5, 4, 3).astype(np.float32)

for mode in ("stream", "dataflow"):
    eng = FusedEngine(prog, mode=mode)
    mem = eng.init_buffers({"u": u0})
    out = eng(mem)
    ref = faces_oracle(u0, cfg)
    np.testing.assert_allclose(np.asarray(out["u"]), ref, rtol=1e-5, atol=1e-5)
    print(f"fused[{mode}] OK")

host = HostEngine(prog, sync="every_op")
mem = host.init_buffers({"u": u0})
out = host(mem)
np.testing.assert_allclose(np.asarray(out["u"]), faces_oracle(u0, cfg), rtol=1e-5, atol=1e-5)
print(f"host OK dispatches={host.stats.dispatches} syncs={host.stats.sync_points}")

# unbatched variant
cfg2 = FacesConfig(grid=(2, 2, 2), points=(5, 4, 3), batched=False)
prog2 = build_faces_program(cfg2, mesh)
eng2 = FusedEngine(prog2, mode="stream")
out2 = eng2(eng2.init_buffers({"u": u0}))
np.testing.assert_allclose(np.asarray(out2["u"]), faces_oracle(u0, cfg2), rtol=1e-5, atol=1e-5)
print("unbatched OK; starts:", prog2.n_batches)

# periodic variant
cfg3 = FacesConfig(grid=(2, 2, 2), points=(4, 4, 4), periodic=True, interior_compute=False)
prog3 = build_faces_program(cfg3, mesh)
eng3 = FusedEngine(prog3, mode="dataflow")
out3 = eng3(eng3.init_buffers({"u": np.ones((2, 2, 2, 4, 4, 4), np.float32)}))
ref3 = faces_oracle(np.ones((2, 2, 2, 4, 4, 4), np.float32), cfg3)
np.testing.assert_allclose(np.asarray(out3["u"]), ref3, rtol=1e-5, atol=1e-5)
print("periodic OK")
print("CORE SMOKE PASS")
