"""qwen1.5-110b [dense] — GQA kv=8, QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    arch_type="dense",
    source="hf:Qwen/Qwen1.5-0.5B (110B sibling)",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152064,
    act="silu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    long_context_ok=False,  # full attention, no windowed variant → skip long_500k
)
