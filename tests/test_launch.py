"""Launcher-layer tests: HLO analysis, step builders, mesh, counting."""

import numpy as np
import pytest

from repro.launch.hlo_analysis import (
    analyze_collectives,
    analyze_dots,
    _tensor_bytes,
)


class TestTensorBytes:
    def test_simple(self):
        assert _tensor_bytes("bf16[2,3]") == 12
        assert _tensor_bytes("f32[128]") == 512
        assert _tensor_bytes("f32[]") == 4

    def test_tuple(self):
        assert _tensor_bytes("(bf16[2,2], f32[4])") == 8 + 16

    def test_unknown_dtype_ignored(self):
        assert _tensor_bytes("token[]") == 0


HLO_SAMPLE = """
ENTRY %main (p0: f32[64,32]) -> f32[64,32] {
  %p0 = f32[64,32] parameter(0)
  %ag = f32[64,32] all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[64,32] all-reduce(%ag), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[64,32] collective-permute(%ar), source_target_pairs={{0,1},{1,0}}
  ROOT %rs = f32[64,32] reduce-scatter(%cp), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""


class TestCollectives:
    def test_kinds_and_counts(self):
        stats = analyze_collectives(HLO_SAMPLE, 4)
        assert stats.count_by_kind == {
            "all-gather": 1, "all-reduce": 1, "collective-permute": 1,
            "reduce-scatter": 1}

    def test_wire_byte_conventions(self):
        stats = analyze_collectives(HLO_SAMPLE, 4)
        nbytes = 64 * 32 * 4
        frac = 3 / 4
        assert np.isclose(stats.bytes_by_kind["all-gather"], nbytes * frac)
        assert np.isclose(stats.bytes_by_kind["all-reduce"], 2 * nbytes * frac)
        assert np.isclose(stats.bytes_by_kind["reduce-scatter"], nbytes * frac)
        assert np.isclose(stats.bytes_by_kind["collective-permute"], nbytes)


DOT_SAMPLE = """
ENTRY %main (p0: f32[8,16], p1: f32[16,4]) -> f32[8,4] {
  %p0 = f32[8,16] parameter(0)
  %p1 = f32[16,4] parameter(1)
  ROOT %dot.1 = f32[8,4] dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
%other (a: f32[2,3]) -> f32[2,2] {
  %a = f32[2,3] parameter(0)
  ROOT %dot.2 = f32[2,2] dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={1}
}
"""


class TestDots:
    def test_flops_and_scoping(self):
        stats = analyze_dots(DOT_SAMPLE)
        assert stats.n_dots == 2
        # 2*8*4*16 + 2*2*2*3
        assert stats.total_flops == 2 * 8 * 4 * 16 + 2 * 2 * 2 * 3


class TestCounting:
    def test_param_counts_match_published(self):
        from repro.configs import get_config
        from repro.models.counting import count_params
        expect = {
            "qwen1.5-0.5b": (0.46e9, 0.47e9),
            "deepseek-v3-671b": (6.6e11, 6.8e11),
            "grok-1-314b": (3.0e11, 3.3e11),
            "glm4-9b": (9.0e9, 9.6e9),
            "mamba2-2.7b": (2.6e9, 2.8e9),
        }
        for arch, (lo, hi) in expect.items():
            n = count_params(get_config(arch))
            assert lo <= n <= hi, (arch, n)

    def test_active_less_than_total_for_moe(self):
        from repro.configs import get_config
        from repro.models.counting import count_params
        for arch in ("deepseek-v3-671b", "grok-1-314b"):
            cfg = get_config(arch)
            assert count_params(cfg, True) < 0.5 * count_params(cfg)

    def test_model_flops_monotone_in_shape(self):
        from repro.configs import get_config
        from repro.configs.base import SHAPES
        from repro.models.counting import model_flops
        cfg = get_config("glm4-9b")
        train = model_flops(cfg, SHAPES["train_4k"])["model_flops"]
        prefill = model_flops(cfg, SHAPES["prefill_32k"])["model_flops"]
        decode = model_flops(cfg, SHAPES["decode_32k"])["model_flops"]
        assert train > prefill > decode > 0


@pytest.mark.slow
def test_step_bundle_lowers_on_small_mesh(subproc):
    """build_bundle lowers train/prefill/serve for a smoke config on a
    4-device data×model mesh (mini version of the 512-chip dry-run)."""
    r = subproc("""
import dataclasses, jax
from repro.configs.base import ShapeConfig, get_config
from repro.launch.steps import build_bundle
from repro.parallel import make_mesh
cfg = dataclasses.replace(get_config("qwen1.5-0.5b").smoke(), vocab=512)
mesh = make_mesh((2, 2), ("data", "model"))
for shape in (ShapeConfig("t", 32, 4, "train"),
              ShapeConfig("p", 32, 4, "prefill"),
              ShapeConfig("d", 64, 4, "decode")):
    bundle = build_bundle(cfg, shape, mesh)
    compiled = bundle.lower().compile()
    assert compiled.cost_analysis()["flops"] > 0
    print(shape.kind, "ok")
""", devices=4)
    assert r.returncode == 0, r.stdout + r.stderr
    assert r.stdout.count("ok") == 3


def test_production_mesh_requires_512_devices():
    """make_production_mesh fails cleanly without forced device count
    (this test runs with the single real device)."""
    from repro.launch.mesh import make_production_mesh
    with pytest.raises(ValueError):
        make_production_mesh()
