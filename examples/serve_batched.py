"""Batched serving example: device-resident decode + continuous batching.

Serves a small gemma3-family model (sliding-window + global layers,
tied embeddings) on a 2×2 mesh — the same prefill_step/serve_step the
256-chip dry-run lowers — twice:

1. a fixed batch of 8, with the whole greedy-decode loop running as ONE
   host dispatch (vs. the legacy one-dispatch-per-token loop, shown for
   contrast);
2. an open-loop stream of 12 requests continuously batched into 4 cache
   slots: freed slots are re-prefilled for waiting requests inside the
   in-flight decode dispatch (composed prefill+decode).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import dataclasses

from repro.configs.base import get_config
from repro.launch.serve import serve, serve_continuous
from repro.parallel import make_mesh

cfg = dataclasses.replace(
    get_config("gemma3-1b"),
    name="gemma3-tiny",
    n_layers=6, d_model=256, n_heads=4, n_kv_heads=1, head_dim=64,
    d_ff=768, vocab=32768, sliding_window=64, global_every=6,
    dtype="float32", param_dtype="float32", scan_layers=False, remat="none",
)
mesh = make_mesh((2, 2), ("data", "model"))

for resident in (True, False):
    gen, stats = serve(cfg, mesh, batch=8, prompt_len=64, gen_len=32,
                       device_resident=resident)
    mode = "resident " if resident else "host-step"
    print(f"[{mode}] generated (first request):", gen[0][:8], "...")
    print(f"[{mode}] prefill {stats['prefill_s']:.2f}s | "
          f"decode {stats['decode_s']:.2f}s | "
          f"{stats['tok_per_s']:.1f} tok/s | "
          f"decode dispatches: {stats['decode_dispatches']}")

results, stats = serve_continuous(
    cfg, mesh, slots=4, prompt_len=64, max_new=32, n_requests=12,
    chunk=8, arrival_rate=100.0, seed=0)
print(f"[continuous] {len(results)} requests, {stats['total_tokens']} tokens "
      f"in {stats['total_s']:.2f}s ({stats['tok_per_s']:.1f} tok/s)")
print(f"[continuous] p50 {stats['p50_ms']:.0f}ms p99 {stats['p99_ms']:.0f}ms | "
      f"{stats['dispatches']} dispatches "
      f"({stats['admit_dispatches']} composed prefill+decode)")
