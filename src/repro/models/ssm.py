"""Mamba2 (SSD) block — attention-free selective state-space layer.

Structure (arXiv:2405.21060): in_proj → [z | x | B | C | dt]; short
causal depthwise conv on (x,B,C); SSD scan (Pallas kernel or jnp
oracle); gated RMSNorm; out_proj.

Distribution note (§DESIGN 4): the SSD inner dimension shards over
``model`` (heads), and for sequence-parallel long-context the
chunk-boundary state hand-off is a ppermute chain — the ST trigger/wait
pattern.  Decode carries (conv_state, ssm_state) instead of a KV cache:
O(1) memory in sequence length, which is why mamba2/hymba run
``long_500k``.
"""

from __future__ import annotations

import math
from functools import lru_cache
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel import act_shard
from .nn import param


@lru_cache(maxsize=None)
def _ssd_kernel_diff(chunk: int):
    """Pallas SSD forward with reference-oracle gradients.

    The Pallas kernel has no JVP rule (VMEM scratch), so the backward
    pass differentiates the pure-jnp oracle — on TPU this acts like a
    remat'd reference backward while the forward keeps the kernel."""
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref

    @jax.custom_vjp
    def f(xh, dt, A, Bg, Cg):
        return kops.ssd_scan(xh, dt, A, Bg, Cg, chunk=chunk, return_state=True)

    def fwd(xh, dt, A, Bg, Cg):
        return f(xh, dt, A, Bg, Cg), (xh, dt, A, Bg, Cg)

    def bwd(res, ct):
        _, vjp = jax.vjp(
            lambda *a: kref.ssd_scan(*a, return_state=True), *res)
        return vjp(ct)

    f.defvjp(fwd, bwd)
    return f


def ssm_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def init_ssm(key, cfg: ModelConfig):
    d = cfg.d_model
    d_inner, H, conv_dim = ssm_dims(cfg)
    G, N, P = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    d_in_proj = 2 * d_inner + 2 * G * N + H
    return {
        "in_proj": param(ks[0], (d, d_in_proj), ("embed", "act_mlp"), dt),
        "conv_w": param(ks[1], (cfg.ssm_conv, conv_dim), ("conv", "act_mlp"), dt,
                        scale=1.0 / math.sqrt(cfg.ssm_conv)),
        "conv_b": param(ks[1], (conv_dim,), ("act_mlp",), dt, init="zeros"),
        "A_log": param(ks[2], (H,), ("heads",), jnp.dtype("float32"), init="ones"),
        "D": param(ks[3], (H,), ("heads",), jnp.dtype("float32"), init="ones"),
        "dt_bias": param(ks[4], (H,), ("heads",), jnp.dtype("float32"), init="zeros"),
        "norm": param(ks[5], (d_inner,), ("act_mlp",), dt, init="zeros"),
        "out_proj": param(ks[5], (d_inner, d), ("act_mlp", "embed"), dt,
                          scale=0.02 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    d_inner, H, _ = ssm_dims(cfg)
    G, N = cfg.ssm_groups, cfg.ssm_state
    z = zxbcdt[..., :d_inner]
    x = zxbcdt[..., d_inner:2 * d_inner]
    Bm = zxbcdt[..., 2 * d_inner:2 * d_inner + G * N]
    C = zxbcdt[..., 2 * d_inner + G * N:2 * d_inner + 2 * G * N]
    dt_raw = zxbcdt[..., 2 * d_inner + 2 * G * N:]
    return z, x, Bm, C, dt_raw


def _causal_conv(xbc, w, b, *, state: Optional[jax.Array] = None):
    """Depthwise causal conv.  xbc: [B,S,C]; w: [K,C].  With `state`
    ([B,K-1,C], decode), prepends it and returns (y, new_state)."""
    K = w.shape[0]
    if state is not None:
        full = jnp.concatenate([state.astype(xbc.dtype), xbc], axis=1)
        new_state = full[:, -(K - 1):] if K > 1 else state
    else:
        full = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
        new_state = None
    # gather K shifted views (K is tiny: 4)
    S = xbc.shape[1]
    y = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(K):
        y = y + full[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    y = jax.nn.silu(y + b.astype(jnp.float32)).astype(xbc.dtype)
    return y, new_state


def _gated_norm(x, z, scale, eps):
    xf = (x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)).astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) *
            (scale.astype(jnp.float32) + 1.0)).astype(x.dtype)


def apply_ssm(p, xin, cfg: ModelConfig, *,
              cache: Optional[Dict] = None) -> Tuple[jax.Array, Optional[Dict]]:
    """xin: [B,S,D] → (y [B,S,D], new_cache | None).

    cache = {"conv": [B,K-1,conv_dim], "state": [B,H,P,N]} for decode.
    """
    B, S, D = xin.shape
    d_inner, H, conv_dim = ssm_dims(cfg)
    G, N, P = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim
    dt_ = xin.dtype

    zxbcdt = act_shard(jnp.einsum("bsd,de->bse", xin, p["in_proj"].astype(dt_)),
                       "batch", "seq", "act_mlp")
    z, x, Bm, C, dt_raw = _split_proj(zxbcdt, cfg)

    xbc = jnp.concatenate([x, Bm, C], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], state=conv_state)
    x = xbc[..., :d_inner]
    Bm = xbc[..., d_inner:d_inner + G * N]
    C = xbc[..., d_inner + G * N:]

    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [H] negative
    xh = act_shard(x.reshape(B, S, H, P), "batch", "seq", "act_heads", None)
    Bg = Bm.reshape(B, S, G, N)
    Cg = C.reshape(B, S, G, N)

    init_state = cache["state"] if cache is not None else None
    if cfg.use_ssd_kernel and cache is None:
        y, last = _ssd_kernel_diff(cfg.ssm_chunk)(xh, dt_v, A, Bg, Cg)
    else:
        from repro.kernels import ref as kref
        if cache is not None and S == 1:
            yh, last = kref.ssd_step(xh[:, 0], dt_v[:, 0], A, Bg[:, 0], Cg[:, 0],
                                     init_state)
            y = yh[:, None]
        else:
            y, last = kref.ssd_scan(xh, dt_v, A, Bg, Cg,
                                    init_state=init_state, return_state=True)

    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, d_inner)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_))

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "state": last}
    return out, new_cache
