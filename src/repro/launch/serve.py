"""Serving driver: device-resident continuous-batching decode.

The serving analogue of the repo's stream-triggered offload thesis: the
greedy-decode control loop — the part the legacy driver host-stepped one
token-dispatch at a time — runs **device-resident** as one
``lax.while_loop`` dispatch with per-sequence EOS / max-len termination
(masked per sequence exactly like the composed scheduler's per-program
``n_done`` in :mod:`repro.core.engine_persistent`), and **continuous
batching** admits new requests into freed KV-cache slots between
dispatches.  Admission itself is a *composed* prefill+decode program:
one dispatch prefills the admitted slots (into a zeroed view, merged
per-slot via :meth:`repro.models.Model.select_slots`) and then resumes
the in-flight decode loop — prefill of incoming requests overlaps
in-flight decode inside ONE dispatch, the launch-layer analogue of
:func:`repro.core.schedule.compose`.  KV-cache slots are recycled
zero-copy: the jitted dispatches donate the cache/state buffers
(PR-4's ``(cur, alt)`` rotation applied to the serve chain — the
``caches = step(caches, ...)`` loop rotates buffers without copies; the
donated input is deleted).

CLI
---
``PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke
--batch 4 --prompt-len 32 --gen 16 [--mesh DxM] [--serve-window W]
[--seed S] [--eos-id K] [--host-stepped] [--requests N --rate R
--chunk C]``

* ``--serve-window`` — windowed-attention serving cap (0 = off),
  threaded to prefill and decode steps.
* ``--seed`` — RNG seed for params and synthetic prompts.
* ``--host-stepped`` — legacy one-dispatch-per-token loop (baseline).
* ``--requests/--rate/--chunk`` — continuous-batching mode: N synthetic
  requests arriving as a Poisson process at R req/s (0 = all at t=0),
  decode chunked every C tokens between admission points.

BENCH_serve.json schema (written by ``benchmarks/serve_bench.py``, gated
by ``benchmarks/run.py serve --check-against BENCH_serve.json``)::

  {
    "serve/<variant>": {            # host_stepped | resident | continuous
      "tok_per_s": float,           # tokens emitted / serve wall-clock s
      "median_ms": float,           # median serve wall-clock over repeats
      "dispatches": int,            # host dispatches for the request set
      "p50_ms": float, "p99_ms": float,   # per-request latency percentiles
    },
    "_meta": { ... }                # workload stamp: medians only compare
  }                                 # like-for-like (cf. BENCH_faces.json)
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig, get_config
from repro.launch.steps import build_prefill_step, build_serve_step
from repro.models import Model
from repro.parallel import sharding_ctx

#: emission marker for a slot that was not active at a given decode step
PAD_TOKEN = -1


class _Counted:
    """Wrap a jitted callable and count host dispatches through it."""

    def __init__(self, fn):
        self._fn = fn
        self.calls = 0

    def __call__(self, *args):
        self.calls += 1
        return self._fn(*args)


def _argmax_tok(logits):
    return jnp.argmax(logits, -1).astype(jnp.int32)


def build_admission_schedule(mesh=None, *, slots: int = 4, width: int = 8,
                             verify: str = "error"):
    """The admission composition as an explicit ST schedule.

    :meth:`ServeEngine._admit_decode_inner` fuses "prefill the admitted
    slots, then resume in-flight decode" into one dispatch, but it does
    so as a plain jitted function — opaque to STLint.  This builder
    expresses the same handoff as two :class:`~repro.core.STQueue`
    programs joined by a cross-program link, so the admission path has a
    lintable model: ``prefill`` computes the KV for the admitted slots
    and *sends* it; ``decode`` *receives* it into its cache slot, waits
    on the deposit (triggered-op semantics: the decode step must not
    read the slot before the prefill deposit lands), then steps.  The
    ``python -m repro.analysis`` CLI and the verifier test sweep lint
    this schedule alongside the faces programs.
    """
    from repro.core import OffsetPeer, STQueue, compose

    if mesh is None:
        from repro.parallel import make_mesh
        mesh = make_mesh((jax.device_count(),), ("x",))
    ax = mesh.axis_names[0]
    n = int(mesh.shape[ax]) * slots

    qp = STQueue(mesh, name="prefill")
    qp.buffer("prompt", (n, width), np.float32, pspec=(ax, None))
    qp.buffer("kv", (n, width), np.float32, pspec=(ax, None))
    qp.enqueue_kernel(jnp.tanh, ["prompt"], ["kv"], name="prefill")
    qp.enqueue_send("kv", OffsetPeer(ax, 0, periodic=True), tag=31,
                    remote="decode")
    qp.enqueue_start()
    qp.enqueue_wait()
    prefill = qp.build()

    qd = STQueue(mesh, name="decode")
    qd.buffer("cache", (n, width), np.float32, pspec=(ax, None))
    qd.buffer("tok", (n, width), np.float32, pspec=(ax, None))
    qd.enqueue_recv("cache", OffsetPeer(ax, 0, periodic=True), tag=31,
                    remote="prefill")
    qd.enqueue_start()
    qd.enqueue_wait()
    qd.enqueue_kernel(lambda c: jnp.cumsum(c, axis=-1), ["cache"], ["tok"],
                      name="decode")
    decode = qd.build()

    return compose(prefill, decode, name="serve_admission", verify=verify)


class ServeEngine:
    """Jit-compiled serve programs over one slot-set of KV caches.

    Three dispatch kinds, all sharing the same per-sequence decode-loop
    core (``chunk`` steps, masked per slot):

    * ``prefill(params, batch_in, caches)`` — the jitted prefill step
      (cache shardings rebuilt against the decode bundle's max-len
      caches, as the legacy driver only promised in a comment);
    * ``decode(params, caches, tok, active, rem)`` — device-resident
      greedy decode: up to ``chunk`` tokens for every active slot in ONE
      dispatch, stopping each slot at EOS / budget / cache capacity;
    * ``admit_decode(params, caches, tok, active, rem, batch_in, admit,
      new_rem)`` — the composed prefill+decode program: masked prefill
      of the admitted slots overlapping the in-flight decode loop, still
      ONE dispatch.

    All decode-state arguments are donated: the serve chain rotates the
    cache buffers zero-copy across dispatches (the donated inputs are
    deleted — PR-4 slot rotation at the serve layer).
    """

    def __init__(self, cfg: ModelConfig, mesh, *, slots: int,
                 prompt_len: int, max_new: int, chunk: Optional[int] = None,
                 eos_id: int = -1, serve_window: int = 0,
                 donate: bool = True):
        self.cfg, self.mesh = cfg, mesh
        self.slots, self.prompt_len, self.max_new = slots, prompt_len, max_new
        self.eos_id, self.serve_window = int(eos_id), serve_window
        self.model = Model(cfg)
        self.prefix_len = self.model._prefix_len()
        self.capacity = self.prefix_len + prompt_len + max_new
        self.chunk = int(chunk) if chunk else max(max_new - 1, 1)
        self.sync_points = 0

        pre_shape = ShapeConfig("serve_prefill", prompt_len, slots, "prefill")
        dec_shape = ShapeConfig("serve_decode", self.capacity, slots, "decode")
        self.pre = build_prefill_step(cfg, pre_shape, mesh,
                                      serve_window=serve_window)
        self.dec = build_serve_step(cfg, dec_shape, mesh,
                                    serve_window=serve_window,
                                    per_seq_pos=True)
        self.cache_shardings = self.dec.in_shardings[1]

        with mesh:
            # satellite bugfix: the prefill step is actually jitted and
            # executed — with its cache shardings rebuilt against the
            # decode bundle's max-len caches (serving shares ONE cache
            # set sized to capacity; the prefill bundle's own caches_sd
            # is sized prompt_len+prefix and must not win).
            self.prefill = _Counted(jax.jit(
                self.pre.step_fn,
                in_shardings=(self.pre.in_shardings[0],
                              self.pre.in_shardings[1],
                              self.cache_shardings),
                out_shardings=(self.pre.out_shardings[0],
                               self.cache_shardings)))
            donate_state = (1, 2, 3, 4) if donate else ()
            self.decode = _Counted(jax.jit(
                self._decode_fn, donate_argnums=donate_state))
            self.admit_decode = _Counted(jax.jit(
                self._admit_decode_fn, donate_argnums=donate_state))
            # legacy-shaped single-token step for the host-stepped
            # baseline (donates caches, like the old driver)
            self.decode_one = _Counted(jax.jit(
                self.dec.step_fn, in_shardings=self.dec.in_shardings,
                out_shardings=self.dec.out_shardings, donate_argnums=(1,)))

    # -- state ----------------------------------------------------------------

    def init_state(self):
        """(caches, tok, active, rem) — all slots free.  Placed with the
        decode bundle's shardings."""
        caches = self.model.init_caches(self.slots, self.capacity,
                                        per_sequence=True)
        caches = jax.device_put(caches, self.cache_shardings)
        tok = jnp.zeros((self.slots,), jnp.int32)
        active = jnp.zeros((self.slots,), bool)
        rem = jnp.zeros((self.slots,), jnp.int32)
        return caches, tok, active, rem

    @property
    def dispatches(self) -> int:
        return (self.prefill.calls + self.decode.calls
                + self.admit_decode.calls + self.decode_one.calls)

    # -- device-resident decode loop core -------------------------------------

    def _decode_loop(self, params, caches, tok, active, rem):
        """Up to ``chunk`` greedy-decode steps as ONE on-device loop.

        Per-sequence masking mirrors the composed scheduler's per-program
        ``n_done``: a finished slot's position freezes (its K/V writes
        land on the frozen next-free index, invisible behind the
        ``k_valid`` mask), its emissions pad, and the loop ends when
        every slot is done or the chunk budget is spent.  Termination
        per slot: EOS (``eos_id >= 0``), per-slot token budget ``rem``,
        or cache capacity (max-len).
        """
        B, chunk, eos = self.slots, self.chunk, self.eos_id
        out0 = jnp.full((B, chunk), PAD_TOKEN, jnp.int32)
        n0 = jnp.zeros((B,), jnp.int32)

        def cond(c):
            i, _, _, active, _, _, _ = c
            return jnp.logical_and(i < chunk, jnp.any(active))

        def body(c):
            i, caches, tok, active, rem, out, n = c
            logits, new_caches = self.model.decode_step(
                params, caches, tok, serve_window=self.serve_window)
            nxt = _argmax_tok(logits)
            emit = jnp.where(active, nxt, PAD_TOKEN)
            out = jax.lax.dynamic_update_index_in_dim(out, emit, i, axis=1)
            n = n + active.astype(jnp.int32)
            # a frozen slot's depth does not advance (its discarded
            # write lands at the frozen next-free index each pass)
            pos = jnp.where(active, new_caches["pos"], caches["pos"])
            new_caches = dict(new_caches)
            new_caches["pos"] = pos
            rem = rem - active.astype(jnp.int32)
            stop = rem <= 0
            if eos >= 0:
                stop = stop | (nxt == eos)
            stop = stop | (pos >= self.capacity)
            active = active & ~stop
            tok = jnp.where(active, nxt, tok)
            return i + 1, new_caches, tok, active, rem, out, n

        _, caches, tok, active, rem, out, n = jax.lax.while_loop(
            cond, body,
            (jnp.zeros((), jnp.int32), caches, tok, active, rem, out0, n0))
        return caches, tok, active, rem, out, n

    def _decode_fn(self, params, caches, tok, active, rem):
        with sharding_ctx(self.dec.rules, self.mesh):
            return self._decode_loop(params, caches, tok, active, rem)

    # -- composed prefill + decode (continuous-batching admission) -------------

    def _admit_decode_fn(self, params, caches, tok, active, rem,
                         batch_in, admit, new_rem):
        """ONE dispatch: masked prefill of the admitted slots, then the
        in-flight decode loop resumes over ALL active slots.

        Prefill runs against a zeroed cache view (a recycled slot's
        stale K/V and SSM state must not leak into the new request) at
        per-slot depth 0, and only the admitted slots take the prefilled
        values (:meth:`Model.select_slots`); everyone else's mid-flight
        state is untouched.  The prefill-produced token is the admitted
        slot's first emission and its first decode input.
        """
        with sharding_ctx(self.dec.rules, self.mesh):
            return self._admit_decode_inner(params, caches, tok, active,
                                            rem, batch_in, admit, new_rem)

    def _admit_decode_inner(self, params, caches, tok, active, rem,
                            batch_in, admit, new_rem):
        zero = jax.tree.map(jnp.zeros_like, caches)
        logits, pre = self.model.prefill(
            params, batch_in, zero, serve_window=self.serve_window)
        caches = self.model.select_slots(admit, pre, caches)
        tok0 = _argmax_tok(logits)
        first = jnp.where(admit, tok0, PAD_TOKEN)
        tok = jnp.where(admit, tok0, tok)
        # the prefill token is emission #1 of the admitted request
        rem_admitted = new_rem - 1
        fresh = admit
        stop = rem_admitted <= 0
        if self.eos_id >= 0:
            stop = stop | (tok0 == self.eos_id)
        stop = stop | (caches["pos"] >= self.capacity)
        fresh = fresh & ~stop
        active = jnp.where(admit, fresh, active)
        rem = jnp.where(admit, rem_admitted, rem)
        caches, tok, active, rem, out, n = self._decode_loop(
            params, caches, tok, active, rem)
        return caches, tok, active, rem, first, out, n


# --------------------------------------------------------------------------
# synthetic workload
# --------------------------------------------------------------------------


def synthetic_batch(cfg: ModelConfig, rng, batch: int, prompt_len: int):
    """Synthetic prompt batch (tokens + any frontend embeddings)."""
    out = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab, (batch, prompt_len)).astype(np.int32))}
    if cfg.enc_dec:
        out["audio_embeds"] = jnp.asarray(
            rng.randn(batch, cfg.frontend_tokens, cfg.frontend_dim),
            jnp.float32)
    if cfg.frontend == "vision":
        out["vision_embeds"] = jnp.asarray(
            rng.randn(batch, cfg.frontend_tokens, cfg.frontend_dim),
            jnp.float32)
    return out


# --------------------------------------------------------------------------
# single-shot serving (one fixed batch, everyone starts together)
# --------------------------------------------------------------------------


def serve(cfg: ModelConfig, mesh, *, batch: int, prompt_len: int,
          gen_len: int, seed: int = 0, serve_window: int = 0,
          eos_id: int = -1, device_resident: bool = True,
          params=None, batch_in=None,
          engine: Optional[ServeEngine] = None):
    """Batched prefill + greedy decode for one fixed batch.

    ``device_resident=True`` (default): the whole decode loop runs as
    ONE host dispatch (``stats["decode_dispatches"] == 1``).  False:
    the legacy host-stepped loop — one dispatch per token — kept as the
    measured baseline and bit-identity reference.

    Returns ``(gen, stats)``: ``gen`` is ``[batch, gen_len]`` int32 —
    column 0 is the prefill-produced token — with ``PAD_TOKEN`` (-1)
    past a sequence's EOS.  ``stats`` counts actual emitted decode
    tokens (early-EOS sequences emit fewer) and syncs once at the end,
    so ``tok_per_s = decode_tokens / decode_s`` is consistent.
    """
    eng = engine or ServeEngine(
        cfg, mesh, slots=batch, prompt_len=prompt_len, max_new=gen_len,
        chunk=gen_len - 1, eos_id=eos_id, serve_window=serve_window)
    assert (eng.slots == batch and eng.chunk == gen_len - 1
            and eng.eos_id == int(eos_id)), "engine/serve shape mismatch"
    base_disp = eng.dispatches
    base_dec = eng.decode.calls + eng.decode_one.calls
    with mesh:
        if params is None:
            params, _ = eng.model.init(jax.random.PRNGKey(seed))
        params = jax.device_put(params, eng.pre.in_shardings[0])
        rng = np.random.RandomState(seed)
        if batch_in is None:
            batch_in = synthetic_batch(cfg, rng, batch, prompt_len)
        caches, tok, active, rem = eng.init_state()

        t0 = time.time()
        logits, caches = eng.prefill(params, batch_in, caches)
        tok0 = _argmax_tok(logits)
        tok0_np = np.asarray(tok0)   # prefill sync point (tok0 is later donated)
        t_prefill = time.time() - t0

        active = jnp.ones((batch,), bool)
        rem = jnp.full((batch,), gen_len - 1, jnp.int32)
        if eos_id >= 0:
            active = active & (tok0 != eos_id)

        t0 = time.time()
        if device_resident:
            caches, tok, active, rem, out, n_emit = eng.decode(
                params, caches, tok0, active, rem)
            out = np.asarray(out)
            n_np = np.asarray(n_emit)
            eng.sync_points += 1
        else:
            # legacy host-stepped loop (fixed accounting: no per-step
            # host sync — emissions stay on device until the end)
            emitted = []
            cur = tok0
            for _ in range(gen_len - 1):
                logits, caches = eng.decode_one(params, caches, cur)
                cur = _argmax_tok(logits)
                emitted.append(cur)
            jax.block_until_ready(cur)
            eng.sync_points += 1
            out = np.stack([np.asarray(t) for t in emitted], axis=1)
            # host-side EOS truncation (the oracle the resident loop's
            # on-device masking must reproduce exactly)
            if eos_id >= 0:
                for b in range(batch):
                    stop = gen_len - 1 if tok0_np[b] != eos_id else 0
                    hits = np.nonzero(out[b] == eos_id)[0]
                    if hits.size:
                        stop = min(stop, hits[0] + 1)
                    out[b, stop:] = PAD_TOKEN
            n_np = (out != PAD_TOKEN).sum(axis=1)
        t_decode = time.time() - t0

    gen = np.concatenate([tok0_np[:, None], out], axis=1)
    decode_tokens = int(n_np.sum())
    stats = {
        "prefill_s": t_prefill, "decode_s": t_decode,
        "decode_tokens": decode_tokens,
        "tok_per_s": decode_tokens / max(t_decode, 1e-9),
        "dispatches": eng.dispatches - base_disp,
        "decode_dispatches": eng.decode.calls + eng.decode_one.calls - base_dec,
        "sync_points": eng.sync_points,
    }
    return gen, stats


# --------------------------------------------------------------------------
# continuous batching (open-loop arrival stream)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray        # emitted tokens (prefill token first)
    t_arrive: float
    t_done: float

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_arrive


def poisson_arrivals(n: int, rate: float, rng) -> np.ndarray:
    """Arrival offsets (s) for an open-loop Poisson stream; rate<=0 → a
    t=0 burst."""
    if rate <= 0:
        return np.zeros(n)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def serve_continuous(cfg: ModelConfig, mesh, *, slots: int, prompt_len: int,
                     max_new: int, n_requests: int, chunk: int = 4,
                     arrival_rate: float = 0.0, seed: int = 0,
                     eos_id: int = -1, serve_window: int = 0,
                     params=None, prompts=None,
                     engine: Optional[ServeEngine] = None):
    """Continuous-batching serve of an open-loop arrival stream.

    ``n_requests`` synthetic requests arrive as a Poisson process
    (``arrival_rate`` req/s; 0 → all at t=0) and are admitted into freed
    KV-cache slots between dispatches.  Each round is ONE dispatch —
    the composed prefill+decode program when any slot was admitted, the
    pure resident decode chunk otherwise — followed by exactly one host
    sync (the admission point).  Slots are recycled zero-copy (donated
    buffers rotate through the dispatch chain).

    Returns ``(results, stats)`` — per-request
    :class:`RequestResult` (tokens are bit-identical to serving the
    request alone) and aggregate stats (tok/s, p50/p99 latency,
    dispatch/sync counts).
    """
    eng = engine or ServeEngine(
        cfg, mesh, slots=slots, prompt_len=prompt_len, max_new=max_new,
        chunk=chunk, eos_id=eos_id, serve_window=serve_window)
    assert (eng.slots == slots and eng.prompt_len == prompt_len
            and eng.max_new >= max_new
            and eng.eos_id == int(eos_id)), "engine/serve shape mismatch"
    rng = np.random.RandomState(seed)
    with mesh:
        if params is None:
            params, _ = eng.model.init(jax.random.PRNGKey(seed))
        params = jax.device_put(params, eng.pre.in_shardings[0])
        all_prompts = (synthetic_batch(cfg, rng, n_requests, prompt_len)
                       if prompts is None else prompts)
        arrivals = poisson_arrivals(n_requests, arrival_rate,
                                    np.random.RandomState(seed + 1))

        caches, tok, active, rem = eng.init_state()
        slot_req = np.full(slots, -1)          # request id per slot
        emitted: List[List[int]] = [[] for _ in range(n_requests)]
        results: List[Optional[RequestResult]] = [None] * n_requests
        next_req = 0
        n_done = 0
        base_prefill = eng.prefill.calls
        base_admit = eng.admit_decode.calls
        base_decode = eng.decode.calls
        base_disp = eng.dispatches
        t0 = time.time()

        while n_done < n_requests:
            now = time.time() - t0
            free = [s for s in range(slots) if slot_req[s] < 0]
            admit_ids: List[Tuple[int, int]] = []   # (slot, rid)
            while free and next_req < n_requests and arrivals[next_req] <= now:
                admit_ids.append((free.pop(0), next_req))
                next_req += 1
            if not admit_ids and not (slot_req >= 0).any():
                # idle: nothing in flight, nothing arrived yet
                time.sleep(min(max(arrivals[next_req] - now, 0.0), 0.01))
                continue

            if admit_ids:
                admit_np = np.zeros(slots, bool)
                new_rem = np.zeros(slots, np.int32)
                rows = {k: np.asarray(v) for k, v in all_prompts.items()}
                batch_rows = {k: np.zeros((slots,) + v.shape[1:], v.dtype)
                              for k, v in rows.items()}
                for s, rid in admit_ids:
                    admit_np[s] = True
                    new_rem[s] = max_new
                    slot_req[s] = rid
                    for k in rows:
                        batch_rows[k][s] = rows[k][rid]
                batch_in = {k: jnp.asarray(v) for k, v in batch_rows.items()}
                caches, tok, active, rem, first, out, n_emit = eng.admit_decode(
                    params, caches, tok, active, rem, batch_in,
                    jnp.asarray(admit_np), jnp.asarray(new_rem))
            else:
                caches, tok, active, rem, out, n_emit = eng.decode(
                    params, caches, tok, active, rem)
                first = None

            # ONE host sync per round: the admission point
            out_np = np.asarray(out)
            act_np = np.asarray(active)
            first_np = np.asarray(first) if first is not None else None
            eng.sync_points += 1
            t_round = time.time() - t0

            for s in range(slots):
                rid = slot_req[s]
                if rid < 0:
                    continue
                if first_np is not None and first_np[s] != PAD_TOKEN:
                    emitted[rid].append(int(first_np[s]))
                emitted[rid].extend(
                    int(t) for t in out_np[s] if t != PAD_TOKEN)
                if not act_np[s]:
                    results[rid] = RequestResult(
                        rid=rid, tokens=np.asarray(emitted[rid], np.int32),
                        t_arrive=float(arrivals[rid]), t_done=t_round)
                    slot_req[s] = -1
                    n_done += 1

        t_total = time.time() - t0
    lat = np.asarray([r.latency_s for r in results])
    total_tokens = int(sum(len(e) for e in emitted))
    stats = {
        "total_s": t_total,
        "total_tokens": total_tokens,
        "tok_per_s": total_tokens / max(t_total, 1e-9),
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "dispatches": eng.dispatches - base_disp,
        "admit_dispatches": eng.admit_decode.calls - base_admit,
        "decode_dispatches": eng.decode.calls - base_decode,
        "prefill_dispatches": eng.prefill.calls - base_prefill,
        "sync_points": eng.sync_points,
    }
    return results, stats


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--serve-window", type=int, default=0,
                    help="windowed-attention serving cap (0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=-1)
    ap.add_argument("--host-stepped", action="store_true",
                    help="legacy one-dispatch-per-token decode loop")
    ap.add_argument("--requests", type=int, default=0,
                    help="continuous-batching mode: serve N requests")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = t=0 burst")
    ap.add_argument("--chunk", type=int, default=4,
                    help="decode chunk between admission points")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    dm, tm = (int(x) for x in args.mesh.split("x"))
    from repro.parallel import make_mesh
    mesh = make_mesh((dm, tm), ("data", "model"))

    if args.requests:
        results, stats = serve_continuous(
            cfg, mesh, slots=args.batch, prompt_len=args.prompt_len,
            max_new=args.gen, n_requests=args.requests, chunk=args.chunk,
            arrival_rate=args.rate, seed=args.seed, eos_id=args.eos_id,
            serve_window=args.serve_window)
        print(f"served {len(results)} requests "
              f"({stats['total_tokens']} tokens)")
        print({k: round(v, 4) if isinstance(v, float) else v
               for k, v in stats.items()})
        return

    gen, stats = serve(cfg, mesh, batch=args.batch,
                       prompt_len=args.prompt_len, gen_len=args.gen,
                       seed=args.seed, serve_window=args.serve_window,
                       eos_id=args.eos_id,
                       device_resident=not args.host_stepped)
    print("generated tokens (first row):", gen[0][:16])
    print({k: round(v, 4) if isinstance(v, float) else v
           for k, v in stats.items()})


if __name__ == "__main__":
    main()
