"""Deferred-execution descriptors — the ST command-queue entries.

An ``STQueue`` records a *program*: an ordered list of descriptors, the
JAX analogue of (a) the NIC command queue holding DWQ entries and (b)
the GPU stream holding kernels and stream-memory ops.  Nothing executes
at enqueue time; an engine executes the program later (fused into one
XLA computation, or host-orchestrated per descriptor).

Descriptor kinds
----------------
``KernelDesc``    a compute kernel enqueued on the stream (D1, D2 in the
                  paper's Fig. 6).  Operates on named buffers.
``SendDesc``      MPIX_Enqueue_send: deferred tagged send to a peer.
``RecvDesc``      MPIX_Enqueue_recv: deferred tagged receive.
``CollDesc``      extension beyond the paper's P2P surface: a whole
                  collective (all-gather / reduce-scatter / all-to-all /
                  all-reduce) as a single deferred descriptor, so model
                  code can route *all* its communication through a queue.
``StartDesc``     MPIX_Enqueue_start: trigger everything enqueued since
                  the previous start (one writeValue for the batch).
``WaitDesc``      MPIX_Enqueue_wait: stream-blocking completion wait
                  (one waitValue for the batch).

Peers
-----
The paper addresses peers by MPI rank.  Under SPMD the same program runs
on every device, so a peer is expressed relationally:

* ``OffsetPeer(axis, delta)`` — "the rank `delta` steps along mesh axis
  `axis`"; non-periodic offsets drop at the boundary (ppermute semantics:
  unmatched receivers get zeros — which is exactly what a halo sum
  wants).
* ``GridOffsetPeer(axes, deltas, periodic)`` — diagonal neighbor on a
  multi-axis grid (the 26-neighbor Faces pattern).
* ``PairListPeer(axis, pairs)`` — explicit (src, dst) rank pairs, the
  closest analogue of the paper's Fig. 7 two-rank example.  Legal
  because ST forbids wildcards: the global pattern is static.

Program identity
----------------
Every descriptor carries a ``pid`` (program id, default 0).  A program
built from a single :class:`~repro.core.queue.STQueue` uses pid 0
throughout; :func:`repro.core.schedule.compose` assigns each fused
sub-program its own pid so the engines can keep **per-program
trigger/completion counter banks** — the multi-DWQ analogue of one
counter pair per ``MPIX_Queue``.

Enqueue-site provenance (``site``)
----------------------------------
Every descriptor records the ``file:line`` of the ``enqueue_*`` call
that created it (captured by :class:`~repro.core.queue.STQueue` via
``traceback.extract_stack``).  Build/compose/verify errors and
:class:`~repro.core.verify.Diagnostic` records carry it, so a failure
in a composed 400-descriptor schedule names the enqueue call at fault
instead of a bare descriptor index.

Cross-program channels (``remote``)
-----------------------------------
``SendDesc``/``RecvDesc`` additionally carry an optional ``remote``
field naming the *peer program* the descriptor pairs with.  A remote
send's matching receive lives in another queue's program (and vice
versa): the queue's own build leaves such descriptors *open*, and
:func:`repro.core.schedule.compose` matches them across the composed
programs into channels whose deposit lands in the peer program's
memory — with the trigger taken from the sender's counter bank and the
completion wired into the *receiver's* bank, so the receiver's wait
gate observes the sender's completion.  This is how concurrent queues
chain triggered operations across streams (the halo exchange *between*
composed domain parts) instead of merely interleaving independently.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import numpy as np

# --------------------------------------------------------------------------
# Peer specifications
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OffsetPeer:
    axis: str
    delta: int
    periodic: bool = False

    def inverse(self) -> "OffsetPeer":
        return OffsetPeer(self.axis, -self.delta, self.periodic)


@dataclasses.dataclass(frozen=True)
class GridOffsetPeer:
    axes: Tuple[str, ...]
    deltas: Tuple[int, ...]
    periodic: bool = False

    def __post_init__(self):
        if len(self.axes) != len(self.deltas):
            raise ValueError("axes and deltas must align")

    def inverse(self) -> "GridOffsetPeer":
        return GridOffsetPeer(self.axes, tuple(-d for d in self.deltas), self.periodic)


@dataclasses.dataclass(frozen=True)
class PairListPeer:
    axis: str
    pairs: Tuple[Tuple[int, int], ...]  # (src_rank, dst_rank)

    def inverse(self) -> "PairListPeer":
        # From the receiver's point of view the pairs are identical; the
        # match check compares (src, dst) sets directly.
        return PairListPeer(self.axis, self.pairs)


Peer = Any  # OffsetPeer | GridOffsetPeer | PairListPeer


def perm_for(peer: Peer, mesh_shape: dict) -> Tuple[str, Sequence[Tuple[int, int]]]:
    """Resolve a peer spec into (axis_name(s), ppermute permutation).

    For grid offsets the permutation is computed over the *flattened*
    multi-axis grid; the engine ppermutes over the axis tuple.
    Returns (axis or tuple-of-axes, [(src, dst), ...]).
    """
    if isinstance(peer, PairListPeer):
        return peer.axis, list(peer.pairs)

    if isinstance(peer, OffsetPeer):
        n = mesh_shape[peer.axis]
        pairs = []
        for src in range(n):
            dst = src + peer.delta
            if peer.periodic:
                dst %= n
            elif not (0 <= dst < n):
                continue
            pairs.append((src, dst))
        return peer.axis, pairs

    if isinstance(peer, GridOffsetPeer):
        dims = [mesh_shape[a] for a in peer.axes]
        pairs = []
        for src_multi in np.ndindex(*dims):
            dst_multi = []
            ok = True
            for c, d, n in zip(src_multi, peer.deltas, dims):
                t = c + d
                if peer.periodic:
                    t %= n
                elif not (0 <= t < n):
                    ok = False
                    break
                dst_multi.append(t)
            if not ok:
                continue
            src = int(np.ravel_multi_index(src_multi, dims))
            dst = int(np.ravel_multi_index(tuple(dst_multi), dims))
            pairs.append((src, dst))
        return tuple(peer.axes), pairs

    raise TypeError(f"unknown peer spec: {peer!r}")


def hop_decomposition(peer: Peer, axis_order: Sequence[str]):
    """Decompose a peer spec into an ordered list of single-axis hops.

    A grid offset ``(dx, dy, dz)`` is the composition of one shift per
    nonzero component; routing a message through those shifts one mesh
    axis at a time delivers bit-identical payloads to the direct
    multi-axis ``ppermute`` (data is relayed verbatim, and on a
    non-periodic grid every intermediate rank of an axis-ordered path
    exists iff the direct source rank exists).  This is what lets the
    coalescing layer (:mod:`.matching`) share ONE by-axis transfer
    between every channel that hops the same ``(axis, delta)``.

    Hops are emitted in ``axis_order`` (the mesh's axis order) so all
    channels agree on stage numbering.  Returns ``[(axis, delta,
    periodic), ...]`` or ``None`` for peers with no offset structure
    (``PairListPeer`` — coalescable only with channels sharing its
    exact permutation).
    """
    if isinstance(peer, OffsetPeer):
        return [(peer.axis, peer.delta, peer.periodic)]
    if isinstance(peer, GridOffsetPeer):
        order = {a: i for i, a in enumerate(axis_order)}
        if any(a not in order for a in peer.axes):
            return None
        hops = sorted(
            ((a, d, peer.periodic) for a, d in zip(peer.axes, peer.deltas)
             if d != 0),
            key=lambda h: order[h[0]],
        )
        # degenerate all-zero offset: a self-send, one identity hop
        return hops or [(peer.axes[0], 0, peer.periodic)]
    return None


# --------------------------------------------------------------------------
# Descriptors
# --------------------------------------------------------------------------


@dataclasses.dataclass
class KernelDesc:
    """A compute kernel enqueued on the stream.

    ``fn(*reads) -> writes`` must be a pure JAX function over the *local*
    (per-shard) views of the named buffers.  ``writes`` names receive the
    outputs positionally.
    """

    fn: Callable
    reads: Tuple[str, ...]
    writes: Tuple[str, ...]
    name: str = "kernel"
    # Program identity (multi-queue composition; see module docstring).
    pid: int = 0
    # Enqueue-site provenance ("file:line"; see module docstring).
    site: Optional[str] = None
    # True when the effect set was NOT declared by the caller and the
    # queue substituted the conservative reads-everything fallback
    # (enqueue_compute with no reads=/writes=).  Surfaced as the ST019
    # warning: implicit effects over-serialize the happens-before graph
    # and weaken every race rule built on it.
    implicit_effects: bool = False


@dataclasses.dataclass
class SendDesc:
    buf: str
    peer: Peer
    tag: int
    # Trigger threshold (SS11 DWQ field); filled in by the queue.
    threshold: int = -1
    # Optional slice of the buffer to send: tuple of slice objects.
    region: Optional[Tuple[slice, ...]] = None
    pid: int = 0
    # Cross-program channel: name of the peer *program* holding the
    # matching receive (None = matched within this program's own batch).
    remote: Optional[str] = None
    # Enqueue-site provenance ("file:line"; see module docstring).
    site: Optional[str] = None


@dataclasses.dataclass
class RecvDesc:
    buf: str
    peer: Peer
    tag: int
    threshold: int = -1
    region: Optional[Tuple[slice, ...]] = None
    # How to deposit into the destination buffer: "replace" or "add"
    # ("add" is the Faces gather-scatter sum deposit).
    mode: str = "replace"
    pid: int = 0
    # Cross-program channel: name of the peer *program* holding the
    # matching send (None = matched within this program's own batch).
    remote: Optional[str] = None
    # Enqueue-site provenance ("file:line"; see module docstring).
    site: Optional[str] = None


@dataclasses.dataclass
class CollDesc:
    """A deferred collective (beyond-paper extension, §DESIGN 4)."""

    op: str  # all_gather | reduce_scatter | all_reduce | all_to_all | ppermute
    buf: str
    out: str
    axis: Any  # mesh axis name or tuple
    kwargs: dict = dataclasses.field(default_factory=dict)
    threshold: int = -1
    pid: int = 0
    # Enqueue-site provenance ("file:line"; see module docstring).
    site: Optional[str] = None


@dataclasses.dataclass
class StartDesc:
    batch: int  # index of the batch this start triggers
    threshold: int = -1
    pid: int = 0
    # Enqueue-site provenance ("file:line"; see module docstring).
    site: Optional[str] = None


@dataclasses.dataclass
class WaitDesc:
    batch: int
    expected: int = -1  # completion-counter target
    pid: int = 0
    # Enqueue-site provenance ("file:line"; see module docstring).
    site: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class BufferSpec:
    """Global-view buffer declaration for a queue program."""

    name: str
    shape: Tuple[int, ...]
    dtype: Any
    # PartitionSpec entries (axis names / None) for the global array.
    pspec: Tuple[Any, ...] = ()


Descriptor = Any  # union of the above
