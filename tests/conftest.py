# NOTE: deliberately NO XLA_FLAGS here — unit/smoke tests run on the
# single real CPU device.  Multi-device behaviour is tested via
# subprocesses (tests/test_distributed.py) that set
# --xla_force_host_platform_device_count themselves.
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess(code: str, devices: int = 8, timeout: int = 560):
    """Run a python snippet in a fresh process with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess
