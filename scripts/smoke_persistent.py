"""Smoke the persistent engine on 8 host devices: N Faces iterations as
ONE host dispatch, vs the host engine's N × per-op dispatches.

``--converge`` additionally smokes the predicate-terminated loop: the
device iterates a damped (contracting) Faces update until the global
residual drops below tolerance — still one dispatch, with the realized
iteration count and the residual trace read back afterwards.

``--pipeline`` smokes the multi-queue schedule: two half-grid Faces
queues composed (`repro.core.schedule.compose`) into ONE dispatch,
fixed-count and per-program-predicate variants, checked against
independent per-queue runs — plus the LINKED composition
(exchange=True cross-program channels), checked bit-for-bit against
the single-queue full-domain run.

``--tune`` smokes the budgeted auto-tuner (`repro.launch.tune`): a
two-candidate search over the linked n=2 composition on the smoke grid
— every candidate must lint clean, and the tuned winner must measure no
slower than the untuned default (which is in the candidate set).

``--overlap`` smokes the ST collective-matmul path
(`repro.core.collectives`): all-gather-matmul / matmul-reduce-scatter /
all-to-all expressed as trigger→wait ST programs on a small 2-device
ring, bit-identical to the decomposed references (and to the stock
shard_map lowering on the pure-copy paths), plus the chained
transformer block as ONE persistent dispatch.

``--serve`` smokes the device-resident serving path
(`repro.launch.serve`): greedy decode for a fixed-length batch as ONE
host dispatch, bit-identical to the host-stepped loop; per-sequence EOS
masking; and continuous-batching admission (composed prefill+decode,
one dispatch per round) against serial serving."""
import argparse
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    FacesConfig, HostEngine, PersistentEngine, build_faces_program,
    faces_oracle, half_config, run_faces_persistent, run_faces_pipelined,
    run_faces_until_converged, split_halves,
)
from repro.core.halo import AXES3

args = argparse.ArgumentParser()
args.add_argument("--converge", action="store_true",
                  help="also smoke the until-converged while_loop path")
args.add_argument("--pipeline", action="store_true",
                  help="also smoke the composed 2-queue pipelined dispatch")
args.add_argument("--overlap", action="store_true",
                  help="also smoke the ST collective-matmul programs")
args.add_argument("--serve", action="store_true",
                  help="also smoke the device-resident serving path")
args.add_argument("--tune", action="store_true",
                  help="also smoke the budgeted auto-tuner on linked n=2")
args = args.parse_args()

N = 5
mesh = jax.make_mesh((2, 2, 2), AXES3)
cfg = FacesConfig(grid=(2, 2, 2), points=(5, 4, 3))
prog = build_faces_program(cfg, mesh).persistent(N)
print("batches:", prog.n_batches, "channels:", prog.n_channels,
      "n_iters:", prog.n_iters)

rng = np.random.RandomState(0)
u0 = rng.randn(2, 2, 2, 5, 4, 3).astype(np.float32)

ref = u0
for _ in range(N):
    ref = faces_oracle(ref, cfg)

host = HostEngine(prog)
hmem = host.init_buffers({"u": u0})
for _ in range(N):
    hmem = host(hmem)
np.testing.assert_allclose(np.asarray(hmem["u"]), ref, rtol=1e-4, atol=1e-4)
print(f"host     OK dispatches={host.stats.dispatches} "
      f"(= {N} x {prog.dispatch_count_host()})")

for mode in ("stream", "dataflow"):
    eng = PersistentEngine(prog, mode=mode)
    out = eng(eng.init_buffers({"u": u0}))
    np.testing.assert_allclose(np.asarray(out["u"]), ref, rtol=1e-4, atol=1e-4)
    print(f"persistent[{mode}] OK dispatches={eng.stats.dispatches} "
          f"double_buffer={eng.double_buffer} slots={len(eng._slots)}")

# convergence-style loop: per-iteration residual with zero host syncs
def sq_norm(mem):
    return jax.lax.psum(jnp.sum(mem["u"].astype(jnp.float32) ** 2), AXES3)

eng = PersistentEngine(prog, mode="dataflow", reduce_fn=sq_norm)
out, residuals = eng(eng.init_buffers({"u": u0}))
print("residual trace:", [f"{float(r):.3e}" for r in np.asarray(residuals)])
assert residuals.shape == (N,)

if args.converge:
    # device-resident termination: while residual >= tol, bounded
    ccfg = FacesConfig(grid=(2, 2, 2), points=(5, 4, 3), damping=0.12)
    tol, max_iters = 1e-3, 40
    mem, res, n_done, stats = run_faces_until_converged(
        ccfg, mesh, u0, tol=tol, max_iters=max_iters)
    print(f"converged in {n_done} iters (bound {max_iters}), "
          f"dispatches={stats.dispatches}, "
          f"trace={[f'{r:.2e}' for r in res]}")
    assert stats.dispatches == 1 and stats.sync_points == 0
    assert 1 <= n_done < max_iters and res[-1] < tol
    cref = u0
    for _ in range(n_done):
        cref = faces_oracle(cref, ccfg)
    np.testing.assert_allclose(np.asarray(mem["u"]), cref,
                               rtol=1e-4, atol=1e-5)
    print("CONVERGENCE SMOKE PASS")

if args.pipeline:
    # two half-grid queues composed (UNLINKED): ONE dispatch, results
    # matching the two independent persistent runs (2 dispatches)
    pcfg = FacesConfig(grid=(2, 2, 2), points=(6, 4, 4), damping=0.12)
    pu0 = rng.randn(2, 2, 2, 6, 4, 4).astype(np.float32)
    pmem, pstats = run_faces_pipelined(pcfg, mesh, pu0, n_iters=N,
                                       exchange=False)
    assert pstats.dispatches == 1 and pstats.sync_points == 0
    cfgh = half_config(pcfg)
    ind_disp = 0
    for nm, uh in zip(("facesA", "facesB"), split_halves(pu0)):
        ind, istats = run_faces_persistent(cfgh, mesh, uh, n_iters=N)
        ind_disp += istats.dispatches
        np.testing.assert_allclose(np.asarray(pmem[f"{nm}/u"]),
                                   np.asarray(ind["u"]),
                                   rtol=1e-6, atol=1e-7)
    print(f"pipelined[fixed] OK composed_dispatches={pstats.dispatches} "
          f"sequential_dispatches={ind_disp}")

    # per-program predicates: each half converges to its OWN tolerance
    tols = (1e-1, 1e-2)
    pmem, reds, n_done, pstats = run_faces_pipelined(
        pcfg, mesh, pu0, tols=tols, max_iters=40, exchange=False)
    assert pstats.dispatches == 1
    for nm, uh, tol in zip(("facesA", "facesB"), split_halves(pu0), tols):
        im, ir, inn, _ = run_faces_until_converged(cfgh, mesh, uh, tol=tol,
                                                   max_iters=40)
        assert inn == n_done[nm], (nm, inn, n_done[nm])
        np.testing.assert_allclose(np.asarray(pmem[f"{nm}/u"]),
                                   np.asarray(im["u"]),
                                   rtol=1e-6, atol=1e-7)
    print(f"pipelined[until] OK n_done={n_done} dispatches=1")

    # LINKED composition (default): cross-program channels exchange the
    # shared faces + ghost planes, so the composed run IS the
    # full-domain solve — bit-identical in stream mode, one dispatch
    from repro.core import merge_parts, part_names
    full, _ = run_faces_persistent(pcfg, mesh, pu0, n_iters=N,
                                   mode="stream")
    for n_parts in (2, 3):
        names = part_names(n_parts)
        lmem, lstats = run_faces_pipelined(pcfg, mesh, pu0, n_iters=N,
                                           n_parts=n_parts, mode="stream")
        assert lstats.dispatches == 1
        got = np.asarray(merge_parts([lmem[f"{nm}/u"] for nm in names]))
        np.testing.assert_array_equal(got, np.asarray(full["u"]))
        print(f"pipelined[linked n={n_parts}] OK bit-identical to "
              f"full-domain, dispatches=1")
    print("PIPELINE SMOKE PASS")

if args.tune:
    # budgeted auto-tune: linked n=2 on the smoke grid, two candidates
    # with the untuned default (round_robin + dataflow) among them —
    # "tuned never slower than untuned" then holds by construction,
    # because the winner is the measured minimum over a set containing
    # the default.  Every candidate must build and lint clean (tune()
    # refuses to time an invalid program).
    from repro.core import merge_parts, part_names
    tcfg = FacesConfig(grid=(2, 2, 2), points=(6, 4, 4))
    tu0 = rng.randn(2, 2, 2, 6, 4, 4).astype(np.float32)
    TN = 4
    space = {"interleave": ["round_robin", "sequential"],
             "mode": ["dataflow"]}
    tmem, tstats, tres = run_faces_pipelined(
        tcfg, mesh, tu0, n_iters=TN, n_parts=2, tune=True,
        tune_space=space, tune_repeats=3, tune_measure_top=2)
    assert tstats.dispatches == 1, tstats.dispatches
    assert all(c.error is None for c in tres.candidates), \
        [c.error for c in tres.candidates]
    untuned = next(c for c in tres.measured
                   if c.knobs.interleave == "round_robin"
                   and c.knobs.mode == "dataflow")
    best = tres.best
    assert best.stats["med_s"] <= untuned.stats["med_s"], \
        (best.knobs.label(), best.stats["med_s"], untuned.stats["med_s"])
    full, _ = run_faces_persistent(tcfg, mesh, tu0, n_iters=TN)
    got = np.asarray(merge_parts(
        [tmem[f"{nm}/u"] for nm in part_names(2)]))
    np.testing.assert_allclose(got, np.asarray(full["u"]),
                               rtol=1e-5, atol=1e-6)
    print(f"tune[linked n=2] OK best=[{best.knobs.label()}] "
          f"med={best.stats['med_s']*1e3:.2f}ms vs untuned "
          f"{untuned.stats['med_s']*1e3:.2f}ms; "
          f"{len(tres.candidates)} candidates built+linted clean")
    print("TUNE SMOKE PASS")

if args.overlap:
    # ST collective matmul: the model-parallel collectives as ST
    # programs on a small 2-device ring — bit-identical to the
    # decomposed references, and the chained TP block as ONE dispatch
    from repro.core import collectives
    from repro.core.engine_fused import FusedEngine
    from repro.parallel import make_mesh

    omesh = make_mesh((2,), ("x",))
    M, K, F, LAYERS = 8, 4, 4, 3
    orng = np.random.RandomState(0)

    for label, cm, inputs in (
        ("ag_matmul",
         collectives.build_all_gather_matmul(omesh, "x", M, K, F),
         {"x": orng.randn(M, K).astype(np.float32),
          "w": orng.randn(K, F).astype(np.float32)}),
        ("matmul_rs",
         collectives.build_matmul_reduce_scatter(omesh, "x", M, K, F),
         {"x": orng.randn(M, K).astype(np.float32),
          "w": orng.randn(K, F).astype(np.float32)}),
        ("a2a",
         collectives.build_all_to_all(omesh, "x", M, K),
         {"x": orng.randn(M, K).astype(np.float32)}),
    ):
        oeng = FusedEngine(cm.program, mode="dataflow")
        got = np.asarray(oeng(oeng.init_buffers(inputs))[cm.output])
        refa = tuple(inputs[b] for b in cm.inputs)
        np.testing.assert_array_equal(got, np.asarray(cm.reference(*refa)))
        if label != "matmul_rs":   # ring rs reorders the float sum
            np.testing.assert_array_equal(
                got, np.asarray(cm.reference_stock(*refa)))
        else:
            np.testing.assert_allclose(
                got, np.asarray(cm.reference_stock(*refa)),
                rtol=1e-5, atol=1e-5)
        assert oeng.stats.dispatches == 1, oeng.stats.dispatches
        print(f"overlap[{label}] OK bit-identical, dispatches=1")

    # chained transformer block: persistent(N) == N stock shard_map
    # applications, in ONE dispatch
    tp = collectives.build_tp_block(omesh, "x", M, K, F, chain=True)
    x0 = orng.randn(M, K).astype(np.float32)
    w1 = orng.randn(K, F).astype(np.float32)
    w2 = orng.randn(F, K).astype(np.float32)
    peng = PersistentEngine(tp.program.persistent(LAYERS), donate=True)
    got = np.asarray(peng(peng.init_buffers(
        {"x": x0, "w1": w1, "w2": w2}))["out"])
    ref = stock = x0
    for _ in range(LAYERS):
        ref = tp.reference(ref, w1, w2)
        stock = tp.reference_stock(stock, w1, w2)
    np.testing.assert_array_equal(got, np.asarray(ref))
    np.testing.assert_allclose(got, np.asarray(stock),
                               rtol=1e-4, atol=1e-5)
    assert peng.stats.dispatches == 1, peng.stats.dispatches
    print(f"overlap[tp_chain x{LAYERS}] OK bit-identical to decomposed "
          f"chain, matches stock shard_map chain, dispatches=1")
    print("OVERLAP SMOKE PASS")

if args.serve:
    # device-resident serving: fixed-length decode as ONE dispatch,
    # bit-identical to host-stepped; EOS masking; continuous batching
    from repro.configs.base import get_config
    from repro.launch.serve import PAD_TOKEN, ServeEngine, serve, \
        serve_continuous, synthetic_batch
    from repro.parallel import make_mesh

    scfg = get_config("qwen1.5-0.5b").smoke()   # dense on purpose
    smesh = make_mesh((2, 2), ("data", "model"))
    B, P, G = 4, 8, 6
    eng = ServeEngine(scfg, smesh, slots=B, prompt_len=P, max_new=G,
                      chunk=G - 1, eos_id=-1)
    with smesh:
        sparams, _ = eng.model.init(jax.random.PRNGKey(0))
        sparams = jax.device_put(sparams, eng.pre.in_shardings[0])
    sbatch = synthetic_batch(scfg, np.random.RandomState(0), B, P)
    gen_d, st_d = serve(scfg, smesh, batch=B, prompt_len=P, gen_len=G,
                        params=sparams, batch_in=sbatch, engine=eng,
                        device_resident=True)
    gen_h, st_h = serve(scfg, smesh, batch=B, prompt_len=P, gen_len=G,
                        params=sparams, batch_in=sbatch, engine=eng,
                        device_resident=False)
    np.testing.assert_array_equal(gen_d, gen_h)
    assert st_d["decode_dispatches"] == 1 and st_h["decode_dispatches"] == G - 1
    print(f"serve[resident] OK bit-identical, decode_dispatches="
          f"{st_d['decode_dispatches']} (host-stepped: "
          f"{st_h['decode_dispatches']})")

    # EOS masking against the host oracle
    eos = int(gen_h[0, G // 2])
    eeng = ServeEngine(scfg, smesh, slots=B, prompt_len=P, max_new=G,
                       chunk=G - 1, eos_id=eos)
    egen_d, _ = serve(scfg, smesh, batch=B, prompt_len=P, gen_len=G,
                      params=sparams, batch_in=sbatch, engine=eeng,
                      device_resident=True, eos_id=eos)
    egen_h, _ = serve(scfg, smesh, batch=B, prompt_len=P, gen_len=G,
                      params=sparams, batch_in=sbatch, engine=eeng,
                      device_resident=False, eos_id=eos)
    np.testing.assert_array_equal(egen_d, egen_h)
    assert (egen_d == PAD_TOKEN).any()
    print(f"serve[eos={eos}] OK masked tokens match the host oracle")

    # continuous batching: admission never dispatches prefill alone
    res, st = serve_continuous(scfg, smesh, slots=2, prompt_len=P,
                               max_new=G, n_requests=5, chunk=3,
                               arrival_rate=0.0, seed=0)
    assert len(res) == 5 and all(len(r.tokens) == G for r in res)
    assert st["prefill_dispatches"] == 0
    assert st["dispatches"] == st["admit_dispatches"] + st["decode_dispatches"]
    print(f"serve[continuous] OK {st['dispatches']} dispatches "
          f"({st['admit_dispatches']} composed prefill+decode), "
          f"{st['total_tokens']} tokens")
    print("SERVE SMOKE PASS")

print("PERSISTENT SMOKE PASS")
