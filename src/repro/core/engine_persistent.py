"""Persistent ST engine — the device owns the iteration loop.

:class:`~repro.core.engine_fused.FusedEngine` offloads the control path
of one communication batch, but the *host* still re-dispatches the
program every iteration of a timed loop (N iterations → N dispatches).
The follow-up work on fully offloaded stream triggering moves the whole
loop onto the device: the host enqueues once, and a device-resident
sequencer re-runs trigger → communicate → wait → compute until the
iteration count (or a convergence predicate) says stop.

This engine is that execution model for an :class:`STProgram`: the
fused interpreter (:func:`~repro.core.engine_fused._interpret_program`)
runs inside an on-device ``jax.lax.fori_loop`` whose carry holds

* every program buffer (the Faces field ``u`` survives on-device across
  iterations — no host round-trip between them);
* the **trigger and completion counters**, threaded through every pass
  so the MPIX_Queue-reuse semantics of :mod:`.queue` hold literally:
  iteration i+1's thresholds sit above iteration i's counter values
  instead of restarting from zero;
* optionally a per-iteration scalar reduction (residual norms etc.), so
  convergence-style loops can report progress without a host sync.

Double buffering
----------------
In ``dataflow`` mode the wait gates only the buffers a batch received
into.  Message *slot* buffers (pure staging: packed faces out, received
faces in) are therefore the only serialization between iterations that
is not a real data dependency.  With ``double_buffer=True`` each slot
buffer gets two copies and iteration i uses copy ``i % 2``: combined
with ``unroll=2`` on the loop, iteration i+1's packs write slot B while
iteration i's waits still gate slot A, recovering the pack/wait overlap
a NIC-offloaded persistent queue gets from alternating DWQ entries.

Slot safety is decided statically: a buffer is double-buffered only if
it is touched by a channel/collective and its first access in execution
order is a write (replace-mode deposits count as writes; add-mode
deposits accumulate across iterations and disqualify the buffer).

Convergence termination (``cond_fn`` / ``until``)
-------------------------------------------------
A convergence-style solver (the Nekbone/Faces regime) cannot know
``n_iters`` up front — the classic implementation round-trips a
residual to the host every iteration to decide when to stop, which is
exactly the host-in-the-control-path cost the ST model removes.  With
``cond_fn`` set (or ``STProgram.persistent(n, until=...)``), the fixed
``fori_loop`` becomes a ``jax.lax.while_loop``:

* each iteration evaluates ``reduce_fn`` (required) into a scalar and
  feeds it to ``cond_fn(reduction) -> bool``; the loop continues while
  the predicate holds (e.g. ``residual >= tol``), bounded by
  ``max_iters``.  The first iteration always runs (there is no
  reduction to test before it).
* double buffering switches to its *carried-predicate* variant: slot
  parity comes from a carried iteration counter (``i % 2`` with ``i``
  in the loop carry — a ``while_loop`` has no induction variable and no
  static unroll), and the final-slot selection uses the **dynamic**
  last parity ``(realized - 1) % 2`` instead of the static
  ``(n_iters - 1) % 2``.
* ``__call__`` returns ``(mem, reductions, n_done)``: the reduction
  trace padded with zeros to ``max_iters`` plus the realized iteration
  count — still ONE host dispatch and zero host syncs until converged.

Dispatch accounting
-------------------
``stats`` is a :class:`~repro.core.engine_host.HostStats`: one call =
one dispatch, zero host sync points, regardless of ``n_iters`` (or of
how many iterations a ``cond_fn`` loop realizes) — the contrast
:mod:`benchmarks.faces_bench` reports against the host
(``n_iters × dispatch_count_host()``) and fused (``n_iters × 1``)
engines.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Set, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from . import counters
from .descriptors import KernelDesc, StartDesc
from .engine_fused import FusedEngine, _interpret_program
from .queue import STProgram


def slot_buffers(prog: STProgram) -> Tuple[str, ...]:
    """Statically identify message-slot buffers safe to double-buffer.

    A buffer qualifies when (a) a channel or collective touches it and
    (b) its first access in *execution* order is a write — so its value
    at iteration start never reaches the result.  Replace-mode channel
    deposits count as writes (non-receiving ranks preserve a value both
    slots share); add-mode deposits read the accumulator and disqualify.
    """
    comm_bufs: Set[str] = set()
    for b in prog.batches:
        for ch in b.channels:
            comm_bufs.add(ch.src_buf)
            comm_bufs.add(ch.dst_buf)
        for coll in b.colls:
            comm_bufs.add(coll.buf)
            comm_bufs.add(coll.out)

    first_access: Dict[str, str] = {}  # buffer -> "read" | "write"

    def see(buf: str, kind: str):
        first_access.setdefault(buf, kind)

    for d in prog.descriptors:
        if isinstance(d, KernelDesc):
            for r in d.reads:
                see(r, "read")
            for w in d.writes:
                see(w, "write")
        elif isinstance(d, StartDesc):
            batch = next(b for b in prog.batches if b.index == d.batch)
            for ch in batch.channels:
                see(ch.src_buf, "read")
            for coll in batch.colls:
                see(coll.buf, "read")
            for ch in batch.channels:
                see(ch.dst_buf, "read" if ch.mode == "add" else "write")
            for coll in batch.colls:
                see(coll.out, "write")

    return tuple(sorted(
        b for b in comm_bufs if first_access.get(b) == "write"
    ))


class PersistentEngine(FusedEngine):
    """Run an STProgram for ``n_iters`` iterations as ONE host dispatch.

    Inherits the buffer/compile surface (``shardings``, ``init_buffers``,
    ``compile``, ``lower``) from :class:`FusedEngine`; only the lowered
    body (the device-resident loop) and the dispatch accounting differ.

    Parameters
    ----------
    program:
        The matched program; ``program.n_iters`` (see
        :meth:`STProgram.persistent`) supplies the iteration count when
        ``n_iters`` is not given.
    n_iters:
        Device-resident iteration count (>= 1).  Values > 1 are subject
        to the same quiescence reuse-guard as ``STProgram.persistent``.
    mode:
        ``stream`` / ``dataflow`` — same ordering semantics as
        :class:`FusedEngine`, applied to every pass.
    double_buffer:
        Alternate message-slot copies between iterations (default: on in
        ``dataflow`` mode).  The loop is unrolled ×2 so consecutive
        iterations coexist in the loop body and XLA may overlap them.
    reduce_fn:
        Optional ``fn(mem) -> scalar`` evaluated after every iteration
        *inside* the device loop (use ``jax.lax.psum`` over the mesh
        axes for a global value).  ``__call__`` then returns
        ``(mem, reductions)`` with ``reductions.shape == (n_iters,)`` —
        convergence traces without any host sync inside the loop.
        Required when ``cond_fn`` is set.
    cond_fn:
        Optional termination predicate ``fn(reduction) -> bool`` (e.g.
        ``lambda residual: residual >= tol``) evaluated on each
        iteration's reduction *inside* the device loop; the loop
        continues while it returns True, bounded by ``max_iters``.
        Defaults to ``program.until``.  ``__call__`` then returns
        ``(mem, reductions, n_done)`` with ``reductions`` zero-padded to
        ``max_iters`` and ``n_done`` the realized iteration count.
    max_iters:
        Safety bound for ``cond_fn`` loops (defaults to
        ``n_iters`` / ``program.n_iters``).  Only meaningful with a
        predicate.
    """

    def __init__(
        self,
        program: STProgram,
        n_iters: Optional[int] = None,
        mode: str = "stream",
        double_buffer: Optional[bool] = None,
        reduce_fn: Optional[Callable[[Dict[str, jax.Array]], jax.Array]] = None,
        cond_fn: Optional[Callable[[jax.Array], jax.Array]] = None,
        max_iters: Optional[int] = None,
        donate: bool = False,
    ):
        super().__init__(program, mode=mode, donate=donate)
        self.cond_fn = cond_fn if cond_fn is not None else program.until
        if max_iters is not None and self.cond_fn is None:
            raise ValueError("max_iters is only meaningful with cond_fn/until")
        if max_iters is None:
            max_iters = program.n_iters if n_iters is None else n_iters
        self.n_iters = self.max_iters = int(max_iters)
        if self.n_iters < 1:
            raise ValueError(f"n_iters must be >= 1, got {self.n_iters}")
        if self.cond_fn is not None and reduce_fn is None:
            raise ValueError(
                "cond_fn requires reduce_fn: the termination predicate is "
                "evaluated on the per-iteration scalar reduction")
        # an explicit n_iters/cond_fn override must pass the same
        # quiescence reuse-guard STProgram.persistent() enforces
        # (raises QueueError)
        program.persistent(self.n_iters, until=self.cond_fn)
        self.double_buffer = (mode == "dataflow") if double_buffer is None \
            else bool(double_buffer)
        self.reduce_fn = reduce_fn
        self._slots: Tuple[str, ...] = (
            slot_buffers(program) if self.double_buffer else ()
        )

    # (__call__ inherited: FusedEngine already counts one dispatch per
    # call — which here covers ALL n_iters iterations.)

    # -- lowering -------------------------------------------------------------

    def _build_jit(self):
        prog = self.program
        specs = {n: P(*s.pspec) for n, s in prog.buffers.items()}

        if self.cond_fn is not None:
            out_specs = (specs, P(), P())
            body = functools.partial(
                _run_persistent_while,
                prog=prog,
                mode=self.mode,
                mesh_shape=self._mesh_shape,
                max_iters=self.max_iters,
                slots=self._slots,
                reduce_fn=self.reduce_fn,
                cond_fn=self.cond_fn,
            )
        else:
            out_specs = (specs, P()) if self.reduce_fn is not None else specs
            body = functools.partial(
                _run_persistent,
                prog=prog,
                mode=self.mode,
                mesh_shape=self._mesh_shape,
                n_iters=self.n_iters,
                slots=self._slots,
                reduce_fn=self.reduce_fn,
                unroll=2 if (self.double_buffer and self.n_iters > 1) else 1,
            )
        sharded = shard_map(
            body, mesh=self.mesh, in_specs=(specs,), out_specs=out_specs,
            check_vma=False,
        )
        donate = (0,) if self.donate else ()
        return jax.jit(sharded, donate_argnums=donate)


# -- device-resident loop body (runs inside shard_map, traced once) ----------


def _run_persistent(
    mem: Dict[str, jax.Array],
    *,
    prog: STProgram,
    mode: str,
    mesh_shape: Dict[str, int],
    n_iters: int,
    slots: Tuple[str, ...],
    reduce_fn,
    unroll: int,
):
    mem = dict(mem)
    # two copies of each message slot; iteration i uses copy i % 2
    slot_mem = {n: jnp.stack([mem.pop(n)] * 2) for n in slots}
    token = counters.fresh_token()
    comp = counters.fresh_token()
    # None is an empty pytree node: no dead carry when reductions are off
    red = jnp.zeros((n_iters,), jnp.float32) if reduce_fn is not None else None

    def one_iter(i, carry):
        mem, slot_mem, token, comp, red = carry
        parity = jax.lax.rem(i, 2)
        cur = dict(mem)
        for n in slots:
            cur[n] = jax.lax.dynamic_index_in_dim(
                slot_mem[n], parity, axis=0, keepdims=False)
        cur, token, comp = _interpret_program(
            cur, prog=prog, mode=mode, mesh_shape=mesh_shape,
            token=token, comp_token=comp)
        if reduce_fn is not None:  # sees every buffer, slots included
            val = jnp.asarray(reduce_fn(cur), jnp.float32).reshape(())
            red = jax.lax.dynamic_update_index_in_dim(red, val, i, axis=0)
        new_slots = {
            n: jax.lax.dynamic_update_index_in_dim(
                slot_mem[n], cur.pop(n), parity, axis=0)
            for n in slots
        }
        return cur, new_slots, token, comp, red

    mem, slot_mem, token, comp, red = jax.lax.fori_loop(
        0, n_iters, one_iter, (mem, slot_mem, token, comp, red),
        unroll=unroll)

    # final values live in the slot the last iteration wrote
    last = (n_iters - 1) % 2
    for n in slots:
        mem[n] = slot_mem[n][last]
    if reduce_fn is not None:
        return mem, red
    return mem


def _run_persistent_while(
    mem: Dict[str, jax.Array],
    *,
    prog: STProgram,
    mode: str,
    mesh_shape: Dict[str, int],
    max_iters: int,
    slots: Tuple[str, ...],
    reduce_fn,
    cond_fn,
):
    """Predicate-terminated variant: ``lax.while_loop`` until
    ``cond_fn(reduction)`` goes False (or ``max_iters`` is hit).

    The carry threads the iteration counter explicitly (a while_loop has
    no induction variable), so slot parity is the *carried* ``i % 2``
    and the final-slot selection below uses the dynamic last parity —
    the realized iteration count is a runtime value here.
    """
    mem = dict(mem)
    # two copies of each message slot; iteration i uses copy i % 2
    slot_mem = {n: jnp.stack([mem.pop(n)] * 2) for n in slots}
    token = counters.fresh_token()
    comp = counters.fresh_token()
    red = jnp.zeros((max_iters,), jnp.float32)

    def cond(carry):
        i, keep_going, *_ = carry
        return jnp.logical_and(keep_going, i < max_iters)

    def body(carry):
        i, _, mem, slot_mem, token, comp, red = carry
        parity = jax.lax.rem(i, 2)
        cur = dict(mem)
        for n in slots:
            cur[n] = jax.lax.dynamic_index_in_dim(
                slot_mem[n], parity, axis=0, keepdims=False)
        cur, token, comp = _interpret_program(
            cur, prog=prog, mode=mode, mesh_shape=mesh_shape,
            token=token, comp_token=comp)
        val = jnp.asarray(reduce_fn(cur), jnp.float32).reshape(())
        red = jax.lax.dynamic_update_index_in_dim(red, val, i, axis=0)
        new_slots = {
            n: jax.lax.dynamic_update_index_in_dim(
                slot_mem[n], cur.pop(n), parity, axis=0)
            for n in slots
        }
        keep_going = jnp.asarray(cond_fn(val), jnp.bool_).reshape(())
        return i + 1, keep_going, cur, new_slots, token, comp, red

    # the first iteration always runs: there is no reduction to test yet
    carry0 = (jnp.zeros((), jnp.int32), jnp.asarray(True),
              mem, slot_mem, token, comp, red)
    n_done, _, mem, slot_mem, token, comp, red = jax.lax.while_loop(
        cond, body, carry0)

    # final values live in the slot the last *realized* iteration wrote —
    # a dynamic parity, unlike the fixed-n_iters loop above
    last = jax.lax.rem(n_done - 1, 2)
    for n in slots:
        mem[n] = jax.lax.dynamic_index_in_dim(
            slot_mem[n], last, axis=0, keepdims=False)
    return mem, red, n_done
