"""qwen1.5-0.5b [dense] — GQA kv=16 (MHA), QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    arch_type="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab=151936,
    act="silu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    serve_window=8192,      # beyond-paper windowed-serving variant
    long_context_ok=True,   # long_500k via the sliding-window serve path
)
