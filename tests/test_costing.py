"""Schedule cost model (repro.launch.costing.schedule_cost).

Fast lane (single device): the predicted orderings the tuner prunes on
— persistent < fused < host, coalesced < uncoalesced, full-domain <
linked n4, sequential interleave < round-robin — plus rename
invariance (costs price structure, never names), component accounting,
and the error surface.

Slow lane: the same orderings on the real 2×2×2 8-device grid, where
the ghost-ring identity elisions and the cross-rank collectives both
actually occur (subprocess, like tests/test_verify.py).
"""

import pytest

from repro.core import (
    FacesConfig,
    build_faces_part_program,
    build_faces_program,
    compose,
    part_names,
)
from repro.core.halo import AXES3
from repro.launch.costing import (
    DEFAULT_PARAMS,
    ScheduleCost,
    predict_ranking,
    schedule_cost,
)

N = 5


def _mesh111():
    from repro.parallel import make_mesh
    return make_mesh((1, 1, 1), AXES3)


def _cfg():
    return FacesConfig(grid=(1, 1, 1), points=(6, 4, 4))


def _prog(name=None):
    return build_faces_program(_cfg(), _mesh111(), name=name)


def _linked(n_parts, interleave=None):
    mesh, cfg = _mesh111(), _cfg()
    names = part_names(n_parts)
    progs = [build_faces_part_program(cfg, mesh, k, n_parts,
                                      names=names).persistent(N)
             for k in range(n_parts)]
    return compose(*progs, verify="off", interleave=interleave)


# -- predicted orderings (what the tuner prunes on) --------------------------


def test_engine_ordering_persistent_beats_fused_beats_host():
    prog = _prog()
    host = schedule_cost(prog, engine="host", n_iters=N).total_us
    fused = schedule_cost(prog, engine="fused", n_iters=N).total_us
    pers = schedule_cost(prog.persistent(N), engine="persistent").total_us
    assert pers < fused < host


def test_coalesced_cheaper_than_uncoalesced():
    pprog = _prog().persistent(N)
    c = schedule_cost(pprog, coalesce=True).total_us
    u = schedule_cost(pprog, coalesce=False).total_us
    assert c < u


def test_full_domain_cheaper_than_linked_n4():
    full = schedule_cost(_prog().persistent(N)).total_us
    linked = schedule_cost(_linked(4)).total_us
    assert full < linked


def test_sequential_interleave_cheaper_than_round_robin():
    # the interleave knob is priced through the pid-switch count — the
    # cost model must SEE the policy, or the tuner could not prune on it
    rr = schedule_cost(_linked(4))
    seq = schedule_cost(_linked(4, interleave="sequential"))
    assert seq.switch_us < rr.switch_us
    assert seq.total_us < rr.total_us


def test_predict_ranking_sorted_cheapest_first():
    pairs = [("full", _prog().persistent(N)), ("linked4", _linked(4))]
    ranked = predict_ranking(pairs)
    assert [n for n, _ in ranked] == ["full", "linked4"]
    assert ranked[0][1] <= ranked[1][1]


# -- rename invariance: costs price structure, never names -------------------


def _random_names(seed, n):
    """Deterministic pseudo-random identifiers (property-style without a
    hypothesis dependency — it is absent from some environments)."""
    import numpy as np
    rng = np.random.RandomState(seed)
    alphabet = "abcdefghij_"
    out = []
    while len(out) < n:
        nm = "".join(alphabet[i] for i in
                     rng.randint(0, len(alphabet), rng.randint(1, 13)))
        if nm not in out:
            out.append(nm)
    return out


@pytest.mark.parametrize("name", ["alpha", "omega"] + _random_names(0, 6))
def test_rename_invariance_property(name):
    base = schedule_cost(_prog().persistent(N))
    renamed = schedule_cost(_prog(name=name).persistent(N))
    assert renamed.row() == base.row()


@pytest.mark.parametrize("seed", range(4))
def test_rename_invariance_composed_property(seed):
    mesh, cfg = _mesh111(), _cfg()

    def build(nm):
        progs = [build_faces_part_program(cfg, mesh, k, 2, names=nm)
                 .persistent(N) for k in range(2)]
        return compose(*progs, verify="off")

    a = schedule_cost(build(tuple(_random_names(seed, 2))))
    b = schedule_cost(build(part_names(2)))
    assert a.row() == b.row()


# -- accounting and error surface --------------------------------------------


def test_total_is_sum_of_components():
    cost = schedule_cost(_prog().persistent(N))
    parts = (cost.dispatch_us + cost.collective_us + cost.bytes_us
             + cost.kernel_us + cost.staging_us + cost.slot_us
             + cost.exposed_us + cost.switch_us)
    assert cost.total_us == pytest.approx(parts)
    row = cost.row()
    assert row["total_us"] == pytest.approx(cost.total_us)


def test_dispatch_models():
    prog = _prog()
    host = schedule_cost(prog, engine="host", n_iters=N)
    fused = schedule_cost(prog, engine="fused", n_iters=N)
    pers = schedule_cost(prog.persistent(N), engine="persistent")
    assert host.n_dispatches == prog.dispatch_count_host() * N
    assert fused.n_dispatches == N
    assert pers.n_dispatches == 1


def test_persistent_prices_slot_pressure():
    pprog = _prog().persistent(N)
    db = schedule_cost(pprog, double_buffer=True)
    single = schedule_cost(pprog, double_buffer=False)
    assert db.slot_bytes == 2 * single.slot_bytes
    assert db.slot_us > single.slot_us
    assert schedule_cost(pprog, engine="fused").slot_bytes == 0


def test_params_are_defaulted_and_overridable():
    import dataclasses
    pprog = _prog().persistent(N)
    base = schedule_cost(pprog)
    pricier = schedule_cost(pprog, params=dataclasses.replace(
        DEFAULT_PARAMS, dispatch_us=DEFAULT_PARAMS.dispatch_us * 10))
    assert pricier.dispatch_us == pytest.approx(base.dispatch_us * 10)


def test_bad_engine_and_mode_raise():
    prog = _prog()
    with pytest.raises(ValueError, match="engine"):
        schedule_cost(prog, engine="nic")
    with pytest.raises(ValueError, match="mode"):
        schedule_cost(prog, mode="chaotic")


def test_cost_row_is_json_ready():
    import json
    row = schedule_cost(_prog().persistent(N)).row()
    json.dumps(row)  # no numpy scalars, no dataclasses
    assert isinstance(schedule_cost(_prog()), ScheduleCost)


# -- slow lane: real 8-device grid -------------------------------------------


@pytest.mark.slow
def test_orderings_8dev(subproc):
    """On the real 2×2×2 grid the ghost-ring channels are full-identity
    (elided) while the face channels fire real collectives — the same
    orderings must hold with both effects in play."""
    code = """
from repro.core import (FacesConfig, build_faces_part_program,
                        build_faces_program, compose, part_names)
from repro.parallel import make_mesh
from repro.launch.costing import schedule_cost

mesh = make_mesh((2, 2, 2), ("gx", "gy", "gz"))
cfg = FacesConfig(grid=(2, 2, 2), points=(12, 12, 12))
N = 10
prog = build_faces_program(cfg, mesh)
host = schedule_cost(prog, engine="host", n_iters=N).total_us
fused = schedule_cost(prog, engine="fused", n_iters=N).total_us
full = schedule_cost(prog.persistent(N))
assert full.total_us < fused < host, (full.total_us, fused, host)
assert full.n_collectives > 0

names = part_names(4)
progs = [build_faces_part_program(cfg, mesh, k, 4, names=names).persistent(N)
         for k in range(4)]
rr = schedule_cost(compose(*progs, verify="off"))
seq = schedule_cost(compose(*progs, verify="off", interleave="sequential"))
assert full.total_us < rr.total_us, (full.total_us, rr.total_us)
assert seq.total_us < rr.total_us, (seq.total_us, rr.total_us)
assert rr.n_elided > 0          # ghost-ring identity perms never fire
assert rr.n_collectives > full.n_collectives

c = schedule_cost(prog.persistent(N), coalesce=True).total_us
u = schedule_cost(prog.persistent(N), coalesce=False).total_us
assert c < u, (c, u)
print("OK")
"""
    r = subproc(code)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout
