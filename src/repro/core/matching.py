"""Static two-sided message matching.

MPI two-sided semantics normally require runtime matching of
(source, tag, communicator) against posted receives — the part of the
paper's design that Slingshot 11 could *not* offload (no triggered
receives) and that forced the per-process progress thread.

The ST interface forbids ``MPI_ANY_SOURCE`` / ``MPI_ANY_TAG``
(paper §III-D), which makes the match function *static*: every send's
peer and tag are known when the program is built.  On TPU we exploit
this fully — matching happens **at trace time**, and each matched
(send, recv) pair lowers to one ``ppermute`` channel.  There is no
runtime matching engine and therefore no progress thread; the paper's
progress-thread cost reappears only in the host-orchestrated engine as
per-descriptor dispatch overhead.

Matching rules (mirroring MPI ordering guarantees):

* within one trigger batch, sends and recvs with equal tags match in
  FIFO order (non-overtaking);
* a send with peer ``OffsetPeer(axis, +d)`` matches a recv with peer
  ``OffsetPeer(axis, -d)`` (the receiver names where the data comes
  *from*); same for grid offsets;
* ``PairListPeer`` sends/recvs match when their (src → dst) pair sets
  are identical;
* unmatched descriptors inside a batch are a program error, raised at
  build time — the paper's equivalent would be a hang.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, List, Optional, Sequence, Tuple

from .descriptors import (
    CollDesc,
    GridOffsetPeer,
    OffsetPeer,
    PairListPeer,
    RecvDesc,
    SendDesc,
    perm_for,
)


@dataclasses.dataclass
class Channel:
    """A matched (send, recv) pair lowered to one ppermute channel."""

    src_buf: str
    dst_buf: str
    axis: Any  # axis name or tuple of axis names
    peer: Any  # the *send-side* peer spec (canonical direction)
    tag: int
    send_region: Optional[Tuple[slice, ...]]
    recv_region: Optional[Tuple[slice, ...]]
    mode: str  # replace | add

    def perm(self, mesh_shape: dict) -> Sequence[Tuple[int, int]]:
        return perm_for(self.peer, mesh_shape)[1]


class MatchError(RuntimeError):
    pass


def _peer_key(peer) -> Tuple:
    """Canonical direction key: send(+d) and recv(-d) share a key."""
    if isinstance(peer, OffsetPeer):
        return ("off", peer.axis, peer.delta, peer.periodic)
    if isinstance(peer, GridOffsetPeer):
        return ("grid", peer.axes, peer.deltas, peer.periodic)
    if isinstance(peer, PairListPeer):
        return ("pairs", peer.axis, tuple(sorted(peer.pairs)))
    raise TypeError(f"unknown peer: {peer!r}")


def _recv_key_as_send(peer) -> Tuple:
    """Key a recv descriptor under the *sender's* direction."""
    if isinstance(peer, (OffsetPeer, GridOffsetPeer)):
        return _peer_key(peer.inverse())
    return _peer_key(peer)


def match_batch(
    sends: Sequence[SendDesc], recvs: Sequence[RecvDesc]
) -> List[Channel]:
    """Match one trigger batch's sends against its recvs (FIFO per key)."""
    recv_queues: dict = defaultdict(list)
    for r in recvs:
        recv_queues[(_recv_key_as_send(r.peer), r.tag)].append(r)

    channels: List[Channel] = []
    for s in sends:
        key = (_peer_key(s.peer), s.tag)
        q = recv_queues.get(key)
        if not q:
            raise MatchError(
                f"unmatched ST send: buf={s.buf!r} tag={s.tag} peer={s.peer} "
                f"(no posted receive in batch; ST forbids wildcards so this "
                f"would hang at runtime)"
            )
        r = q.pop(0)
        axis = (
            s.peer.axis
            if isinstance(s.peer, (OffsetPeer, PairListPeer))
            else s.peer.axes
        )
        channels.append(
            Channel(
                src_buf=s.buf,
                dst_buf=r.buf,
                axis=axis,
                peer=s.peer,
                tag=s.tag,
                send_region=s.region,
                recv_region=r.region,
                mode=r.mode,
            )
        )

    leftovers = [r for q in recv_queues.values() for r in q]
    if leftovers:
        r = leftovers[0]
        raise MatchError(
            f"unmatched ST recv: buf={r.buf!r} tag={r.tag} peer={r.peer} "
            f"({len(leftovers)} receive(s) never matched by a send)"
        )
    return channels


@dataclasses.dataclass
class Batch:
    """Everything triggered by one `start` (paper: one writeValue)."""

    index: int
    kernels_before: List[Any]  # KernelDescs enqueued before this start
    channels: List[Channel]
    colls: List[CollDesc]
    waited: bool = False
    # Program identity under composition (see repro.core.schedule):
    # batches keep their owning program's pid so engines can bank
    # counters per program.
    pid: int = 0


def validate_program_order(descs: Sequence[Any]) -> None:
    """Queue-level FIFO invariants (raised at build, not at run).

    * every send/recv/coll must be covered by a later `start`;
    * `wait` must reference a batch that has a `start`;
    * thresholds must be monotonically non-decreasing (DWQ contract).
    """
    from .descriptors import StartDesc, WaitDesc  # local to avoid cycle

    open_comm = 0
    started = 0
    waits_seen = 0
    last_threshold = 0
    for d in descs:
        if isinstance(d, (SendDesc, RecvDesc, CollDesc)):
            open_comm += 1
            if d.threshold >= 0 and d.threshold < last_threshold:
                raise MatchError("descriptor thresholds must be monotone")
            last_threshold = max(last_threshold, d.threshold)
        elif isinstance(d, StartDesc):
            started += 1
            open_comm = 0
        elif isinstance(d, WaitDesc):
            waits_seen += 1
            if waits_seen > started:
                raise MatchError(
                    "MPIX_Enqueue_wait before any matching MPIX_Enqueue_start"
                )
    if open_comm:
        raise MatchError(
            f"{open_comm} enqueued communication op(s) not covered by an "
            f"MPIX_Enqueue_start — they would never trigger"
        )
