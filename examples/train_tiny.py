"""End-to-end training driver: ~100M-param model, a few hundred steps.

Trains a reduced-depth glm4-family decoder (≈100M params) on the
synthetic Markov stream with the full production stack: sharded params
(data×model mesh), AdamW, LR schedule, checkpointing.  Loss should drop
from ~10.9 (ln V) to well under 7 within a few hundred steps.

Run:  PYTHONPATH=src python examples/train_tiny.py [--steps 300]
"""
import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import argparse
import dataclasses

from repro.configs.base import ShapeConfig, get_config
from repro.launch.train import train
from repro.optim import AdamWConfig
from repro.parallel import make_mesh

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
args = ap.parse_args()

cfg = dataclasses.replace(
    get_config("glm4-9b"),
    name="glm4-100m",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=2, d_ff=2560,
    vocab=4096, dtype="float32", param_dtype="float32",
    scan_layers=True, remat="none",
)
from repro.models.counting import count_params
print(f"model: {cfg.name}, {count_params(cfg)/1e6:.1f}M params")

mesh = make_mesh((2, 2), ("data", "model"))
shape = ShapeConfig("tiny_train", args.seq, args.batch, "train")
params, opt_state, hist = train(
    cfg, shape, mesh, steps=args.steps,
    opt=AdamWConfig(lr=1e-3, weight_decay=0.01),
    checkpoint_dir="/tmp/repro_ckpt", checkpoint_every=100, log_every=20)
first, last = hist[0]["loss"], hist[-1]["loss"]
print(f"loss {first:.3f} -> {last:.3f} ({'LEARNED ✓' if last < first - 1 else 'check settings'})")
