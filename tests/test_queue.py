"""STQueue API semantics (paper §III) — single-device unit tests."""

import jax
import numpy as np
import pytest

from repro.core import (
    GridOffsetPeer,
    MatchError,
    OffsetPeer,
    QueueError,
    STQueue,
    create_queue,
    match_batch,
)
from repro.core.descriptors import RecvDesc, SendDesc, perm_for


def _mesh1():
    from repro.parallel import make_mesh
    return make_mesh((1,), ("x",))


def _queue():
    q = create_queue(_mesh1(), "t")
    q.buffer("a", (4, 4), np.float32, pspec=("x",))
    q.buffer("b", (4, 4), np.float32, pspec=("x",))
    return q


class TestQueueAPI:
    def test_enqueue_is_nonblocking_descriptor_append(self):
        q = _queue()
        q.enqueue_send("a", OffsetPeer("x", 1), tag=0)
        q.enqueue_recv("b", OffsetPeer("x", -1), tag=0)
        assert q.n_descriptors == 2  # nothing executed, nothing built

    def test_wait_before_start_rejected(self):
        q = _queue()
        with pytest.raises(QueueError):
            q.enqueue_wait()

    def test_uncovered_sends_rejected_at_build(self):
        q = _queue()
        q.enqueue_send("a", OffsetPeer("x", 1), tag=0)
        q.enqueue_recv("b", OffsetPeer("x", -1), tag=0)
        with pytest.raises(MatchError, match="never trigger"):
            q.build()

    def test_use_after_free_rejected(self):
        q = _queue()
        q.free()
        with pytest.raises(QueueError, match="use-after-free"):
            q.enqueue_send("a", OffsetPeer("x", 1), tag=0)

    def test_undeclared_buffer_rejected(self):
        q = _queue()
        with pytest.raises(QueueError, match="undeclared"):
            q.enqueue_send("nope", OffsetPeer("x", 1), tag=0)

    def test_batching_one_start_covers_all(self):
        q = _queue()
        for t in range(4):
            q.enqueue_recv("b", OffsetPeer("x", -1), tag=t)
        for t in range(4):
            q.enqueue_send("a", OffsetPeer("x", 1), tag=t)
        q.enqueue_start()
        q.enqueue_wait()
        prog = q.build()
        assert prog.n_batches == 1
        assert len(prog.batches[0].channels) == 4
        assert prog.batches[0].waited

    def test_dispatch_count_contrast(self):
        # the paper's headline structural claim: ST = 1 dispatch,
        # host-orchestrated = one per kernel+channel
        q = _queue()
        q.enqueue_kernel(lambda a: a * 2, ["a"], ["a"])
        q.enqueue_recv("b", OffsetPeer("x", -1), tag=0)
        q.enqueue_send("a", OffsetPeer("x", 1), tag=0)
        q.enqueue_start()
        q.enqueue_wait()
        prog = q.build()
        assert prog.dispatch_count_fused() == 1
        assert prog.dispatch_count_host() == 2  # 1 kernel + 1 channel

    def test_build_idempotent(self):
        q = _queue()
        q.enqueue_recv("b", OffsetPeer("x", -1), tag=0)
        q.enqueue_send("a", OffsetPeer("x", 1), tag=0)
        q.enqueue_start()
        assert q.build() is q.build()

    def test_build_name_not_served_from_stale_cache(self):
        # regression: a second build("other") used to return the cached
        # program built under the first name
        q = _queue()
        q.enqueue_recv("b", OffsetPeer("x", -1), tag=0)
        q.enqueue_send("a", OffsetPeer("x", 1), tag=0)
        q.enqueue_start()
        first = q.build("first")
        assert first.name == "first"
        other = q.build("other")
        assert other.name == "other"
        assert other.descriptors == first.descriptors
        # same-name rebuilds still hit the cache; default name rebuilds too
        assert q.build("other") is other
        assert q.build().name == q.name
        assert q.build() is q.build()

    def test_free_invalidates_built_program_cache(self):
        # regression: free() must drop the built-program cache — a
        # program built, freed, then rebuilt from a reused queue name
        # must never be served descriptors from the freed queue
        q = _queue()
        q.enqueue_recv("b", OffsetPeer("x", -1), tag=0)
        q.enqueue_send("a", OffsetPeer("x", 1), tag=0)
        q.enqueue_start()
        stale = q.build()
        q.free()
        assert q._built is None  # cache dropped with the queue
        with pytest.raises(QueueError, match="use-after-free"):
            q.build()
        # a fresh queue reusing the name builds its own program, not the
        # freed queue's cached one
        q2 = _queue()
        q2.enqueue_recv("b", OffsetPeer("x", -1), tag=5)
        q2.enqueue_send("a", OffsetPeer("x", 1), tag=5)
        q2.enqueue_start()
        rebuilt = q2.build()
        assert rebuilt is not stale
        assert rebuilt.descriptors != stale.descriptors

    def test_wait_marks_all_earlier_batches_waited(self):
        # regression: completion counters are cumulative, so ONE trailing
        # wait quiesces every batch <= its own — earlier unwaited batches
        # must not misreport quiescence
        q = _queue()
        for t in range(2):
            q.enqueue_recv("b", OffsetPeer("x", -1), tag=t)
            q.enqueue_send("a", OffsetPeer("x", 1), tag=t)
            q.enqueue_start()
        q.enqueue_wait()  # waits on batch 1; batch 0 completes before it
        prog = q.build()
        assert prog.n_batches == 2
        assert all(b.waited for b in prog.batches)
        assert prog.persistent(4).n_iters == 4  # quiescent: reuse allowed

    def test_wait_does_not_cover_later_batches(self):
        q = _queue()
        q.enqueue_recv("b", OffsetPeer("x", -1), tag=0)
        q.enqueue_send("a", OffsetPeer("x", 1), tag=0)
        q.enqueue_start()
        q.enqueue_wait()
        q.enqueue_recv("b", OffsetPeer("x", -1), tag=1)
        q.enqueue_send("a", OffsetPeer("x", 1), tag=1)
        q.enqueue_start()  # never waited
        prog = q.build()
        assert prog.batches[0].waited and not prog.batches[1].waited
        with pytest.raises(QueueError, match="quiescent"):
            prog.persistent(2)


class TestMatching:
    def test_offset_peers_match_by_inverse(self):
        s = [SendDesc("a", OffsetPeer("x", 1), tag=7)]
        r = [RecvDesc("b", OffsetPeer("x", -1), tag=7)]
        chans = match_batch(s, r)
        assert len(chans) == 1
        assert chans[0].src_buf == "a" and chans[0].dst_buf == "b"

    def test_tag_mismatch_raises(self):
        s = [SendDesc("a", OffsetPeer("x", 1), tag=7)]
        r = [RecvDesc("b", OffsetPeer("x", -1), tag=8)]
        with pytest.raises(MatchError, match="unmatched ST send"):
            match_batch(s, r)

    def test_leftover_recv_raises(self):
        r = [RecvDesc("b", OffsetPeer("x", -1), tag=7)]
        with pytest.raises(MatchError, match="unmatched ST recv"):
            match_batch([], r)

    def test_fifo_order_same_tag(self):
        # MPI non-overtaking: same (peer, tag) matches in FIFO order
        s = [SendDesc("a1", OffsetPeer("x", 1), tag=0),
             SendDesc("a2", OffsetPeer("x", 1), tag=0)]
        r = [RecvDesc("b1", OffsetPeer("x", -1), tag=0),
             RecvDesc("b2", OffsetPeer("x", -1), tag=0)]
        chans = match_batch(s, r)
        assert [(c.src_buf, c.dst_buf) for c in chans] == [
            ("a1", "b1"), ("a2", "b2")]

    def test_grid_offset_inverse(self):
        s = [SendDesc("a", GridOffsetPeer(("x", "y"), (1, -1)), tag=0)]
        r = [RecvDesc("b", GridOffsetPeer(("x", "y"), (-1, 1)), tag=0)]
        assert len(match_batch(s, r)) == 1


class TestPerms:
    def test_offset_perm_nonperiodic_drops_boundary(self):
        axis, pairs = perm_for(OffsetPeer("x", 1), {"x": 4})
        assert axis == "x"
        assert pairs == [(0, 1), (1, 2), (2, 3)]

    def test_offset_perm_periodic_wraps(self):
        _, pairs = perm_for(OffsetPeer("x", 1, periodic=True), {"x": 4})
        assert (3, 0) in pairs and len(pairs) == 4

    def test_grid_perm_diagonal(self):
        axes, pairs = perm_for(GridOffsetPeer(("x", "y"), (1, 1)),
                               {"x": 2, "y": 2})
        assert axes == ("x", "y")
        # only (0,0)->(1,1) survives the boundary on a 2x2 grid
        assert pairs == [(0, 3)]

    def test_grid_perm_is_injective(self):
        _, pairs = perm_for(GridOffsetPeer(("x", "y", "z"), (1, -1, 0),
                                           periodic=True),
                            {"x": 3, "y": 2, "z": 2})
        dsts = [d for _, d in pairs]
        assert len(set(dsts)) == len(dsts) == 12
