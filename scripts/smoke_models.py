"""Smoke every arch (reduced config): forward logits + loss/grad + prefill/decode."""
import sys
import numpy as np
import jax
import jax.numpy as jnp
from repro.configs import all_configs, get_config
from repro.models import Model

only = sys.argv[1:] if len(sys.argv) > 1 else None
key = jax.random.PRNGKey(0)
B, S = 2, 24

for arch, full in all_configs().items():
    if only and arch not in only:
        continue
    cfg = full.smoke()
    m = Model(cfg)
    params, axes = m.init(key)
    n_params = sum(int(np.prod(v.shape)) for v in jax.tree.leaves(params))
    batch = {
        "tokens": jnp.asarray(np.random.randint(0, cfg.vocab, (B, S)), jnp.int32),
        "targets": jnp.asarray(np.random.randint(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.enc_dec:
        batch["audio_embeds"] = jnp.asarray(
            np.random.randn(B, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jnp.asarray(
            np.random.randn(B, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)

    logits = m.forward_logits(params, batch)
    assert logits.shape == (B, S, cfg.vocab), (arch, logits.shape)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN in logits"

    loss, metrics = m.loss(params, batch)
    g = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(x.astype(jnp.float32) ** 2)) for x in jax.tree.leaves(g)) ** 0.5
    assert np.isfinite(float(loss)) and np.isfinite(gnorm), f"{arch}: NaN loss/grad"

    # prefill + 2 decode steps, compare with full forward
    caches = m.init_caches(B, S + 4 + m._prefix_len())
    lg_pre, caches = m.prefill(params, batch, caches)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(logits[:, -1]),
                               rtol=2e-3, atol=2e-3)
    tok = jnp.argmax(lg_pre, -1).astype(jnp.int32)
    lg_dec, caches = m.decode_step(params, caches, tok)
    assert np.isfinite(np.asarray(lg_dec)).all(), f"{arch}: NaN in decode"
    print(f"{arch:20s} OK params={n_params:,} loss={float(loss):.3f} gnorm={gnorm:.2f}")
print("MODEL SMOKE PASS")
