"""Kernel-vs-oracle smoke (1 device, interpret mode)."""
import numpy as np
import jax.numpy as jnp
from repro.kernels import ops, ref

rng = np.random.RandomState(0)

# halo pack family
u = rng.randn(6, 5, 4).astype(np.float32)
region = (slice(0, 1), slice(0, 5), slice(0, 4))
np.testing.assert_allclose(ops.halo_pack(u, region), ref.halo_pack(jnp.asarray(u), region))
msg = rng.randn(1, 5, 4).astype(np.float32)
np.testing.assert_allclose(ops.halo_unpack_add(u, msg, region),
                           ref.halo_unpack_add(jnp.asarray(u), jnp.asarray(msg), region))
regions = [
    (slice(0, 1), slice(0, 5), slice(0, 4)),
    (slice(5, 6), slice(0, 5), slice(0, 4)),
    (slice(0, 6), slice(0, 1), slice(0, 4)),
    (slice(0, 1), slice(0, 1), slice(0, 1)),
]
np.testing.assert_allclose(ops.pack_boundary(u, regions), ref.pack_boundary(jnp.asarray(u), regions))
buf = rng.randn(sum(np.prod([s.stop - s.start for s in r]) for r in regions)).astype(np.float32)
np.testing.assert_allclose(ops.unpack_boundary_add(u, buf, regions),
                           ref.unpack_boundary_add(jnp.asarray(u), jnp.asarray(buf), regions), rtol=1e-6)
print("halo kernels OK")

# rmsnorm
x = rng.randn(37, 256).astype(np.float32)
w = rng.randn(256).astype(np.float32)
np.testing.assert_allclose(ops.rmsnorm(x, w), ref.rmsnorm(jnp.asarray(x), jnp.asarray(w)), rtol=2e-5)
xb = rng.randn(2, 3, 128).astype(np.float32)
wb = rng.randn(128).astype(np.float32)
np.testing.assert_allclose(ops.rmsnorm(xb, wb, weight_offset=1.0),
                           ref.rmsnorm(jnp.asarray(xb), jnp.asarray(wb), weight_offset=1.0), rtol=2e-5)
print("rmsnorm OK")

# flash attention
B, Hq, Hkv, S, D = 2, 4, 2, 96, 32
q = rng.randn(B, Hq, S, D).astype(np.float32)
k = rng.randn(B, Hkv, S, D).astype(np.float32)
v = rng.randn(B, Hkv, S, D).astype(np.float32)
for kwargs in [dict(causal=True), dict(causal=False), dict(causal=True, window=17),
               dict(causal=True, logit_softcap=20.0)]:
    out = ops.flash_attention(q, k, v, block_q=32, block_k=32, **kwargs)
    want = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), **kwargs)
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-5)
# decode: Sq=1 with q_offset
qd = rng.randn(B, Hq, 1, D).astype(np.float32)
out = ops.flash_attention(qd, k, v, q_offset=S - 1, block_q=1, block_k=32)
want = ref.attention(jnp.asarray(qd), jnp.asarray(k), jnp.asarray(v), q_offset=S - 1)
np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-5)
print("flash attention OK")

# ssd
B, S, H, P, G, N = 2, 80, 4, 16, 2, 24
x = rng.randn(B, S, H, P).astype(np.float32)
dt = np.abs(rng.randn(B, S, H)).astype(np.float32) * 0.1
A = -np.abs(rng.randn(H)).astype(np.float32)
Bm = rng.randn(B, S, G, N).astype(np.float32)
C = rng.randn(B, S, G, N).astype(np.float32)
y, h = ops.ssd_scan(x, dt, A, Bm, C, chunk=32, return_state=True)
yr, hr = ref.ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                      jnp.asarray(Bm), jnp.asarray(C), return_state=True)
np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-5)
np.testing.assert_allclose(h, hr, rtol=2e-4, atol=2e-5)
print("ssd OK")
print("KERNEL SMOKE PASS")
