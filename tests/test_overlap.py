"""core/overlap.py + core/collectives.py — decomposed and ST-expressed
collectives vs their jax.lax references.

Fast lane: single-device trivial paths (axis size 1 short-circuits),
the `triggered` ST wrapper, and the ST collective-matmul builders at
n=1.  Slow lane: per-collective subprocess tests on an 8-device mesh
(finer-grained than the combined check in tests/test_distributed.py,
so a regression names the exact collective), plus bit-identity
properties of the ST programs — across dtypes, uneven (non-square,
non-power-of-two) tiles, bidirectional rings, and the chained
transformer block as one persistent dispatch.
"""

import numpy as np
import pytest


def _smap1(f, in_specs, out_specs):
    from repro.compat import jit_shard_map
    from repro.parallel import make_mesh

    mesh = make_mesh((1,), ("x",))
    return jit_shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs)


# -- trivial paths (fast, single device) --------------------------------------


def test_single_device_paths_are_identity():
    from functools import partial

    from jax.sharding import PartitionSpec as P

    from repro.core import overlap

    x = np.random.RandomState(0).randn(8, 4).astype(np.float32)
    for fn in (
        partial(overlap.all_gather_ring, axis="x"),
        partial(overlap.all_gather_ring, axis="x", bidirectional=False),
        partial(overlap.reduce_scatter_ring, axis="x"),
        partial(overlap.all_to_all_ppermute, axis="x"),
    ):
        got = _smap1(fn, (P("x"),), P("x"))(x)
        np.testing.assert_allclose(np.asarray(got), x, rtol=1e-6)


def test_all_gather_matmul_single_device():
    from functools import partial

    from jax.sharding import PartitionSpec as P

    from repro.core import overlap

    rng = np.random.RandomState(1)
    x = rng.randn(8, 4).astype(np.float32)
    w = rng.randn(4, 3).astype(np.float32)
    got = _smap1(partial(overlap.all_gather_matmul, axis="x"),
                 (P("x"), P()), P("x"))(x, w)
    np.testing.assert_allclose(np.asarray(got), x @ w, rtol=1e-5, atol=1e-5)


def test_triggered_wrapper_preserves_values():
    import jax.numpy as jnp

    from repro.core import fresh_token, overlap

    token = fresh_token()
    fn = overlap.triggered(lambda v: v * 2.0, token)
    out = fn(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), [0.0, 2.0, 4.0, 6.0])


def test_collective_builders_single_device():
    # n=1 degenerate ring: the ST programs reduce to their local math
    from repro.core import collectives
    from repro.core.engine_fused import FusedEngine
    from repro.parallel import make_mesh

    mesh = make_mesh((1,), ("x",))
    rng = np.random.RandomState(0)
    x = rng.randn(8, 4).astype(np.float32)
    w = rng.randn(4, 3).astype(np.float32)
    for cm, inputs, want in (
        (collectives.build_all_gather_matmul(mesh, "x", 8, 4, 3),
         {"x": x, "w": w}, x @ w),
        (collectives.build_matmul_reduce_scatter(mesh, "x", 8, 4, 3),
         {"x": x, "w": w}, x @ w),
        (collectives.build_all_to_all(mesh, "x", 8, 4), {"x": x}, x),
    ):
        eng = FusedEngine(cm.program, mode="dataflow")
        got = np.asarray(eng(eng.init_buffers(inputs))[cm.output])
        np.testing.assert_array_equal(
            got, np.asarray(cm.reference(*(inputs[b] for b in cm.inputs))))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
        assert eng.stats.dispatches == 1


def test_tp_block_single_device():
    from repro.core import collectives
    from repro.core.engine_persistent import PersistentEngine
    from repro.parallel import make_mesh

    mesh = make_mesh((1,), ("x",))
    rng = np.random.RandomState(1)
    x = rng.randn(6, 4).astype(np.float32)
    w1 = rng.randn(4, 5).astype(np.float32)
    w2 = rng.randn(5, 4).astype(np.float32)
    tp = collectives.build_tp_block(mesh, "x", 6, 4, 5, chain=True)
    eng = PersistentEngine(tp.program.persistent(3), donate=True)
    got = np.asarray(eng(eng.init_buffers(
        {"x": x, "w1": w1, "w2": w2}))["out"])
    ref = x
    for _ in range(3):
        ref = np.maximum(ref @ w1, 0.0) @ w2
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
    assert eng.stats.dispatches == 1


def test_moe_dispatch_builder_builds_and_runs():
    from repro.core import collectives
    from repro.core.engine_fused import FusedEngine
    from repro.models.moe import build_moe_dispatch_program
    from repro.parallel import make_mesh

    mesh = make_mesh((1,), ("x",))
    cm = build_moe_dispatch_program(mesh, "x", n_experts=2, capacity=3,
                                    d_model=4)
    assert isinstance(cm, collectives.CollectiveMatmul)
    assert cm.inputs == ("x",)
    x = np.random.RandomState(2).randn(6, 4).astype(np.float32)
    eng = FusedEngine(cm.program, mode="dataflow")
    got = np.asarray(eng(eng.init_buffers({"x": x}))[cm.output])
    np.testing.assert_array_equal(got, x)  # n=1 dispatch is the identity


# -- 8-device references (subprocess, slow lane) ------------------------------

_PRELUDE = """
import numpy as np, jax, jax.numpy as jnp
from functools import partial
from repro.compat import jit_shard_map
from repro.core import overlap
from repro.parallel import make_mesh
from jax.sharding import PartitionSpec as P
mesh = make_mesh((8,), ("x",))
def smap(f, in_specs, out_specs):
    return jit_shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs)
"""


def _check(subproc, code, prelude=None):
    r = subproc((prelude if prelude is not None else _PRELUDE) + code)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


@pytest.mark.slow
@pytest.mark.parametrize("bidirectional", [False, True])
def test_all_gather_ring_matches_lax(subproc, bidirectional):
    _check(subproc, f"""
x = np.random.RandomState(0).randn(32, 16).astype(np.float32)
got = smap(partial(overlap.all_gather_ring, axis="x",
                   bidirectional={bidirectional}), (P("x"),), P())(x)
want = smap(lambda v: jax.lax.all_gather(v, "x", axis=0, tiled=True),
            (P("x"),), P())(x)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
np.testing.assert_allclose(np.asarray(got), x, rtol=1e-6)
""")


@pytest.mark.slow
def test_reduce_scatter_ring_matches_lax(subproc):
    _check(subproc, """
x = np.random.RandomState(1).randn(32, 16).astype(np.float32)
got = smap(partial(overlap.reduce_scatter_ring, axis="x"),
           (P(None, None),), P("x"))(x)
want = smap(lambda v: jax.lax.psum_scatter(v, "x", scatter_dimension=0,
                                           tiled=True),
            (P(None, None),), P("x"))(x)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                           atol=1e-5)
""")


@pytest.mark.slow
def test_all_to_all_ppermute_matches_lax(subproc):
    _check(subproc, """
x = np.random.RandomState(2).randn(64, 4).astype(np.float32)
got = smap(partial(overlap.all_to_all_ppermute, axis="x"),
           (P("x"),), P("x"))(x)
want = smap(lambda v: jax.lax.all_to_all(v, "x", split_axis=0,
                                         concat_axis=0, tiled=True),
            (P("x"),), P("x"))(x)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
""")


# -- ST-expressed collectives, 8-device bit-identity (slow lane) --------------

_ST_PRELUDE = _PRELUDE + """
from repro.core import collectives
from repro.core.engine_fused import FusedEngine
from repro.core.engine_persistent import PersistentEngine

def run_st(cm, inputs):
    eng = FusedEngine(cm.program, mode="dataflow")
    out = np.asarray(eng(eng.init_buffers(inputs))[cm.output])
    assert eng.stats.dispatches == 1, eng.stats.dispatches
    return out
"""


@pytest.mark.slow
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("bidirectional", [False, True])
def test_st_all_gather_matmul_bit_identical(subproc, dtype, bidirectional):
    # uneven (non-square, non-power-of-two) tiles on purpose: m=24 is
    # 3 rows per rank, k=7 / f=5 share no factor with the ring size
    _check(subproc, prelude=_ST_PRELUDE, code=f"""
dt = jnp.{dtype}
cm = collectives.build_all_gather_matmul(mesh, "x", 24, 7, 5, dt,
                                         bidirectional={bidirectional})
rng = np.random.RandomState(0)
inputs = {{"x": rng.randn(24, 7).astype(dt),
           "w": rng.randn(7, 5).astype(dt)}}
got = run_st(cm, inputs)
ref = np.asarray(cm.reference(inputs["x"], inputs["w"]))
stock = np.asarray(cm.reference_stock(inputs["x"], inputs["w"]))
np.testing.assert_array_equal(got, ref)
np.testing.assert_array_equal(got, stock)  # pure gather: stock bitwise too
""")


@pytest.mark.slow
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_st_matmul_reduce_scatter_bit_identical(subproc, dtype):
    _check(subproc, prelude=_ST_PRELUDE, code=f"""
dt = jnp.{dtype}
cm = collectives.build_matmul_reduce_scatter(mesh, "x", 24, 16, 5, dt)
rng = np.random.RandomState(1)
inputs = {{"x": rng.randn(24, 16).astype(dt),
           "w": rng.randn(16, 5).astype(dt)}}
got = run_st(cm, inputs)
ref = np.asarray(cm.reference(inputs["x"], inputs["w"]))
np.testing.assert_array_equal(got, ref)  # same ring accumulate order
# psum_scatter sums in a different order: allclose only
stock = np.asarray(cm.reference_stock(inputs["x"], inputs["w"]))
tol = 1e-5 if dt == jnp.float32 else 1e-1
np.testing.assert_allclose(got.astype(np.float32),
                           stock.astype(np.float32), rtol=tol, atol=tol)
""")


@pytest.mark.slow
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_st_all_to_all_bit_identical(subproc, dtype):
    _check(subproc, prelude=_ST_PRELUDE, code=f"""
dt = jnp.{dtype}
cm = collectives.build_all_to_all(mesh, "x", 128, 3, dt)
x = np.random.RandomState(2).randn(128, 3).astype(dt)
got = run_st(cm, {{"x": x}})
np.testing.assert_array_equal(got, np.asarray(cm.reference(x)))
np.testing.assert_array_equal(got, np.asarray(cm.reference_stock(x)))
""")


@pytest.mark.slow
def test_st_tp_block_chain_8dev(subproc):
    # the headline row at test scale: N chained blocks, ONE dispatch,
    # bitwise vs the decomposed chain, allclose vs the stock lowering
    _check(subproc, prelude=_ST_PRELUDE, code="""
N = 3
tp = collectives.build_tp_block(mesh, "x", 32, 8, 16, chain=True)
rng = np.random.RandomState(3)
x0 = rng.randn(32, 8).astype(np.float32)
w1 = rng.randn(8, 16).astype(np.float32)
w2 = rng.randn(16, 8).astype(np.float32)
eng = PersistentEngine(tp.program.persistent(N), donate=True)
got = np.asarray(eng(eng.init_buffers(
    {"x": x0, "w1": w1, "w2": w2}))["out"])
assert eng.stats.dispatches == 1, eng.stats.dispatches
ref = stock = x0
for _ in range(N):
    ref = tp.reference(ref, w1, w2)
    stock = tp.reference_stock(stock, w1, w2)
np.testing.assert_array_equal(got, np.asarray(ref))
np.testing.assert_allclose(got, np.asarray(stock), rtol=1e-4, atol=1e-5)
""")


@pytest.mark.slow
def test_st_builders_reject_uneven_tiles(subproc):
    _check(subproc, prelude=_ST_PRELUDE, code="""
from repro.core.queue import QueueError
from repro.models.moe import build_moe_dispatch_program
for bad in (
    lambda: collectives.build_all_gather_matmul(mesh, "x", 20, 4, 4),
    lambda: collectives.build_matmul_reduce_scatter(mesh, "x", 20, 4, 4),
    lambda: collectives.build_all_to_all(mesh, "x", 96, 4),  # % 64 != 0
    lambda: build_moe_dispatch_program(mesh, "x", 3, 2, 4),
):
    try:
        bad()
    except (QueueError, ValueError):
        pass
    else:
        raise AssertionError(f"indivisible shape accepted: {bad}")
""")


@pytest.mark.slow
def test_st_moe_dispatch_matches_lax_8dev(subproc):
    _check(subproc, prelude=_ST_PRELUDE, code="""
from repro.models.moe import build_moe_dispatch_program
cm = build_moe_dispatch_program(mesh, "x", n_experts=8, capacity=2,
                                d_model=3)
x = np.random.RandomState(4).randn(128, 3).astype(np.float32)
got = run_st(cm, {"x": x})
np.testing.assert_array_equal(got, np.asarray(cm.reference_stock(x)))
# the tiled a2a is an involution: the combine leg is the same program
back = run_st(cm, {"x": got})
np.testing.assert_array_equal(back, x)
""")


@pytest.mark.slow
def test_overlapped_matmuls_match_references(subproc):
    _check(subproc, """
rng = np.random.RandomState(3)
x = rng.randn(32, 16).astype(np.float32)
w = rng.randn(16, 8).astype(np.float32)
got = smap(partial(overlap.all_gather_matmul, axis="x"),
           (P("x"), P()), P())(x, w)
np.testing.assert_allclose(np.asarray(got), x @ w, rtol=1e-4, atol=1e-5)

xk = rng.randn(32, 64).astype(np.float32)
wk = rng.randn(64, 8).astype(np.float32)
got = smap(partial(overlap.matmul_reduce_scatter, axis="x"),
           (P(None, "x"), P("x")), P("x"))(xk, wk)
np.testing.assert_allclose(np.asarray(got), xk @ wk, rtol=1e-4, atol=1e-4)
""")
