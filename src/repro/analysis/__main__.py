"""``python -m repro.analysis`` — lint every benchmark-built ST program.

Prints one diagnostics table per program (rule id, severity, pid,
descriptor index, message, enqueue site) and a final summary line.
Exit status is non-zero if any **error**-severity diagnostic is
emitted; ``--strict`` additionally fails on warning-severity findings
(shipped programs must lint completely clean — the CI lint job runs
``--strict``) and prints a per-program certificate table: the effect
digest from :func:`repro.core.effects.program_certificate` plus the
happens-before race-free verdict (ST015–ST018 — race freedom under ANY
interleave policy, not just the emitted stream order).
"""

import os

# benchmark grids assume 8 host devices (same default as benchmarks/run.py);
# must be set before jax initialises
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="STLint every ST program the benchmarks build")
    ap.add_argument("filter", nargs="?", default="",
                    help="only lint programs whose name contains this")
    ap.add_argument("--strict", action="store_true",
                    help="fail on warning-severity diagnostics too, and "
                         "print the per-program effect-certificate table")
    args = ap.parse_args(argv)

    from repro.core.verify import format_diagnostics

    from .programs import certificates, lint_all

    results = [(name, diags) for name, diags in lint_all()
               if args.filter in name]
    if not results:
        print(f"no programs match {args.filter!r}", file=sys.stderr)
        return 2

    total = 0
    for name, diags in results:
        total += len(diags)
        print(f"== {name}")
        print(format_diagnostics(diags))

    rc = 0
    failing = ("error",) if not args.strict else ("error", "warning")
    dirty = [name for name, diags in results
             if any(d.severity in failing for d in diags)]
    if dirty:
        print(f"\nSTLint: {total} diagnostic(s) across "
              f"{len(dirty)}/{len(results)} failing program(s): "
              f"{', '.join(dirty)}",
              file=sys.stderr)
        rc = 1
    else:
        print(f"\nSTLint: {len(results)} program(s) clean"
              + ("" if total == 0 else f" ({total} non-failing finding(s))"))

    if args.strict:
        print("\n== effect certificates (STProve)")
        racy = []
        for name, cert in certificates():
            if args.filter not in name:
                continue
            verdict = ("race-free" if cert.race_free
                       else f"RACY ({cert.n_races} race(s))")
            print(f"  {name:28s} digest={cert.digest}  "
                  f"effects={cert.n_effects:4d}  {verdict}")
            if not cert.race_free:
                racy.append(name)
        if racy:
            print(f"\nSTProve: race(s) found in: {', '.join(racy)}",
                  file=sys.stderr)
            rc = 1
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
