"""Device-resident convergence loops — the "zero host syncs until
converged" acceptance tests.

Fast lane: single-device (1,1,1 periodic) Faces with damping so the
iteration is a contraction; :func:`run_faces_until_converged` must reach
the tolerance in ONE host dispatch and match the NumPy oracle iterated
to the same realized count.  Slow lane: the same contract on a real
2×2×2 8-device grid.
"""

import numpy as np
import pytest

from repro.core import (
    FacesConfig,
    PersistentEngine,
    build_faces_program,
    faces_oracle,
    global_residual_fn,
    run_faces_until_converged,
)
from repro.core.halo import AXES3


def _mesh111():
    from repro.parallel import make_mesh
    return make_mesh((1, 1, 1), AXES3)


def _u0(cfg, seed=0):
    rng = np.random.RandomState(seed)
    return rng.randn(*cfg.grid, *cfg.points).astype(np.float32)


def _oracle_n(u0, cfg, n):
    ref = np.asarray(u0)
    for _ in range(n):
        ref = faces_oracle(ref, cfg)
    return ref


CFG = FacesConfig(grid=(1, 1, 1), points=(4, 3, 5), periodic=True,
                  damping=0.08)


def test_converges_in_one_dispatch_and_matches_oracle():
    """Acceptance: tolerance reached, exactly ONE host dispatch
    (HostStats), field == oracle at the realized iteration count."""
    tol, max_iters = 1e-2, 50
    u0 = _u0(CFG)
    mem, res, n_done, stats = run_faces_until_converged(
        CFG, _mesh111(), u0, tol=tol, max_iters=max_iters)

    assert stats.dispatches == 1          # the device owned the loop
    assert stats.sync_points == 0         # no host polling inside it
    assert 1 <= n_done < max_iters        # genuinely early-terminated
    assert res.shape == (n_done,)
    assert res[-1] < tol                  # converged...
    assert np.all(res[:-1] >= tol)        # ...exactly when the trace says

    ref = _oracle_n(u0, CFG, n_done)
    np.testing.assert_allclose(np.asarray(mem["u"]), ref,
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("double_buffer", [True, False])
def test_dynamic_last_parity_slot_selection(double_buffer):
    """The final-slot choice must follow the *realized* parity.  With
    tolerances picked so realized counts are odd and even, the converged
    field (and the message slots, when double-buffered) must agree with
    the non-double-buffered run either way."""
    u0 = _u0(CFG, seed=4)
    for tol in (2e-2, 1e-2, 5e-3, 2e-3):
        mem, res, n_done, _ = run_faces_until_converged(
            CFG, _mesh111(), u0, tol=tol, max_iters=50,
            double_buffer=double_buffer)
        ref = _oracle_n(u0, CFG, n_done)
        np.testing.assert_allclose(
            np.asarray(mem["u"]), ref, rtol=1e-4, atol=1e-5,
            err_msg=f"tol={tol} n_done={n_done} (parity {n_done % 2})")


def test_max_iters_bound_respected():
    """An unreachable tolerance stops at the safety bound."""
    u0 = _u0(CFG)
    mem, res, n_done, stats = run_faces_until_converged(
        CFG, _mesh111(), u0, tol=0.0, max_iters=7)
    assert n_done == 7 and res.shape == (7,)
    assert stats.dispatches == 1
    np.testing.assert_allclose(np.asarray(mem["u"]), _oracle_n(u0, CFG, 7),
                               rtol=1e-4, atol=1e-5)


def test_reduction_trace_matches_host_recomputation():
    """The on-device residual trace equals residuals recomputed on the
    host from oracle iterates."""
    u0 = _u0(CFG, seed=9)
    _, res, n_done, _ = run_faces_until_converged(
        CFG, _mesh111(), u0, tol=1e-2, max_iters=50)
    ref = np.asarray(u0)
    want = []
    for _ in range(n_done):
        ref = faces_oracle(ref, CFG)
        want.append(np.sqrt((ref.astype(np.float64) ** 2).mean()))
    np.testing.assert_allclose(res, want, rtol=1e-4)


def test_growing_residual_runs_to_bound_in_stream_mode():
    """Without damping the Faces update grows, so `residual >= tol`
    never breaks — stream mode hits the bound too (mode coverage for
    the while_loop path)."""
    cfg = FacesConfig(grid=(1, 1, 1), points=(3, 3, 3), periodic=True)
    prog = build_faces_program(cfg, _mesh111()).persistent(
        4, until=lambda r: r >= 1e-6)
    eng = PersistentEngine(prog, mode="stream",
                           reduce_fn=global_residual_fn(cfg))
    mem, res, n_done = eng(eng.init_buffers({"u": _u0(cfg)}))
    assert int(n_done) == 4
    assert eng.stats.dispatches == 1


@pytest.mark.slow
def test_until_converged_8dev(subproc):
    """The acceptance contract on a real 2×2×2 8-device grid."""
    r = subproc("""
import numpy as np
from repro.core import FacesConfig, faces_oracle, run_faces_until_converged
from repro.parallel import make_mesh

mesh = make_mesh((2, 2, 2), ("gx", "gy", "gz"))
cfg = FacesConfig(grid=(2, 2, 2), points=(6, 6, 6), damping=0.12)
u0 = np.random.RandomState(0).randn(2, 2, 2, 6, 6, 6).astype(np.float32)
mem, res, n_done, stats = run_faces_until_converged(
    cfg, mesh, u0, tol=1e-3, max_iters=40)
assert stats.dispatches == 1 and stats.sync_points == 0
assert 1 <= n_done < 40 and res[-1] < 1e-3 and np.all(res[:-1] >= 1e-3)
ref = u0
for _ in range(n_done):
    ref = faces_oracle(ref, cfg)
np.testing.assert_allclose(np.asarray(mem["u"]), ref, rtol=1e-4, atol=1e-5)
print("converged 8dev OK", n_done)
""")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "converged 8dev OK" in r.stdout
