"""STQueue — the ``MPIX_Queue`` analogue and the ST enqueue API.

Maps the paper's proposed interface (Fig. 4/5) onto JAX:

=====================   =====================================================
Paper                   Here
=====================   =====================================================
MPIX_Create_queue       ``STQueue(mesh, ...)`` / ``create_queue(...)``
MPIX_Free_queue         ``queue.free()`` (resource bookkeeping + reuse guard)
MPIX_Enqueue_send       ``queue.enqueue_send(buf, peer, tag)``
MPIX_Enqueue_recv       ``queue.enqueue_recv(buf, peer, tag)``
MPIX_Enqueue_start      ``queue.enqueue_start()``
MPIX_Enqueue_wait       ``queue.enqueue_wait()``
(kernel launch)         ``queue.enqueue_kernel(fn, reads, writes)`` /
                        ``queue.enqueue_compute(fn, reads=, writes=)``
                        (keyword alias — the per-chunk compute hook of
                        the collective-matmul verbs)
(extension)             ``queue.enqueue_collective(op, buf, out, axis)``
(collective matmul,     ``repro.core.collectives.CollectiveQueue``:
 §V-F "how the          ``enqueue_all_gather / enqueue_reduce_scatter /
 schedule is            enqueue_all_to_all`` — ring collectives emitted
 expressed decides      as ordinary trigger→wait channels with per-chunk
 the win")              ``enqueue_compute`` kernels inside the windows;
                        builders ``build_all_gather_matmul`` /
                        ``build_matmul_reduce_scatter`` /
                        ``build_all_to_all`` / ``build_tp_block`` (the
                        "transformer block as ST schedule") return
                        engine-ready programs bit-identical to the
                        decomposed ``core.overlap`` lowerings, so model
                        parallelism inherits coalescing, STLint
                        (ST013/ST014 ring rules), `schedule_cost`
                        pricing, `tune()` and 1-dispatch persistence
(multi-queue)           ``compose(progA, progB, ...)`` /
                        ``prog.concurrent_with(...)`` → :class:`STSchedule`
                        (:mod:`.schedule` — N queues, one device program)
(cross-queue            ``enqueue_send/recv(..., remote="peerprog")`` +
 channels)              ``compose(..., links=[("A","B"), ...])``: a send in
                        queue A deposits into queue B's memory, trigger on
                        A's counter bank, completion on B's — B's wait gate
                        observes A's completion (halo exchange *between*
                        composed queues)
(§V-A contiguous        ``build(coalesce=True)`` →
 MPI buffer)            :class:`~repro.core.matching.CoalescedChannel` plan
                        per batch: matched channels grouped by
                        ``(axis, permutation)`` and lowered to ONE fused
                        by-axis transfer each (26 → ≤6 collectives per
                        start gate for direct26), bit-identical deposits
(DWQ validation        ``build(verify="warn"|"error"|"off")`` /
 face)                  ``compose(..., verify=)`` → :mod:`repro.core.verify`:
                        a static pass symbolically executes the program's
                        trigger/completion counter banks in stream order
                        and emits ``ST0xx`` :class:`~repro.core.verify.
                        Diagnostic` records (deadlocked waits, slot races,
                        counter drift, structural lint) with enqueue-site
                        provenance — the build-time stand-in for the
                        debugger the NIC's offloaded DWQ does not have;
                        ``engine(..., sanitize=True)`` adds the runtime
                        NaN-canary sanitizer
(effect/race            ``repro.core.effects`` + the happens-before
 analysis face)         analysis of ``repro.core.verify``: every batch
                        records its declared effect set
                        (``Batch.effects`` — pack reads, staging
                        traffic, deposits; kernels carry ``reads=``/
                        ``writes=``), rules ST015–ST018 prove a program
                        race-free under EVERY interleave policy (not
                        just the emitted order), and
                        ``effects.certify_equivalence`` proves a
                        transformed candidate's per-buffer effect trace
                        equal to its baseline's — consumed by ``tune()``
                        (certified candidates skip allclose) and
                        ``python -m repro.analysis --strict``
(§V-C hand-tuned        ``repro.launch.tune.tune``: a generic knob search
 shaders)               (trigger mode, coalescing, interleave policy,
                        double-buffer/unroll) over a built program —
                        candidates priced by the analytic cost model
                        (``repro.launch.costing.schedule_cost``),
                        STLint-verified, the cheapest few measured; the
                        software analogue of tuning the NIC's trigger
                        shaders by hand, made self-optimizing
(ML serving face)       ``repro.launch.serve.ServeEngine``: greedy decode
                        as a device-resident masked while_loop (ONE host
                        dispatch per chunk, per-sequence EOS/max-len
                        termination — the per-program ``n_done`` idiom at
                        per-sequence grain), continuous-batching admission
                        as a composed prefill+decode dispatch, cache slots
                        recycled via donation (zero-copy rotation)
=====================   =====================================================

All enqueue operations are **non-blocking descriptor appends** — nothing
touches a device.  ``build()`` performs trace-time matching and returns
an immutable :class:`STProgram`; the two engines
(:mod:`.engine_fused`, :mod:`.engine_host`) execute it.

Semantics preserved from the paper:

* FIFO execution of enqueued operations per queue;
* batching: one ``start`` triggers every comm op enqueued since the
  previous ``start`` (one writeValue per batch, not per op);
* ``wait`` blocks only the *stream* (in the fused engine, a data-
  dependency gate; the host never blocks), and host-level ``MPI_Wait``
  style blocking exists separately (``engine_host`` sync points);
* no wildcards — matching is static (see :mod:`.matching`);
* a queue may be reused across iterations (the program re-executes).
  ``STProgram.persistent(n_iters)`` promotes that reuse to a device-
  resident loop (one host dispatch for all iterations — see
  :mod:`.engine_persistent`); it requires the queue to be *quiescent*
  per pass (every started batch waited), which ``persistent`` enforces;
* several *independent* queues may be in flight concurrently: build one
  program per queue and fuse them with :func:`repro.core.schedule.compose`
  (or ``progA.concurrent_with(progB)``).  The composed
  :class:`~repro.core.schedule.STSchedule` interleaves the programs'
  batches round-robin with namespaced buffers and per-program counter
  banks, so one queue's communication overlaps another's compute in a
  single host dispatch — the multi-DWQ pipelined schedule;
* concurrent queues may also *chain*: a send/recv enqueued with
  ``remote=<peer program>`` stays open through this queue's build and
  is matched by ``compose`` into a cross-program channel — triggered by
  the sender's counters, deposited into the receiver's memory,
  completed on the receiver's counters (so the receiver's ``wait``
  observes it).  This is the halo exchange *between* composed queues
  (e.g. :func:`repro.core.halo.build_faces_part_program`).
"""

from __future__ import annotations

import dataclasses
import traceback
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from .counters import CompletionCounter, TriggerCounter
from .descriptors import (
    BufferSpec,
    CollDesc,
    KernelDesc,
    RecvDesc,
    SendDesc,
    StartDesc,
    WaitDesc,
)
from .effects import batch_effects, stamp_staging
from .matching import (
    Batch,
    MatchError,
    coalesce_batch,
    match_batch,
    validate_program_order,
)


def _adapt_arity(fn: Callable, n_reads: int) -> Callable:
    """Adapt a kernel to the conservative implicit-reads fallback.

    The engines call ``fn(*reads)`` positionally; when the queue widens
    an undeclared read set to every buffer, a kernel written for fewer
    arguments would crash at trace time — so pass it only the prefix it
    was written for.  Kernels taking ``*args`` are left untouched (they
    accept the widened call by construction).
    """
    import inspect

    try:
        params = inspect.signature(fn).parameters.values()
    except (TypeError, ValueError):
        return fn
    if any(p.kind == inspect.Parameter.VAR_POSITIONAL for p in params):
        return fn
    arity = sum(p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                           inspect.Parameter.POSITIONAL_OR_KEYWORD)
                for p in params)
    if arity >= n_reads:
        return fn

    def adapted(*vals):
        return fn(*vals[:arity])

    return adapted


def _call_site() -> Optional[str]:
    """``file:line`` of the enqueue call that created a descriptor.

    Walks the extracted stack outward past this module's own frames so
    builder helpers (``halo.py``, user code, tests) are named rather
    than ``queue.py`` itself.  Paths are shortened to their last two
    components — enough to be clickable, short enough for a table.
    """
    for frame in reversed(traceback.extract_stack(limit=8)):
        if frame.filename == __file__:
            continue
        parts = frame.filename.replace("\\", "/").rsplit("/", 2)
        short = "/".join(parts[-2:]) if len(parts) > 1 else parts[-1]
        return f"{short}:{frame.lineno}"
    return None


@dataclasses.dataclass
class STProgram:
    """Immutable, matched ST program ready for an engine."""

    buffers: Dict[str, BufferSpec]
    descriptors: Tuple[Any, ...]
    batches: Tuple[Batch, ...]
    mesh: Any  # jax.sharding.Mesh
    name: str = "st_program"
    # Persistent-iteration metadata (MPIX_Queue reuse): how many times a
    # single host dispatch re-executes the whole program on-device.  Set
    # via :meth:`persistent`; engines other than PersistentEngine ignore
    # it (they run one pass per dispatch).
    n_iters: int = 1
    # Optional device-resident termination predicate: ``until(reduction)
    # -> bool`` evaluated on the per-iteration scalar reduction inside
    # the loop; the loop keeps running while it returns True (bounded by
    # ``n_iters``, which becomes the max_iters safety bound).  Set via
    # ``persistent(n_iters, until=...)``.
    until: Optional[Callable[[Any], Any]] = None

    @property
    def n_batches(self) -> int:
        return len(self.batches)

    @property
    def n_channels(self) -> int:
        return sum(len(b.channels) for b in self.batches)

    @property
    def is_coalesced(self) -> bool:
        """True when at least one batch carries a coalescing plan."""
        return any(b.plan is not None for b in self.batches)

    def collective_counts(self) -> Dict[int, Tuple[int, int]]:
        """Per start gate: (uncoalesced, as-lowered) collective counts.

        The uncoalesced count is one collective per matched channel plus
        one per deferred collective; the as-lowered count replaces the
        per-channel collectives with the batch's fused transfers when a
        coalescing plan is recorded (the paper's 26 → ≤6 reduction,
        measurable rather than asserted).
        """
        out: Dict[int, Tuple[int, int]] = {}
        for b in self.batches:
            un = len(b.channels) + len(b.colls)
            co = (len(b.plan.transfers) if b.plan is not None
                  else len(b.channels)) + len(b.colls)
            out[b.index] = (un, co)
        return out

    def max_collectives_per_start(self) -> Tuple[int, int]:
        """Max over start gates of (uncoalesced, as-lowered) counts."""
        counts = self.collective_counts()
        if not counts:
            return (0, 0)
        return (max(u for u, _ in counts.values()),
                max(c for _, c in counts.values()))

    @property
    def is_persistent(self) -> bool:
        return self.n_iters > 1 or self.until is not None

    @property
    def open_links(self) -> int:
        """Number of unresolved cross-program (``remote=``) descriptors.

        Nonzero means this program declares channels whose peer lives in
        another program: it must go through
        :func:`repro.core.schedule.compose` (which resolves them into
        cross-program channels) before any engine may run it.
        """
        return sum(len(b.open_sends) + len(b.open_recvs)
                   for b in self.batches)

    def require_closed(self) -> None:
        """Raise unless every cross-program descriptor is resolved
        (engines call this: an open channel has no matching side and
        would hang)."""
        if self.open_links:
            raise ValueError(
                f"[ST012] program {self.name!r} has {self.open_links} unresolved "
                f"cross-program (remote=) descriptor(s): compose() it with "
                f"its peer program(s) before running — an open channel has "
                f"no matching side and would hang")

    def buffers_by_pid(self) -> Dict[int, Tuple[str, ...]]:
        """Buffer names grouped by owning program id.

        A plain single-queue program owns every buffer under pid 0; a
        composed :class:`~repro.core.schedule.STSchedule` overrides this
        with one entry per sub-program, which is what lets the engines
        keep stream-FIFO ordering (and counter banks) *per program*
        instead of serializing the whole composition.
        """
        return {0: tuple(self.buffers)}

    def concurrent_with(self, *others: "STProgram",
                        name: Optional[str] = None) -> "STProgram":
        """Fuse this program with ``others`` into one
        :class:`~repro.core.schedule.STSchedule` — sugar for
        ``compose(self, *others)`` (see :mod:`repro.core.schedule`)."""
        from .schedule import compose  # local import: schedule imports us
        return compose(self, *others, name=name)

    def persistent(self, n_iters: int,
                   until: Optional[Callable[[Any], Any]] = None) -> "STProgram":
        """Mark the program for device-resident re-execution.

        Returns a copy whose ``n_iters`` requests that an engine keep the
        *entire* iteration loop on-device — the MPIX_Queue-reuse contract
        ("a queue may be reused across iterations") delivered without a
        host round-trip per iteration.

        With ``until`` set the iteration count becomes *dynamic*: the
        engine re-runs the program while ``until(reduction)`` stays True
        (e.g. ``lambda residual: residual >= tol``), with ``n_iters`` as
        the max-iteration safety bound.  The predicate runs inside the
        device loop, on the per-iteration scalar reduction — zero host
        syncs until converged.

        Reuse guards: re-execution is only well-defined when the queue is
        *quiescent* at the end of a pass — a ``wait`` must follow the
        final ``start`` so every triggered completion is observed before
        the next pass begins (the completion counter is cumulative, so
        one trailing wait covers all earlier batches; without it,
        iteration i+1's triggers could fire against iteration i's
        in-flight completions).  A predicate-terminated loop may always
        run more than one pass, so ``until`` triggers the guard even
        when the bound is 1.
        """
        if n_iters < 1:
            raise QueueError(f"persistent n_iters must be >= 1, got {n_iters}")
        last_start = last_wait = -1
        for i, d in enumerate(self.descriptors):
            if isinstance(d, StartDesc):
                last_start = i
            elif isinstance(d, WaitDesc):
                last_wait = i
        if ((n_iters > 1 or until is not None)
                and last_start >= 0 and last_wait < last_start):
            raise QueueError(
                "persistent reuse of a non-quiescent queue: the final "
                "enqueue_start has no following enqueue_wait; counters "
                "would not agree across iterations"
            )
        return dataclasses.replace(self, n_iters=n_iters, until=until)

    def dispatch_count_host(self) -> int:
        """How many separate device dispatches the host-orchestrated
        (baseline / progress-thread) engine needs — the paper's
        'expensive synchronization points'."""
        n = 0
        for d in self.descriptors:
            if isinstance(d, KernelDesc):
                n += 1
        for b in self.batches:
            n += len(b.channels) + len(b.colls)
        return n

    def dispatch_count_fused(self) -> int:
        """The fused ST engine dispatches the whole program once (so a
        Faces loop of N iterations costs N host dispatches)."""
        return 1

    def dispatch_count_persistent(self) -> int:
        """The persistent engine dispatches once for ALL ``n_iters``
        iterations — the device owns the loop, the host dispatches 1."""
        return 1


class QueueError(RuntimeError):
    pass


class STQueue:
    """Records an ST program (the MPIX_Queue + GPU-stream pair).

    Parameters
    ----------
    mesh:
        The ``jax.sharding.Mesh`` the program communicates over.  Plays
        the role of the MPI communicator.
    name:
        Diagnostic name (shows up in lowered HLO metadata).
    """

    def __init__(self, mesh, name: str = "stq"):
        self.mesh = mesh
        self.name = name
        self._descs: List[Any] = []
        self._buffers: Dict[str, BufferSpec] = {}
        self._trigger = TriggerCounter(name=f"{name}.trigger")
        self._completion = CompletionCounter(name=f"{name}.completion")
        self._freed = False
        self._built: Optional[STProgram] = None
        self._built_key: Optional[Tuple[str, bool]] = None

    # -- buffer declaration -------------------------------------------------

    def buffer(self, name: str, shape: Sequence[int], dtype=np.float32, pspec: Sequence[Any] = ()) -> str:
        """Declare a named global buffer the program operates on."""
        self._check_live()
        if name in self._buffers:
            raise QueueError(f"buffer {name!r} already declared")
        self._buffers[name] = BufferSpec(name, tuple(shape), dtype, tuple(pspec))
        self._built = None
        return name

    # -- enqueue API (paper Fig. 5) ------------------------------------------

    def enqueue_kernel(
        self, fn: Callable, reads: Sequence[str], writes: Sequence[str], name: str = "kernel"
    ) -> None:
        """Enqueue a compute kernel on the stream (non-blocking)."""
        self._check_live()
        for b in tuple(reads) + tuple(writes):
            if b not in self._buffers:
                raise QueueError(f"kernel touches undeclared buffer {b!r}")
        self._descs.append(
            KernelDesc(fn, tuple(reads), tuple(writes), name,
                       site=_call_site()))
        self._built = None

    def enqueue_compute(self, fn: Callable, *,
                        reads: Optional[Sequence[str]] = None,
                        writes: Sequence[str] = (),
                        name: str = "compute") -> None:
        """Keyword alias of :meth:`enqueue_kernel` — the per-chunk
        compute hook used by the collective-matmul verbs
        (:mod:`repro.core.collectives`): a kernel enqueued between a
        ring step's start and the next step's trigger runs inside that
        trigger→wait window, which is where overlap comes from.

        Omitting ``reads=`` does NOT make the kernel effect-free: the
        queue substitutes the conservative fallback — the kernel is
        assumed to read **every** buffer declared so far (its declared
        writes stay as given) — and the descriptor is flagged
        ``implicit_effects``, which STLint reports as the ST019 warning.
        Implicit effects over-serialize the happens-before analysis
        (every pending deposit looks like a race with this kernel), so
        declare ``reads=``/``writes=`` explicitly; the in-repo builders
        are lint-enforced to (``scripts/lint_repo.py``).
        """
        implicit = reads is None
        if implicit:
            all_bufs = tuple(self._buffers)
            fn = _adapt_arity(fn, len(all_bufs))
            reads = all_bufs
        self.enqueue_kernel(fn, reads, writes, name=name)
        if implicit:
            self._descs[-1] = dataclasses.replace(
                self._descs[-1], implicit_effects=True)

    def enqueue_send(self, buf: str, peer, tag: int, region=None,
                     remote: Optional[str] = None) -> None:
        """MPIX_Enqueue_send: deferred tagged send (returns immediately).

        With ``remote=<program name>`` the matching receive lives in
        another queue's program: the send stays *open* through this
        queue's build and is matched by
        :func:`repro.core.schedule.compose` into a cross-program
        channel depositing into the peer program's memory.
        """
        self._check_live()
        self._check_buf(buf)
        self._descs.append(
            SendDesc(buf, peer, tag, threshold=self._trigger.next_threshold(),
                     region=region, remote=remote, site=_call_site())
        )
        self._built = None

    def enqueue_recv(self, buf: str, peer, tag: int, region=None, mode: str = "replace",
                     remote: Optional[str] = None) -> None:
        """MPIX_Enqueue_recv: deferred tagged receive (returns immediately).

        With ``remote=<program name>`` the matching send lives in
        another queue's program (see :meth:`enqueue_send`); the wait
        covering this batch then gates on the *sender's* completion,
        wired across the per-program counter banks by the engines.
        """
        self._check_live()
        self._check_buf(buf)
        if mode not in ("replace", "add"):
            raise QueueError("recv mode must be 'replace' or 'add'")
        self._descs.append(
            RecvDesc(buf, peer, tag, threshold=self._trigger.next_threshold(),
                     region=region, mode=mode, remote=remote,
                     site=_call_site())
        )
        self._built = None

    def enqueue_collective(self, op: str, buf: str, out: str, axis, **kwargs) -> None:
        """Beyond-paper: enqueue a whole collective as one deferred op."""
        self._check_live()
        self._check_buf(buf)
        if out not in self._buffers:
            raise QueueError(f"collective writes undeclared buffer {out!r}")
        if op not in ("all_gather", "reduce_scatter", "all_reduce", "all_to_all", "ppermute"):
            raise QueueError(f"unknown collective {op!r}")
        self._descs.append(
            CollDesc(op, buf, out, axis, kwargs,
                     threshold=self._trigger.next_threshold(),
                     site=_call_site())
        )
        self._built = None

    def enqueue_start(self) -> None:
        """MPIX_Enqueue_start: one trigger (writeValue) for the batch of
        every comm op enqueued since the previous start."""
        self._check_live()
        batch = self._trigger.record_start()
        self._descs.append(
            StartDesc(batch=batch - 1, threshold=batch, site=_call_site()))
        self._built = None

    def enqueue_wait(self) -> None:
        """MPIX_Enqueue_wait: stream-blocking completion gate (waitValue).
        Non-blocking for the host."""
        self._check_live()
        n_started = self._trigger.scheduled
        if n_started == 0:
            raise QueueError("enqueue_wait before any enqueue_start")
        self._descs.append(
            WaitDesc(batch=n_started - 1,
                     expected=self._completion.record_op(),
                     site=_call_site()))
        self._built = None

    def free(self) -> None:
        """MPIX_Free_queue: releases the queue.  Caller is responsible for
        having completed outstanding work (paper §III-A).

        Also drops the built-program cache: a program built, freed, then
        rebuilt under a reused queue name must never be served
        descriptors that reference the freed queue's resources.
        """
        self._check_live()
        self._freed = True
        self._built = None

    # -- build ---------------------------------------------------------------

    def build(self, name: Optional[str] = None,
              coalesce: bool = True, verify: str = "warn") -> STProgram:
        """Trace-time matching + validation → immutable STProgram.

        With ``coalesce=True`` (default) every batch's matched channels
        are additionally grouped into fused by-axis transfers
        (:func:`~repro.core.matching.coalesce_batch`, the paper's §V-A
        contiguous-buffer step) and the plan is recorded on the batch;
        engines execute the plan when present and results stay
        bit-identical to the uncoalesced lowering.

        ``verify`` runs the :mod:`repro.core.verify` static pass on the
        built program: ``"warn"`` (default) reports every diagnostic as
        an :class:`~repro.core.verify.STLintWarning`, ``"error"`` raises
        :class:`~repro.core.verify.VerifyError` on error-severity
        diagnostics (warnings still warn), ``"off"`` skips the pass.
        A program with open ``remote=`` descriptors is only checked for
        single-queue rules here; :func:`repro.core.schedule.compose`
        re-verifies the whole schedule (default ``"error"``) once the
        cross-program links are resolved.
        """
        self._check_live()
        resolved = name or self.name
        # the cache is keyed on the resolved program name AND the
        # coalesce flag: a second build("other") — or a rebuild with
        # coalescing toggled — must not hand back the cached program.
        # (verify is not part of the key: it never changes the program,
        # so the pass simply re-runs on the cached result.)
        if self._built is not None and self._built_key == (resolved, coalesce):
            from .verify import run_verify  # local: verify imports queue
            run_verify(self._built, verify)
            return self._built
        validate_program_order(self._descs)
        mesh_shape = dict(self.mesh.shape)

        batches: List[Batch] = []
        pending_sends: List[SendDesc] = []
        pending_recvs: List[RecvDesc] = []
        pending_colls: List[CollDesc] = []
        kernels_since_start: List[KernelDesc] = []
        for d in self._descs:
            if isinstance(d, KernelDesc):
                kernels_since_start.append(d)
            elif isinstance(d, SendDesc):
                pending_sends.append(d)
            elif isinstance(d, RecvDesc):
                pending_recvs.append(d)
            elif isinstance(d, CollDesc):
                pending_colls.append(d)
            elif isinstance(d, StartDesc):
                # remote= sends/recvs pair with another program: leave
                # them open for compose() instead of matching here
                local_sends = [s for s in pending_sends if s.remote is None]
                local_recvs = [r for r in pending_recvs if r.remote is None]
                open_sends = [s for s in pending_sends if s.remote is not None]
                open_recvs = [r for r in pending_recvs if r.remote is not None]
                for o in open_sends + open_recvs:
                    if o.remote == resolved:
                        raise QueueError(
                            f"remote={resolved!r} names this program itself: "
                            f"a channel to the own queue is a plain (local) "
                            f"send/recv pair, not a cross-program link")
                channels = match_batch(local_sends, local_recvs)
                plan = stamp_staging(
                    coalesce_batch(channels, self._buffers, mesh_shape)
                    if coalesce else None, d.batch)
                batch = Batch(
                    index=d.batch,
                    kernels_before=list(kernels_since_start),
                    channels=channels,
                    colls=list(pending_colls),
                    plan=plan,
                    coalesce=coalesce,
                    open_sends=open_sends,
                    open_recvs=open_recvs,
                )
                batch.effects = batch_effects(batch)
                batches.append(batch)
                pending_sends, pending_recvs, pending_colls = [], [], []
                kernels_since_start = []
            elif isinstance(d, WaitDesc):
                # completion counters are cumulative (see
                # STProgram.persistent): a wait on batch k observes the
                # completions of every batch <= k, so all of them are
                # quiescent after it — not just batch k itself.
                for b in batches[: d.batch + 1]:
                    b.waited = True

        self._built = STProgram(
            buffers=dict(self._buffers),
            descriptors=tuple(self._descs),
            batches=tuple(batches),
            mesh=self.mesh,
            name=resolved,
        )
        self._built_key = (resolved, coalesce)
        from .verify import run_verify  # local import: verify imports queue
        run_verify(self._built, verify)
        return self._built

    # -- helpers ---------------------------------------------------------------

    def _check_live(self):
        if self._freed:
            raise QueueError("operation on freed MPIX_Queue (use-after-free)")

    def _check_buf(self, buf: str):
        if buf not in self._buffers:
            raise QueueError(f"undeclared buffer {buf!r}")

    @property
    def n_descriptors(self) -> int:
        return len(self._descs)


def create_queue(mesh, name: str = "stq") -> STQueue:
    """MPIX_Create_queue analogue (local operation, no communication)."""
    return STQueue(mesh, name)
