"""Model zoo: one facade class for all assigned architectures."""
from .model import Model
from .nn import Boxed, unbox
from .transformer import plan_segments

__all__ = ["Model", "Boxed", "unbox", "plan_segments"]
